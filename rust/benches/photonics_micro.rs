//! `cargo bench --bench photonics_micro` — the photonic machine simulator's
//! hot paths: weight sampling, patch convolution, full depthwise layers,
//! calibration, entropy extraction.  Reports simulator wall-clock rates
//! next to the simulated-optical rates so the gap is explicit.

use photonic_bayes::benchkit::{black_box, section, Bench};
use photonic_bayes::calibration::{calibrate_kernel, CalibrationOptions};
use photonic_bayes::data::synth::{random_activations, random_kernel};
use photonic_bayes::entropy::gaussian::Gaussian;
use photonic_bayes::entropy::{gamma, ChaoticLightSource, Xoshiro256pp};
use photonic_bayes::photonics::{timing, MachineConfig, PhotonicMachine};

fn main() {
    let bench = Bench::default();
    let h = timing::headline();

    section("ENTROPY PRIMITIVES");
    {
        let mut rng = Xoshiro256pp::new(1);
        let s = bench.run("xoshiro256++ next_u64", || {
            use photonic_bayes::entropy::BitSource;
            black_box(rng.next_u64());
        });
        println!("{}   ({:.0} M words/s)", s.row(), s.throughput(1.0) / 1e6);

        let mut rng = Xoshiro256pp::new(2);
        let mut g = Gaussian::new();
        let s = bench.run("gaussian sample", || {
            black_box(g.sample(&mut rng));
        });
        println!("{}   ({:.0} M/s)", s.row(), s.throughput(1.0) / 1e6);

        let mut rng = Xoshiro256pp::new(3);
        let mut g = Gaussian::new();
        let s = bench.run("gamma sample (M = 2.56)", || {
            black_box(gamma::sample_gamma(&mut rng, &mut g, 2.56, 0.4));
        });
        println!("{}   ({:.0} M/s)", s.row(), s.throughput(1.0) / 1e6);

        let mut src = ChaoticLightSource::with_defaults(4);
        let s = bench.run("chaotic intensity (150 GHz ch)", || {
            black_box(src.intensity_dof(0, 1.0, 6.625));
        });
        println!("{}   ({:.0} M/s)", s.row(), s.throughput(1.0) / 1e6);

        let mut src = ChaoticLightSource::with_defaults(5);
        let mut buf = vec![0.0f32; 4096];
        let s = bench.run("fill_eps 4096 floats", || {
            src.fill_eps(150.0, &mut buf);
            black_box(buf[0]);
        });
        println!("{}   ({:.0} M floats/s)", s.row(), s.throughput(4096.0) / 1e6);

        let mut src = ChaoticLightSource::with_defaults(6);
        let s = bench.run("extract_bits 1024", || {
            black_box(src.extract_bits(100.0, 1024));
        });
        println!("{}   ({:.1} Mbit/s)", s.row(), s.throughput(1024.0) / 1e6);
    }

    section("MACHINE HOT PATH — conv_patches (9-tap probabilistic conv)");
    {
        let mut machine = PhotonicMachine::with_defaults(7);
        let mut rng = Xoshiro256pp::new(8);
        let idx = machine.load_kernel(&random_kernel(&mut rng));
        for n_patches in [49usize, 490, 4900] {
            let patches = random_activations(&mut rng, n_patches * 9, 4.0);
            let mut out = vec![0.0f32; n_patches];
            let s = bench.run(&format!("conv_patches x{n_patches}"), || {
                machine.conv_patches(idx, &patches, &mut out);
                black_box(out[0]);
            });
            let conv_rate = s.throughput(n_patches as f64);
            println!(
                "{}   ({:.2} M conv/s wall; optical would be {:.1} G conv/s -> sim slowdown {:.0}x)",
                s.row(),
                conv_rate / 1e6,
                h.convolutions_per_sec / 1e9,
                h.convolutions_per_sec / conv_rate
            );
        }
    }

    section("MACHINE — full depthwise layer (64 ch, 7x7)");
    {
        let mut machine = PhotonicMachine::with_defaults(9);
        let mut rng = Xoshiro256pp::new(10);
        for _ in 0..64 {
            let k = random_kernel(&mut rng);
            machine.load_kernel(&k);
        }
        let x = random_activations(&mut rng, 64 * 49, 4.0);
        let s = bench.run("depthwise_conv 64ch 7x7", || {
            black_box(machine.depthwise_conv(0, &x, 64, 7, 7));
        });
        let macs = 64.0 * 49.0 * 9.0;
        println!("{}   ({:.1} M MAC/s wall)", s.row(), s.throughput(macs) / 1e6);
        println!(
            "  one BNN pass (N=10) costs 10 such layers: ~{:.1} ms wall",
            s.mean_ns * 10.0 / 1e6
        );
    }

    section("CALIBRATION");
    {
        let quick = Bench::quick();
        let mut machine = PhotonicMachine::new(MachineConfig {
            seed: 11,
            ..MachineConfig::default()
        });
        let mut rng = Xoshiro256pp::new(12);
        let targets = random_kernel(&mut rng);
        let idx = machine.load_kernel(&targets);
        let opts = CalibrationOptions::default();
        let s = quick.run("calibrate_kernel (4 rounds x 256 probes)", || {
            black_box(calibrate_kernel(&mut machine, idx, &targets, &opts));
        });
        println!("{}", s.row());
        println!("  64-kernel bank load-time calibration: ~{:.1} ms", s.mean_ns * 64.0 / 1e6);
    }
}
