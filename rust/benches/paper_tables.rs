//! `cargo bench --bench paper_tables [-- filter]` — regenerates every table
//! and figure of the paper's evaluation section (DESIGN.md experiment
//! index).  Each section prints the paper's value next to the measured one.
//!
//! Sections: headline, backends, entropy, adaptive, multimodel, serving,
//! cluster, observe, fig2_error, fig2_delay, nist, health, fig4_roc,
//! fig4_confusion, fig5_scatter, fig5_auroc, ablations.
//!
//! Machine-readable trajectories (`--json <path>`): `backends` →
//! `BENCH_backends.json`, `entropy` → `BENCH_entropy.json`, `adaptive` →
//! `BENCH_adaptive.json`, `health` → `BENCH_health.json`, `multimodel` →
//! `BENCH_multimodel.json`, `serving` → `BENCH_serving.json`, `cluster` →
//! `BENCH_cluster.json`, `observe` → `BENCH_observe.json`; CI regenerates
//! all eight per push and archives them as workflow artifacts.
//!
//! The Fig. 4/5 sections need trained checkpoints
//! (`pbm train --dataset digits` / `--dataset blood`); they fall back to a
//! reduced sample count + a warning when only init params exist.

use std::sync::Arc;

use photonic_bayes::backend::{
    self, BackendKind, PipelineOptions, PrefetchMode, ProbConvBackend, SamplePlan,
};
use photonic_bayes::benchkit::{black_box, section, Bench, JsonSink};
use photonic_bayes::bnn::UncertaintyPolicy;
use photonic_bayes::calibration::computation_error_experiment;
use photonic_bayes::coordinator::{Engine, EngineConfig, ExecMode};
use photonic_bayes::data::synth::{random_activations, random_kernel};
use photonic_bayes::data::{Dataset, DatasetKind};
use photonic_bayes::entropy::{nist, ChaoticLightSource};
use photonic_bayes::exec::ThreadPool;
use photonic_bayes::experiments::uncertainty::{build_report, eval_split};
use photonic_bayes::photonics::grating::{channel_frequency_thz, ChirpedGrating};
use photonic_bayes::photonics::{timing, MachineConfig, PhotonicMachine};
use photonic_bayes::runtime::artifact::artifacts_root;
use photonic_bayes::runtime::{ModelArtifacts, ParamStore};
use photonic_bayes::util::mathstat::{linfit, mean, median};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    // the filter is the first bare token that is not the value of `--json`
    let mut filter = String::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if a == "--json" {
            i += 1; // skip the path value
        } else if !a.starts_with("--") && filter.is_empty() {
            filter = a.clone();
        }
        i += 1;
    }
    let mut sink = JsonSink::from_args(&args, "paper_tables");
    let run = |name: &str| filter.is_empty() || name.contains(&filter);

    if run("headline") {
        headline();
    }
    if run("backends") {
        backends(&mut sink);
    }
    if run("entropy") {
        entropy(&mut sink);
    }
    if run("adaptive") {
        adaptive(&mut sink);
    }
    if run("multimodel") {
        multimodel(&mut sink);
    }
    if run("serving") {
        serving(&mut sink);
    }
    if run("cluster") {
        cluster_bench(&mut sink);
    }
    if run("observe") {
        observe(&mut sink);
    }
    if run("fig2_error") {
        fig2_error();
    }
    if run("fig2_delay") {
        fig2_delay();
    }
    if run("nist") {
        nist_table();
    }
    if run("health") {
        health(&mut sink);
    }
    if run("fig4") {
        fig4();
    }
    if run("fig5") {
        fig5();
    }
    if run("ablations") {
        ablations();
    }
    if let Some(s) = &sink {
        match s.write() {
            Ok(()) => println!("\nwrote {}", s.path().display()),
            Err(e) => eprintln!("\nfailed writing {}: {e}", s.path().display()),
        }
    }
}

// ---------------------------------------------------------------------------

fn headline() {
    section("HEADLINE — abstract numbers derived from architecture constants");
    let h = timing::headline();
    println!("{:<38} {:>12} {:>12}", "metric", "measured", "paper");
    println!(
        "{:<38} {:>12.1} {:>12}",
        "ps per probabilistic convolution", h.symbol_period_ps, "37.5"
    );
    println!("{:<38} {:>12.2} {:>12}", "G convolutions / s", h.convolutions_per_sec / 1e9, "26.7");
    println!(
        "{:<38} {:>12.2} {:>12}",
        "Tbit/s digital interface", h.interface_tbit_per_sec, "1.28"
    );
    println!(
        "{:<38} {:>12.2} {:>12}",
        "grating delay step (ps/channel)", h.channel_delay_step_ps, "37.5"
    );
    println!(
        "{:<38} {:>12.2} {:>12}",
        "grating latency (ns, sub-100 claim)", h.grating_latency_ns, "<100"
    );
}

/// Photonic-vs-digital sampling throughput — the paper's core systems
/// claim, measured through the one `ProbConvBackend` API across thread
/// counts.  Runs on a synthetic workload, so it needs no artifacts.  With
/// `--json <path>` the rows are also written machine-readably (the perf
/// trajectory file `BENCH_backends.json`).
fn backends(sink: &mut Option<JsonSink>) {
    section("BACKENDS — sampling throughput, photonic vs digital vs mean-field");
    // N x B = 128 >= 64: enough grid rows for every shard at 8 threads
    let (n_samples, batch, channels, hw) = (16usize, 8usize, 8usize, 7usize);
    let plan = SamplePlan::new(n_samples, batch, channels, hw, hw);
    let mut rng = photonic_bayes::entropy::Xoshiro256pp::new(17);
    let kernels: Vec<_> = (0..channels).map(|_| random_kernel(&mut rng)).collect();
    let mcfg = photonic_bayes::photonics::MachineConfig {
        seed: 17,
        ..photonic_bayes::photonics::MachineConfig::default()
    };
    let x = random_activations(&mut rng, plan.sample_size(), mcfg.scale_dac);
    let bench = Bench::quick();
    println!(
        "plan: N = {n_samples} samples x B = {batch} items x {channels}ch@{hw}x{hw} = {} probabilistic convolutions/call",
        plan.convolutions()
    );
    println!(
        "{:<12} {:>8} {:>16} {:>16} {:>12} {:>12} {:>12}",
        "backend", "threads", "call latency", "conv/s (sim)", "vs 1-thread", "vs digital", "vs off"
    );
    let mut digital_1t_ns_per_conv = f64::NAN;
    for kind in [BackendKind::Digital, BackendKind::Photonic, BackendKind::MeanField] {
        let runs: &[(usize, PrefetchMode)] = if kind == BackendKind::MeanField {
            &[(1, PrefetchMode::Off)] // deterministic single pass
        } else {
            // prefetch-on at t in {1, 4}: the ISSUE 4 acceptance points
            &[
                (1, PrefetchMode::Off),
                (1, PrefetchMode::On),
                (2, PrefetchMode::Off),
                (4, PrefetchMode::Off),
                (4, PrefetchMode::On),
                (8, PrefetchMode::Off),
            ]
        };
        let mut base_ns = f64::NAN;
        let mut off_ns_by_t = [f64::NAN; 9];
        for &(t, mode) in runs {
            let pool = (t > 1).then(|| Arc::new(ThreadPool::new(t)));
            let popts = PipelineOptions {
                mode,
                ..PipelineOptions::default()
            };
            let mut be = backend::build_with_opts(kind, &mcfg, pool, popts);
            be.program(&kernels, false).unwrap();
            let eff = SamplePlan {
                // the mean-field fast path executes a single deterministic pass
                n_samples: if be.is_deterministic() { 1 } else { n_samples },
                ..plan
            };
            let mut out = vec![0.0f32; eff.total_size()];
            let s = bench.run(&format!("{} t{} {}", kind.name(), t, mode), || {
                be.sample_conv(&eff, &x, &mut out).unwrap();
                black_box(&out);
            });
            let ns_per_conv = s.mean_ns / eff.convolutions() as f64;
            if t == 1 && mode == PrefetchMode::Off {
                base_ns = s.mean_ns;
                if kind == BackendKind::Digital {
                    digital_1t_ns_per_conv = ns_per_conv;
                }
            }
            if mode == PrefetchMode::Off {
                off_ns_by_t[t.min(8)] = s.mean_ns;
            }
            let label = if mode == PrefetchMode::On {
                format!("{}+pf", kind.name())
            } else {
                kind.name().to_string()
            };
            // the acceptance metric: prefetch-on vs prefetch-off at equal t
            let vs_off = off_ns_by_t[t.min(8)] / s.mean_ns;
            println!(
                "{:<12} {:>8} {:>16} {:>16.2e} {:>11.2}x {:>11.2}x {:>11.2}x",
                label,
                t,
                photonic_bayes::benchkit::fmt_ns(s.mean_ns),
                1e9 / ns_per_conv,
                base_ns / s.mean_ns,
                digital_1t_ns_per_conv / ns_per_conv,
                vs_off,
            );
            if let Some(sink) = sink {
                let name = if mode == PrefetchMode::On {
                    format!("backends/sample_conv/{}/t{}/prefetch", kind.name(), t)
                } else {
                    format!("backends/sample_conv/{}/t{}", kind.name(), t)
                };
                sink.push(&name, s.mean_ns, 1e9 / ns_per_conv);
            }
        }
    }
    println!("(simulator wall-clock; the machine's *optical* rate is the 26.7 Gconv/s headline)");
    println!("(speedup columns: per-call latency vs the same backend at 1 thread/off,");
    println!(" ns/conv vs the digital backend at 1 thread — the PR 2 baseline — and");
    println!(" prefetch-on vs prefetch-off at the same thread count)");
}

/// The entropy pipeline's own numbers: producer-side generation throughput
/// in Gbit/s (one f64 draw = 64 delivered bits; the paper's interface
/// streams 1.28 Tbit/s) and the piped-vs-sync `fill` delta a consumer
/// actually sees.
fn entropy(sink: &mut Option<JsonSink>) {
    use photonic_bayes::entropy::gaussian::Gaussian;
    use photonic_bayes::entropy::pipeline::{EntropyStream, NormalGen, WeightGen};
    use photonic_bayes::entropy::Xoshiro256pp;
    use std::sync::atomic::AtomicU64;

    section("ENTROPY — producer throughput vs the paper's 1.28 Tbit/s interface");
    let bench = Bench::quick();
    let block = 4096usize;
    let mut buf = vec![0.0f64; block];
    println!(
        "{:<40} {:>14} {:>14}  (paper interface: 1.28 Tbit/s)",
        "stream", "draws/s", "Gbit/s"
    );
    let report = |sink: &mut Option<JsonSink>, name: &str, mean_ns: f64| {
        let draws_per_s = block as f64 / (mean_ns * 1e-9);
        let gbit = draws_per_s * 64.0 / 1e9;
        println!("{name:<40} {draws_per_s:>14.3e} {gbit:>14.2}");
        if let Some(s) = sink {
            s.push(&format!("entropy/{name}"), mean_ns, draws_per_s);
        }
    };

    // raw generators (what one producer thread can draw)
    let mut ng = NormalGen::new(Xoshiro256pp::new(7));
    let s = bench.run("normal-gen", || {
        photonic_bayes::entropy::pipeline::BlockGen::fill(&mut ng, &mut buf);
        black_box(&buf);
    });
    report(sink, "producer/digital_normals", s.mean_ns);

    let mut wg = WeightGen {
        rng: Xoshiro256pp::new(9),
        gauss: Gaussian::new(),
        p_plus: 1.2,
        p_minus: 0.4,
        dof: 5.0,
        gain_eff: 0.9,
    };
    let s = bench.run("weight-gen", || {
        photonic_bayes::entropy::pipeline::BlockGen::fill(&mut wg, &mut buf);
        black_box(&buf);
    });
    report(sink, "producer/photonic_weights", s.mean_ns);

    // consumer-visible fill: piped (copy out of prefetched blocks) vs sync
    for mode in [PrefetchMode::Sync, PrefetchMode::On] {
        let mut stream = EntropyStream::new(
            NormalGen::new(Xoshiro256pp::new(11)),
            &PipelineOptions {
                mode,
                block,
                depth: 8,
            },
            "bench",
            std::sync::Arc::new(AtomicU64::new(0)),
        );
        let s = bench.run(&format!("fill {mode}"), || {
            stream.fill(&mut buf);
            black_box(&buf);
        });
        report(sink, &format!("fill/normals_{mode}"), s.mean_ns);
    }
}

/// The adaptive sampler's economy, measured without model artifacts: a
/// synthetic depthwise classifier (logit `c` = mean of channel `c`'s conv
/// outputs) served fixed-N vs adaptive over a half-easy / half-ambiguous
/// request stream.  Easy requests light up one channel (decisive posterior
/// → the gap rule resolves in `min_samples`); ambiguous ones excite all
/// channels equally (the rule runs to the max budget).  Reported per
/// backend: end-to-end request latency/throughput and the mean
/// samples/request.  `mean_samples` rows carry the sample count in both
/// JSON fields (the row schema is latency/throughput shaped).
fn adaptive(sink: &mut Option<JsonSink>) {
    use photonic_bayes::sampler::{synth, SamplerConfig};

    section("ADAPTIVE — early-stopping sampling cost, fixed vs adaptive");
    let (channels, hw, max_n) = (4usize, synth::HW, 16usize);
    let mcfg = photonic_bayes::photonics::MachineConfig {
        seed: 23,
        ..photonic_bayes::photonics::MachineConfig::default()
    };
    // one decisive kernel, three near-zero ones: channel 0 dominates when
    // its input plane is lit (shared harness with the adaptive tests)
    let kernels = synth::decisive_kernels(channels);
    let easy = synth::decisive_input(channels);
    let hard = synth::ambiguous_input(channels);
    let rules = [
        ("fixed", SamplerConfig::fixed(max_n)),
        ("adaptive", synth::gap_config(max_n)),
    ];
    let bench = Bench::quick();
    println!("plan: {channels}ch@{hw}x{hw}, max N = {max_n}, stream = 50% easy / 50% ambiguous");
    println!(
        "{:<22} {:>14} {:>14} {:>14}",
        "backend/rule", "req latency", "req/s", "mean samples"
    );
    for kind in [BackendKind::Digital, BackendKind::Photonic] {
        for (label, scfg) in &rules {
            let mut be = backend::build(kind, &mcfg);
            be.program(&kernels, false).unwrap();
            let mut total_samples = 0u64;
            let mut total_requests = 0u64;
            let mut flip = false;
            let s = bench.run(&format!("{} {label}", kind.name()), || {
                flip = !flip;
                let x = if flip { &easy } else { &hard };
                // one request: chunked sample plans + stop checks at every
                // chunk boundary — the engine's adaptive loop, minus PJRT
                let (used, probs) =
                    synth::classify_synthetic(be.as_mut(), scfg, 1, channels, max_n, x);
                total_samples += used as u64;
                total_requests += 1;
                black_box(probs);
            });
            let mean_samples = total_samples as f64 / total_requests.max(1) as f64;
            println!(
                "{:<22} {:>14} {:>14.1} {:>14.2}",
                format!("{}/{}", kind.name(), label),
                photonic_bayes::benchkit::fmt_ns(s.mean_ns),
                1e9 / s.mean_ns,
                mean_samples,
            );
            if let Some(sink) = sink {
                sink.push(
                    &format!("adaptive/{}/{}", kind.name(), label),
                    s.mean_ns,
                    1e9 / s.mean_ns,
                );
                sink.push(
                    &format!("adaptive/{}/{}/mean_samples", kind.name(), label),
                    mean_samples,
                    mean_samples,
                );
            }
        }
    }
    println!("(adaptive rows must show mean samples well below {max_n} — the easy half of the");
    println!(" stream resolves at the gap rule's min; fixed rows pin the full budget)");
}

/// Multi-model serving economics, measured at the `ProbConvBackend`
/// boundary without artifacts: single-model steady state vs N virtualized
/// models under the program registry's bank cache.  `interleaved/cached`
/// switches models every request with an unbounded budget (every switch a
/// hit), `interleaved/thrash` with budget 0 (every switch rebuilds the
/// banked state from seed), and `coalesced` batches 8 same-model requests
/// per switch — the batcher's model-aware grouping.  The amortization row
/// is the measured thrash/coalesced per-request ratio: what same-model
/// coalescing buys when models do not fit the budget.  With `--json <path>`
/// the rows land machine-readably in `BENCH_multimodel.json`.
fn multimodel(sink: &mut Option<JsonSink>) {
    use photonic_bayes::registry::{ProgramKey, RegistryMetrics};

    section("MULTIMODEL — registry bank-cache cost, 1 model vs N virtualized");
    let (n_samples, batch, channels, hw) = (16usize, 8usize, 8usize, 7usize);
    let plan = SamplePlan::new(n_samples, batch, channels, hw, hw);
    let mut rng = photonic_bayes::entropy::Xoshiro256pp::new(59);
    let kernels: Vec<_> = (0..channels).map(|_| random_kernel(&mut rng)).collect();
    let mcfg = MachineConfig {
        seed: 59,
        ..MachineConfig::default()
    };
    let x = random_activations(&mut rng, plan.sample_size(), mcfg.scale_dac);
    let models = ["m0", "m1"];
    let keys: Vec<ProgramKey> = models
        .iter()
        .map(|m| ProgramKey::new(m, mcfg.seed, mcfg.scale_dac, mcfg.scale_adc))
        .collect();
    let bench = Bench::quick();
    println!(
        "plan: N = {n_samples} x B = {batch} x {channels}ch@{hw}x{hw}, {} models, coalesce run = 8",
        models.len()
    );
    println!(
        "{:<26} {:>14} {:>16} {:>12}",
        "schedule", "req latency", "conv/s (sim)", "vs 1-model"
    );
    // (schedule label, budget, requests per model before switching)
    let cases: [(&str, usize, usize); 4] = [
        ("steady_1model", usize::MAX, usize::MAX),
        ("interleaved/cached", usize::MAX, 1),
        ("interleaved/thrash", 0, 1),
        ("coalesced", 0, 8),
    ];
    let mut base_ns = f64::NAN;
    let mut thrash_ns = f64::NAN;
    let mut coalesced_ns = f64::NAN;
    for kind in [BackendKind::Photonic, BackendKind::Digital] {
        for (label, budget, run_len) in cases {
            let popts = PipelineOptions {
                mode: PrefetchMode::Sync,
                ..PipelineOptions::default()
            };
            let mut be = backend::build_with_opts(kind, &mcfg, None, popts);
            be.enable_model_cache(budget, Arc::new(RegistryMetrics::default()));
            be.switch_program(&keys[0], &kernels, false).unwrap();
            let mut out = vec![0.0f32; plan.total_size()];
            let mut req = 0usize;
            let s = bench.run(&format!("{} {label}", kind.name()), || {
                // request schedule: `run_len` same-model requests, then the
                // next model — switch cost lands inside the measured call
                let model = (req / run_len.max(1)) % models.len();
                if run_len != usize::MAX {
                    be.switch_program(&keys[model], &kernels, false).unwrap();
                }
                be.sample_conv(&plan, &x, &mut out).unwrap();
                req += 1;
                black_box(&out);
            });
            let ns_per_conv = s.mean_ns / plan.convolutions() as f64;
            match label {
                "steady_1model" => base_ns = s.mean_ns,
                "interleaved/thrash" => thrash_ns = s.mean_ns,
                "coalesced" => coalesced_ns = s.mean_ns,
                _ => {}
            }
            println!(
                "{:<26} {:>14} {:>16.2e} {:>11.2}x",
                format!("{}/{}", kind.name(), label),
                photonic_bayes::benchkit::fmt_ns(s.mean_ns),
                1e9 / ns_per_conv,
                base_ns / s.mean_ns,
            );
            if let Some(sink) = sink {
                sink.push(
                    &format!("multimodel/{}/{}", kind.name(), label),
                    s.mean_ns,
                    1e9 / ns_per_conv,
                );
            }
        }
        // the switch-amortization headline: per-request cost of thrashing
        // every call vs amortizing one rebuild over an 8-request run
        let amortization = thrash_ns / coalesced_ns;
        println!(
            "{:<26} {:>43.2}x",
            format!("{}/amortization", kind.name()),
            amortization
        );
        if let Some(sink) = sink {
            sink.push(
                &format!("multimodel/{}/switch_amortization", kind.name()),
                amortization,
                amortization,
            );
        }
    }
    println!("(cached interleaving must sit near the 1-model baseline: a hit swaps bank");
    println!(" pointers instead of replaying streams; the amortization row is the win the");
    println!(" model-aware batcher's same-model grouping realizes at tight budgets)");
}

fn serving(sink: &mut Option<JsonSink>) {
    use photonic_bayes::coordinator::{
        run_service_loop, submit_with_admission, ClassifyRequest, OverloadConfig,
        OverloadControl, RequestBudget, ServeCounters, ServiceConfig, SynthExecutor,
    };
    use photonic_bayes::exec::channel;
    use std::sync::atomic::Ordering;
    use std::time::{Duration, Instant};

    section("SERVING — goodput + typed shedding at 2x overload (synthetic engine)");
    // synthetic engine: 8 samples x 200 us = 1.6 ms per request, so
    // capacity ~625 req/s; the mixed stream offers 2 requests per 1.6 ms
    let n_samples = 8usize;
    let work_per_sample = Duration::from_micros(200);
    let svc = ServiceConfig {
        queue_depth: 32,
        overload: OverloadConfig {
            default_cost: n_samples as u64,
            ..OverloadConfig::default()
        },
        ..ServiceConfig::default()
    };
    let ctrl = Arc::new(OverloadControl::new(svc.overload.clone(), svc.queue_depth));
    let counters = Arc::new(ServeCounters::default());
    let (tx, rx) = channel::<ClassifyRequest>(svc.queue_depth);
    let (c2, k2, svc2) = (ctrl.clone(), counters.clone(), svc.clone());
    let engine = std::thread::spawn(move || {
        let mut exec = SynthExecutor::new(17, n_samples);
        exec.work_per_sample = work_per_sample;
        run_service_loop(&mut exec, rx, &svc2, &c2, &k2);
    });

    let offered = 600usize;
    let mut replies = Vec::with_capacity(offered);
    let mut overload_rejected = 0u64;
    let t0 = Instant::now();
    for i in 0..offered {
        // mixed stream: every 3rd request runs on a small budget, every
        // 4th carries a tight deadline that queue wait will blow through
        let budget = if i % 3 == 0 {
            RequestBudget {
                max_samples: Some(2),
                target_confidence: None,
            }
        } else {
            RequestBudget::default()
        };
        let (mut req, rep) = ClassifyRequest::with_budget(vec![0.1; 4], budget);
        if i % 4 == 0 {
            req.deadline = Some(Instant::now() + Duration::from_millis(10));
        }
        match submit_with_admission(&tx, &ctrl, &counters, 0, req) {
            Ok(()) => replies.push(rep),
            Err(_) => overload_rejected += 1,
        }
        if i % 2 == 1 {
            std::thread::sleep(work_per_sample * n_samples as u32); // 2x pace
        }
    }
    let mut served = 0u64;
    let mut shed_deadline = 0u64;
    let mut other = 0u64;
    for rep in replies {
        match rep.recv() {
            Some(Ok(_)) => served += 1,
            Some(Err(_)) => shed_deadline += 1,
            None => other += 1,
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let goodput = served as f64 / elapsed;
    let typed_rejects = overload_rejected + shed_deadline;
    let reject_rate = typed_rejects as f64 / offered as f64;
    println!(
        "offered {offered} req in {elapsed:.2}s (2x capacity), queue depth {}",
        svc.queue_depth
    );
    println!("{:<30} {:>12}", "metric", "measured");
    println!("{:<30} {:>12.0} req/s", "goodput (answered ok)", goodput);
    println!("{:<30} {:>12.3}", "typed rejection rate", reject_rate);
    println!("{:<30} {:>12}", "overloaded at admission", overload_rejected);
    println!("{:<30} {:>12}", "deadline_exceeded replies", shed_deadline);
    println!("{:<30} {:>12}", "dropped replies (must be 0)", other);
    println!(
        "{:<30} {:>12}",
        "queue depth gauge (final)",
        counters.queue_depth.load(Ordering::Relaxed)
    );
    println!("(every offered request is answered or typed-shed: overload never hangs");
    println!(" a client, and the bounded queue keeps latency honest under 2x load)");
    if let Some(sink) = sink {
        sink.push("serving/goodput_rps", 1e9 / goodput.max(1e-9), goodput);
        sink.push("serving/typed_reject_rate", reject_rate, reject_rate);
        sink.push(
            "serving/overload_rejects",
            overload_rejected as f64,
            overload_rejected as f64,
        );
        sink.push(
            "serving/deadline_expired",
            shed_deadline as f64,
            shed_deadline as f64,
        );
    }
    tx.close();
    engine.join().unwrap();
}

/// Cluster mode: a coordinator fronting N local workers over loopback TCP
/// — round-trip throughput per pool size, plus the cost of the request
/// that discovers a dead worker and re-routes.  Dispatch is serial per
/// placement (one coordinator engine thread), so the per-pool-size rows
/// measure protocol + shard overhead, not parallel speedup.  The rows
/// land machine-readably in `BENCH_cluster.json`.
fn cluster_bench(sink: &mut Option<JsonSink>) {
    use photonic_bayes::cluster::{self, ClusterConfig, WorkerGuard, WorkerOptions};
    use photonic_bayes::coordinator::ServiceConfig;
    use photonic_bayes::server::ClientConfig;
    use std::time::{Duration, Instant};

    section("CLUSTER — sharded serving over loopback, failover cost");
    let image = vec![0.2f32, 0.4, 0.6, 0.8];
    let n_samples = 4usize;
    let work = Duration::from_micros(100);
    let mk_cfg = || ClusterConfig {
        n_samples,
        probe_interval: Duration::ZERO,
        client: ClientConfig {
            connect_timeout: Duration::from_millis(500),
            ..ClientConfig::default()
        },
        ..ClusterConfig::default()
    };
    let spawn_workers = |n: usize| -> Vec<WorkerGuard> {
        (0..n)
            .map(|i| {
                cluster::spawn_local_worker(WorkerOptions {
                    seed: 100 + i as u64,
                    n_samples,
                    work_per_sample: work,
                    ..WorkerOptions::default()
                })
                .expect("spawn worker")
            })
            .collect()
    };

    println!("{:<26} {:>14} {:>14}", "pool", "req/s", "us/req");
    let reqs = 64usize;
    for w in [1usize, 2, 4] {
        let workers = spawn_workers(w);
        let addrs: Vec<String> = workers.iter().map(|g| g.addr.clone()).collect();
        let (handle, _pool) = cluster::spawn_coordinator(mk_cfg(), addrs, ServiceConfig::default())
            .expect("spawn coordinator");
        handle.classify_blocking(image.clone()).expect("warm");
        let t0 = Instant::now();
        for _ in 0..reqs {
            black_box(handle.classify_blocking(image.clone()).expect("classify"));
        }
        let elapsed = t0.elapsed();
        let us = elapsed.as_micros() as f64 / reqs as f64;
        let rps = reqs as f64 / elapsed.as_secs_f64();
        println!("{:<26} {:>14.0} {:>14.1}", format!("{w} worker(s)"), rps, us);
        if let Some(sink) = sink {
            sink.push(&format!("cluster/throughput_w{w}"), us * 1e3, rps);
        }
        handle.shutdown();
        drop(workers);
    }

    // failover: kill one of two workers, then time the request whose lane
    // points at the corpse — connect-refused on loopback plus the re-route
    let mut workers = spawn_workers(2);
    let addrs: Vec<String> = workers.iter().map(|g| g.addr.clone()).collect();
    let (handle, _pool) = cluster::spawn_coordinator(mk_cfg(), addrs, ServiceConfig::default())
        .expect("spawn coordinator");
    handle.classify_blocking(image.clone()).expect("warm"); // placement 0 → lane 0
    workers.pop().expect("two workers").stop();
    let t0 = Instant::now();
    // placement 1 → lane 1 → the dead worker: transport failure + re-route
    black_box(handle.classify_blocking(image.clone()).expect("failover"));
    let failover_us = t0.elapsed().as_micros() as f64;
    println!("{:<26} {:>14.0} us", "failover (dead lane)", failover_us);
    if let Some(sink) = sink {
        sink.push("cluster/failover_latency_us", failover_us * 1e3, failover_us);
    }
    handle.shutdown();
    drop(workers);
}

/// Tracing overhead: the observability tentpole's acceptance point is
/// traced serving throughput within 2% of untraced.  A synthetic engine
/// serves sequential requests three ways — recorder off, recorder on
/// (gateway-style minted ids), and recorder on with an exemplar retained
/// for every request (`slow_ms = 0`, the worst case).  The rows land
/// machine-readably in `BENCH_observe.json`.
fn observe(sink: &mut Option<JsonSink>) {
    use photonic_bayes::coordinator::{
        ClassifyRequest, EngineHandle, ServiceConfig, SynthExecutor,
    };
    use photonic_bayes::observe::ObserveConfig;
    use std::time::{Duration, Instant};

    section("OBSERVE — span-recording overhead, off vs on vs exemplar-every-request");
    let n_samples = 4usize;
    let work = Duration::from_micros(50);
    let reqs = 400usize;
    let cases: [(&str, ObserveConfig); 3] = [
        ("off", ObserveConfig::default()),
        ("on", ObserveConfig::enabled()),
        (
            "exemplar",
            ObserveConfig {
                slow_ms: 0,
                ..ObserveConfig::enabled()
            },
        ),
    ];
    println!(
        "plan: synthetic engine, {n_samples} samples x {} us/sample, {reqs} sequential requests",
        work.as_micros()
    );
    println!("{:<18} {:>14} {:>12} {:>10}", "tracing", "req/s", "us/req", "vs off");
    let mut off_us = f64::NAN;
    for (label, ocfg) in cases {
        let svc = ServiceConfig {
            observe: ocfg,
            ..ServiceConfig::default()
        };
        let handle = EngineHandle::spawn_executor(
            "synth",
            vec!["synth".to_string()],
            None,
            n_samples,
            svc,
            move || {
                let mut e = SynthExecutor::new(71, n_samples);
                e.work_per_sample = work;
                Ok(e)
            },
        )
        .expect("spawn synth executor");
        let image = vec![0.3f32; 4];
        // warm the engine thread + channel before the timed run
        let (req, rx) = ClassifyRequest::new(image.clone());
        handle.submit(req).expect("warm admit");
        rx.recv().expect("warm reply").expect("warm ok");
        let t0 = Instant::now();
        for _ in 0..reqs {
            let (mut req, rx) = ClassifyRequest::new(image.clone());
            // mirror the gateway: mint an id and capture exemplars only
            // when the recorder is on
            if handle.recorder.enabled() {
                req.request_id = handle.recorder.mint_id();
            }
            let rid = req.request_id;
            let t_req = Instant::now();
            handle.submit(req).expect("admit");
            rx.recv().expect("reply").expect("ok");
            if rid != 0 {
                handle.recorder.maybe_capture_exemplar(rid, t_req.elapsed());
            }
        }
        let elapsed = t0.elapsed();
        let us = elapsed.as_micros() as f64 / reqs as f64;
        let rps = reqs as f64 / elapsed.as_secs_f64();
        if label == "off" {
            off_us = us;
        }
        let vs_off = us / off_us;
        println!("{label:<18} {rps:>14.0} {us:>12.1} {vs_off:>9.3}x");
        if let Some(sink) = sink {
            sink.push(&format!("observe/throughput_{label}"), us * 1e3, rps);
            sink.push(&format!("observe/overhead_{label}"), vs_off, vs_off);
        }
        let stats = handle.recorder.stats();
        if stats.enabled {
            println!(
                "    recorded {} spans, dropped {} (ring wrap), {} exemplars retained",
                stats.recorded, stats.dropped, stats.exemplars
            );
        }
        handle.shutdown();
    }
    println!("(acceptance: the 'on' row within 2% of 'off' — the record path is a");
    println!(" handful of relaxed atomic stores; exemplar capture is off the steady path)");
}

fn fig2_error() {
    section("FIG 2(c,d) — computation error, 25 random kernels");
    let mut machine = PhotonicMachine::with_defaults(7);
    let rep = computation_error_experiment(&mut machine, 25, 1024, 99);
    println!("{:<38} {:>12} {:>12}", "quantity", "measured", "paper");
    println!("{:<38} {:>12.3} {:>12}", "normalized mean error", rep.mean_error, "0.158");
    println!("{:<38} {:>12.3} {:>12}", "normalized std error", rep.std_error, "0.266");
    println!("{:<38} {:>12.3} {:>12}", "measured-vs-target mean slope", rep.mean_slope, "1.0");
    println!("{:<38} {:>12.3} {:>12}", "measured-vs-target std slope", rep.std_slope, "1.0");
}

fn fig2_delay() {
    section("FIG 2(e) — frequency-dependent group delay");
    let g = ChirpedGrating::paper_device(9, 0.5, 7);
    let mut fs = Vec::new();
    let mut ds = Vec::new();
    println!("{:<10} {:>14} {:>14}", "channel", "f (THz)", "delay (ps)");
    for k in 0..9 {
        let f = channel_frequency_thz(k, 9);
        let d = g.channel_delay_ps(k);
        println!("{:<10} {:>14.3} {:>14.2}", k, f, d);
        fs.push(f);
        ds.push(d);
    }
    let (_, slope, r2) = linfit(&fs, &ds);
    println!("fitted dispersion: {slope:.2} ps/THz (r2 = {r2:.6})   [paper: -93.1]");
}

fn nist_table() {
    section("NIST SP800-22 — chaotic-light entropy source (paper: passes)");
    let mut src = ChaoticLightSource::with_defaults(2024);
    let bits = src.extract_bits(100.0, 200_000);
    println!("{:<20} {:>10} {:>8}", "test", "p-value", "pass");
    let run = nist::run_battery(&bits);
    for r in &run.results {
        println!("{:<20} {:>10.4} {:>8}", r.name, r.p_value, if r.pass { "yes" } else { "NO" });
    }
    for e in &run.skipped {
        println!("skipped: {e}");
    }
    println!("overall: {}", if run.all_pass() { "PASS" } else { "FAIL" });
}

/// Entropy-health monitor overhead: the tentpole acceptance point is
/// monitor-on sampling throughput within 5% of monitor-off at the default
/// 5% duty cycle.  Runs the backends' synthetic workload through tapped
/// (`Sync`-mode) streams, so it needs no artifacts.  With `--json <path>`
/// the rows land machine-readably in `BENCH_health.json`.
fn health(sink: &mut Option<JsonSink>) {
    use photonic_bayes::entropy::health::{HealthConfig, Monitor};

    section("HEALTH — entropy-monitor overhead, monitor-off vs monitor-on");
    let (n_samples, batch, channels, hw) = (16usize, 8usize, 8usize, 7usize);
    let plan = SamplePlan::new(n_samples, batch, channels, hw, hw);
    let mut rng = photonic_bayes::entropy::Xoshiro256pp::new(41);
    let kernels: Vec<_> = (0..channels).map(|_| random_kernel(&mut rng)).collect();
    let mcfg = MachineConfig {
        seed: 41,
        ..MachineConfig::default()
    };
    let x = random_activations(&mut rng, plan.sample_size(), mcfg.scale_dac);
    let bench = Bench::quick();
    println!(
        "plan: N = {n_samples} x B = {batch} x {channels}ch@{hw}x{hw}, duty = {}",
        HealthConfig::default().duty
    );
    println!(
        "{:<26} {:>14} {:>16} {:>10}",
        "backend/monitor", "call latency", "conv/s (sim)", "vs off"
    );
    for kind in [BackendKind::Digital, BackendKind::Photonic] {
        let mut off_ns = f64::NAN;
        for monitored in [false, true] {
            let popts = PipelineOptions {
                mode: PrefetchMode::Sync,
                ..PipelineOptions::default()
            };
            let monitor = monitored.then(|| {
                Arc::new(Monitor::new(HealthConfig {
                    enabled: true,
                    ..HealthConfig::default()
                }))
            });
            let mut be =
                backend::build_with_opts_monitored(kind, &mcfg, None, popts, monitor.clone());
            be.program(&kernels, false).unwrap();
            let mut out = vec![0.0f32; plan.total_size()];
            let label = format!(
                "{}/{}",
                kind.name(),
                if monitored { "monitor-on" } else { "monitor-off" }
            );
            let s = bench.run(&label, || {
                be.sample_conv(&plan, &x, &mut out).unwrap();
                black_box(&out);
            });
            let ns_per_conv = s.mean_ns / plan.convolutions() as f64;
            if !monitored {
                off_ns = s.mean_ns;
            }
            println!(
                "{:<26} {:>14} {:>16.2e} {:>9.2}x",
                label,
                photonic_bayes::benchkit::fmt_ns(s.mean_ns),
                1e9 / ns_per_conv,
                off_ns / s.mean_ns,
            );
            if let Some(sink) = sink {
                sink.push(
                    &format!(
                        "health/sample_conv/{}/{}",
                        kind.name(),
                        if monitored { "monitor_on" } else { "monitor_off" }
                    ),
                    s.mean_ns,
                    1e9 / ns_per_conv,
                );
            }
            if let Some(m) = &monitor {
                println!(
                    "    tapped {} blocks, analyzed {} windows, degraded: {}",
                    m.observed_blocks(),
                    m.analyzed_windows(),
                    m.any_degraded(),
                );
            }
        }
    }
    println!("(acceptance: monitor-on within 5% of monitor-off at the default duty cycle)");
}

// ---------------------------------------------------------------------------

fn load_engine(
    dataset: &str,
    mode: ExecMode,
    n_samples: usize,
    seed: u64,
) -> Option<(Engine, bool)> {
    let root = artifacts_root();
    if !root.join(dataset).join("meta.json").exists() {
        println!("  !! artifacts for {dataset} missing; run `make artifacts`");
        return None;
    }
    let arts = ModelArtifacts::load_dataset(&root, dataset).ok()?;
    let trained_path = root.join(dataset).join("params_trained.bin");
    let trained = trained_path.exists();
    let params = if trained {
        ParamStore::load_bin(&arts.meta, &trained_path).ok()?
    } else {
        println!("  !! no trained checkpoint for {dataset}; numbers will be near-chance");
        ParamStore::load_init(&arts.meta, &root.join(dataset)).ok()?
    };
    let engine = Engine::new(
        arts,
        params,
        EngineConfig {
            n_samples,
            mode,
            policy: UncertaintyPolicy::ood_only(0.0185),
            calibrate: true,
            machine: MachineConfig::default(),
            noise_bw_ghz: 150.0,
            threads: 1,
            seed,
            ..Default::default()
        },
    )
    .ok()?;
    Some((engine, trained))
}

fn load_split(stem: &str, kind: DatasetKind) -> Option<Dataset> {
    Dataset::load(&artifacts_root().join("data"), stem, kind).ok()
}

fn fig4() {
    section("FIG 4 — blood cells: OOD ROC, accuracy with rejection, confusion");
    let Some((mut engine, trained)) = load_engine("blood", ExecMode::photonic(), 10, 7) else {
        return;
    };
    let limit = if trained { 300 } else { 96 };
    let id_split = load_split("blood_test", DatasetKind::InDomain).unwrap();
    let id = eval_split(&mut engine, &id_split, limit).unwrap();
    let ood_split = load_split("blood_ood", DatasetKind::Epistemic).unwrap();
    let ood = eval_split(&mut engine, &ood_split, limit).unwrap();
    let rep = build_report(id, ood, None, 7);
    println!("{:<38} {:>12} {:>12}", "quantity", "measured", "paper");
    println!("{:<38} {:>11.2}% {:>12}", "OOD AUROC (MI)", rep.ood_auroc * 100.0, "91.16%");
    println!("{:<38} {:>11.2}% {:>12}", "ID accuracy (plain)", rep.acc_plain * 100.0, "90.26%");
    println!(
        "{:<38} {:>11.2}% {:>12}",
        "ID accuracy (MI rejection)",
        rep.acc_reject * 100.0,
        "94.62%"
    );
    println!("{:<38} {:>12.5} {:>12}", "optimal MI threshold", rep.mi_threshold, "0.0185");
    println!("\nROC curve (threshold sweep, 10 sample points):");
    let pts = &rep.ood_roc;
    for i in (0..pts.len()).step_by((pts.len() / 10).max(1)) {
        println!("  thr {:>9.5}  FPR {:.3}  TPR {:.3}", pts[i].threshold, pts[i].fpr, pts[i].tpr);
    }
    println!("\nconfusion matrix with rejection (x = erythroblast):");
    let names = ["baso", "eosi", "ig", "lymp", "mono", "neut", "plt"];
    println!("{}", rep.confusion.render(&names));
}

fn fig5() {
    section("FIG 5 — uncertainty disentanglement (digits / ambiguous / fashion)");
    let Some((mut engine, trained)) = load_engine("digits", ExecMode::photonic(), 10, 11) else {
        return;
    };
    let limit = if trained { 300 } else { 96 };
    let id_split = load_split("digits_test", DatasetKind::InDomain).unwrap();
    let id = eval_split(&mut engine, &id_split, limit).unwrap();
    let amb_split = load_split("ambiguous", DatasetKind::Aleatoric).unwrap();
    let amb = eval_split(&mut engine, &amb_split, limit).unwrap();
    let fash_split = load_split("fashion", DatasetKind::Epistemic).unwrap();
    let fash = eval_split(&mut engine, &fash_split, limit).unwrap();

    println!("Fig 5(e) cluster medians:");
    println!("{:<14} {:>10} {:>10}", "split", "med MI", "med SE");
    for s in [&id, &amb, &fash] {
        println!("{:<14} {:>10.4} {:>10.3}", s.name, median(&s.mi), median(&s.se));
    }

    let rep = build_report(id, fash, Some(amb), 10);
    println!("\n{:<38} {:>12} {:>12}", "quantity", "measured", "paper");
    println!("{:<38} {:>11.2}% {:>12}", "ID accuracy (plain)", rep.acc_plain * 100.0, "96.01%");
    println!(
        "{:<38} {:>11.2}% {:>12}",
        "ID accuracy (MI rejection)",
        rep.acc_reject * 100.0,
        "99.7%"
    );
    println!(
        "{:<38} {:>11.2}% {:>12}",
        "epistemic AUROC (MI, fashion)",
        rep.ood_auroc * 100.0,
        "84.42%"
    );
    println!(
        "{:<38} {:>11.2}% {:>12}",
        "aleatoric AUROC (SE, ambiguous)",
        rep.aleatoric_auroc.unwrap_or(0.0) * 100.0,
        "88.03%"
    );
    println!("{:<38} {:>12.5} {:>12}", "optimal MI threshold", rep.mi_threshold, "0.00308");
}

// ---------------------------------------------------------------------------

fn ablations() {
    section("ABLATIONS — design choices called out in DESIGN.md");

    // (a) surrogate vs photonic agreement on predictions
    if let Some((mut photonic, _)) = load_engine("digits", ExecMode::photonic(), 10, 21) {
        if let Some((mut surrogate, _)) = load_engine("digits", ExecMode::Surrogate, 10, 21) {
            let ds = load_split("digits_test", DatasetKind::InDomain).unwrap();
            let a = eval_split(&mut photonic, &ds, 120).unwrap();
            let b = eval_split(&mut surrogate, &ds, 120).unwrap();
            let agree = a
                .predicted
                .iter()
                .zip(&b.predicted)
                .filter(|(x, y)| x == y)
                .count() as f64
                / a.predicted.len() as f64;
            println!("(a) photonic-vs-surrogate prediction agreement: {:.1}%", agree * 100.0);
            println!(
                "    accuracy photonic {:.2}%  surrogate {:.2}%",
                a.accuracy() * 100.0,
                b.accuracy() * 100.0
            );
        }
    }

    // (b) N-sample sweep: MI resolution vs sampling cost
    println!("\n(b) N-sample sweep (mean OOD MI - mean ID MI gap, digits/fashion):");
    for n in [3, 5, 10, 20] {
        if let Some((mut e, _)) = load_engine("digits", ExecMode::photonic(), n, 31) {
            let id_split = load_split("digits_test", DatasetKind::InDomain).unwrap();
            let id = eval_split(&mut e, &id_split, 100).unwrap();
            let fa_split = load_split("fashion", DatasetKind::Epistemic).unwrap();
            let fa = eval_split(&mut e, &fa_split, 100).unwrap();
            println!(
                "    N = {n:>2}: MI gap = {:.4} (id {:.4}, fashion {:.4})",
                mean(&fa.mi) - mean(&id.mi),
                mean(&id.mi),
                mean(&fa.mi)
            );
        }
    }

    // (c) bandwidth range vs std-programming error (Discussion claim:
    //     larger max bandwidth would cut the std error at the cost of
    //     channel count)
    println!("\n(c) channel-bandwidth range vs Fig 2(d) std error:");
    for bw_max in [100.0, 150.0, 300.0, 600.0] {
        let mut cfg = MachineConfig {
            seed: 13,
            ..MachineConfig::default()
        };
        cfg.source.bw_max_ghz = bw_max;
        let mut m = PhotonicMachine::new(cfg);
        let rep = computation_error_experiment(&mut m, 12, 512, 5);
        println!(
            "    B_max = {bw_max:>5.0} GHz: mean err {:.3}, std err {:.3}",
            rep.mean_error, rep.std_error
        );
    }
}
