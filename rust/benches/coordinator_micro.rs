//! `cargo bench --bench coordinator_micro` — L3 serving-path latency
//! decomposition: PJRT entry points per batch size, end-to-end classify in
//! both execution modes, batching/channel overhead, protocol costs.

use std::sync::Arc;
use std::time::Duration;

use photonic_bayes::backend::{self, BackendKind, ProbConvBackend, SamplePlan};
use photonic_bayes::benchkit::{black_box, section, Bench};
use photonic_bayes::bnn::UncertaintyPolicy;
use photonic_bayes::coordinator::{DynamicBatcher, Engine, EngineConfig, ExecMode};
use photonic_bayes::data::synth::{random_activations, random_kernel};
use photonic_bayes::entropy::Xoshiro256pp;
use photonic_bayes::exec::channel::channel;
use photonic_bayes::exec::ThreadPool;
use photonic_bayes::photonics::MachineConfig;
use photonic_bayes::runtime::artifact::artifacts_root;
use photonic_bayes::runtime::{Arg, ModelArtifacts, ParamStore};
use photonic_bayes::server::protocol;

fn main() {
    let bench = Bench::default();
    let quick = Bench::quick();

    section("BACKEND — batched sample plan (N = 10, batch 8, 8ch@7x7)");
    {
        let plan = SamplePlan::new(10, 8, 8, 7, 7);
        let mut rng = Xoshiro256pp::new(3);
        let kernels: Vec<_> = (0..8).map(|_| random_kernel(&mut rng)).collect();
        let mcfg = MachineConfig::default();
        let x = random_activations(&mut rng, plan.sample_size(), mcfg.scale_dac);
        for kind in [BackendKind::Photonic, BackendKind::Digital, BackendKind::MeanField] {
            let threads: &[usize] = if kind == BackendKind::MeanField {
                &[1]
            } else {
                &[1, 4] // sequential vs sharded-across-the-pool
            };
            for &t in threads {
                let pool = (t > 1).then(|| Arc::new(ThreadPool::new(t)));
                let mut be = backend::build_with_pool(kind, &mcfg, pool);
                be.program(&kernels, false).unwrap();
                let eff = SamplePlan {
                    n_samples: if be.is_deterministic() { 1 } else { plan.n_samples },
                    ..plan
                };
                let mut out = vec![0.0f32; eff.total_size()];
                let s = quick.run(
                    &format!("sample_conv backend={} threads={t}", kind.name()),
                    || {
                        be.sample_conv(&eff, &x, &mut out).unwrap();
                        black_box(&out);
                    },
                );
                println!(
                    "{}   ({:.2} M conv/s)",
                    s.row(),
                    s.throughput(eff.convolutions() as f64) / 1e6
                );
            }
        }
    }

    let root = artifacts_root();
    if !root.join("digits/meta.json").exists() {
        eprintln!("artifacts missing; run `make artifacts` first");
        return;
    }

    section("SUBSTRATE — channel + batcher overhead");
    {
        let (tx, rx) = channel::<u64>(1024);
        let s = bench.run("mpmc send+recv", || {
            tx.send(1).unwrap();
            black_box(rx.recv());
        });
        println!("{}   ({:.1} M msg/s)", s.row(), s.throughput(1.0) / 1e6);

        let (tx, rx) = channel::<u64>(1024);
        let b = DynamicBatcher::new(rx, 8, Duration::from_micros(100));
        let s = bench.run("batcher 8-item batch", || {
            for i in 0..8 {
                tx.send(i).unwrap();
            }
            black_box(b.next_batch());
        });
        println!("{}   ({:.2} M items/s)", s.row(), s.throughput(8.0) / 1e6);
    }

    section("PROTOCOL — JSON encode/decode");
    {
        let image = vec![0.5f32; 784];
        let line = protocol::encode_classify("digits", &image);
        println!("classify request size: {} bytes", line.len());
        let s = bench.run("parse classify request (784 px)", || {
            black_box(protocol::parse_request(&line).unwrap());
        });
        println!("{}   ({:.0} k req/s)", s.row(), s.throughput(1.0) / 1e3);
    }

    section("PJRT ENTRY POINTS (digits model)");
    {
        let arts = ModelArtifacts::load_dataset(&root, "digits").unwrap();
        let meta = arts.meta.clone();
        let ps = ParamStore::load_init(&meta, &root.join("digits")).unwrap();
        let np = meta.num_params as i64;
        let mut rng = Xoshiro256pp::new(5);
        for b in [1usize, 8, 32] {
            let f = arts.get(&format!("fwd_full_b{b}")).unwrap();
            let x = random_activations(&mut rng, b * meta.image_size(), 1.0);
            let eps = random_activations(&mut rng, b * meta.eps_size(), 1.0);
            let xs = [b as i64, meta.in_channels as i64, 28, 28];
            let es = [b as i64, meta.prob_ch as i64, 7, 7, 9];
            let s = quick.run(&format!("fwd_full b={b}"), || {
                black_box(
                    f.call(&[Arg::F32(&ps.theta, &[np]), Arg::F32(&x, &xs), Arg::F32(&eps, &es)])
                        .unwrap(),
                );
            });
            println!("{}   ({:.0} img/s)", s.row(), s.throughput(b as f64));
        }
        for b in [1usize, 8] {
            let f = arts.get(&format!("fwd_pre_b{b}")).unwrap();
            let x = random_activations(&mut rng, b * meta.image_size(), 1.0);
            let xs = [b as i64, meta.in_channels as i64, 28, 28];
            let s = quick.run(&format!("fwd_pre  b={b}"), || {
                black_box(f.call(&[Arg::F32(&ps.theta, &[np]), Arg::F32(&x, &xs)]).unwrap());
            });
            println!("{}", s.row());
            let g = arts.get(&format!("fwd_post_b{b}")).unwrap();
            let act = random_activations(&mut rng, b * meta.act_size(), 4.0);
            let a_s = [b as i64, meta.prob_ch as i64, 7, 7];
            let s = quick.run(&format!("fwd_post b={b}"), || {
                black_box(
                    g.call(&[
                        Arg::F32(&ps.theta, &[np]),
                        Arg::F32(&act, &a_s),
                        Arg::F32(&act, &a_s),
                    ])
                    .unwrap(),
                );
            });
            println!("{}", s.row());
        }
    }

    section("END-TO-END classify (N = 10 passes, batch 8)");
    {
        for (name, mode) in [
            ("surrogate", ExecMode::Surrogate),
            ("photonic", ExecMode::photonic()),
            ("digital", ExecMode::Split(BackendKind::Digital)),
            ("mean", ExecMode::Split(BackendKind::MeanField)),
        ] {
            let arts = ModelArtifacts::load_dataset(&root, "digits").unwrap();
            let params = ParamStore::load_init(&arts.meta, &root.join("digits")).unwrap();
            let image_size = arts.meta.image_size();
            let mut engine = Engine::new(
                arts,
                params,
                EngineConfig {
                    n_samples: 10,
                    mode,
                    policy: UncertaintyPolicy::ood_only(0.02),
                    calibrate: false,
                    machine: MachineConfig::default(),
                    noise_bw_ghz: 150.0,
                    threads: 1,
                    seed: 7,
                    ..Default::default()
                },
            )
            .unwrap();
            let mut rng = Xoshiro256pp::new(9);
            let images = random_activations(&mut rng, 8 * image_size, 1.0);
            let s = quick.run(&format!("classify batch=8 mode={name}"), || {
                black_box(engine.classify(&images, 8).unwrap());
            });
            println!("{}   ({:.1} img/s)", s.row(), s.throughput(8.0));
        }
    }
}
