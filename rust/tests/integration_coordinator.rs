//! Integration: coordinator service + router + property-based L3 invariants.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use photonic_bayes::bnn::UncertaintyPolicy;
use photonic_bayes::coordinator::service::{ClassifyRequest, EngineHandle, ServiceConfig};
use photonic_bayes::coordinator::{DynamicBatcher, EngineConfig, ExecMode, Router};
use photonic_bayes::entropy::BitSource;
use photonic_bayes::exec::channel::channel;
use photonic_bayes::photonics::MachineConfig;
use photonic_bayes::proptest_mini as pt;
use photonic_bayes::runtime::artifact::artifacts_root;

fn have_artifacts() -> bool {
    artifacts_root().join("digits/meta.json").exists()
}

fn fast_engine_cfg() -> EngineConfig {
    EngineConfig {
        n_samples: 3,
        mode: ExecMode::Surrogate,
        policy: UncertaintyPolicy::ood_only(0.05),
        calibrate: false,
        machine: MachineConfig::default(),
        noise_bw_ghz: 150.0,
        threads: 1,
        seed: 5,
        ..Default::default()
    }
}

#[test]
fn engine_service_answers_concurrent_clients() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let handle = EngineHandle::spawn(
        &artifacts_root(),
        "digits",
        None,
        fast_engine_cfg(),
        ServiceConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            queue_depth: 64,
            ..Default::default()
        },
    )
    .unwrap();
    let handle = Arc::new(handle);
    let image_size = 28 * 28;

    let results: Arc<Mutex<Vec<usize>>> = Arc::new(Mutex::new(Vec::new()));
    let mut clients = Vec::new();
    for c in 0..4 {
        let handle = handle.clone();
        let results = results.clone();
        clients.push(std::thread::spawn(move || {
            for i in 0..6 {
                let image = vec![0.1 * (c as f32 + 1.0); image_size];
                let r = handle.classify_blocking(image).unwrap();
                assert_eq!(r.predictive.n_classes(), 10);
                assert!(r.predictive.mutual_information >= 0.0);
                results.lock().unwrap().push(c * 10 + i);
            }
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    assert_eq!(results.lock().unwrap().len(), 24);
}

#[test]
fn engine_service_rejects_wrong_image_size() {
    if !have_artifacts() {
        return;
    }
    let handle = EngineHandle::spawn(
        &artifacts_root(),
        "digits",
        None,
        fast_engine_cfg(),
        ServiceConfig::default(),
    )
    .unwrap();
    let err = handle.classify_blocking(vec![0.0; 12]);
    assert!(err.is_err());
    // and the engine must still be healthy afterwards
    let ok = handle.classify_blocking(vec![0.5; 28 * 28]);
    assert!(ok.is_ok());
}

#[test]
fn router_routes_and_errors() {
    if !have_artifacts() {
        return;
    }
    let mut router = Router::new();
    router.register(
        EngineHandle::spawn(
            &artifacts_root(),
            "digits",
            None,
            fast_engine_cfg(),
            ServiceConfig::default(),
        )
        .unwrap(),
    );
    assert!(router.get("digits").is_ok());
    assert!(router.get("nope").is_err());
    let (req, rx) = ClassifyRequest::new(vec![0.3; 28 * 28]);
    router.route("digits", req).unwrap();
    let res = rx.recv().unwrap().unwrap();
    assert!(res.predictive.n_samples() == 3);
    router.shutdown();
}

// ---------------------------------------------------------------------------
// Property-based L3 invariants (proptest_mini)
// ---------------------------------------------------------------------------

#[test]
fn prop_batcher_never_exceeds_max_and_never_drops() {
    let cfg = pt::Config { cases: 30, ..Default::default() };
    pt::check(
        "batcher-bounds",
        &cfg,
        |rng: &mut photonic_bayes::entropy::Xoshiro256pp| {
            let n_items = 1 + rng.next_below(40);
            let max_batch = 1 + rng.next_below(9);
            (n_items, max_batch)
        },
        |&(n_items, max_batch)| {
            let (tx, rx) = channel(64);
            for i in 0..n_items {
                tx.send(i).unwrap();
            }
            tx.close();
            let b = DynamicBatcher::new(rx, max_batch, Duration::from_millis(1));
            let mut seen = Vec::new();
            while let Some(batch) = b.next_batch() {
                if batch.len() > max_batch {
                    return Err(format!("batch {} > max {max_batch}", batch.len()));
                }
                seen.extend(batch);
            }
            if seen != (0..n_items).collect::<Vec<_>>() {
                return Err(format!("items lost or reordered: {seen:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_uncertainty_metrics_invariants() {
    // H = SE + MI with MI >= 0 for arbitrary prob matrices (the quantities
    // the policy thresholds act on must be well-formed for any engine output)
    let cfg = pt::Config { cases: 200, ..Default::default() };
    pt::check(
        "entropy-decomposition",
        &cfg,
        pt::prob_matrix(16, 12),
        |m| {
            let pred = photonic_bayes::bnn::Predictive::from_probs(m.clone());
            let h = pred.shannon_entropy;
            let se = pred.softmax_entropy;
            let mi = pred.mutual_information;
            if mi < 0.0 {
                return Err(format!("MI {mi} < 0"));
            }
            if (h - (se + mi)).abs() > 1e-6 && h >= se {
                return Err(format!("H {h} != SE {se} + MI {mi}"));
            }
            if !(0.0..=1.0 + 1e-9).contains(&pred.agreement) {
                return Err("agreement out of range".into());
            }
            let s: f32 = pred.mean_probs.iter().sum();
            if (s - 1.0).abs() > 1e-4 {
                return Err(format!("mean probs sum {s}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_policy_decisions_partition() {
    // every predictive gets exactly one decision, consistent with thresholds
    let cfg = pt::Config { cases: 100, ..Default::default() };
    pt::check(
        "policy-partition",
        &cfg,
        pt::prob_matrix(12, 8),
        |m| {
            let pred = photonic_bayes::bnn::Predictive::from_probs(m.clone());
            let pol = UncertaintyPolicy::full(0.05, 0.9);
            match pol.decide(&pred) {
                photonic_bayes::bnn::Decision::RejectOod { mutual_information } => {
                    if mutual_information <= 0.05 {
                        return Err("rejected below threshold".into());
                    }
                }
                photonic_bayes::bnn::Decision::FlagAmbiguous { softmax_entropy, .. } => {
                    if pred.mutual_information > 0.05 {
                        return Err("should have rejected first".into());
                    }
                    if softmax_entropy <= 0.9 {
                        return Err("flagged below threshold".into());
                    }
                }
                photonic_bayes::bnn::Decision::Accept { class, .. } => {
                    if pred.mutual_information > 0.05 || pred.softmax_entropy > 0.9 {
                        return Err("accepted above thresholds".into());
                    }
                    if class != pred.predicted {
                        return Err("accept class != argmax".into());
                    }
                }
            }
            Ok(())
        },
    );
}
