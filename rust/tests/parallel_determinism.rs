//! Integration: sharded `sample_conv` determinism + scratch-arena hygiene.
//!
//! The threading contract (README §Performance): for a fixed
//! `(seed, n_threads)` a backend replays bit-identically; across thread
//! counts outputs differ bitwise (different stream interleaving) but are
//! statistically equivalent.  None of these tests need model artifacts.

use std::sync::Arc;

use photonic_bayes::backend::{self, BackendKind, ProbConvBackend, SamplePlan};
use photonic_bayes::exec::ThreadPool;
use photonic_bayes::photonics::{MachineConfig, TapTarget};
use photonic_bayes::util::mathstat::{mean_f32, std_f32};

fn quiet_cfg(seed: u64) -> MachineConfig {
    MachineConfig {
        rx_noise: 0.0,
        actuator_sigma: 0.0,
        actuator_jitter: 0.0,
        ripple_rms_ps: 0.0,
        seed,
        ..MachineConfig::default()
    }
}

fn kernels(c: usize) -> Vec<Vec<TapTarget>> {
    (0..c)
        .map(|i| {
            let mu = 0.2 + 0.1 * i as f32;
            vec![TapTarget { mu, sigma: 0.5 * mu }; 9]
        })
        .collect()
}

fn run_once(
    kind: BackendKind,
    threads: usize,
    plan: &SamplePlan,
    x: &[f32],
    seed: u64,
) -> Vec<f32> {
    let pool = (threads > 1).then(|| Arc::new(ThreadPool::new(threads)));
    let mut be = backend::build_with_pool(kind, &quiet_cfg(seed), pool);
    be.program(&kernels(plan.channels), false).unwrap();
    let mut out = vec![0.0f32; plan.total_size()];
    be.sample_conv(plan, x, &mut out).unwrap();
    out
}

fn test_input(plan: &SamplePlan) -> Vec<f32> {
    (0..plan.sample_size())
        .map(|i| 0.3 * ((i % 11) as f32) / 3.0)
        .collect()
}

#[test]
fn sharded_sample_conv_is_bitwise_deterministic_per_thread_count() {
    let plan = SamplePlan::new(6, 4, 2, 5, 5);
    let x = test_input(&plan);
    for kind in [BackendKind::Digital, BackendKind::Photonic] {
        for threads in [1, 2, 4] {
            let a = run_once(kind, threads, &plan, &x, 33);
            let b = run_once(kind, threads, &plan, &x, 33);
            assert_eq!(a, b, "{kind} at {threads} threads must replay bitwise");
        }
    }
}

#[test]
fn thread_counts_are_statistically_equivalent() {
    // a large grid so per-thread-count moments are tight
    let plan = SamplePlan::new(64, 4, 2, 5, 5);
    let x = test_input(&plan);
    for kind in [BackendKind::Digital, BackendKind::Photonic] {
        let reference = run_once(kind, 1, &plan, &x, 7);
        let (m_ref, s_ref) = (mean_f32(&reference), std_f32(&reference));
        assert!(s_ref > 0.0, "{kind}: stochastic backend must fluctuate");
        for threads in [2, 4] {
            let out = run_once(kind, threads, &plan, &x, 7);
            let (m, s) = (mean_f32(&out), std_f32(&out));
            assert!(
                (m - m_ref).abs() < 0.02 + 0.05 * s_ref,
                "{kind} t={threads}: mean {m} vs sequential {m_ref}"
            );
            assert!(
                (s - s_ref).abs() < 0.1 * s_ref + 0.01,
                "{kind} t={threads}: std {s} vs sequential {s_ref}"
            );
        }
    }
}

#[test]
fn more_workers_than_grid_rows_is_sound() {
    // 2 grid rows sharded over 4 workers: trailing shards get empty ranges
    let plan = SamplePlan::new(2, 1, 1, 3, 3);
    let x = test_input(&plan);
    for kind in [BackendKind::Digital, BackendKind::Photonic] {
        let a = run_once(kind, 4, &plan, &x, 5);
        let b = run_once(kind, 4, &plan, &x, 5);
        assert_eq!(a, b);
        assert!(a.iter().all(|v| v.is_finite()));
    }
}

#[test]
fn parallel_writes_stay_inside_the_plan_region() {
    let plan = SamplePlan::new(5, 3, 2, 4, 4);
    let x = test_input(&plan);
    let pool = Some(Arc::new(ThreadPool::new(4)));
    let mut be = backend::build_with_pool(BackendKind::Digital, &quiet_cfg(2), pool);
    be.program(&kernels(plan.channels), false).unwrap();
    const SENTINEL: f32 = 777.25;
    let mut out = vec![SENTINEL; plan.total_size() + 32];
    be.sample_conv(&plan, &x, &mut out).unwrap();
    assert!(
        out[..plan.total_size()].iter().all(|v| v.is_finite() && *v != SENTINEL),
        "plan region fully written"
    );
    assert!(
        out[plan.total_size()..].iter().all(|&v| v == SENTINEL),
        "tail beyond the plan untouched"
    );
}

#[test]
fn scratch_arena_reuse_leaves_no_stale_data() {
    // two consecutive requests on one (deterministic) backend: the second,
    // smaller request must match a fresh backend exactly even though the
    // arena still holds the first request's larger buffers
    let big = SamplePlan::new(8, 4, 2, 6, 6);
    let small = SamplePlan::new(2, 1, 2, 3, 3);
    let cfg = quiet_cfg(4);

    let mut warm = backend::build(BackendKind::MeanField, &cfg);
    warm.program(&kernels(2), false).unwrap();
    let xb = test_input(&big);
    let mut sink = vec![0.0f32; big.total_size()];
    warm.sample_conv(&big, &xb, &mut sink).unwrap();

    let xs = test_input(&small);
    let mut warm_out = vec![0.0f32; small.total_size()];
    warm.sample_conv(&small, &xs, &mut warm_out).unwrap();

    let mut fresh = backend::build(BackendKind::MeanField, &cfg);
    fresh.program(&kernels(2), false).unwrap();
    let mut fresh_out = vec![0.0f32; small.total_size()];
    fresh.sample_conv(&small, &xs, &mut fresh_out).unwrap();

    assert_eq!(warm_out, fresh_out, "arena reuse must not leak request state");
}

#[test]
fn sequential_pool_free_backends_match_single_worker_pool() {
    // a 1-worker pool must take the sequential path (photonic stays
    // bit-identical to the machine's own streams)
    let plan = SamplePlan::new(3, 2, 1, 4, 4);
    let x = test_input(&plan);
    let none = run_once(BackendKind::Photonic, 1, &plan, &x, 9);
    let one = {
        let pool = Some(Arc::new(ThreadPool::new(1)));
        let mut be = backend::build_with_pool(BackendKind::Photonic, &quiet_cfg(9), pool);
        be.program(&kernels(plan.channels), false).unwrap();
        let mut out = vec![0.0f32; plan.total_size()];
        be.sample_conv(&plan, &x, &mut out).unwrap();
        out
    };
    assert_eq!(none, one);
}
