//! Chaos suite: drives the full service loop through the seeded
//! fault-injection harness (`--features fault-injection`).
//!
//! The three contracts under test:
//! 1. an injected engine panic answers *that batch* with a typed
//!    `internal_error`, the engine thread survives, and every surviving
//!    output is bitwise identical to an unfaulted run;
//! 2. a full queue / exhausted work budget sheds with a typed
//!    `overloaded` error immediately — never a hang;
//! 3. a deadline expiring mid-run reports the samples actually spent.
//!
//! Fault points are process-global, so tests that arm them are
//! serialized through `harness()`.

#![cfg(feature = "fault-injection")]

use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use photonic_bayes::coordinator::{
    run_service_loop, submit_with_admission, ClassifyRequest, ClassifyResult, OverloadConfig,
    OverloadControl, ServeCounters, ServeError, ServiceConfig, SynthExecutor,
};
use photonic_bayes::exec::{channel, Receiver, Sender};
use photonic_bayes::util::fault::{self, Fault, Trigger};

/// Serialize tests that arm global fault points (and disarm any residue
/// a previous test left behind, even if it panicked mid-assert).
fn harness() -> MutexGuard<'static, ()> {
    static GUARD: Mutex<()> = Mutex::new(());
    let g = GUARD.lock().unwrap_or_else(|p| p.into_inner());
    fault::disarm_all();
    g
}

struct Service {
    tx: Sender<ClassifyRequest>,
    ctrl: Arc<OverloadControl>,
    counters: Arc<ServeCounters>,
    thread: Option<JoinHandle<()>>,
}

impl Service {
    fn spawn(seed: u64, n_samples: usize, queue_depth: usize) -> Self {
        let svc = ServiceConfig {
            queue_depth,
            overload: OverloadConfig {
                default_cost: n_samples as u64,
                ..OverloadConfig::default()
            },
            ..ServiceConfig::default()
        };
        let ctrl = Arc::new(OverloadControl::new(svc.overload.clone(), svc.queue_depth));
        let counters = Arc::new(ServeCounters::default());
        let (tx, rx) = channel::<ClassifyRequest>(queue_depth);
        let (c2, k2) = (ctrl.clone(), counters.clone());
        let thread = std::thread::spawn(move || {
            let mut exec = SynthExecutor::new(seed, n_samples);
            run_service_loop(&mut exec, rx, &svc, &c2, &k2);
        });
        Self {
            tx,
            ctrl,
            counters,
            thread: Some(thread),
        }
    }

    /// One request/response round trip (each forms its own batch, keeping
    /// batch composition deterministic across faulted and control runs).
    fn roundtrip(&self, image: Vec<f32>) -> Result<ClassifyResult, anyhow::Error> {
        let (mut req, rx) = ClassifyRequest::new(image);
        req.deadline = None;
        self.tx.send(req).unwrap();
        rx.recv().expect("reply channel open")
    }

    fn roundtrip_deadline(
        &self,
        image: Vec<f32>,
        deadline: Instant,
    ) -> Result<ClassifyResult, anyhow::Error> {
        let (mut req, rx) = ClassifyRequest::new(image);
        req.deadline = Some(deadline);
        self.tx.send(req).unwrap();
        rx.recv().expect("reply channel open")
    }

    fn shutdown(mut self) {
        self.tx.close();
        self.thread.take().unwrap().join().unwrap();
    }
}

fn mean_bits(r: &ClassifyResult) -> Vec<u32> {
    r.predictive.mean_probs.iter().map(|p| p.to_bits()).collect()
}

#[test]
fn injected_panic_is_isolated_and_survivors_replay_bitwise() {
    let _g = harness();
    let img = |v: f32| vec![v; 4];

    let svc = Service::spawn(42, 5, 16);
    // healthy batch before the fault
    let r1 = svc.roundtrip(img(0.1)).unwrap();

    // poison exactly the next batch
    fault::arm("synth.classify", Fault::Panic, Trigger::Nth(1));
    let err = svc.roundtrip(img(0.2)).unwrap_err();
    fault::disarm("synth.classify");
    let se = err.downcast_ref::<ServeError>().expect("typed error");
    assert!(
        matches!(se, ServeError::Internal { .. }),
        "panicked batch answers internal_error, got {se:?}"
    );
    assert_eq!(se.code(), "internal_error");

    // the engine thread survived and keeps serving
    let r3 = svc.roundtrip(img(0.3)).unwrap();
    assert_eq!(svc.counters.panics_recovered.load(Ordering::Relaxed), 1);
    svc.shutdown();

    // pre-fault output replays bitwise against an unfaulted run
    let control = Service::spawn(42, 5, 16);
    let c1 = control.roundtrip(img(0.1)).unwrap();
    assert_eq!(mean_bits(&r1), mean_bits(&c1), "pre-fault output diverged");
    control.shutdown();

    // recovery rebuilds from seed: the post-recovery output is bitwise
    // identical to a freshly built engine serving the same request
    let fresh = Service::spawn(42, 5, 16);
    let f3 = fresh.roundtrip(img(0.3)).unwrap();
    assert_eq!(
        mean_bits(&r3),
        mean_bits(&f3),
        "post-recovery output is not a bitwise replay of a fresh engine"
    );
    fresh.shutdown();
}

#[test]
fn injected_io_error_answers_that_batch_without_killing_the_engine() {
    let _g = harness();
    let svc = Service::spawn(7, 4, 16);
    fault::arm("synth.classify", Fault::IoError, Trigger::Nth(1));
    let err = svc.roundtrip(vec![0.5; 4]).unwrap_err();
    fault::disarm("synth.classify");
    assert!(err.to_string().contains("injected IO fault"), "{err}");
    // no panic happened, and the loop keeps serving
    assert_eq!(svc.counters.panics_recovered.load(Ordering::Relaxed), 0);
    assert!(svc.roundtrip(vec![0.5; 4]).is_ok());
    svc.shutdown();
}

#[test]
fn overload_sheds_typed_and_never_hangs() {
    let _g = harness();
    // engine crawls: every simulated sample takes 20 ms
    fault::arm("synth.sample", Fault::DelayMs(20), Trigger::Always);
    let depth = 2;
    let svc = Service::spawn(3, 10, depth);

    // flood at well over 2x capacity; admission must answer every request
    // immediately — accepted or typed-overloaded — without blocking
    let mut replies: Vec<Receiver<Result<ClassifyResult, anyhow::Error>>> = Vec::new();
    let mut rejected = 0u32;
    for _ in 0..12 {
        let (req, rx) = ClassifyRequest::new(vec![0.2; 4]);
        let t0 = Instant::now();
        match submit_with_admission(&svc.tx, &svc.ctrl, &svc.counters, 0, req) {
            Ok(()) => replies.push(rx),
            Err(e) => {
                let se = e.downcast_ref::<ServeError>().expect("typed error");
                match se {
                    ServeError::Overloaded { retry_after_ms } => {
                        assert!(*retry_after_ms >= 1, "retry hint present");
                    }
                    other => panic!("expected overloaded, got {other:?}"),
                }
                rejected += 1;
            }
        }
        assert!(
            t0.elapsed() < Duration::from_secs(1),
            "admission decision must not block"
        );
    }
    assert!(rejected > 0, "2x+ overload must shed something");
    assert!(
        svc.counters.overload_rejects.load(Ordering::Relaxed) >= u64::from(rejected)
    );

    // every admitted request still gets an answer (bounded, no hang)
    for rx in replies {
        assert!(rx.recv().expect("reply delivered").is_ok());
    }
    fault::disarm("synth.sample");
    svc.shutdown();
}

#[test]
fn deadline_mid_run_reports_partial_samples() {
    let _g = harness();
    // 50-sample budget at 5 ms per sample = 250 ms of work against a
    // 30 ms deadline: the run must stop at a chunk boundary partway in
    fault::arm("synth.sample", Fault::DelayMs(5), Trigger::Always);
    let svc = Service::spawn(9, 50, 16);
    let err = svc
        .roundtrip_deadline(vec![0.4; 4], Instant::now() + Duration::from_millis(30))
        .unwrap_err();
    fault::disarm("synth.sample");
    match err.downcast_ref::<ServeError>() {
        Some(ServeError::DeadlineExceeded { samples_used }) => {
            assert!(
                *samples_used > 0 && *samples_used < 50,
                "expected partial spend, got {samples_used}"
            );
        }
        other => panic!("expected DeadlineExceeded, got {other:?}"),
    }
    assert!(svc.counters.deadline_expired.load(Ordering::Relaxed) >= 1);
    // the engine is free again immediately for well-budgeted requests
    let ok = svc
        .roundtrip_deadline(vec![0.4; 4], Instant::now() + Duration::from_secs(30))
        .unwrap();
    assert_eq!(ok.samples_used, 50);
    svc.shutdown();
}

#[test]
fn default_budget_without_fixture_faults_is_clean() {
    // sanity for the harness itself: with nothing armed the loop behaves
    // exactly like the unfaulted service-layer tests
    let _g = harness();
    let svc = Service::spawn(1, 3, 8);
    let r = svc.roundtrip(vec![0.9; 4]).unwrap();
    assert_eq!(r.samples_used, 3);
    assert!(!r.degraded);
    assert_eq!(svc.counters.requests_shed.load(Ordering::Relaxed), 0);
    svc.shutdown();
}
