//! Cluster chaos suite (`--features fault-injection`): proves the
//! coordinator loses no request, answers none twice, and reproduces a
//! healthy cluster **bitwise** across every injected failure mode —
//! worker crashes mid-batch, dead workers, stalls (hedged), garbage
//! responses, and entropy-degraded workers (drained from routing).
//!
//! Every chaos run is compared against a fault-free *control* cluster
//! built from workers with different private seeds: because a request's
//! plan seed is `lane_seed(cluster_seed, placement)`, the two runs must
//! agree bit for bit no matter which worker (or failover/hedge path)
//! served each placement.
//!
//! Fault points are process-global, so tests are serialized through
//! `harness()` (same idiom as `chaos.rs`).

#![cfg(feature = "fault-injection")]

use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use photonic_bayes::cluster::{
    self, ClusterConfig, WorkerGuard, WorkerOptions, WorkerPool, WorkerState,
};
use photonic_bayes::coordinator::{
    ClassifyRequest, ClassifyResult, EngineHandle, ServiceConfig,
};
use photonic_bayes::entropy::health::{HealthConfig, Monitor};
use photonic_bayes::entropy::Xoshiro256pp;
use photonic_bayes::observe::{ObserveConfig, Stage};
use photonic_bayes::server::{Client, ClientConfig};
use photonic_bayes::util::fault::{self, Fault, Trigger};

/// Serialize tests that arm global fault points (and disarm any residue
/// a previous test left behind, even if it panicked mid-assert).
fn harness() -> MutexGuard<'static, ()> {
    static GUARD: Mutex<()> = Mutex::new(());
    let g = GUARD.lock().unwrap_or_else(|p| p.into_inner());
    fault::disarm_all();
    g
}

fn fast_client() -> ClientConfig {
    ClientConfig {
        connect_timeout: Duration::from_millis(500),
        read_timeout: Duration::from_secs(5),
        ..ClientConfig::default()
    }
}

fn test_cfg() -> ClusterConfig {
    ClusterConfig {
        probe_interval: Duration::ZERO, // probes driven by hand
        client: fast_client(),
        ..ClusterConfig::default()
    }
}

fn image(k: usize) -> Vec<f32> {
    (0..4).map(|i| ((k * 4 + i) as f32) * 0.013).collect()
}

fn bits(r: &ClassifyResult) -> Vec<u32> {
    r.predictive.mean_probs.iter().map(|p| p.to_bits()).collect()
}

struct TestCluster {
    workers: Vec<WorkerGuard>,
    handle: EngineHandle,
    pool: Arc<WorkerPool>,
}

impl TestCluster {
    fn spawn(cfg: ClusterConfig, worker_opts: Vec<WorkerOptions>) -> Self {
        Self::spawn_svc(cfg, worker_opts, ServiceConfig::default())
    }

    fn spawn_svc(cfg: ClusterConfig, worker_opts: Vec<WorkerOptions>, svc: ServiceConfig) -> Self {
        let workers: Vec<WorkerGuard> = worker_opts
            .into_iter()
            .map(|o| cluster::spawn_local_worker(o).expect("spawn worker"))
            .collect();
        let addrs = workers.iter().map(|w| w.addr.clone()).collect();
        let (handle, pool) =
            cluster::spawn_coordinator(cfg, addrs, svc).expect("spawn coordinator");
        Self {
            workers,
            handle,
            pool,
        }
    }

    fn spawn_pair(cfg: ClusterConfig, seeds: [u64; 2]) -> Self {
        let opts = seeds
            .iter()
            .map(|&seed| WorkerOptions {
                seed,
                ..WorkerOptions::default()
            })
            .collect();
        Self::spawn(cfg, opts)
    }

    /// Classify exactly-once: submit, take the single reply, and prove
    /// no second one can ever arrive.
    fn classify_once(&self, im: Vec<f32>) -> ClassifyResult {
        let (req, rx) = ClassifyRequest::new(im);
        self.handle.submit(req).expect("admit");
        let first = rx
            .recv()
            .expect("request must be answered")
            .expect("request must succeed");
        assert!(rx.recv().is_none(), "request answered twice");
        first
    }

    fn shutdown(self) {
        self.handle.shutdown();
        drop(self.workers);
    }
}

/// Fault-free reference run: same cluster seed, *different* worker
/// seeds — the bitwise yardstick every chaos run must match.
fn control_bits(cfg: &ClusterConfig, images: &[Vec<f32>]) -> Vec<Vec<u32>> {
    fault::disarm_all();
    let c = TestCluster::spawn_pair(cfg.clone(), [101, 102]);
    let out = images
        .iter()
        .map(|im| bits(&c.classify_once(im.clone())))
        .collect();
    c.shutdown();
    out
}

#[test]
fn worker_kill_mid_batch_loses_nothing_and_replays_bitwise() {
    let _g = harness();
    let cfg = test_cfg();
    let images: Vec<Vec<f32>> = (0..4).map(image).collect();
    let control = control_bits(&cfg, &images);

    let c = TestCluster::spawn_pair(cfg, [1, 2]);
    // the 2nd classify line to reach any worker gateway drops the
    // connection with no response — a mid-batch worker crash
    fault::arm("worker.kill", Fault::IoError, Trigger::Nth(2));
    let got: Vec<Vec<u32>> = images
        .iter()
        .map(|im| bits(&c.classify_once(im.clone())))
        .collect();
    assert!(fault::hits("worker.kill") >= 2, "fault actually traversed");
    fault::disarm_all();
    assert_eq!(
        got, control,
        "failover must reproduce the healthy cluster bitwise"
    );
    c.shutdown();
}

#[test]
fn dead_worker_is_drained_within_one_probe_and_rerouted_bitwise() {
    let _g = harness();
    let cfg = test_cfg();
    let images: Vec<Vec<f32>> = (0..4).map(image).collect();
    let control = control_bits(&cfg, &images);

    let mut c = TestCluster::spawn_pair(cfg, [3, 4]);
    // kill worker 1 outright (process death, not a protocol fault)
    c.workers.pop().expect("two workers").stop();
    // one probe interval is enough to take it out of routing
    c.pool.probe_all();
    let card = &c.pool.cards()[1];
    assert_ne!(card.state, WorkerState::Healthy, "dead worker drained");
    assert!(card.consecutive_fails >= 1);

    let got: Vec<Vec<u32>> = images
        .iter()
        .map(|im| bits(&c.classify_once(im.clone())))
        .collect();
    assert_eq!(got, control, "survivor must replay every placement bitwise");
    c.shutdown();
}

#[test]
fn entropy_degraded_worker_is_drained_and_skipped() {
    let _g = harness();
    let cfg = test_cfg();
    let images: Vec<Vec<f32>> = (0..4).map(image).collect();
    let control = control_bits(&cfg, &images);

    // worker 1 carries a monitor already in the degraded state (80/20
    // biased bits fail the battery inside one 512-bit window)
    let mon = Arc::new(Monitor::new(HealthConfig {
        enabled: true,
        window_bits: 512,
        duty: 1.0,
        ewma_alpha: 1.0,
        fail_threshold: 0.6,
        fail_consecutive: 1,
        ..HealthConfig::default()
    }));
    let mut rng = Xoshiro256pp::new(7);
    let biased: Vec<u8> = (0..512).map(|_| u8::from(rng.next_f64() < 0.8)).collect();
    mon.ingest_bits(0, "synth-s0", &biased);
    assert!(mon.any_degraded());

    let c = TestCluster::spawn(
        cfg,
        vec![
            WorkerOptions {
                seed: 5,
                ..WorkerOptions::default()
            },
            WorkerOptions {
                seed: 6,
                health: Some(mon),
                ..WorkerOptions::default()
            },
        ],
    );
    // spawn_coordinator's inline first probe already scraped /info
    let cards = c.pool.cards();
    assert!(cards[1].entropy_degraded, "scorecard folded into the card");
    assert_eq!(cards[1].state, WorkerState::Suspect, "drained from routing");
    assert_eq!(cards[0].state, WorkerState::Healthy);

    // all traffic lands on the healthy worker — and still replays
    let got: Vec<Vec<u32>> = images
        .iter()
        .map(|im| bits(&c.classify_once(im.clone())))
        .collect();
    assert_eq!(got, control);
    assert_eq!(
        c.pool.cards()[1].state,
        WorkerState::Suspect,
        "degraded worker stays drained (no success notes revived it)"
    );
    c.shutdown();
}

#[test]
fn straggler_is_hedged_and_first_response_wins() {
    let _g = harness();
    let cfg = ClusterConfig {
        hedge_min: Duration::from_millis(10),
        ..test_cfg()
    };
    let images: Vec<Vec<f32>> = (0..2).map(image).collect();
    let control = control_bits(&cfg, &images);

    let c = TestCluster::spawn_pair(cfg, [8, 9]);
    // the first classify line to reach a worker stalls well past the
    // hedge delay; the hedge on the other worker must win the race
    fault::arm("worker.stall", Fault::DelayMs(400), Trigger::Nth(1));
    let t0 = Instant::now();
    let first = bits(&c.classify_once(images[0].clone()));
    let elapsed = t0.elapsed();
    fault::disarm_all();
    assert!(
        elapsed < Duration::from_millis(300),
        "hedge should beat the 400ms straggler, took {elapsed:?}"
    );
    let second = bits(&c.classify_once(images[1].clone()));
    assert_eq!(vec![first, second], control, "hedged answers replay bitwise");
    c.shutdown();
}

/// A [`ServiceConfig`] with span recording on (defaults otherwise).
fn traced_svc() -> ServiceConfig {
    ServiceConfig {
        observe: ObserveConfig::enabled(),
        ..ServiceConfig::default()
    }
}

#[test]
fn trace_stitches_across_failover() {
    let _g = harness();
    let cfg = test_cfg();
    let images: Vec<Vec<f32>> = (0..1).map(image).collect();
    let control = control_bits(&cfg, &images);

    // tracing on at BOTH hops: the coordinator records its spans, and the
    // request id rides the wire so the serving worker's recorder files
    // its own spans under the same id — one stitched trace
    let worker_opts: Vec<WorkerOptions> = [21u64, 22]
        .iter()
        .map(|&seed| WorkerOptions {
            seed,
            svc: traced_svc(),
            ..WorkerOptions::default()
        })
        .collect();
    let c = TestCluster::spawn_svc(cfg, worker_opts, traced_svc());
    // the first classify line to reach a worker drops the connection:
    // the primary dies mid-request and the dispatcher fails over
    fault::arm("worker.kill", Fault::IoError, Trigger::Nth(1));
    let (mut req, rx) = ClassifyRequest::new(images[0].clone());
    req.request_id = 777;
    c.handle.submit(req).expect("admit");
    let r = rx
        .recv()
        .expect("request must be answered")
        .expect("request must succeed");
    assert!(fault::hits("worker.kill") >= 1, "fault actually traversed");
    fault::disarm_all();
    assert_eq!(bits(&r), control[0], "traced failover still replays bitwise");

    // coordinator side: the failed attempt is annotated, and the remote
    // dispatch (failover included) is accounted as the request's chunk
    let spans = c.handle.recorder.spans_for(777);
    assert!(
        spans.iter().any(|s| s.stage == Stage::Failover),
        "failover annotation missing: {spans:?}"
    );
    assert!(spans.iter().any(|s| s.stage == Stage::Queue), "{spans:?}");
    assert!(spans.iter().any(|s| s.stage == Stage::Chunk), "{spans:?}");

    // worker side: the `trace` verb on the survivor returns spans for the
    // same id (the killed primary never served it, so exactly one worker
    // holds them)
    let mut worker_spans = 0usize;
    for w in &c.workers {
        let mut cl = Client::connect(&w.addr).expect("dial worker");
        let j = cl.trace(Some(777)).expect("trace verb");
        worker_spans += j
            .get("spans")
            .and_then(|v| v.as_arr())
            .map_or(0, |a| a.len());
    }
    assert!(
        worker_spans > 0,
        "the request id must stitch into the serving worker's trace"
    );
    c.shutdown();
}

#[test]
fn trace_marks_hedge() {
    let _g = harness();
    let cfg = ClusterConfig {
        hedge_min: Duration::from_millis(10),
        ..test_cfg()
    };
    let images: Vec<Vec<f32>> = (0..1).map(image).collect();
    let control = control_bits(&cfg, &images);

    let opts = [31u64, 32]
        .iter()
        .map(|&seed| WorkerOptions {
            seed,
            ..WorkerOptions::default()
        })
        .collect();
    let c = TestCluster::spawn_svc(cfg, opts, traced_svc());
    // the primary stalls well past the hedge delay; the hedge wins and
    // the trace records where the duplicate attempt went
    fault::arm("worker.stall", Fault::DelayMs(400), Trigger::Nth(1));
    let (mut req, rx) = ClassifyRequest::new(images[0].clone());
    req.request_id = 778;
    c.handle.submit(req).expect("admit");
    let r = rx
        .recv()
        .expect("request must be answered")
        .expect("request must succeed");
    fault::disarm_all();
    assert_eq!(bits(&r), control[0], "hedged answer replays bitwise");
    let spans = c.handle.recorder.spans_for(778);
    assert!(
        spans.iter().any(|s| s.stage == Stage::Hedge),
        "hedge annotation missing: {spans:?}"
    );
    c.shutdown();
}

#[test]
fn garbage_response_fails_over_bitwise() {
    let _g = harness();
    let cfg = test_cfg();
    let images: Vec<Vec<f32>> = (0..2).map(image).collect();
    let control = control_bits(&cfg, &images);

    let c = TestCluster::spawn_pair(cfg, [12, 13]);
    // the first classify answer is a non-protocol line: the dispatcher
    // must treat it as a transport fault and fail over, not surface it
    fault::arm("worker.garbage", Fault::IoError, Trigger::Nth(1));
    let got: Vec<Vec<u32>> = images
        .iter()
        .map(|im| bits(&c.classify_once(im.clone())))
        .collect();
    assert!(fault::hits("worker.garbage") >= 1);
    fault::disarm_all();
    assert_eq!(got, control, "corruption must never change an answer");
    c.shutdown();
}
