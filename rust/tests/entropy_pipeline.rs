//! Integration: the decoupled entropy pipeline.
//!
//! Contract (README §Performance): for a fixed `(seed, threads)`, a backend
//! with `PrefetchMode::On` (background producers + SPSC block rings) is
//! **bitwise identical** to `PrefetchMode::Sync` (the same banked streams
//! drawn synchronously at consumption time).  The digital backend is
//! additionally identical to `PrefetchMode::Off` (its shard streams are
//! unchanged by the pipeline).  Photonic prefetched weight-plane banks are
//! invalidated by any reprogramming.  None of these tests need model
//! artifacts.

use std::sync::Arc;

use photonic_bayes::backend::{
    self, BackendKind, PipelineOptions, PrefetchMode, ProbConvBackend, SamplePlan,
};
use photonic_bayes::exec::ring;
use photonic_bayes::exec::{CancelToken, ThreadPool};
use photonic_bayes::photonics::{MachineConfig, TapTarget};
use photonic_bayes::util::mathstat::{mean_f32, std_f32};

fn quiet_cfg(seed: u64) -> MachineConfig {
    MachineConfig {
        rx_noise: 0.0,
        actuator_sigma: 0.0,
        actuator_jitter: 0.0,
        ripple_rms_ps: 0.0,
        seed,
        ..MachineConfig::default()
    }
}

fn kernels(c: usize) -> Vec<Vec<TapTarget>> {
    (0..c)
        .map(|i| {
            let mu = 0.2 + 0.1 * i as f32;
            vec![TapTarget { mu, sigma: 0.5 * mu }; 9]
        })
        .collect()
}

fn test_input(plan: &SamplePlan) -> Vec<f32> {
    (0..plan.sample_size())
        .map(|i| 0.3 * ((i % 11) as f32) / 3.0)
        .collect()
}

/// Build a backend at (kind, threads, mode), program it, run the plan twice
/// (two consecutive calls: the second exercises stream continuation), and
/// return both outputs concatenated.
fn run_twice(
    kind: BackendKind,
    threads: usize,
    mode: PrefetchMode,
    plan: &SamplePlan,
    x: &[f32],
    seed: u64,
) -> Vec<f32> {
    let pool = (threads > 1).then(|| Arc::new(ThreadPool::new(threads)));
    let popts = PipelineOptions {
        mode,
        // small blocks + shallow rings on purpose: more boundary crossings
        block: 256,
        depth: 2,
    };
    let mut be = backend::build_with_opts(kind, &quiet_cfg(seed), pool, popts);
    be.program(&kernels(plan.channels), false).unwrap();
    let mut out = vec![0.0f32; plan.total_size() * 2];
    let (a, b) = out.split_at_mut(plan.total_size());
    be.sample_conv(plan, x, a).unwrap();
    be.sample_conv(plan, x, b).unwrap();
    out
}

#[test]
fn prefetch_on_matches_sync_fallback_bitwise_per_backend_and_threads() {
    let plan = SamplePlan::new(6, 4, 2, 5, 5);
    let x = test_input(&plan);
    for kind in [BackendKind::Digital, BackendKind::Photonic] {
        for threads in [1usize, 2, 4] {
            let sync = run_twice(kind, threads, PrefetchMode::Sync, &plan, &x, 33);
            let piped = run_twice(kind, threads, PrefetchMode::On, &plan, &x, 33);
            assert_eq!(
                sync, piped,
                "{kind} t={threads}: prefetch-on must equal the sync fallback"
            );
            assert!(sync.iter().any(|&v| v != 0.0), "{kind} t={threads}: non-trivial output");
        }
    }
}

#[test]
fn digital_pipeline_is_bitwise_identical_to_inline_path() {
    // the digital backend's draws are independent of the programmed
    // targets, so all three modes share one stream organization
    let plan = SamplePlan::new(5, 3, 2, 4, 4);
    let x = test_input(&plan);
    for threads in [1usize, 4] {
        let off = run_twice(BackendKind::Digital, threads, PrefetchMode::Off, &plan, &x, 11);
        let sync = run_twice(BackendKind::Digital, threads, PrefetchMode::Sync, &plan, &x, 11);
        let on = run_twice(BackendKind::Digital, threads, PrefetchMode::On, &plan, &x, 11);
        assert_eq!(off, sync, "t={threads}");
        assert_eq!(off, on, "t={threads}");
    }
}

#[test]
fn prefetched_runs_replay_bitwise_and_are_statistically_equivalent_to_inline() {
    let plan = SamplePlan::new(32, 4, 2, 5, 5);
    let x = test_input(&plan);
    for kind in [BackendKind::Digital, BackendKind::Photonic] {
        // replay determinism at a fixed (seed, threads, prefetch)
        let a = run_twice(kind, 2, PrefetchMode::On, &plan, &x, 7);
        let b = run_twice(kind, 2, PrefetchMode::On, &plan, &x, 7);
        assert_eq!(a, b, "{kind}: prefetch-on must replay bitwise");

        // the banked stream organization is a different draw order than the
        // inline path, but the same physics: moments must agree
        let inline = run_twice(kind, 1, PrefetchMode::Off, &plan, &x, 7);
        let (m_ref, s_ref) = (mean_f32(&inline), std_f32(&inline));
        let (m, s) = (mean_f32(&a), std_f32(&a));
        assert!(s_ref > 0.0, "{kind}: stochastic backend must fluctuate");
        assert!(
            (m - m_ref).abs() < 0.02 + 0.05 * s_ref,
            "{kind}: prefetched mean {m} vs inline {m_ref}"
        );
        assert!(
            (s - s_ref).abs() < 0.1 * s_ref + 0.01,
            "{kind}: prefetched std {s} vs inline {s_ref}"
        );
    }
}

#[test]
fn photonic_bank_invalidated_on_reprogram_with_pipeline_running() {
    // program A, sample (producers now hold planes drawn against A),
    // reprogram to B: the next sample must reflect B in both engines, and
    // the two engines must stay bitwise identical through the transition
    let plan = SamplePlan::new(4, 2, 1, 4, 4);
    let x = vec![2.0f32; plan.sample_size()];
    let k_pos = vec![vec![TapTarget { mu: 0.6, sigma: 0.2 }; 9]];
    let k_neg = vec![vec![TapTarget { mu: -0.6, sigma: 0.2 }; 9]];
    let mut outs = Vec::new();
    for mode in [PrefetchMode::Sync, PrefetchMode::On] {
        let mut be = backend::build_with_opts(
            BackendKind::Photonic,
            &quiet_cfg(21),
            None,
            PipelineOptions {
                mode,
                block: 128,
                depth: 2,
            },
        );
        be.program(&k_pos, false).unwrap();
        let mut first = vec![0.0f32; plan.total_size()];
        be.sample_conv(&plan, &x, &mut first).unwrap();
        be.program(&k_neg, false).unwrap();
        let mut second = vec![0.0f32; plan.total_size()];
        be.sample_conv(&plan, &x, &mut second).unwrap();
        let mean = |v: &[f32]| v.iter().map(|&y| y as f64).sum::<f64>() / v.len() as f64;
        assert!(mean(&first) > 0.5, "{mode}: first program positive");
        assert!(mean(&second) < -0.5, "{mode}: stale prefetched planes leaked");
        outs.push((first, second));
    }
    assert_eq!(outs[0], outs[1], "sync and prefetch-on agree across reprogram");
}

#[test]
fn backend_drop_with_live_producers_does_not_deadlock_or_leak() {
    // producers are parked on full rings at drop time; drop must cancel,
    // unblock, and join them — repeatedly, at several shapes
    let plan = SamplePlan::new(2, 1, 2, 3, 3);
    let x = test_input(&plan);
    for threads in [1usize, 4] {
        for kind in [BackendKind::Digital, BackendKind::Photonic] {
            let pool = (threads > 1).then(|| Arc::new(ThreadPool::new(threads)));
            let mut be = backend::build_with_opts(
                kind,
                &quiet_cfg(3),
                pool,
                PipelineOptions {
                    mode: PrefetchMode::On,
                    block: 64,
                    depth: 2,
                },
            );
            be.program(&kernels(plan.channels), false).unwrap();
            let mut out = vec![0.0f32; plan.total_size()];
            be.sample_conv(&plan, &x, &mut out).unwrap();
            drop(be); // must return promptly (joins all producer threads)
        }
    }
}

#[test]
fn ring_stress_no_lost_or_reordered_blocks_under_cancellation() {
    // a torrent of sequence-numbered blocks through a tiny ring with the
    // producer cancelled at a random-ish point: the consumer must observe
    // a gapless prefix
    for trial in 0..20u64 {
        let (mut tx, mut rx) = ring::ring::<Vec<u64>>(2);
        let cancel = CancelToken::new();
        let cancel_p = cancel.clone();
        let producer = std::thread::spawn(move || {
            let mut seq = 0u64;
            loop {
                let block: Vec<u64> = (seq * 16..(seq + 1) * 16).collect();
                if tx.push_blocking(block, &cancel_p).is_err() {
                    return seq; // cancelled or consumer gone
                }
                seq += 1;
            }
        });
        let mut expect = 0u64;
        for _ in 0..(trial * 7 % 40) {
            match rx.pop_blocking() {
                Some(block) => {
                    let want: Vec<u64> = (expect * 16..(expect + 1) * 16).collect();
                    assert_eq!(block, want, "trial {trial}: gapless in-order blocks");
                    expect += 1;
                }
                None => break,
            }
        }
        cancel.cancel();
        let pushed = producer.join().unwrap();
        // whatever was pushed but not popped is still there, in order
        while let Some(block) = rx.pop_blocking() {
            let want: Vec<u64> = (expect * 16..(expect + 1) * 16).collect();
            assert_eq!(block, want, "trial {trial}: tail drains in order");
            expect += 1;
        }
        assert_eq!(expect, pushed, "trial {trial}: every pushed block arrived");
    }
}
