//! End-to-end cluster-mode tests (no fault injection — the chaos side
//! lives in `cluster_chaos.rs` behind `--features fault-injection`).
//!
//! The three contracts under test:
//! 1. a request's answer is a pure function of `(seed, placement)` —
//!    two clusters built from workers with *different* private seeds
//!    reproduce each other bitwise;
//! 2. the coordinator's gateway speaks the full protocol: `hello`
//!    answers role `coordinator`, `/info` carries per-worker cluster
//!    cards and the serving latency percentiles;
//! 3. a flood sheds with a typed `overloaded` + `retry_after_ms` from
//!    *cluster* capacity (two workers admit strictly more than one
//!    worker's queue), and every admitted request is answered exactly
//!    once.

use std::time::Duration;

use photonic_bayes::cluster::{self, ClusterConfig, WorkerGuard, WorkerOptions};
use photonic_bayes::coordinator::{
    ClassifyRequest, ClassifyResult, Router, ServeError, ServiceConfig,
};
use photonic_bayes::exec::CancelToken;
use photonic_bayes::server::{serve, Client, ClientConfig, ServerOptions};

fn fast_client() -> ClientConfig {
    ClientConfig {
        connect_timeout: Duration::from_millis(500),
        ..ClientConfig::default()
    }
}

fn test_cfg() -> ClusterConfig {
    ClusterConfig {
        // tests drive probes explicitly
        probe_interval: Duration::ZERO,
        client: fast_client(),
        ..ClusterConfig::default()
    }
}

fn image(k: usize) -> Vec<f32> {
    (0..4).map(|i| ((k * 4 + i) as f32) * 0.017).collect()
}

/// Bitwise fingerprint of a result's predictive distribution.
fn bits(r: &ClassifyResult) -> Vec<u32> {
    r.predictive.mean_probs.iter().map(|p| p.to_bits()).collect()
}

fn spawn_pair(seeds: [u64; 2], opts: WorkerOptions) -> Vec<WorkerGuard> {
    seeds
        .iter()
        .map(|&seed| {
            cluster::spawn_local_worker(WorkerOptions {
                seed,
                ..opts.clone()
            })
            .expect("spawn worker")
        })
        .collect()
}

fn addrs_of(workers: &[WorkerGuard]) -> Vec<String> {
    workers.iter().map(|w| w.addr.clone()).collect()
}

#[test]
fn answers_are_worker_independent_and_replay_bitwise() {
    let images: Vec<Vec<f32>> = (0..4).map(image).collect();
    let run = |worker_seeds: [u64; 2]| -> Vec<Vec<u32>> {
        let workers = spawn_pair(worker_seeds, WorkerOptions::default());
        let (handle, _pool) =
            cluster::spawn_coordinator(test_cfg(), addrs_of(&workers), ServiceConfig::default())
                .expect("spawn coordinator");
        let out = images
            .iter()
            .map(|im| bits(&handle.classify_blocking(im.clone()).expect("classify")))
            .collect();
        handle.shutdown();
        out
    };
    // same cluster seed, wildly different worker-private seeds: the
    // plan-seeded shard path must make worker identity irrelevant
    let a = run([1, 2]);
    let b = run([91, 92]);
    assert_eq!(a, b, "answers must depend on (seed, placement), not workers");
    // while distinct placements still get distinct entropy streams
    assert_ne!(a[0], a[1], "placements must not share a stream");
}

#[test]
fn coordinator_gateway_reports_cluster_cards_and_percentiles() {
    let workers = spawn_pair([5, 6], WorkerOptions::default());
    let (handle, pool) =
        cluster::spawn_coordinator(test_cfg(), addrs_of(&workers), ServiceConfig::default())
            .expect("spawn coordinator");
    let mut router = Router::new();
    router.set_role("coordinator");
    router.register(handle);
    let cancel = CancelToken::new();
    let cancel2 = cancel.clone();
    let (atx, arx) = std::sync::mpsc::channel();
    let gateway = std::thread::spawn(move || {
        let opts = ServerOptions {
            addr: "127.0.0.1:0".into(),
            workers: 2,
            ..ServerOptions::default()
        };
        serve(router, opts, cancel2, move |a| {
            let _ = atx.send(a);
        })
        .expect("coordinator gateway");
    });
    let addr = arx
        .recv_timeout(Duration::from_secs(5))
        .expect("gateway bind")
        .to_string();
    let mut client = Client::connect_with(&addr, fast_client()).expect("connect");

    // role handshake end to end
    assert_eq!(client.hello("client").expect("hello"), "coordinator");

    // real traffic through the whole stack...
    for k in 0..3 {
        let j = client.classify("synth", &image(k)).expect("classify");
        assert_eq!(j.get("ok").and_then(|v| v.as_bool()), Some(true), "{j:?}");
    }
    // ...then refresh the pool's scrape of the workers' /info
    pool.probe_all();

    let j = client.info().expect("info");
    let cards = j
        .get("cluster")
        .and_then(|c| c.get("synth"))
        .and_then(|v| v.as_arr())
        .unwrap_or_else(|| panic!("info missing cluster cards: {j:?}"));
    assert_eq!(cards.len(), 2);
    for card in cards {
        assert_eq!(card.get("state").and_then(|v| v.as_str()), Some("healthy"));
        assert_eq!(
            card.get("entropy_degraded").and_then(|v| v.as_bool()),
            Some(false)
        );
    }
    // the workers served shard traffic, so scraped percentiles are live
    assert!(
        cards
            .iter()
            .any(|c| c.get("p50_us").and_then(|v| v.as_f64()).unwrap_or(0.0) > 0.0),
        "worker percentiles should reflect served traffic: {cards:?}"
    );
    // and the coordinator's own serving section aggregates its latency
    let p50 = j
        .get("serving")
        .and_then(|s| s.get("synth"))
        .and_then(|s| s.get("p50_us"))
        .and_then(|v| v.as_f64())
        .unwrap_or(0.0);
    assert!(p50 > 0.0, "coordinator p50 after traffic: {j:?}");

    cancel.cancel();
    gateway.join().expect("gateway thread");
}

#[test]
fn flood_sheds_typed_overload_at_cluster_capacity() {
    // slow workers so the coordinator's queue actually fills
    let workers = spawn_pair(
        [21, 22],
        WorkerOptions {
            n_samples: 4,
            work_per_sample: Duration::from_millis(2),
            ..WorkerOptions::default()
        },
    );
    let cfg = ClusterConfig {
        n_samples: 4,
        ..test_cfg()
    };
    let svc = ServiceConfig {
        queue_depth: 4, // scaled ×2 workers by spawn_coordinator
        ..ServiceConfig::default()
    };
    let (handle, _pool) =
        cluster::spawn_coordinator(cfg, addrs_of(&workers), svc).expect("spawn coordinator");

    let mut admitted = Vec::new();
    let mut shed = 0u32;
    for k in 0..48 {
        let (req, rx) = ClassifyRequest::new(image(k % 4));
        match handle.submit(req) {
            Ok(()) => admitted.push(rx),
            Err(e) => match e.downcast_ref::<ServeError>() {
                Some(ServeError::Overloaded { retry_after_ms }) => {
                    assert!(*retry_after_ms >= 1, "retry hint present");
                    shed += 1;
                }
                other => panic!("expected overloaded, got {other:?}: {e:#}"),
            },
        }
    }
    assert!(shed > 0, "a 48-deep flood must shed");
    // admission reflects CLUSTER capacity: the scaled queue alone admits
    // two workers' worth (8) even before the engine drains anything
    assert!(
        admitted.len() >= 8,
        "cluster admission should exceed one worker's depth, admitted {}",
        admitted.len()
    );
    // no admitted request is lost — and none is answered twice
    for rx in admitted {
        let first = rx.recv().expect("admitted request must be answered");
        assert!(first.is_ok(), "{first:?}");
        assert!(
            rx.recv().is_none(),
            "a request must be answered exactly once"
        );
    }
    handle.shutdown();
}
