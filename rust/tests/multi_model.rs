//! Multi-model serving: bank-churn correctness at the backend boundary and
//! (artifact-gated) the two-model gateway demo end-to-end.
//!
//! The backend-level tests pin the registry's replay contract without any
//! artifacts: outputs replay bitwise per `(model, seed, threads, prefetch,
//! rule)` — a cache hit continues the model's streams exactly as if the
//! engine had never switched away, and an eviction + reload replays from
//! the model-mixed seed exactly like a cold single-model engine.

use std::sync::Arc;

use photonic_bayes::backend::{
    build_with_opts, BackendKind, PipelineOptions, PrefetchMode, ProbConvBackend, SamplePlan,
};
use photonic_bayes::photonics::{MachineConfig, TapTarget};
use photonic_bayes::registry::{ProgramKey, RegistryMetrics, Residency};

/// Noise-free machine: every divergence below is a real state bug, not rx
/// noise.
fn quiet_cfg(seed: u64) -> MachineConfig {
    MachineConfig {
        rx_noise: 0.0,
        actuator_sigma: 0.0,
        actuator_jitter: 0.0,
        ripple_rms_ps: 0.0,
        seed,
        ..MachineConfig::default()
    }
}

fn backend(kind: BackendKind, seed: u64, mode: PrefetchMode) -> Box<dyn ProbConvBackend> {
    build_with_opts(
        kind,
        &quiet_cfg(seed),
        None,
        PipelineOptions {
            mode,
            block: 128,
            depth: 2,
        },
    )
}

fn targets9(mu: f32, sigma: f32) -> Vec<Vec<TapTarget>> {
    vec![vec![TapTarget { mu, sigma }; 9]]
}

fn key(model: &str, cfg: &MachineConfig) -> ProgramKey {
    ProgramKey::new(model, cfg.seed, cfg.scale_dac, cfg.scale_adc)
}

fn sample(be: &mut dyn ProbConvBackend, plan: &SamplePlan, x: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; plan.total_size()];
    be.sample_conv(plan, x, &mut out).unwrap();
    out
}

fn mean_of(out: &[f32]) -> f64 {
    out.iter().map(|&v| v as f64).sum::<f64>() / out.len() as f64
}

/// Rapid switches with live background entropy producers: every sample must
/// come from the *active* model's program and bank generation.  A stale
/// bank would surface immediately as the wrong sign (the two models carry
/// opposite-sign kernels).
#[test]
fn rapid_switches_never_serve_a_stale_bank() {
    let cfg = quiet_cfg(4242);
    let plan = SamplePlan::new(2, 1, 1, 4, 4);
    let x = vec![0.5f32; plan.sample_size()];
    let (ka, kb) = (targets9(0.8, 0.05), targets9(-0.8, 0.05));
    for kind in [BackendKind::Photonic, BackendKind::Digital] {
        // On = background producer threads stay live across every switch
        let mut be = backend(kind, cfg.seed, PrefetchMode::On);
        let (key_a, key_b) = (key("a", &cfg), key("b", &cfg));
        for round in 0..4 {
            be.switch_program(&key_a, &ka, false).unwrap();
            let a = mean_of(&sample(&mut be, &plan, &x));
            assert!(a > 0.5, "{kind:?} round {round}: model a served {a}");
            be.switch_program(&key_b, &kb, false).unwrap();
            let b = mean_of(&sample(&mut be, &plan, &x));
            assert!(b < -0.5, "{kind:?} round {round}: model b served {b}");
        }
    }
}

/// Budget 0 evicts every parked model: each switch back is a miss that
/// rebuilds from the model-mixed seed, so outputs are bitwise identical to
/// a cold engine that only ever served that model.
#[test]
fn eviction_then_reload_replays_bitwise_like_a_cold_engine() {
    let cfg = quiet_cfg(99);
    let plan = SamplePlan::new(3, 1, 1, 4, 4);
    let x = vec![1.0f32; plan.sample_size()];
    let (ka, kb) = (targets9(0.5, 0.3), targets9(-0.5, 0.3));
    for kind in [BackendKind::Photonic, BackendKind::Digital] {
        for mode in [PrefetchMode::Sync, PrefetchMode::On] {
            let metrics = Arc::new(RegistryMetrics::default());
            metrics.register("a");
            metrics.register("b");
            let mut be = backend(kind, cfg.seed, mode);
            be.enable_model_cache(0, metrics.clone());
            let (key_a, key_b) = (key("a", &cfg), key("b", &cfg));
            be.switch_program(&key_a, &ka, false).unwrap();
            let a1 = sample(&mut be, &plan, &x);
            be.switch_program(&key_b, &kb, false).unwrap();
            let _b1 = sample(&mut be, &plan, &x);
            be.switch_program(&key_a, &ka, false).unwrap();
            let a2 = sample(&mut be, &plan, &x);

            // cold single-model reference: different machine seed on
            // purpose — the model-mixed key seed governs the streams
            let mut cold = backend(kind, 12345, mode);
            cold.switch_program(&key("a", &cfg), &ka, false).unwrap();
            let r1 = sample(&mut cold, &plan, &x);
            assert_eq!(a1, r1, "{kind:?}/{mode:?}: first serve == cold engine");
            assert_eq!(a2, r1, "{kind:?}/{mode:?}: evicted reload replays from seed");

            let snap = metrics.snapshot();
            assert_eq!(snap.switches, 3);
            assert_eq!(snap.misses, 3, "budget 0: every checkout misses");
            assert_eq!(snap.hits, 0);
            assert_eq!(snap.evictions, 2, "each park at budget 0 evicts");
            let a_card = snap.models.iter().find(|c| c.model == "a").unwrap();
            assert_eq!(a_card.state, Residency::Active);
            assert_eq!(a_card.switches_in, 2);
        }
    }
}

/// An unbounded budget keeps parked models resident: switching back is a
/// hit that *continues* the model's streams — bitwise what a single-model
/// engine that never switched away would have produced next.
#[test]
fn cache_hit_continues_streams_like_an_unswitched_engine() {
    let cfg = quiet_cfg(7);
    let plan = SamplePlan::new(3, 1, 1, 4, 4);
    let x = vec![1.0f32; plan.sample_size()];
    let (ka, kb) = (targets9(0.5, 0.3), targets9(-0.5, 0.3));
    for kind in [BackendKind::Photonic, BackendKind::Digital] {
        let metrics = Arc::new(RegistryMetrics::default());
        metrics.register("a");
        metrics.register("b");
        let mut be = backend(kind, cfg.seed, PrefetchMode::Sync);
        be.enable_model_cache(usize::MAX, metrics.clone());
        let (key_a, key_b) = (key("a", &cfg), key("b", &cfg));
        be.switch_program(&key_a, &ka, false).unwrap();
        let a1 = sample(&mut be, &plan, &x);
        be.switch_program(&key_b, &kb, false).unwrap();
        let _ = sample(&mut be, &plan, &x);
        be.switch_program(&key_a, &ka, false).unwrap();
        let a2 = sample(&mut be, &plan, &x);

        // reference engine serving only model a, continuously
        let mut solo = backend(kind, cfg.seed, PrefetchMode::Sync);
        solo.switch_program(&key("a", &cfg), &ka, false).unwrap();
        let r1 = sample(&mut solo, &plan, &x);
        let r2 = sample(&mut solo, &plan, &x);
        assert_eq!(a1, r1, "{kind:?}: identical cold start");
        assert_eq!(a2, r2, "{kind:?}: hit continues streams, no replay");
        assert_ne!(a1, a2, "{kind:?}: streams advance across the round trip");

        let snap = metrics.snapshot();
        assert_eq!(snap.hits, 1, "the switch back to a is a hit");
        assert_eq!(snap.evictions, 0);
        assert!(snap.resident_bytes > 0);
        let b_card = snap.models.iter().find(|c| c.model == "b").unwrap();
        assert_eq!(b_card.state, Residency::Resident, "b stays cached");
    }
}

// ---------------------------------------------------------------------------
// artifact-gated: the two-model gateway demo
// ---------------------------------------------------------------------------

mod gateway {
    use std::sync::{Arc, Mutex};
    use std::time::Duration;

    use photonic_bayes::bnn::UncertaintyPolicy;
    use photonic_bayes::coordinator::service::{EngineHandle, ServiceConfig};
    use photonic_bayes::coordinator::{EngineConfig, ExecMode, ModelSpec, Router};
    use photonic_bayes::exec::CancelToken;
    use photonic_bayes::photonics::MachineConfig;
    use photonic_bayes::runtime::artifact::artifacts_root;
    use photonic_bayes::runtime::ModelArtifacts;
    use photonic_bayes::server::{serve, Client, ServerOptions};

    fn have_artifacts() -> bool {
        let root = artifacts_root();
        root.join("digits/meta.json").exists() && root.join("blood/meta.json").exists()
    }

    /// One engine virtualized across two checkpoints, served over TCP: a
    /// single client session classifies against both models, `/info` shows
    /// both registered with residency counters, and an unknown model gets
    /// the typed coded error.
    #[test]
    fn two_model_engine_serves_both_over_one_session() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` (needs digits + blood)");
            return;
        }
        let root = artifacts_root();
        let digits_px = ModelArtifacts::load(&root.join("digits")).unwrap().meta.image_size();
        let blood_px = ModelArtifacts::load(&root.join("blood")).unwrap().meta.image_size();
        let mut router = Router::new();
        router.register(
            EngineHandle::spawn_multi(
                &root,
                vec![ModelSpec::named("digits"), ModelSpec::named("blood")],
                EngineConfig {
                    n_samples: 3,
                    mode: ExecMode::Surrogate,
                    policy: UncertaintyPolicy::ood_only(0.5),
                    calibrate: false,
                    machine: MachineConfig::default(),
                    noise_bw_ghz: 150.0,
                    threads: 2,
                    seed: 3,
                    ..Default::default()
                },
                ServiceConfig {
                    max_batch: 4,
                    max_wait: Duration::from_millis(1),
                    queue_depth: 32,
                    ..Default::default()
                },
            )
            .unwrap(),
        );

        let cancel = CancelToken::new();
        let bound: Arc<Mutex<Option<std::net::SocketAddr>>> = Arc::new(Mutex::new(None));
        let b2 = bound.clone();
        let c2 = cancel.clone();
        let server = std::thread::spawn(move || {
            serve(
                router,
                ServerOptions {
                    addr: "127.0.0.1:0".into(),
                    workers: 4,
                    ..Default::default()
                },
                c2,
                move |a| {
                    *b2.lock().unwrap() = Some(a);
                },
            )
        });
        let addr = loop {
            if let Some(a) = *bound.lock().unwrap() {
                break a;
            }
            std::thread::sleep(Duration::from_millis(5));
        };

        let mut client = Client::connect(&addr.to_string()).unwrap();
        // both models classify in one session (forces at least one switch)
        let r = client.classify("digits", &vec![0.4f32; digits_px]).unwrap();
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
        let r = client.classify("blood", &vec![0.4f32; blood_px]).unwrap();
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
        let r = client.classify("digits", &vec![0.2f32; digits_px]).unwrap();
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");

        // /info: both models registered, registry counters live
        let info = client.call("{\"op\":\"info\"}").unwrap();
        let models: Vec<String> = info
            .get("models")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_str().unwrap().to_string())
            .collect();
        assert!(models.contains(&"digits".to_string()), "{models:?}");
        assert!(models.contains(&"blood".to_string()), "{models:?}");
        let reg = info.get("registry").unwrap().get("digits").unwrap();
        assert!(reg.get("switches").unwrap().as_f64().unwrap() >= 2.0, "{reg:?}");
        let cards = reg.get("models").unwrap().as_arr().unwrap();
        assert_eq!(cards.len(), 2);
        for card in cards {
            let state = card.get("state").unwrap().as_str().unwrap();
            assert!(
                ["active", "resident", "evicted", "cold"].contains(&state),
                "{card:?}"
            );
        }

        // wrong image size for the *named* model is a per-request error
        let err = client.classify("blood", &vec![0.1f32; digits_px + 1]).unwrap();
        assert_eq!(err.get("ok").unwrap().as_bool(), Some(false));
        // unknown model: machine-readable code
        let err = client.classify("nope", &vec![0.1f32; 4]).unwrap();
        assert_eq!(err.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(err.get("code").unwrap().as_str(), Some("unknown_model"));
        // connection survives the errors
        assert!(client.ping().unwrap());

        cancel.cancel();
        server.join().unwrap().unwrap();
    }
}
