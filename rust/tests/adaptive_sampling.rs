//! Integration: adaptive sequential sampling — equivalence, determinism,
//! and statistical sanity.
//!
//! The contracts (README §Adaptive sampling):
//!
//! * incremental accumulation to full budget is **bitwise equal** to the
//!   one-shot `Predictive::from_batched_logits` aggregation;
//! * with `StopRule::Fixed` the engine issues the identical single batched
//!   `sample_conv` call, so classify outputs replay bit-identically per
//!   `(seed, threads, prefetch)`;
//! * at `threads = 1` a *chunked* run to full budget is bitwise identical
//!   to the one-shot call (persistent shard streams, same grid order);
//! * early-stop decisions are deterministic per `(seed, threads)` —
//!   replaying a run reproduces both outputs and `samples_used`;
//! * adaptive rules spend fewer samples on decisive inputs than ambiguous
//!   ones, and (artifact-gated) OOD AUROC at matched max budget is no
//!   worse than fixed-N sampling.
//!
//! Backend-level tests need no model artifacts; engine-level tests
//! self-skip when `meta.json` is absent (run `make artifacts`).

use std::sync::Arc;

use photonic_bayes::backend::{self, BackendKind, ProbConvBackend, SamplePlan};
use photonic_bayes::bnn::{Predictive, UncertaintyPolicy};
use photonic_bayes::coordinator::{Engine, EngineConfig, ExecMode};
use photonic_bayes::exec::ThreadPool;
use photonic_bayes::photonics::{MachineConfig, TapTarget};
use photonic_bayes::runtime::artifact::artifacts_root;
use photonic_bayes::runtime::{ModelArtifacts, ParamStore};
use photonic_bayes::sampler::{synth, PredictiveAccum, RequestBudget, SamplerConfig, StopRule};

fn quiet_cfg(seed: u64) -> MachineConfig {
    MachineConfig {
        rx_noise: 0.0,
        actuator_sigma: 0.0,
        actuator_jitter: 0.0,
        ripple_rms_ps: 0.0,
        seed,
        ..MachineConfig::default()
    }
}

fn kernels(c: usize) -> Vec<Vec<TapTarget>> {
    (0..c)
        .map(|i| {
            let mu = 0.25 + 0.1 * i as f32;
            vec![TapTarget { mu, sigma: 0.4 * mu }; 9]
        })
        .collect()
}

// ---------------------------------------------------------------------------
// accumulator equivalence
// ---------------------------------------------------------------------------

#[test]
fn incremental_accum_matches_batched_aggregation_bitwise() {
    // per-pass batch buffers of 4 images x 3 classes, 12 passes
    let mut state = 97u64;
    let mut next = || {
        state = state
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        ((state >> 33) as f32 / (1u64 << 31) as f32) * 6.0 - 3.0
    };
    let passes: Vec<Vec<f32>> = (0..12)
        .map(|_| (0..4 * 3).map(|_| next()).collect())
        .collect();
    for image in 0..4 {
        let mut acc = PredictiveAccum::new(3);
        // uneven chunk boundaries: 1 + 2 + 5 + 4 passes
        for bounds in [0..1usize, 1..3, 3..8, 8..12] {
            for p in &passes[bounds] {
                acc.push_logits(&p[image * 3..(image + 1) * 3]);
            }
        }
        let a = acc.into_predictive();
        let b = Predictive::from_batched_logits(&passes, image, 3);
        assert_eq!(a.probs, b.probs, "image {image}: per-pass rows");
        assert_eq!(a.mean_probs, b.mean_probs, "image {image}: mean");
        assert_eq!(a.predicted, b.predicted);
        assert!(a.shannon_entropy == b.shannon_entropy, "H bitwise");
        assert!(a.softmax_entropy == b.softmax_entropy, "SE bitwise");
        assert!(a.mutual_information == b.mutual_information, "MI bitwise");
        assert!(a.agreement == b.agreement);
    }
}

// ---------------------------------------------------------------------------
// chunked backend execution
// ---------------------------------------------------------------------------

fn run_chunked(
    kind: BackendKind,
    threads: usize,
    chunks: &[usize],
    batch: usize,
    x: &[f32],
    seed: u64,
) -> Vec<f32> {
    let pool = (threads > 1).then(|| Arc::new(ThreadPool::new(threads)));
    let mut be = backend::build_with_pool(kind, &quiet_cfg(seed), pool);
    be.program(&kernels(2), false).unwrap();
    let mut out = Vec::new();
    for &chunk in chunks {
        let plan = SamplePlan::new(chunk, batch, 2, 5, 5);
        let mut part = vec![0.0f32; plan.total_size()];
        be.sample_conv(&plan, x, &mut part).unwrap();
        out.extend_from_slice(&part);
    }
    out
}

/// The schedule-level half of the fixed-rule compatibility claim: at one
/// worker the shard stream is consumed in grid order, so any chunking of
/// the budget concatenates to the one-shot call bit-for-bit.
#[test]
fn sequential_chunked_run_is_bitwise_identical_to_one_shot() {
    let batch = 2usize;
    let x: Vec<f32> = (0..batch * 2 * 25).map(|i| 0.3 * ((i % 11) as f32) / 3.0).collect();
    for kind in [BackendKind::Digital, BackendKind::Photonic] {
        let one_shot = run_chunked(kind, 1, &[10], batch, &x, 31);
        for chunks in [vec![2, 3, 5], vec![4, 4, 2], vec![1; 10]] {
            let chunked = run_chunked(kind, 1, &chunks, batch, &x, 31);
            assert_eq!(one_shot, chunked, "{kind:?} chunks {chunks:?}");
        }
    }
}

/// Sharded chunked runs replay bit-identically per `(seed, threads)` for a
/// fixed chunk sequence (the persistent per-shard streams are the only
/// state; the chunk sequence is itself deterministic given the outputs).
#[test]
fn chunked_runs_replay_bitwise_per_thread_count() {
    let batch = 2usize;
    let x: Vec<f32> = (0..batch * 2 * 25).map(|i| 0.2 * ((i % 7) as f32)).collect();
    for kind in [BackendKind::Digital, BackendKind::Photonic] {
        for threads in [1, 2, 4] {
            let a = run_chunked(kind, threads, &[4, 4, 2], batch, &x, 7);
            let b = run_chunked(kind, threads, &[4, 4, 2], batch, &x, 7);
            assert_eq!(a, b, "{kind:?} t={threads}");
        }
    }
}

// ---------------------------------------------------------------------------
// early-stop determinism + statistical sanity (synthetic classifier —
// shared harness `sampler::synth`, also measured by `paper_tables --
// adaptive`)
// ---------------------------------------------------------------------------

const MAX_N: usize = 16;

#[test]
fn early_stop_is_deterministic_per_thread_count() {
    let channels = 4usize;
    let easy = synth::decisive_input(channels);
    let hard = synth::ambiguous_input(channels);
    for kind in [BackendKind::Digital, BackendKind::Photonic] {
        for threads in [1usize, 2, 4] {
            let run = |x: &[f32]| {
                let pool = (threads > 1).then(|| Arc::new(ThreadPool::new(threads)));
                let mut be = backend::build_with_pool(kind, &quiet_cfg(11), pool);
                be.program(&synth::decisive_kernels(channels), false).unwrap();
                synth::classify_synthetic(
                    be.as_mut(),
                    &synth::gap_config(MAX_N),
                    threads,
                    channels,
                    MAX_N,
                    x,
                )
            };
            for x in [&easy, &hard] {
                let (used_a, probs_a) = run(x);
                let (used_b, probs_b) = run(x);
                assert_eq!(used_a, used_b, "{kind:?} t={threads}: samples_used replays");
                assert_eq!(probs_a, probs_b, "{kind:?} t={threads}: outputs replay");
            }
        }
    }
}

#[test]
fn adaptive_spends_fewer_samples_on_decisive_inputs() {
    let channels = 4usize;
    let easy = synth::decisive_input(channels);
    let hard = synth::ambiguous_input(channels);
    let gap = synth::gap_config(MAX_N);
    for kind in [BackendKind::Digital, BackendKind::Photonic] {
        let mut be = backend::build(kind, &quiet_cfg(3));
        be.program(&synth::decisive_kernels(channels), false).unwrap();
        let (easy_used, probs) =
            synth::classify_synthetic(be.as_mut(), &gap, 1, channels, MAX_N, &easy);
        let (hard_used, _) =
            synth::classify_synthetic(be.as_mut(), &gap, 1, channels, MAX_N, &hard);
        assert!(
            easy_used < MAX_N,
            "{kind:?}: decisive input must resolve early (used {easy_used})"
        );
        assert!(
            easy_used < hard_used,
            "{kind:?}: easy {easy_used} >= hard {hard_used}"
        );
        assert_eq!(
            hard_used, MAX_N,
            "{kind:?}: ambiguous input runs to the max budget"
        );
        let top: f32 = probs.iter().cloned().fold(f32::MIN, f32::max);
        assert!(top > 0.75, "{kind:?}: decisive posterior, got top {top}");
    }
    // the fixed rule pins the budget regardless of difficulty
    let mut be = backend::build(BackendKind::Digital, &quiet_cfg(3));
    be.program(&synth::decisive_kernels(channels), false).unwrap();
    let (used, _) = synth::classify_synthetic(
        be.as_mut(),
        &SamplerConfig::fixed(MAX_N),
        1,
        channels,
        MAX_N,
        &easy,
    );
    assert_eq!(used, MAX_N);
}

// ---------------------------------------------------------------------------
// budget validation (protocol/CLI boundary)
// ---------------------------------------------------------------------------

#[test]
fn hostile_budgets_are_typed_errors_not_panics() {
    use photonic_bayes::sampler::BudgetError;
    // zero budgets
    assert!(matches!(
        SamplerConfig::default().resolve(0, &RequestBudget::default()),
        Err(BudgetError::ZeroSamples)
    ));
    assert!(matches!(
        RequestBudget {
            max_samples: Some(0),
            target_confidence: None,
        }
        .validate(),
        Err(BudgetError::ZeroSamples)
    ));
    // min > max
    let bad = SamplerConfig {
        min_samples: 9,
        max_samples: 3,
        ..SamplerConfig::default()
    };
    assert!(matches!(bad.validate(), Err(BudgetError::MinAboveMax { .. })));
    // non-finite / out-of-range confidence
    for c in [f64::NAN, f64::INFINITY] {
        assert!(RequestBudget {
            max_samples: None,
            target_confidence: Some(c),
        }
        .validate()
        .is_err());
    }
    assert!(StopRule::confidence_target(1.0).is_err());
    // the wire protocol surfaces the same typed rejections
    let base = "{\"op\":\"classify\",\"dataset\":\"d\",\"image\":[1]";
    for (field, bad) in [("max_samples", "0"), ("target_confidence", "2.0")] {
        let err = photonic_bayes::server::protocol::parse_request(&format!(
            "{base},\"{field}\":{bad}}}"
        ))
        .unwrap_err();
        assert!(
            err.to_string().contains("budget") || err.to_string().contains("confidence"),
            "{field}={bad}: {err}"
        );
    }
}

// ---------------------------------------------------------------------------
// engine-level contracts (artifact-gated)
// ---------------------------------------------------------------------------

fn have_artifacts() -> bool {
    artifacts_root().join("digits/meta.json").exists()
}

fn have_trained() -> bool {
    artifacts_root().join("digits/params_trained.bin").exists()
}

/// Engine over the trained checkpoint when present (the statistical tests
/// need separable splits), the init params otherwise (replay tests are
/// parameter-agnostic).
fn engine(cfg: EngineConfig) -> Engine {
    let root = artifacts_root();
    let arts = ModelArtifacts::load_dataset(&root, "digits").unwrap();
    let trained = root.join("digits/params_trained.bin");
    let params = if trained.exists() {
        ParamStore::load_bin(&arts.meta, &trained).unwrap()
    } else {
        ParamStore::load_init(&arts.meta, &root.join("digits")).unwrap()
    };
    Engine::new(arts, params, cfg).unwrap()
}

fn digits_batch(n: usize) -> Vec<f32> {
    (0..n * 28 * 28).map(|i| ((i % 17) as f32) / 16.0).collect()
}

fn base_cfg(threads: usize) -> EngineConfig {
    EngineConfig {
        n_samples: 6,
        mode: ExecMode::Split(BackendKind::Digital),
        policy: UncertaintyPolicy::ood_only(0.05),
        calibrate: false,
        threads,
        seed: 5,
        ..EngineConfig::default()
    }
}

/// Fixed-rule classify replays bit-identically and carries the full
/// budget as `samples_used` — the pre-sampler contract, per thread count.
#[test]
fn engine_fixed_rule_replays_bitwise() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let images = digits_batch(3);
    for threads in [1usize, 2] {
        let collect = |e: &mut Engine| {
            e.classify(&images, 3)
                .unwrap()
                .into_iter()
                .map(|r| (r.predictive.probs, r.predictive.predicted, r.samples_used))
                .collect::<Vec<_>>()
        };
        let a = collect(&mut engine(base_cfg(threads)));
        let b = collect(&mut engine(base_cfg(threads)));
        assert_eq!(a, b, "t={threads}");
        assert!(a.iter().all(|(_, _, used)| *used == 6));
    }
}

/// `classify` and `classify_with_budget(default)` are the same path, and a
/// request `max_samples` cap lowers the spend on the fixed rule.
#[test]
fn engine_default_budget_is_identity_and_caps_apply() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let images = digits_batch(2);
    let a: Vec<_> = engine(base_cfg(1))
        .classify(&images, 2)
        .unwrap()
        .into_iter()
        .map(|r| (r.predictive.probs, r.samples_used))
        .collect();
    let b: Vec<_> = engine(base_cfg(1))
        .classify_with_budget(&images, 2, &RequestBudget::default())
        .unwrap()
        .into_iter()
        .map(|r| (r.predictive.probs, r.samples_used))
        .collect();
    assert_eq!(a, b);

    let capped = engine(base_cfg(1))
        .classify_with_budget(
            &images,
            2,
            &RequestBudget {
                max_samples: Some(2),
                target_confidence: None,
            },
        )
        .unwrap();
    assert!(capped.iter().all(|r| r.samples_used == 2));
    assert!(capped.iter().all(|r| r.predictive.n_samples() == 2));
}

/// Adaptive engine classify: samples_used within clamps, deterministic
/// replay, and OOD AUROC at matched max budget no worse than fixed-N.
#[test]
fn engine_adaptive_replays_and_auroc_holds() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    use photonic_bayes::bnn::rocauc::auroc;
    use photonic_bayes::data::{Dataset, DatasetKind};
    use photonic_bayes::experiments::uncertainty::eval_split;

    let adaptive_cfg = || EngineConfig {
        sampler: SamplerConfig {
            rule: StopRule::UncertaintyResolved {
                mi_low: 0.001,
                mi_high: 0.2,
                stable: 2,
            },
            min_samples: 2,
            max_samples: 0,
            chunk: 2,
        },
        ..base_cfg(1)
    };
    let images = digits_batch(3);
    let collect = |e: &mut Engine| {
        e.classify(&images, 3)
            .unwrap()
            .into_iter()
            .map(|r| (r.predictive.probs, r.samples_used))
            .collect::<Vec<_>>()
    };
    let a = collect(&mut engine(adaptive_cfg()));
    let b = collect(&mut engine(adaptive_cfg()));
    assert_eq!(a, b, "adaptive replay");
    assert!(a.iter().all(|(_, used)| (2..=6).contains(used)));

    // AUROC comparison needs the real dataset splits AND a trained
    // checkpoint (init params make both detectors coin flips)
    if !have_trained() {
        eprintln!("skipping AUROC half: no trained checkpoint");
        return;
    }
    let data_dir = artifacts_root().join("data");
    let (Ok(id), Ok(ood)) = (
        Dataset::load(&data_dir, "digits_test", DatasetKind::InDomain),
        Dataset::load(&data_dir, "fashion", DatasetKind::Epistemic),
    ) else {
        eprintln!("skipping AUROC half: dataset splits missing");
        return;
    };
    let limit = 48;
    let mut fixed = engine(base_cfg(1));
    let f_id = eval_split(&mut fixed, &id, limit).unwrap();
    let f_ood = eval_split(&mut fixed, &ood, limit).unwrap();
    let mut adap = engine(adaptive_cfg());
    let a_id = eval_split(&mut adap, &id, limit).unwrap();
    let a_ood = eval_split(&mut adap, &ood, limit).unwrap();
    let f_auroc = auroc(&f_ood.mi, &f_id.mi);
    let a_auroc = auroc(&a_ood.mi, &a_id.mi);
    // small-sample slack: "no worse" within noise at matched max budget
    assert!(
        a_auroc >= f_auroc - 0.1,
        "adaptive AUROC {a_auroc} << fixed {f_auroc}"
    );
    assert!(
        a_id.mean_samples() <= 6.0 + 1e-9,
        "mean samples within budget"
    );
}

/// Statistical sanity (artifact-gated): the aleatoric probe split needs
/// more samples per request than the in-domain split under an adaptive
/// rule — ambiguity is exactly what refuses to resolve early.
#[test]
fn engine_adaptive_mean_samples_higher_on_ambiguous_split() {
    if !have_artifacts() || !have_trained() {
        eprintln!("skipping: run `make artifacts` + `pbm train --dataset digits`");
        return;
    }
    use photonic_bayes::data::{Dataset, DatasetKind};
    use photonic_bayes::experiments::uncertainty::eval_split_budget;

    let data_dir = artifacts_root().join("data");
    let (Ok(id), Ok(amb)) = (
        Dataset::load(&data_dir, "digits_test", DatasetKind::InDomain),
        Dataset::load(&data_dir, "ambiguous", DatasetKind::Aleatoric),
    ) else {
        eprintln!("skipping: dataset splits missing");
        return;
    };
    // confidence-gap stopping: decisive in-domain posteriors resolve
    // early, ambiguous ones keep sampling
    let budget = RequestBudget {
        max_samples: None,
        target_confidence: Some(0.7),
    };
    let mut cfg = base_cfg(1);
    cfg.n_samples = 10;
    let mut e = engine(cfg);
    let limit = 48;
    let id_scores = eval_split_budget(&mut e, &id, limit, &budget).unwrap();
    let amb_scores = eval_split_budget(&mut e, &amb, limit, &budget).unwrap();
    assert!(
        amb_scores.mean_samples() > id_scores.mean_samples(),
        "ambiguous {:.2} <= in-domain {:.2}",
        amb_scores.mean_samples(),
        id_scores.mean_samples()
    );
}
