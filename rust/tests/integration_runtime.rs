//! Integration: AOT artifacts ↔ PJRT runtime ↔ the L2 contract.
//!
//! These tests require `make artifacts` to have run; they skip (with a
//! message) otherwise so `cargo test` stays green on a fresh checkout.

use photonic_bayes::photonics::converters::Quantizer;
use photonic_bayes::photonics::machine::im2col_3x3;
use photonic_bayes::runtime::artifact::artifacts_root;
use photonic_bayes::runtime::{Arg, ModelArtifacts, ParamStore};

fn arts(ds: &str) -> Option<ModelArtifacts> {
    let root = artifacts_root().join(ds);
    if !root.join("meta.json").exists() {
        eprintln!("skipping: artifacts for {ds} missing (run `make artifacts`)");
        return None;
    }
    Some(ModelArtifacts::load(&root).unwrap())
}

fn init_params(a: &ModelArtifacts) -> ParamStore {
    ParamStore::load_init(&a.meta, &artifacts_root().join(&a.meta.dataset)).unwrap()
}

#[test]
fn fwd_full_is_deterministic_given_inputs() {
    let Some(a) = arts("digits") else { return };
    let meta = &a.meta;
    let f = a.get("fwd_full_b1").unwrap();
    let ps = init_params(&a);
    let x = vec![0.3f32; meta.image_size()];
    let eps = vec![0.7f32; meta.eps_size()];
    let np = meta.num_params as i64;
    let shape_x = [1, meta.in_channels as i64, 28, 28];
    let shape_e = [1, meta.prob_ch as i64, 7, 7, 9];
    let o1 = f
        .call(&[Arg::F32(&ps.theta, &[np]), Arg::F32(&x, &shape_x), Arg::F32(&eps, &shape_e)])
        .unwrap();
    let o2 = f
        .call(&[Arg::F32(&ps.theta, &[np]), Arg::F32(&x, &shape_x), Arg::F32(&eps, &shape_e)])
        .unwrap();
    assert_eq!(o1[0], o2[0]);
}

/// The serving split (`fwd_pre` -> probabilistic depthwise conv -> ADC
/// quantization -> `fwd_post`) must agree with the monolithic surrogate
/// (`fwd_full`) when the noise is zero: with eps = 0 the sampled taps
/// collapse to their means regardless of the sigma floor, so the conv can
/// be reproduced exactly in Rust from the parameter vector.
#[test]
fn split_path_matches_fwd_full_at_zero_noise() {
    let Some(a) = arts("digits") else { return };
    let meta = a.meta.clone();
    let ps = init_params(&a);
    let np = meta.num_params as i64;

    // a smooth but non-trivial input
    let x: Vec<f32> = (0..meta.image_size())
        .map(|i| ((i % 29) as f32 / 29.0))
        .collect();
    let shape_x = [1, meta.in_channels as i64, 28, 28];

    // reference: fwd_full with eps = 0
    let eps = vec![0.0f32; meta.eps_size()];
    let full = a.get("fwd_full_b1").unwrap();
    let want = full
        .call(&[
            Arg::F32(&ps.theta, &[np]),
            Arg::F32(&x, &shape_x),
            Arg::F32(&eps, &[1, meta.prob_ch as i64, 7, 7, 9]),
        ])
        .unwrap()[0]
        .clone();

    // split path: pre -> rust depthwise(mu) -> quant -> post
    let pre = a.get("fwd_pre_b1").unwrap();
    let post = a.get("fwd_post_b1").unwrap();
    let x3q = pre
        .call(&[Arg::F32(&ps.theta, &[np]), Arg::F32(&x, &shape_x)])
        .unwrap()[0]
        .clone();
    let mu = ps.slice("prob_mu").unwrap();
    let (c, h, w) = (meta.prob_ch, meta.prob_hw, meta.prob_hw);
    let mut d3 = vec![0.0f32; c * h * w];
    let mut patches = vec![0.0f32; h * w * 9];
    for ch in 0..c {
        im2col_3x3(&x3q[ch * h * w..(ch + 1) * h * w], h, w, &mut patches);
        for p in 0..h * w {
            let mut acc = 0.0f32;
            for k in 0..9 {
                acc += mu[ch * 9 + k] * patches[p * 9 + k];
            }
            d3[ch * h * w + p] = acc;
        }
    }
    let q = Quantizer::new(meta.scale_adc);
    for v in &mut d3 {
        *v = q.quantize(*v);
    }
    let act_shape = [1, c as i64, h as i64, w as i64];
    let got = post
        .call(&[
            Arg::F32(&ps.theta, &[np]),
            Arg::F32(&x3q, &act_shape),
            Arg::F32(&d3, &act_shape),
        ])
        .unwrap()[0]
        .clone();

    assert_eq!(got.len(), want.len());
    for (g, w_) in got.iter().zip(&want) {
        assert!((g - w_).abs() < 1e-3, "split {g} vs full {w_}");
    }
}

#[test]
fn train_step_memorizes_fixed_batch() {
    let Some(a) = arts("digits") else { return };
    let meta = a.meta.clone();
    let f = a.get("train_step").unwrap();
    let mut ps = init_params(&a);
    let np = meta.num_params as i64;
    let b = meta.train_batch;

    // deterministic pseudo-batch
    let x: Vec<f32> = (0..b * meta.image_size())
        .map(|i| ((i * 2654435761usize) % 256) as f32 / 255.0)
        .collect();
    let y: Vec<i32> = (0..b).map(|i| (i % meta.n_classes) as i32).collect();
    let eps: Vec<f32> = (0..b * meta.eps_size())
        .map(|i| (((i * 97 + 13) % 200) as f32 / 100.0) - 1.0)
        .collect();

    let mut m = vec![0.0f32; meta.num_params];
    let mut v = vec![0.0f32; meta.num_params];
    let mut losses = Vec::new();
    for step in 0..25 {
        let out = f
            .call(&[
                Arg::F32(&ps.theta, &[np]),
                Arg::F32(&m, &[np]),
                Arg::F32(&v, &[np]),
                Arg::ScalarF32(step as f32),
                Arg::F32(&x, &[b as i64, meta.in_channels as i64, 28, 28]),
                Arg::I32(&y, &[b as i64]),
                Arg::F32(&eps, &[b as i64, meta.prob_ch as i64, 7, 7, 9]),
                Arg::ScalarF32(1e-5),
                Arg::ScalarF32(3e-3),
            ])
            .unwrap();
        ps.theta = out[0].clone();
        m = out[1].clone();
        v = out[2].clone();
        losses.push(out[3][0]);
        assert!(out[5][0] >= 0.0, "KL must be nonnegative");
    }
    assert!(
        losses[24] < losses[0] * 0.8,
        "loss should drop: {} -> {}",
        losses[0],
        losses[24]
    );
}

#[test]
fn all_entry_points_compile_and_declare_consistent_shapes() {
    for ds in ["digits", "blood"] {
        let Some(a) = arts(ds) else { return };
        // compile the small ones (the rest are covered by other tests)
        for ep in ["fwd_pre_b1", "fwd_post_b1", "fwd_full_b1"] {
            a.get(ep).unwrap();
        }
        assert!(a.meta.num_params > 1000);
        assert_eq!(a.meta.prob_hw, 7);
    }
}

#[test]
fn eps_zero_vs_eps_nonzero_differ() {
    let Some(a) = arts("digits") else { return };
    let meta = &a.meta;
    let f = a.get("fwd_full_b1").unwrap();
    let ps = init_params(&a);
    let np = meta.num_params as i64;
    let x = vec![0.5f32; meta.image_size()];
    let shape_x = [1, meta.in_channels as i64, 28, 28];
    let shape_e = [1, meta.prob_ch as i64, 7, 7, 9];
    let e0 = vec![0.0f32; meta.eps_size()];
    let e1 = vec![2.0f32; meta.eps_size()];
    let o0 = f
        .call(&[Arg::F32(&ps.theta, &[np]), Arg::F32(&x, &shape_x), Arg::F32(&e0, &shape_e)])
        .unwrap();
    let o1 = f
        .call(&[Arg::F32(&ps.theta, &[np]), Arg::F32(&x, &shape_x), Arg::F32(&e1, &shape_e)])
        .unwrap();
    assert_ne!(o0[0], o1[0], "noise must influence the logits");
}
