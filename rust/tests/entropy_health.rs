//! Integration: entropy-health monitor — fault-injected degradation must
//! drive the scorecard down, trip the opt-in digital fallback
//! deterministically, and surface per-(shard, stream) scores on `/info`.

use std::sync::Arc;

use photonic_bayes::bnn::UncertaintyPolicy;
use photonic_bayes::coordinator::service::{EngineHandle, ServiceConfig};
use photonic_bayes::coordinator::{BackendKind, Engine, EngineConfig, ExecMode, Router};
use photonic_bayes::entropy::{HealthConfig, Monitor};
use photonic_bayes::photonics::MachineConfig;
use photonic_bayes::runtime::artifact::artifacts_root;
use photonic_bayes::runtime::{ModelArtifacts, ParamStore};
use photonic_bayes::server::tcp;

fn have_artifacts() -> bool {
    artifacts_root().join("digits/meta.json").exists()
}

/// A monitor config that degrades after one bad window: the smallest legal
/// window and a single failing window suffices.
fn tight_health() -> HealthConfig {
    HealthConfig {
        enabled: true,
        window_bits: 256,
        duty: 1.0,
        fail_consecutive: 1,
        ..HealthConfig::default()
    }
}

fn photonic_engine(
    health: HealthConfig,
    fallback: Option<BackendKind>,
    monitor: Option<Arc<Monitor>>,
) -> Engine {
    let root = artifacts_root();
    let arts = ModelArtifacts::load_dataset(&root, "digits").unwrap();
    let params = ParamStore::load_init(&arts.meta, &root.join("digits")).unwrap();
    let cfg = EngineConfig {
        n_samples: 3,
        mode: ExecMode::Split(BackendKind::Photonic),
        policy: UncertaintyPolicy::ood_only(0.05),
        calibrate: false,
        machine: MachineConfig::default(),
        noise_bw_ghz: 150.0,
        threads: 1,
        seed: 5,
        health,
        entropy_fallback: fallback,
        health_monitor: monitor,
        ..Default::default()
    };
    Engine::new(arts, params, cfg).unwrap()
}

/// Drive `monitor` into the degraded state: a constant window fails every
/// applicable battery test, the min-entropy floor, and the correlation cap.
fn inject_degraded(monitor: &Monitor) {
    monitor.ingest_bits(0, "pho-s0", &[0u8; 256]);
    assert!(monitor.any_degraded(), "constant window must degrade");
}

#[test]
fn degraded_stream_triggers_deterministic_digital_fallback() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let image_size = 28 * 28;
    let image = vec![0.4f32; image_size];

    // control: same engine, healthy source -> stays photonic
    let control_monitor = Arc::new(Monitor::new(tight_health()));
    let mut control = photonic_engine(
        tight_health(),
        Some(BackendKind::Digital),
        Some(control_monitor),
    );
    control.classify(&image, 1).unwrap();
    assert_eq!(control.backend_kind(), BackendKind::Photonic);
    assert!(!control.fell_back());

    // two identically-seeded engines, both fault-injected before their
    // first request: the swap must happen on both and the post-fallback
    // outputs must replay bitwise identically
    let mut outputs = Vec::new();
    for _ in 0..2 {
        let monitor = Arc::new(Monitor::new(tight_health()));
        let mut engine = photonic_engine(
            tight_health(),
            Some(BackendKind::Digital),
            Some(monitor.clone()),
        );
        assert_eq!(engine.backend_kind(), BackendKind::Photonic);
        inject_degraded(&monitor);
        let r = engine.classify(&image, 1).unwrap();
        assert_eq!(engine.backend_kind(), BackendKind::Digital, "fallback swap");
        assert!(engine.fell_back());
        // the scorecard keeps reporting the degraded stream after the swap
        let cards = monitor.scorecards();
        assert!(cards.iter().any(|c| c.degraded && c.stream == "pho-s0"));
        outputs.push(r[0].predictive.probs.clone());
    }
    assert_eq!(
        outputs[0], outputs[1],
        "post-fallback sampling must be bitwise deterministic"
    );

    // without the opt-in, the same degradation only logs: no swap
    let monitor = Arc::new(Monitor::new(tight_health()));
    let mut engine = photonic_engine(tight_health(), None, Some(monitor.clone()));
    inject_degraded(&monitor);
    engine.classify(&image, 1).unwrap();
    assert_eq!(engine.backend_kind(), BackendKind::Photonic);
    assert!(!engine.fell_back());
}

#[test]
fn info_reports_per_stream_scorecards() {
    if !have_artifacts() {
        return;
    }
    // surrogate mode keeps this test fast; the monitor is fed by fault
    // injection, which exercises the same /info path as live taps
    let engine_cfg = EngineConfig {
        n_samples: 3,
        mode: ExecMode::Surrogate,
        policy: UncertaintyPolicy::ood_only(0.05),
        calibrate: false,
        machine: MachineConfig::default(),
        noise_bw_ghz: 150.0,
        threads: 1,
        seed: 5,
        health: tight_health(),
        ..Default::default()
    };
    let handle = EngineHandle::spawn(
        &artifacts_root(),
        "digits",
        None,
        engine_cfg,
        ServiceConfig::default(),
    )
    .unwrap();
    let monitor = handle.health.clone().expect("spawn creates the monitor");
    inject_degraded(&monitor);
    let mut router = Router::new();
    router.register(handle);

    let snap = router.health_snapshot();
    assert_eq!(snap.len(), 1);
    assert_eq!(snap[0].0, "digits");
    assert!(snap[0].1.iter().any(|c| c.degraded));

    let info = tcp::respond(&router, "{\"op\":\"info\"}");
    let j = photonic_bayes::util::json::parse(&info).unwrap();
    let health = j
        .get("entropy_health")
        .and_then(|h| h.get("digits"))
        .and_then(|d| d.as_arr())
        .expect("/info carries per-dataset scorecards");
    assert!(health
        .iter()
        .any(|c| c.get("degraded").and_then(|v| v.as_bool()) == Some(true)));
    router.shutdown();
}
