//! End-to-end request tracing: span attribution, the replay guarantee
//! (responses unchanged by tracing), the `request_id` echo, the `trace`
//! and `metrics` protocol verbs, and slow-request exemplars.
//!
//! All tests run over the deterministic [`SynthExecutor`] substrate so
//! span durations are controlled by `work_per_sample` and every response
//! is reproducible for a fixed seed and request order.

use std::time::{Duration, Instant};

use photonic_bayes::coordinator::{
    ClassifyRequest, EngineHandle, RequestBudget, Router, ServiceConfig, SynthExecutor,
};
use photonic_bayes::observe::{critical_path_us, ObserveConfig, Stage};
use photonic_bayes::server::{protocol, respond};
use photonic_bayes::util::json;

const N_SAMPLES: usize = 8;

fn spawn_synth(seed: u64, observe: ObserveConfig, work: Duration) -> EngineHandle {
    let svc = ServiceConfig {
        observe,
        ..ServiceConfig::default()
    };
    EngineHandle::spawn_executor(
        "synth",
        vec!["synth".to_string()],
        None,
        N_SAMPLES,
        svc,
        move || {
            let mut e = SynthExecutor::new(seed, N_SAMPLES);
            e.work_per_sample = work;
            Ok(e)
        },
    )
    .expect("spawn synth executor")
}

fn image(k: usize) -> Vec<f32> {
    (0..4).map(|i| ((k * 4 + i) as f32) * 0.017).collect()
}

/// Blank out the one inherently nondeterministic response field (the
/// measured `latency_us`) so the rest of the line can be compared
/// byte-for-byte across runs.
fn mask_latency(s: &str) -> String {
    let key = "\"latency_us\":";
    match s.find(key) {
        None => s.to_string(),
        Some(i) => {
            let tail = &s[i + key.len()..];
            let end = tail.find([',', '}']).unwrap_or(tail.len());
            format!("{}{}<t>{}", &s[..i], key, &tail[end..])
        }
    }
}

/// The acceptance bar for attribution: the disjoint top-level spans
/// (admission + queue + batch_form + chunk) must account for the
/// request's measured wall clock to within 5%.
#[test]
fn span_durations_sum_to_wall_clock_within_5_percent() {
    // 8 samples x 5 ms of simulated work dominate the request, so the
    // tolerance has real slack over scheduling noise
    let handle = spawn_synth(11, ObserveConfig::enabled(), Duration::from_millis(5));
    let rid = handle.recorder.mint_id();
    let (mut req, rx) = ClassifyRequest::new(image(0));
    req.request_id = rid;
    let t0 = Instant::now();
    handle.submit(req).expect("admit");
    rx.recv().expect("request answered").expect("request succeeds");
    let wall_us = t0.elapsed().as_micros() as u64;

    let spans = handle.recorder.spans_for(rid);
    for stage in [Stage::Admission, Stage::Queue, Stage::BatchForm, Stage::Chunk] {
        assert!(
            spans.iter().any(|s| s.stage == stage),
            "missing {stage:?}: {spans:?}"
        );
    }
    // children (sample_conv / fwd_post) nest inside chunks and must not
    // inflate the disjoint account
    let sum = critical_path_us(&spans);
    assert!(
        sum <= wall_us + wall_us / 20,
        "span sum {sum}us exceeds wall {wall_us}us by >5%: {spans:?}"
    );
    assert!(
        sum + wall_us / 20 >= wall_us,
        "span sum {sum}us accounts for <95% of wall {wall_us}us: {spans:?}"
    );
    handle.shutdown();
}

/// The replay guarantee: with no client-supplied `request_id`, enabling
/// tracing changes no response byte (everything except the measured
/// `latency_us`, which differs run to run regardless of tracing).
#[test]
fn responses_are_byte_identical_with_tracing_on_or_off() {
    let on = spawn_synth(5, ObserveConfig::enabled(), Duration::ZERO);
    let off = spawn_synth(5, ObserveConfig::default(), Duration::ZERO);
    let mut traced = Router::new();
    traced.register(on);
    let mut plain = Router::new();
    plain.register(off);
    for k in 0..4 {
        let line = protocol::encode_classify("synth", &image(k));
        let a = respond(&traced, &line);
        let b = respond(&plain, &line);
        assert!(a.contains("\"ok\":true"), "{a}");
        assert_eq!(mask_latency(&a), mask_latency(&b), "request {k}");
        // the internally minted trace id never leaks into the response
        assert!(!a.contains("request_id"), "{a}");
    }
    // ...and the traced server did actually record the requests
    let stats = traced.trace_stats();
    assert!(stats.iter().any(|(_, t)| t.enabled && t.recorded > 0));
    traced.shutdown();
    plain.shutdown();
}

/// A client-chosen `request_id` is used for the trace AND echoed in the
/// response; the `trace` verb then returns the spans with their critical
/// path.
#[test]
fn client_supplied_request_id_is_echoed_and_traceable() {
    let handle = spawn_synth(3, ObserveConfig::enabled(), Duration::from_millis(1));
    let mut router = Router::new();
    router.register(handle);
    let line = protocol::encode_classify_sharded_traced(
        "synth",
        &image(1),
        &RequestBudget::default(),
        None,
        42,
        9001,
    );
    let resp = respond(&router, &line);
    assert!(resp.contains("\"ok\":true"), "{resp}");
    assert!(resp.contains("\"request_id\":\"9001\""), "{resp}");

    let t = respond(&router, "{\"op\":\"trace\",\"request_id\":\"9001\"}");
    let j = json::parse(&t).expect("trace response parses");
    let spans = j.get("spans").and_then(|v| v.as_arr()).expect("spans");
    assert!(!spans.is_empty(), "{t}");
    assert!(
        j.get("critical_path_us").and_then(|v| v.as_f64()).unwrap_or(0.0) > 0.0,
        "{t}"
    );
    // a zero id is rejected at the protocol boundary
    let bad = respond(&router, "{\"op\":\"trace\",\"request_id\":\"0\"}");
    assert!(bad.contains("\"ok\":false"), "{bad}");
    router.shutdown();
}

/// The `metrics` verb renders a Prometheus exposition that the in-repo
/// checker accepts, with live-traffic series present.
#[test]
fn metrics_exposition_lints_clean_with_live_traffic() {
    let handle = spawn_synth(9, ObserveConfig::enabled(), Duration::ZERO);
    let mut router = Router::new();
    router.register(handle);
    for k in 0..3 {
        let r = respond(&router, &protocol::encode_classify("synth", &image(k)));
        assert!(r.contains("\"ok\":true"), "{r}");
    }
    let m = respond(&router, "{\"op\":\"metrics\"}");
    let j = json::parse(&m).expect("metrics response parses");
    assert_eq!(
        j.get("content_type").and_then(|v| v.as_str()),
        Some("text/plain; version=0.0.4")
    );
    let body = j.get("body").and_then(|v| v.as_str()).expect("body");
    assert!(body.contains("pbm_request_latency_us_bucket"), "latency histogram");
    assert!(body.contains("pbm_trace_enabled"), "trace stats");
    assert!(body.contains("pbm_samples_used"), "uncertainty telemetry");
    assert!(body.contains("pbm_predictive_entropy_nats"), "entropy histogram");
    let errs = photonic_bayes::observe::expo::lint(body);
    assert!(errs.is_empty(), "{errs:?}");
    router.shutdown();
}

/// With `slow_ms = 0` every traced request retains an exemplar, and the
/// bare `trace` verb returns them keyed by engine.
#[test]
fn slow_request_exemplars_are_retained_and_queryable() {
    let ocfg = ObserveConfig {
        slow_ms: 0,
        ..ObserveConfig::enabled()
    };
    let handle = spawn_synth(13, ocfg, Duration::from_millis(1));
    let mut router = Router::new();
    router.register(handle);
    let r = respond(&router, &protocol::encode_classify("synth", &image(2)));
    assert!(r.contains("\"ok\":true"), "{r}");
    let ex = respond(&router, "{\"op\":\"trace\"}");
    let j = json::parse(&ex).expect("exemplar response parses");
    let list = j
        .get("exemplars")
        .and_then(|v| v.get("synth"))
        .and_then(|v| v.as_arr())
        .expect("synth exemplars");
    assert!(!list.is_empty(), "{ex}");
    assert!(
        list[0].get("total_us").and_then(|v| v.as_f64()).unwrap_or(0.0) > 0.0,
        "{ex}"
    );
    assert!(
        !list[0]
            .get("spans")
            .and_then(|v| v.as_arr())
            .unwrap_or_default()
            .is_empty(),
        "{ex}"
    );
    router.shutdown();
}
