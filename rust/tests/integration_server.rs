//! Integration: full TCP round trip through the gateway.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use photonic_bayes::bnn::UncertaintyPolicy;
use photonic_bayes::coordinator::service::{EngineHandle, ServiceConfig};
use photonic_bayes::coordinator::{EngineConfig, ExecMode, Router};
use photonic_bayes::exec::CancelToken;
use photonic_bayes::photonics::MachineConfig;
use photonic_bayes::runtime::artifact::artifacts_root;
use photonic_bayes::server::{serve, Client, ServerOptions};

fn have_artifacts() -> bool {
    artifacts_root().join("digits/meta.json").exists()
}

#[test]
fn tcp_round_trip_ping_info_classify() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let mut router = Router::new();
    router.register(
        EngineHandle::spawn(
            &artifacts_root(),
            "digits",
            None,
            EngineConfig {
                n_samples: 3,
                mode: ExecMode::Surrogate,
                policy: UncertaintyPolicy::ood_only(0.5),
                calibrate: false,
                machine: MachineConfig::default(),
                noise_bw_ghz: 150.0,
                threads: 2, // exercise the sharded sampling path end-to-end
                seed: 3,
                ..Default::default()
            },
            ServiceConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                queue_depth: 32,
                ..Default::default()
            },
        )
        .unwrap(),
    );

    let cancel = CancelToken::new();
    let bound: Arc<Mutex<Option<std::net::SocketAddr>>> = Arc::new(Mutex::new(None));
    let b2 = bound.clone();
    let c2 = cancel.clone();
    let server = std::thread::spawn(move || {
        serve(
            router,
            ServerOptions {
                addr: "127.0.0.1:0".into(),
                workers: 4,
                ..Default::default()
            },
            c2,
            move |a| {
                *b2.lock().unwrap() = Some(a);
            },
        )
    });
    let addr = loop {
        if let Some(a) = *bound.lock().unwrap() {
            break a;
        }
        std::thread::sleep(Duration::from_millis(5));
    };

    let mut client = Client::connect(&addr.to_string()).unwrap();
    // ping
    assert!(client.ping().unwrap());
    // info
    let info = client.call("{\"op\":\"info\"}").unwrap();
    assert_eq!(info.get("ok").unwrap().as_bool(), Some(true));
    let ds: Vec<String> = info
        .get("datasets")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_str().unwrap().to_string())
        .collect();
    assert!(ds.contains(&"digits".to_string()));
    // classify a synthetic image
    let image = vec![0.4f32; 28 * 28];
    let resp = client.classify("digits", &image).unwrap();
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
    assert!(resp.get("mi").unwrap().as_f64().unwrap() >= 0.0);
    assert!(resp.get("mean_probs").unwrap().as_arr().unwrap().len() == 10);
    // malformed request -> structured error, connection stays usable
    let err = client.call("{\"op\":\"classify\"}").unwrap();
    assert_eq!(err.get("ok").unwrap().as_bool(), Some(false));
    assert!(client.ping().unwrap());
    // unknown dataset -> error
    let err = client.classify("nope", &image).unwrap();
    assert_eq!(err.get("ok").unwrap().as_bool(), Some(false));

    cancel.cancel();
    server.join().unwrap().unwrap();
}
