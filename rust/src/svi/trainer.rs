//! The SVI training loop.

use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::backend::EpsSource;
use crate::data::Dataset;
use crate::entropy::gaussian::Gaussian;
use crate::entropy::Xoshiro256pp;
use crate::log_info;
use crate::runtime::params::softplus;
use crate::runtime::{Arg, ModelArtifacts, ParamStore};
use crate::util::mathstat::mean;

/// Training hyperparameters.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub epochs: usize,
    pub lr: f32,
    /// Final KL scale; the effective beta-ELBO weight is `kl_scale / n_train`
    /// (standard minibatch ELBO scaling), annealed linearly over
    /// `kl_warmup_epochs`.
    pub kl_scale: f32,
    pub kl_warmup_epochs: usize,
    pub seed: u64,
    /// Flat tap indices whose posterior sigma is traced per epoch (Fig. 4b).
    pub sigma_track: Vec<usize>,
    /// Evaluate on the test set every `eval_every` epochs (0 = only at end).
    pub eval_every: usize,
    /// Stochastic forward passes per test input at evaluation time.
    pub eval_samples: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            epochs: 12,
            lr: 2e-3,
            kl_scale: 1.0,
            kl_warmup_epochs: 4,
            seed: 1234,
            sigma_track: vec![0, 100, 400],
            eval_every: 0,
            eval_samples: 4,
        }
    }
}

/// Per-epoch record.
#[derive(Debug, Clone)]
pub struct EpochLog {
    pub epoch: usize,
    pub loss: f64,
    pub nll: f64,
    pub kl: f64,
    pub train_acc: f64,
    pub sigma_traces: Vec<f32>,
    pub wall_s: f64,
    pub eval_acc: Option<f64>,
}

/// Full training log.
#[derive(Debug, Clone, Default)]
pub struct TrainLog {
    pub epochs: Vec<EpochLog>,
}

/// Evaluation result in surrogate mode.
#[derive(Debug, Clone)]
pub struct EvalSummary {
    pub accuracy: f64,
    pub n: usize,
}

/// Train the BNN with SVI, driving the `train_step` HLO from Rust.
pub fn train(
    arts: &ModelArtifacts,
    train_ds: &Dataset,
    test_ds: Option<&Dataset>,
    mut params: ParamStore,
    cfg: &TrainConfig,
) -> Result<(ParamStore, TrainLog)> {
    let meta = &arts.meta;
    let step_fn = arts.get("train_step")?;
    let b = meta.train_batch;
    if train_ds.image_size() != meta.image_size() {
        return Err(anyhow!(
            "dataset image size {} != model {}",
            train_ds.image_size(),
            meta.image_size()
        ));
    }

    let mut m = vec![0.0f32; meta.num_params];
    let mut v = vec![0.0f32; meta.num_params];
    let mut step = 0.0f32;
    let mut rng = Xoshiro256pp::new(cfg.seed);
    let mut gauss = Gaussian::new();

    let n_train = train_ds.n as f32;
    let mut log = TrainLog::default();

    let mut batch_x: Vec<f32> = Vec::with_capacity(b * meta.image_size());
    let mut batch_y: Vec<i32> = Vec::with_capacity(b);
    let mut eps = vec![0.0f32; b * meta.eps_size()];

    let x_shape = [
        b as i64,
        meta.in_channels as i64,
        meta.img_hw as i64,
        meta.img_hw as i64,
    ];
    let eps_shape = [
        b as i64,
        meta.prob_ch as i64,
        meta.prob_hw as i64,
        meta.prob_hw as i64,
        meta.num_taps as i64,
    ];
    let np = meta.num_params as i64;

    let rho_off = meta
        .param("prob_rho")
        .ok_or_else(|| anyhow!("no prob_rho"))?
        .offset;

    for epoch in 0..cfg.epochs {
        let t0 = Instant::now();
        let anneal = if cfg.kl_warmup_epochs == 0 {
            1.0
        } else {
            ((epoch + 1) as f32 / cfg.kl_warmup_epochs as f32).min(1.0)
        };
        let kl_scale = cfg.kl_scale * anneal / n_train;

        let mut losses = Vec::new();
        let mut nlls = Vec::new();
        let mut kls = Vec::new();
        let mut accs = Vec::new();

        for batch in train_ds.shuffled_batches(b, cfg.seed ^ (epoch as u64 + 1)) {
            train_ds.gather(&batch, &mut batch_x, &mut batch_y);
            gauss.fill_f32(&mut rng, &mut eps);
            let out = step_fn.call(&[
                Arg::F32(&params.theta, &[np]),
                Arg::F32(&m, &[np]),
                Arg::F32(&v, &[np]),
                Arg::ScalarF32(step),
                Arg::F32(&batch_x, &x_shape),
                Arg::I32(&batch_y, &[b as i64]),
                Arg::F32(&eps, &eps_shape),
                Arg::ScalarF32(kl_scale),
                Arg::ScalarF32(cfg.lr),
            ])?;
            // outputs: theta', m', v', loss, nll, kl, acc
            params.theta = out[0].clone();
            m = out[1].clone();
            v = out[2].clone();
            losses.push(out[3][0] as f64);
            nlls.push(out[4][0] as f64);
            kls.push(out[5][0] as f64);
            accs.push(out[6][0] as f64);
            step += 1.0;
        }

        let sigma_traces: Vec<f32> = cfg
            .sigma_track
            .iter()
            .map(|&i| softplus(params.theta[rho_off + i]))
            .collect();

        let eval_acc = if test_ds.is_some()
            && cfg.eval_every > 0
            && (epoch + 1) % cfg.eval_every == 0
        {
            Some(evaluate(arts, test_ds.unwrap(), &params, cfg.eval_samples, cfg.seed)?.accuracy)
        } else {
            None
        };

        let el = EpochLog {
            epoch,
            loss: mean(&losses),
            nll: mean(&nlls),
            kl: mean(&kls),
            train_acc: mean(&accs),
            sigma_traces,
            wall_s: t0.elapsed().as_secs_f64(),
            eval_acc,
        };
        log_info!(
            "epoch {:>3}: loss {:.4} nll {:.4} kl {:.1} acc {:.3}{} ({:.1}s)",
            el.epoch,
            el.loss,
            el.nll,
            el.kl,
            el.train_acc,
            el.eval_acc
                .map(|a| format!(" eval {a:.3}"))
                .unwrap_or_default(),
            el.wall_s
        );
        log.epochs.push(el);
    }
    Ok((params, log))
}

/// Surrogate-mode evaluation: `n_samples` stochastic passes per input via
/// the `fwd_full` entry points, majority vote on the mean predictive.
///
/// Draws the reparameterization noise from the digital PRNG — the training
/// default.  Use [`evaluate_with`] to evaluate under a different serving
/// noise source (e.g. the chaotic-light [`EpsSource`] the engine serves
/// with), closing the train/serve noise gap in ablations.
pub fn evaluate(
    arts: &ModelArtifacts,
    ds: &Dataset,
    params: &ParamStore,
    n_samples: usize,
    seed: u64,
) -> Result<EvalSummary> {
    let mut noise = EpsSource::digital(seed.wrapping_add(0x5EED));
    evaluate_with(arts, ds, params, n_samples, &mut noise)
}

/// [`evaluate`] with an explicit serving-time noise source.
pub fn evaluate_with(
    arts: &ModelArtifacts,
    ds: &Dataset,
    params: &ParamStore,
    n_samples: usize,
    noise: &mut EpsSource,
) -> Result<EvalSummary> {
    let meta = &arts.meta;
    let bsize = *meta.full_batches.last().unwrap();
    let f = arts.get(&format!("fwd_full_b{bsize}"))?;
    let np = meta.num_params as i64;
    let x_shape = [
        bsize as i64,
        meta.in_channels as i64,
        meta.img_hw as i64,
        meta.img_hw as i64,
    ];
    let eps_shape = [
        bsize as i64,
        meta.prob_ch as i64,
        meta.prob_hw as i64,
        meta.prob_hw as i64,
        meta.num_taps as i64,
    ];
    let mut eps = vec![0.0f32; bsize * meta.eps_size()];
    let mut correct = 0usize;
    let mut total = 0usize;
    let mut batch_x = Vec::new();
    let mut batch_y = Vec::new();

    let full_batches = ds.n / bsize;
    for bi in 0..full_batches {
        let idxs: Vec<usize> = (bi * bsize..(bi + 1) * bsize).collect();
        ds.gather(&idxs, &mut batch_x, &mut batch_y);
        // mean probs over n_samples passes
        let mut mean_logit_probs = vec![0.0f32; bsize * meta.n_classes];
        for _ in 0..n_samples {
            noise.fill(&mut eps);
            let out = f.call(&[
                Arg::F32(&params.theta, &[np]),
                Arg::F32(&batch_x, &x_shape),
                Arg::F32(&eps, &eps_shape),
            ])?;
            for (i, chunk) in out[0].chunks(meta.n_classes).enumerate() {
                let p = crate::util::mathstat::softmax(chunk);
                for (j, &pj) in p.iter().enumerate() {
                    mean_logit_probs[i * meta.n_classes + j] += pj / n_samples as f32;
                }
            }
        }
        for i in 0..bsize {
            let row = &mean_logit_probs[i * meta.n_classes..(i + 1) * meta.n_classes];
            let pred = crate::bnn::aggregate::argmax(row);
            if pred as i32 == batch_y[i] {
                correct += 1;
            }
            total += 1;
        }
    }
    Ok(EvalSummary {
        accuracy: correct as f64 / total.max(1) as f64,
        n: total,
    })
}
