//! Checkpointing: parameters (raw f32) + training log (JSON).

use std::path::Path;

use anyhow::Result;

use super::trainer::TrainLog;
use crate::runtime::ParamStore;
use crate::util::json::Json;

/// Save parameters and the training log next to each other:
/// `<stem>.bin` and `<stem>.log.json`.
pub fn save(stem: &Path, params: &ParamStore, log: &TrainLog) -> Result<()> {
    params.save_bin(&stem.with_extension("bin"))?;
    std::fs::write(
        stem.with_extension("log.json"),
        log_to_json(log).to_string_pretty(),
    )?;
    Ok(())
}

/// Serialize the training log (consumed by EXPERIMENTS.md tooling and the
/// Fig. 4(b) sigma-trace report).
pub fn log_to_json(log: &TrainLog) -> Json {
    Json::from_pairs(vec![(
        "epochs",
        Json::Arr(
            log.epochs
                .iter()
                .map(|e| {
                    let mut o = Json::obj();
                    o.set("epoch", Json::Num(e.epoch as f64));
                    o.set("loss", Json::Num(e.loss));
                    o.set("nll", Json::Num(e.nll));
                    o.set("kl", Json::Num(e.kl));
                    o.set("train_acc", Json::Num(e.train_acc));
                    o.set("sigma_traces", Json::arr_f32(&e.sigma_traces));
                    o.set("wall_s", Json::Num(e.wall_s));
                    if let Some(a) = e.eval_acc {
                        o.set("eval_acc", Json::Num(a));
                    }
                    o
                })
                .collect(),
        ),
    )])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::svi::trainer::EpochLog;

    #[test]
    fn log_serializes_roundtrip() {
        let log = TrainLog {
            epochs: vec![EpochLog {
                epoch: 0,
                loss: 2.3,
                nll: 2.1,
                kl: 40.0,
                train_acc: 0.4,
                sigma_traces: vec![0.05, 0.06],
                wall_s: 1.5,
                eval_acc: Some(0.5),
            }],
        };
        let j = log_to_json(&log);
        let text = j.to_string_pretty();
        let back = crate::util::json::parse(&text).unwrap();
        let e0 = &back.get("epochs").unwrap().as_arr().unwrap()[0];
        assert_eq!(e0.get("loss").unwrap().as_f64(), Some(2.3));
        assert_eq!(e0.get("eval_acc").unwrap().as_f64(), Some(0.5));
    }
}
