//! Stochastic Variational Inference training driver (L3 side).
//!
//! The entire training computation — surrogate forward with the L1 Pallas
//! kernel, beta-ELBO, gradients, Adam — lives in one AOT-exported
//! `train_step` HLO; this module owns the *loop*: epoch shuffling,
//! minibatch assembly, reparameterization noise, KL annealing, metric
//! logging (including the Fig. 4(b) per-weight sigma traces), checkpoints,
//! and surrogate-mode evaluation.

pub mod checkpoint;
pub mod trainer;

pub use trainer::{evaluate, evaluate_with, train, EvalSummary, TrainConfig, TrainLog};
