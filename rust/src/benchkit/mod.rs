//! Micro-benchmark harness (criterion substitute).
//!
//! `cargo bench` targets use `harness = false` binaries built on this
//! module: warmup, fixed-duration measurement, outlier-trimmed statistics,
//! and aligned table output so the paper-table benches print rows directly
//! comparable to the paper's evaluation section.
//!
//! Passing `--json <path>` to a bench binary that wires up a [`JsonSink`]
//! additionally writes the measured rows as machine-readable JSON, making
//! the perf trajectory diffable across PRs (see `BENCH_backends.json`).

use std::path::PathBuf;
use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::mathstat::{mean, percentile, std};

/// Robust summary of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p05_ns: f64,
    pub p95_ns: f64,
    pub std_ns: f64,
}

impl BenchStats {
    /// Operations per second given `ops_per_iter` work items per iteration.
    pub fn throughput(&self, ops_per_iter: f64) -> f64 {
        ops_per_iter / (self.mean_ns * 1e-9)
    }

    pub fn row(&self) -> String {
        format!(
            "{:<42} {:>10} iters  mean {:>12}  median {:>12}  p95 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.p95_ns),
        )
    }
}

/// Format nanoseconds human-readably.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Benchmark runner configuration.
#[derive(Debug, Clone)]
pub struct Bench {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            min_iters: 10,
            max_iters: 1_000_000,
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(200),
            min_iters: 5,
            max_iters: 100_000,
        }
    }

    /// Run `f` repeatedly and summarize per-iteration latency.  The closure
    /// should return something observable to defeat dead-code elimination
    /// (use [`black_box`]).
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchStats {
        // warmup
        let t0 = Instant::now();
        while t0.elapsed() < self.warmup {
            f();
        }
        // measure
        let mut samples_ns: Vec<f64> = Vec::new();
        let t0 = Instant::now();
        while (t0.elapsed() < self.measure || samples_ns.len() < self.min_iters)
            && samples_ns.len() < self.max_iters
        {
            let it = Instant::now();
            f();
            samples_ns.push(it.elapsed().as_nanos() as f64);
        }
        // trim 2% tails against scheduler outliers
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let trim = samples_ns.len() / 50;
        let trimmed = &samples_ns[trim..samples_ns.len() - trim.min(samples_ns.len() - 1)];
        BenchStats {
            name: name.to_string(),
            iters: samples_ns.len(),
            mean_ns: mean(trimmed),
            median_ns: percentile(trimmed, 50.0),
            p05_ns: percentile(trimmed, 5.0),
            p95_ns: percentile(trimmed, 95.0),
            std_ns: std(trimmed),
        }
    }
}

/// One emitted JSON row: a bench name plus its latency and throughput.
#[derive(Debug, Clone)]
pub struct JsonRow {
    pub name: String,
    pub ns_per_iter: f64,
    pub ops_per_sec: f64,
}

/// Machine-readable bench emission, enabled by `--json <path>` on a bench
/// binary.  Collect rows with [`JsonSink::push`] / [`JsonSink::push_stats`]
/// and call [`JsonSink::write`] once at the end.
#[derive(Debug)]
pub struct JsonSink {
    path: PathBuf,
    bench: String,
    rows: Vec<JsonRow>,
}

impl JsonSink {
    pub fn new(path: impl Into<PathBuf>, bench: &str) -> Self {
        Self {
            path: path.into(),
            bench: bench.to_string(),
            rows: Vec::new(),
        }
    }

    /// Build a sink from a bench binary's raw argument list if it contains
    /// `--json <path>` or `--json=<path>`.
    pub fn from_args(args: &[String], bench: &str) -> Option<Self> {
        let mut it = args.iter();
        while let Some(a) = it.next() {
            if let Some(p) = a.strip_prefix("--json=") {
                return Some(Self::new(p, bench));
            }
            if a == "--json" {
                return it.next().map(|p| Self::new(p, bench));
            }
        }
        None
    }

    pub fn path(&self) -> &std::path::Path {
        &self.path
    }

    pub fn push(&mut self, name: &str, ns_per_iter: f64, ops_per_sec: f64) {
        self.rows.push(JsonRow {
            name: name.to_string(),
            ns_per_iter,
            ops_per_sec,
        });
    }

    pub fn push_stats(&mut self, stats: &BenchStats, ops_per_iter: f64) {
        self.push(&stats.name, stats.mean_ns, stats.throughput(ops_per_iter));
    }

    /// Serialize all rows to the sink path.
    pub fn write(&self) -> std::io::Result<()> {
        std::fs::write(&self.path, self.render())
    }

    /// The JSON document this sink would write.
    pub fn render(&self) -> String {
        let mut doc = Json::obj();
        doc.set("version", Json::Num(1.0));
        doc.set("bench", Json::Str(self.bench.clone()));
        doc.set(
            "rows",
            Json::Arr(
                self.rows
                    .iter()
                    .map(|r| {
                        let mut o = Json::obj();
                        o.set("name", Json::Str(r.name.clone()));
                        o.set("ns_per_iter", Json::Num(r.ns_per_iter));
                        o.set("ops_per_s", Json::Num(r.ops_per_sec));
                        o
                    })
                    .collect(),
            ),
        );
        doc.to_string_pretty()
    }
}

/// Prevent the optimizer from discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Section header helper for bench binaries.
pub fn section(title: &str) {
    println!("\n=== {title} {}", "=".repeat(66usize.saturating_sub(title.len())));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let b = Bench::quick();
        let stats = b.run("spin", || {
            let mut s = 0u64;
            for i in 0..1000 {
                s = s.wrapping_add(black_box(i));
            }
            black_box(s);
        });
        assert!(stats.iters >= 5);
        assert!(stats.mean_ns > 0.0);
        assert!(stats.p95_ns >= stats.median_ns);
        assert!(stats.median_ns >= stats.p05_ns);
    }

    #[test]
    fn throughput_inverse_of_latency() {
        let s = BenchStats {
            name: "x".into(),
            iters: 10,
            mean_ns: 1000.0,
            median_ns: 1000.0,
            p05_ns: 900.0,
            p95_ns: 1100.0,
            std_ns: 50.0,
        };
        assert!((s.throughput(1.0) - 1e6).abs() < 1e-6);
        assert!((s.throughput(100.0) - 1e8).abs() < 1e-3);
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12_000.0).contains("us"));
        assert!(fmt_ns(12_000_000.0).contains("ms"));
        assert!(fmt_ns(2e9).contains('s'));
    }

    #[test]
    fn json_sink_parses_args_and_renders_valid_json() {
        let args: Vec<String> = ["backends", "--json", "/tmp/b.json"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let mut sink = JsonSink::from_args(&args, "paper_tables").unwrap();
        assert_eq!(sink.path(), std::path::Path::new("/tmp/b.json"));
        sink.push("backends/sample_conv/digital/t4", 1234.5, 1e6);

        let doc = crate::util::json::parse(&sink.render()).unwrap();
        assert_eq!(doc.get("bench").unwrap().as_str(), Some("paper_tables"));
        let rows = doc.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(
            rows[0].get("name").unwrap().as_str(),
            Some("backends/sample_conv/digital/t4")
        );
        assert!(rows[0].get("ops_per_s").unwrap().as_f64().unwrap() > 0.0);

        // equals form and absence
        let eq: Vec<String> = vec!["--json=x.json".into()];
        assert!(JsonSink::from_args(&eq, "b").is_some());
        let none: Vec<String> = vec!["backends".into()];
        assert!(JsonSink::from_args(&none, "b").is_none());
    }
}
