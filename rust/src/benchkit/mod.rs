//! Micro-benchmark harness (criterion substitute).
//!
//! `cargo bench` targets use `harness = false` binaries built on this
//! module: warmup, fixed-duration measurement, outlier-trimmed statistics,
//! and aligned table output so the paper-table benches print rows directly
//! comparable to the paper's evaluation section.

use std::time::{Duration, Instant};

use crate::util::mathstat::{mean, percentile, std};

/// Robust summary of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p05_ns: f64,
    pub p95_ns: f64,
    pub std_ns: f64,
}

impl BenchStats {
    /// Operations per second given `ops_per_iter` work items per iteration.
    pub fn throughput(&self, ops_per_iter: f64) -> f64 {
        ops_per_iter / (self.mean_ns * 1e-9)
    }

    pub fn row(&self) -> String {
        format!(
            "{:<42} {:>10} iters  mean {:>12}  median {:>12}  p95 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.p95_ns),
        )
    }
}

/// Format nanoseconds human-readably.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Benchmark runner configuration.
#[derive(Debug, Clone)]
pub struct Bench {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            min_iters: 10,
            max_iters: 1_000_000,
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(200),
            min_iters: 5,
            max_iters: 100_000,
        }
    }

    /// Run `f` repeatedly and summarize per-iteration latency.  The closure
    /// should return something observable to defeat dead-code elimination
    /// (use [`black_box`]).
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchStats {
        // warmup
        let t0 = Instant::now();
        while t0.elapsed() < self.warmup {
            f();
        }
        // measure
        let mut samples_ns: Vec<f64> = Vec::new();
        let t0 = Instant::now();
        while (t0.elapsed() < self.measure || samples_ns.len() < self.min_iters)
            && samples_ns.len() < self.max_iters
        {
            let it = Instant::now();
            f();
            samples_ns.push(it.elapsed().as_nanos() as f64);
        }
        // trim 2% tails against scheduler outliers
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let trim = samples_ns.len() / 50;
        let trimmed = &samples_ns[trim..samples_ns.len() - trim.min(samples_ns.len() - 1)];
        BenchStats {
            name: name.to_string(),
            iters: samples_ns.len(),
            mean_ns: mean(trimmed),
            median_ns: percentile(trimmed, 50.0),
            p05_ns: percentile(trimmed, 5.0),
            p95_ns: percentile(trimmed, 95.0),
            std_ns: std(trimmed),
        }
    }
}

/// Prevent the optimizer from discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Section header helper for bench binaries.
pub fn section(title: &str) {
    println!("\n=== {title} {}", "=".repeat(66usize.saturating_sub(title.len())));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let b = Bench::quick();
        let stats = b.run("spin", || {
            let mut s = 0u64;
            for i in 0..1000 {
                s = s.wrapping_add(black_box(i));
            }
            black_box(s);
        });
        assert!(stats.iters >= 5);
        assert!(stats.mean_ns > 0.0);
        assert!(stats.p95_ns >= stats.median_ns);
        assert!(stats.median_ns >= stats.p05_ns);
    }

    #[test]
    fn throughput_inverse_of_latency() {
        let s = BenchStats {
            name: "x".into(),
            iters: 10,
            mean_ns: 1000.0,
            median_ns: 1000.0,
            p05_ns: 900.0,
            p95_ns: 1100.0,
            std_ns: 50.0,
        };
        assert!((s.throughput(1.0) - 1e6).abs() < 1e-6);
        assert!((s.throughput(100.0) - 1e8).abs() < 1e-3);
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12_000.0).contains("us"));
        assert!(fmt_ns(12_000_000.0).contains("ms"));
        assert!(fmt_ns(2e9).contains('s'));
    }
}
