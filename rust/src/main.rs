//! `pbm` — the photonic-Bayesian-machine coordinator CLI.
//!
//! Subcommands:
//!
//! * `train`      — SVI training via the AOT `train_step` HLO
//! * `eval`       — accuracy of a trained model (surrogate or photonic)
//! * `report`     — regenerate a paper figure/table (fig2, fig2e, fig4,
//!                  fig5, headline, nist)
//! * `calibrate`  — the Fig. 2(c,d) computation-error experiment
//! * `nist`       — SP800-22 battery on the chaotic-light source
//! * `serve`      — TCP serving gateway (router + dynamic batcher + engines)
//! * `worker`     — cluster backend: serve plan-seeded shards (role `worker`)
//! * `cluster`    — cluster coordinator: shard requests across workers with
//!                  health-checked failover and hedging
//! * `classify`   — client: classify a test image against a running server
//! * `scrape`     — client: fetch (and optionally lint) a server's
//!                  Prometheus text-format `/metrics` exposition
//! * `info`       — artifact inventory

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use photonic_bayes::bnn::UncertaintyPolicy;
use photonic_bayes::calibration;
use photonic_bayes::cli::Args;
use photonic_bayes::config::Config;
use photonic_bayes::coordinator::service::ServiceConfig;
use photonic_bayes::coordinator::{
    BackendKind, Engine, EngineConfig, ExecMode, PrefetchMode, RequestBudget, Router,
    SamplerConfig, StopRule,
};
use photonic_bayes::data::{Dataset, DatasetKind};
use photonic_bayes::entropy::{nist, ChaoticLightSource, HealthConfig};
use photonic_bayes::exec::CancelToken;
use photonic_bayes::observe::ObserveConfig;
use photonic_bayes::experiments::uncertainty::{accuracy_vs_samples, build_report, eval_split};
use photonic_bayes::photonics::{timing, MachineConfig, PhotonicMachine};
use photonic_bayes::runtime::artifact::artifacts_root;
use photonic_bayes::runtime::{ModelArtifacts, ParamStore};
use photonic_bayes::server::{serve, Client, ServerOptions};
use photonic_bayes::svi::{self, TrainConfig};
use photonic_bayes::util::mathstat::linfit;

fn main() {
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand() {
        Some("train") => cmd_train(args),
        Some("eval") => cmd_eval(args),
        Some("report") => cmd_report(args),
        Some("calibrate") => cmd_calibrate(args),
        Some("nist") => cmd_nist(args),
        Some("serve") => cmd_serve(args),
        Some("worker") => cmd_worker(args),
        Some("cluster") => cmd_cluster(args),
        Some("classify") => cmd_classify(args),
        Some("scrape") => cmd_scrape(args),
        Some("info") => cmd_info(args),
        other => {
            print_usage();
            if other.is_none() {
                Ok(())
            } else {
                Err(anyhow!("unknown subcommand {other:?}"))
            }
        }
    }
}

fn print_usage() {
    println!(
        "pbm {} — photonic Bayesian machine coordinator

USAGE: pbm <subcommand> [flags]

  train     --dataset digits|blood [--epochs N --lr F --kl-scale F --warmup N
            --seed N --eval-every N --out STEM]
  eval      --dataset D [--params FILE --samples N --backend photonic|digital|mean
            --mode M|surrogate --limit N --split test|ood|ambiguous|fashion
            --threads N --entropy-prefetch off|sync|on --entropy-block N
            --adaptive --min-samples N --max-samples N --target-confidence F]
  report    fig2 | fig2e | fig4 | fig5 | headline | nist [--params FILE
            --samples N --backend B --mode M --limit N --threads N
            --adaptive --min-samples N --max-samples N --target-confidence F]
  calibrate [--kernels N --outputs M --seed N]
  nist      [--bits N --bw GHZ]
  serve     [--config FILE --addr HOST:PORT --datasets digits,blood
            --models a,b --models-dir DIR --bank-budget-mb N
            --backend B --mode M --samples N --mi-threshold F
            --max-batch N --max-wait-ms N --threads N
            --entropy-prefetch off|sync|on --entropy-block N
            --adaptive --min-samples N --max-samples N --target-confidence F
            --health --health-window BITS --health-duty F
            --entropy-fallback digital|none
            --deadline-ms N --brownout --idle-timeout-ms N
            --trace --trace-slow-ms N]
            (--threads: sampling workers per engine; 1 = sequential,
             0 = one per core; --entropy-prefetch on: background entropy
             producers feed the sampling hot path via lock-free block
             rings; results are deterministic per (seed, threads, prefetch);
             --adaptive: sequential sampling with early stopping — see the
             [sampler] config table; clients may send per-request
             max_samples / target_confidence fields;
             --models: ONE engine virtualized across the listed model
             checkpoints (program registry + LRU bank cache, budget
             --bank-budget-mb, default 256); requests pick a model via the
             protocol's `model` field, first listed = default; /info shows
             per-model residency + hit/miss/switch counters;
             --health: online entropy-health monitor — NIST battery +
             min-entropy over tapped producer blocks, scorecards on /info;
             --entropy-fallback digital: swap degraded photonic sampling
             to the digital baseline; see the [health] config table;
             --deadline-ms: server-default request deadline (0 = none),
             clients may send per-request deadline_ms; full/over-budget
             queues shed with code=overloaded + retry_after_ms; --brownout
             opts into the mean-field degradation tier under sustained
             overload (responses flag degraded:true); --idle-timeout-ms:
             close silent connections, default 60000; see the [overload]
             config table; --trace: record per-request spans (admission →
             queue → batch_form → chunk[k] → respond) queryable via the
             `trace` protocol verb, with slow-request exemplars retained
             beyond --trace-slow-ms (default 250); responses stay bitwise
             identical with tracing on or off; see the [observe] config
             table; Prometheus text metrics via `pbm scrape` either way)
  worker    [--addr HOST:PORT --seed N --samples N --work-us N
            --health --health-window BITS --health-duty F
            --queue-depth N --idle-timeout-ms N --trace --trace-slow-ms N]
            (cluster backend: serves shard-scoped plan-seeded classifies
             over the synthetic substrate, answers hello with role=worker;
             probes read its entropy-health scorecards + latency
             percentiles from /info)
  cluster   [--config FILE --addr HOST:PORT --workers H:P[,H:P...]
            --seed N --samples N --image-size N --model NAME
            --hedge-ms N --hedge-factor F --probe-ms N --local-fallback
            --idle-timeout-ms N --trace --trace-slow-ms N]
            (coordinator: shards classifies across the worker pool; each
             request's plan_seed = lane_seed(seed, placement), so failover,
             hedging, and replay are bitwise-deterministic per
             (model, seed, threads, prefetch, rule, placement); admission
             capacity scales with pool size; workers whose entropy health
             degrades are drained within one probe interval (--probe-ms,
             0 = no probing); --local-fallback degrades into local
             execution instead of code=worker_unavailable when the pool is
             empty; see the [cluster] config table)
  classify  [--addr HOST:PORT --model D --split S --index I
            --max-samples N --target-confidence F --deadline-ms N]
            [--local --backend B --threads N --adaptive]  (in-process)
  scrape    [--addr HOST:PORT --lint]
            (fetch the server's Prometheus text exposition via the
             `metrics` protocol verb and print the body; --lint checks it
             against the exposition format and exits nonzero on errors)
  info
",
        photonic_bayes::version()
    );
}

/// Default parameter file for a dataset: the trained checkpoint if present,
/// otherwise the init params (with a warning).
fn default_params(root: &Path, dataset: &str) -> (PathBuf, bool) {
    let trained = root.join(dataset).join("params_trained.bin");
    if trained.exists() {
        (trained, true)
    } else {
        (root.join(dataset).join("params_init.bin"), false)
    }
}

/// Resolve the execution mode from `--backend` (photonic|digital|mean,
/// always the split path) or `--mode` (adds `surrogate`); `--backend` wins.
fn parse_mode(args: &Args) -> Result<ExecMode> {
    if let Some(b) = args.get("backend") {
        return Ok(ExecMode::Split(BackendKind::parse(b)?));
    }
    ExecMode::parse(&args.get_or("mode", "photonic"))
}

/// Assemble the sampler configuration from CLI flags layered over an
/// optional `[sampler]` config-file table.  `--target-confidence` implies
/// the confidence-gap rule; bare `--adaptive` selects the MI-band rule
/// (knobs: `mi_low` / `mi_high` / `stable` / `target_gap` / `chunk` under
/// `[sampler]`).  Validated here — the CLI boundary — so `--samples 0`,
/// `--min-samples > --max-samples`, and non-finite confidences die with a
/// typed error instead of a downstream panic.
fn parse_sampler(args: &Args, file: &Config) -> Result<SamplerConfig> {
    let min_explicit =
        args.get("min-samples").is_some() || file.get("sampler", "min_samples").is_some();
    let mut min_samples =
        args.get_usize("min-samples", file.get_usize("sampler", "min_samples", 2)?)?;
    let max_samples =
        args.get_usize("max-samples", file.get_usize("sampler", "max_samples", 0)?)?;
    if !min_explicit && max_samples != 0 {
        // a lone --max-samples below the *default* min is a clamp, not a
        // conflict (mirrors how a wire-request max_samples cap behaves);
        // only an explicitly-set min > max is rejected below
        min_samples = min_samples.min(max_samples);
    }
    let chunk = file.get_usize("sampler", "chunk", 0)?;
    let stable = file.get_usize("sampler", "stable", 2)?;
    let rule_name = file.get_or("sampler", "rule", "fixed");
    let target_conf = if args.has("target-confidence") {
        Some(args.get_f64("target-confidence", 0.0)?)
    } else if file.get("sampler", "target_confidence").is_some() {
        Some(file.get_f64("sampler", "target_confidence", 0.0)?)
    } else {
        None
    };
    let rule = if let Some(c) = target_conf {
        match StopRule::confidence_target(c).map_err(|e| anyhow!("target-confidence: {e}"))? {
            StopRule::ConfidenceGap { target_gap, .. } => StopRule::ConfidenceGap {
                target_gap,
                stable,
            },
            r => r,
        }
    } else if args.has("adaptive") || rule_name != "fixed" {
        match rule_name.as_str() {
            "fixed" | "uncertainty" => StopRule::UncertaintyResolved {
                mi_low: file.get_f64("sampler", "mi_low", 0.002)?,
                mi_high: file.get_f64("sampler", "mi_high", 0.08)?,
                stable,
            },
            "confidence-gap" => StopRule::ConfidenceGap {
                target_gap: file.get_f64("sampler", "target_gap", 0.5)?,
                stable,
            },
            other => {
                return Err(anyhow!(
                    "[sampler] rule must be fixed|confidence-gap|uncertainty, got {other}"
                ))
            }
        }
    } else {
        StopRule::Fixed(0)
    };
    let cfg = SamplerConfig {
        rule,
        min_samples,
        max_samples,
        chunk,
    };
    cfg.validate().map_err(|e| anyhow!("sampler config: {e}"))?;
    Ok(cfg)
}

/// Assemble the entropy-health monitor configuration from `--health*`
/// flags layered over an optional `[health]` config-file table.  Knobs are
/// range-clamped by `HealthConfig::sanitized`, so a typo'd duty cycle
/// degrades to the nearest sane value instead of wedging the monitor.
fn parse_health(args: &Args, file: &Config) -> Result<HealthConfig> {
    let d = HealthConfig::default();
    Ok(HealthConfig {
        enabled: args.has("health") || file.get_bool("health", "enabled", d.enabled)?,
        window_bits: args
            .get_usize("health-window", file.get_usize("health", "window_bits", d.window_bits)?)?,
        duty: args.get_f64("health-duty", file.get_f64("health", "duty", d.duty)?)?,
        ewma_alpha: file.get_f64("health", "ewma_alpha", d.ewma_alpha)?,
        fail_threshold: file.get_f64("health", "fail_threshold", d.fail_threshold)?,
        fail_consecutive: file.get_usize(
            "health",
            "fail_consecutive",
            d.fail_consecutive as usize,
        )? as u32,
        min_entropy_floor: file.get_f64("health", "min_entropy_floor", d.min_entropy_floor)?,
        serial_corr_cap: file.get_f64("health", "serial_corr_cap", d.serial_corr_cap)?,
    }
    .sanitized())
}

/// Assemble the tracing configuration from `--trace` / `--trace-slow-ms`
/// layered over an optional `[observe]` config-file table.
fn parse_observe(args: &Args, file: &Config) -> Result<ObserveConfig> {
    let d = ObserveConfig::default();
    Ok(ObserveConfig {
        trace: args.has("trace") || file.get_bool("observe", "trace", d.trace)?,
        trace_capacity: file.get_usize("observe", "trace_capacity", d.trace_capacity)?,
        slow_ms: args.get_u64(
            "trace-slow-ms",
            file.get_usize("observe", "slow_ms", d.slow_ms as usize)? as u64,
        )?,
        exemplars: file.get_usize("observe", "exemplars", d.exemplars)?,
    })
}

/// Resolve the opt-in automatic backend fallback (`--entropy-fallback` /
/// `[engine] entropy_fallback`).  `none` (or absent) disables it; any
/// backend name the `--backend` flag accepts is a valid target, though
/// `digital` is the intended one.
fn parse_entropy_fallback(args: &Args, file: &Config) -> Result<Option<BackendKind>> {
    let raw = args
        .get("entropy-fallback")
        .map(str::to_string)
        .or_else(|| file.get("engine", "entropy_fallback").map(str::to_string));
    match raw.as_deref() {
        None | Some("") | Some("none") | Some("off") => Ok(None),
        Some(s) => Ok(Some(
            BackendKind::parse(s).map_err(|e| anyhow!("entropy-fallback: {e}"))?,
        )),
    }
}

fn build_engine(args: &Args, dataset: &str) -> Result<Engine> {
    let root = artifacts_root();
    let arts = ModelArtifacts::load_dataset(&root, dataset)?;
    let params_path = match args.get("params") {
        Some(p) => PathBuf::from(p),
        None => {
            let (p, trained) = default_params(&root, dataset);
            if !trained {
                eprintln!(
                    "warning: no trained checkpoint, using init params ({})",
                    p.display()
                );
            }
            p
        }
    };
    let params = ParamStore::load_bin(&arts.meta, &params_path)?;
    let cfg = EngineConfig {
        n_samples: args.get_usize("samples", 10)?,
        mode: parse_mode(args)?,
        policy: UncertaintyPolicy::ood_only(args.get_f64("mi-threshold", 0.0185)?),
        calibrate: !args.has("no-calibrate"),
        machine: MachineConfig::default(),
        noise_bw_ghz: args.get_f64("noise-bw", 150.0)?,
        threads: args.get_usize("threads", 1)?,
        entropy_prefetch: PrefetchMode::parse(&args.get_or("entropy-prefetch", "off"))?,
        entropy_block: args.get_usize("entropy-block", 4096)?,
        sampler: parse_sampler(args, &Config::default())?,
        seed: args.get_u64("seed", 42)?,
        health: parse_health(args, &Config::default())?,
        entropy_fallback: parse_entropy_fallback(args, &Config::default())?,
        health_monitor: None,
        bank_budget_bytes: args.get_usize("bank-budget-mb", 256)? << 20,
        registry_metrics: None,
    };
    Engine::new(arts, params, cfg)
}

fn load_split(dataset: &str, split: &str) -> Result<Dataset> {
    let data_dir = artifacts_root().join("data");
    let (stem, kind) = match (dataset, split) {
        ("digits", "train") => ("digits_train", DatasetKind::InDomain),
        ("digits", "test") => ("digits_test", DatasetKind::InDomain),
        ("digits", "ambiguous") => ("ambiguous", DatasetKind::Aleatoric),
        ("digits", "fashion") => ("fashion", DatasetKind::Epistemic),
        ("blood", "train") => ("blood_train", DatasetKind::InDomain),
        ("blood", "test") => ("blood_test", DatasetKind::InDomain),
        ("blood", "ood") => ("blood_ood", DatasetKind::Epistemic),
        _ => return Err(anyhow!("unknown split {dataset}/{split}")),
    };
    Dataset::load(&data_dir, stem, kind)
}

// ---------------------------------------------------------------------------
// train
// ---------------------------------------------------------------------------

fn cmd_train(args: &Args) -> Result<()> {
    let dataset = args
        .get("dataset")
        .ok_or_else(|| anyhow!("--dataset required"))?
        .to_string();
    let root = artifacts_root();
    let arts = ModelArtifacts::load_dataset(&root, &dataset)?;
    let train_ds = load_split(&dataset, "train")?;
    let test_ds = load_split(&dataset, "test")?;
    let params = ParamStore::load_init(&arts.meta, &root.join(&dataset))?;

    let cfg = TrainConfig {
        epochs: args.get_usize("epochs", 12)?,
        lr: args.get_f64("lr", 2e-3)? as f32,
        kl_scale: args.get_f64("kl-scale", 1.0)? as f32,
        kl_warmup_epochs: args.get_usize("warmup", 4)?,
        seed: args.get_u64("seed", 1234)?,
        eval_every: args.get_usize("eval-every", 0)?,
        ..TrainConfig::default()
    };
    println!("training {dataset}: {cfg:?}");
    let (params, log) = svi::train(&arts, &train_ds, Some(&test_ds), params, &cfg)?;

    let eval = svi::evaluate(&arts, &test_ds, &params, 10, cfg.seed)?;
    println!(
        "final surrogate test accuracy: {:.2}% over {} inputs",
        eval.accuracy * 100.0,
        eval.n
    );

    let stem = args.get_or(
        "out",
        &format!("{}/{dataset}/params_trained", root.display()),
    );
    svi::checkpoint::save(Path::new(&stem), &params, &log)?;
    println!("checkpoint: {stem}.bin / {stem}.log.json");
    Ok(())
}

// ---------------------------------------------------------------------------
// eval
// ---------------------------------------------------------------------------

fn cmd_eval(args: &Args) -> Result<()> {
    let dataset = args
        .get("dataset")
        .ok_or_else(|| anyhow!("--dataset required"))?
        .to_string();
    let split = args.get_or("split", "test");
    let limit = args.get_usize("limit", usize::MAX)?;
    let ds = load_split(&dataset, &split)?;
    let mut engine = build_engine(args, &dataset)?;
    let scores = eval_split(&mut engine, &ds, limit)?;
    println!(
        "{dataset}/{split} ({} inputs, mode {:?}): accuracy {:.2}%  mean MI {:.4}  mean SE \
         {:.4}  mean samples/request {:.2} (rule {})",
        scores.labels.len(),
        engine.mode(),
        scores.accuracy() * 100.0,
        photonic_bayes::util::mathstat::mean(&scores.mi),
        photonic_bayes::util::mathstat::mean(&scores.se),
        scores.mean_samples(),
        engine.sampler_config().rule.name(),
    );
    println!("{}", engine.report());
    Ok(())
}

// ---------------------------------------------------------------------------
// report — the paper figures
// ---------------------------------------------------------------------------

fn cmd_report(args: &Args) -> Result<()> {
    match args.positional.get(1).map(String::as_str) {
        Some("fig2") => report_fig2(args),
        Some("fig2e") => report_fig2e(),
        Some("fig4b") => report_fig4b(args),
        Some("fig4") => report_uncertainty(args, "blood"),
        Some("fig5") => report_uncertainty(args, "digits"),
        Some("headline") => report_headline(),
        Some("nist") => cmd_nist(args),
        other => Err(anyhow!(
            "report target {other:?}; want fig2|fig2e|fig4|fig5|headline|nist"
        )),
    }
}

fn report_fig2(args: &Args) -> Result<()> {
    let kernels = args.get_usize("kernels", 25)?;
    let outputs = args.get_usize("outputs", 1024)?;
    let seed = args.get_u64("seed", 7)?;
    let mut machine = PhotonicMachine::with_defaults(seed);
    let rep = calibration::computation_error_experiment(&mut machine, kernels, outputs, seed ^ 99);
    println!(
        "Fig. 2(c,d) — computation error over {} random kernels",
        rep.kernels
    );
    println!("  mean error: {:.3}   [paper: 0.158]", rep.mean_error);
    println!("  std  error: {:.3}   [paper: 0.266]", rep.std_error);
    println!(
        "  measured-vs-target slope: mean {:.3}, std {:.3} (ideal 1.0)",
        rep.mean_slope, rep.std_slope
    );
    Ok(())
}

fn report_fig2e() -> Result<()> {
    let grating = photonic_bayes::photonics::grating::ChirpedGrating::paper_device(9, 0.5, 7);
    println!("Fig. 2(e) — group delay vs channel frequency");
    let mut fs = Vec::new();
    let mut ds = Vec::new();
    for k in 0..9 {
        let f = photonic_bayes::photonics::grating::channel_frequency_thz(k, 9);
        let d = grating.channel_delay_ps(k);
        println!("  ch {k}: f = {f:.3} THz, delay = {d:8.2} ps");
        fs.push(f);
        ds.push(d);
    }
    let (_, slope, r2) = linfit(&fs, &ds);
    println!("  fitted dispersion: {slope:.1} ps/THz (r2 = {r2:.6})   [paper: -93.1 ps/THz]");
    println!(
        "  grating latency: {:.1} ns (sub-100 ns claim)",
        grating.latency_ns()
    );
    Ok(())
}

/// Fig. 4(b): evolution of per-weight posterior sigma during SVI, read from
/// the training log the checkpoint saver writes next to the parameters.
fn report_fig4b(args: &Args) -> Result<()> {
    let dataset = args.get_or("dataset", "blood");
    let default_log = format!(
        "{}/{dataset}/params_trained.log.json",
        artifacts_root().display()
    );
    let path = args.get_or("log", &default_log);
    let text = std::fs::read_to_string(&path)
        .map_err(|e| anyhow!("{path}: {e} (run `pbm train --dataset {dataset}` first)"))?;
    let j = photonic_bayes::util::json::parse(&text).map_err(|e| anyhow!("{e}"))?;
    let epochs = j
        .req("epochs")
        .map_err(|e| anyhow!(e))?
        .as_arr()
        .ok_or_else(|| anyhow!("bad log"))?;
    println!("Fig. 4(b) — posterior sigma evolution of three tracked taps ({dataset}):");
    println!(
        "{:>6} {:>12} {:>12} {:>12} {:>10}",
        "epoch", "sigma[0]", "sigma[100]", "sigma[400]", "train acc"
    );
    for e in epochs {
        let tr = e
            .get("sigma_traces")
            .and_then(|v| v.as_f64_vec())
            .unwrap_or_default();
        println!(
            "{:>6} {:>12.5} {:>12.5} {:>12.5} {:>10.3}",
            e.get("epoch").and_then(|v| v.as_f64()).unwrap_or(-1.0),
            tr.first().copied().unwrap_or(f64::NAN),
            tr.get(1).copied().unwrap_or(f64::NAN),
            tr.get(2).copied().unwrap_or(f64::NAN),
            e.get("train_acc").and_then(|v| v.as_f64()).unwrap_or(f64::NAN),
        );
    }
    println!(
        "(mean and std of each weight distribution are learned from the data — paper Fig. 4b)"
    );
    Ok(())
}

fn report_uncertainty(args: &Args, dataset: &str) -> Result<()> {
    let limit = args.get_usize("limit", 1000)?;
    let mut engine = build_engine(args, dataset)?;
    let id = eval_split(&mut engine, &load_split(dataset, "test")?, limit)?;
    let (epi, alea) = if dataset == "blood" {
        (
            eval_split(&mut engine, &load_split(dataset, "ood")?, limit)?,
            None,
        )
    } else {
        (
            eval_split(&mut engine, &load_split(dataset, "fashion")?, limit)?,
            Some(eval_split(
                &mut engine,
                &load_split(dataset, "ambiguous")?,
                limit,
            )?),
        )
    };
    let n_classes = engine.n_classes();
    let rep = build_report(id, epi, alea, n_classes);
    let figure = if dataset == "blood" { "Fig. 4" } else { "Fig. 5" };
    println!(
        "{figure} — uncertainty evaluation on '{dataset}' (mode {:?})",
        engine.mode()
    );
    print!("{}", rep.summary());
    println!(
        "\nconfusion matrix with rejection @ MI > {:.5}:",
        rep.mi_threshold
    );
    let names: Vec<String> = (0..n_classes).map(|c| c.to_string()).collect();
    let name_refs: Vec<&str> = names.iter().map(String::as_str).collect();
    println!("{}", rep.confusion.render(&name_refs));
    if args.has("adaptive") || engine.sampler_config().rule.is_adaptive() {
        let targets = [0.6, 0.75, 0.9, 0.97];
        let curve =
            accuracy_vs_samples(&mut engine, &load_split(dataset, "test")?, limit, &targets)?;
        println!("\naccuracy vs mean samples/request (confidence-target sweep):");
        println!("{:>10} {:>14} {:>10}", "target", "mean samples", "accuracy");
        for p in &curve {
            println!(
                "{:>10.2} {:>14.2} {:>9.2}%",
                p.target_confidence,
                p.mean_samples,
                p.accuracy * 100.0
            );
        }
    }
    println!("{}", engine.report());
    Ok(())
}

fn report_headline() -> Result<()> {
    let h = timing::headline();
    println!("Headline metrics (derived from architecture constants):");
    println!(
        "  symbol period / conv latency: {:.1} ps      [paper: 37.5 ps]",
        h.symbol_period_ps
    );
    println!(
        "  probabilistic convolutions:   {:.2} G/s     [paper: 26.7 G/s]",
        h.convolutions_per_sec / 1e9
    );
    println!("  probabilistic MACs:           {:.1} G/s", h.macs_per_sec / 1e9);
    println!(
        "  digital interface:            {:.2} Tbit/s  [paper: 1.28 Tbit/s]",
        h.interface_tbit_per_sec
    );
    println!(
        "  grating delay step:           {:.2} ps/ch   [paper: 1 symbol/403 GHz]",
        h.channel_delay_step_ps
    );
    println!(
        "  grating latency:              {:.1} ns      [paper: sub-100 ns]",
        h.grating_latency_ns
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// calibrate / nist
// ---------------------------------------------------------------------------

fn cmd_calibrate(args: &Args) -> Result<()> {
    report_fig2(args)
}

fn cmd_nist(args: &Args) -> Result<()> {
    let bits = args.get_usize("bits", 100_000)?;
    let bw = args.get_f64("bw", 100.0)?;
    let mut src = ChaoticLightSource::with_defaults(args.get_u64("seed", 2024)?);
    println!("NIST SP800-22 battery over {bits} bits from the chaotic source (B = {bw} GHz):");
    let stream = src.extract_bits(bw, bits);
    let run = nist::run_battery(&stream);
    for r in &run.results {
        println!(
            "  {:<18} p = {:.4}  {}",
            r.name,
            r.p_value,
            if r.pass { "PASS" } else { "FAIL" }
        );
    }
    for e in &run.skipped {
        println!("  SKIP  {e}");
    }
    println!(
        "overall: {}",
        if run.all_pass() { "PASS (alpha = 0.01)" } else { "FAIL" }
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// serve / classify
// ---------------------------------------------------------------------------

fn cmd_serve(args: &Args) -> Result<()> {
    // layered configuration: built-in defaults < --config file < CLI flags
    let file = match args.get("config") {
        Some(p) => Config::load(Path::new(p))?,
        None => Config::default(),
    };
    let root = match args.get("models-dir") {
        Some(d) => PathBuf::from(d),
        None => artifacts_root(),
    };
    let datasets = args.get_or(
        "datasets",
        &file.get_or("engine", "datasets", "digits,blood"),
    );
    let mode = if args.has("backend") || args.has("mode") {
        parse_mode(args)?
    } else {
        file.get_mode("engine", "backend", ExecMode::photonic())?
    };
    let make_engine_cfg = || -> Result<EngineConfig> {
        Ok(EngineConfig {
            n_samples: args.get_usize("samples", file.get_usize("engine", "n_samples", 10)?)?,
            mode,
            policy: UncertaintyPolicy::ood_only(
                args.get_f64("mi-threshold", file.get_f64("engine", "mi_threshold", 0.0185)?)?,
            ),
            calibrate: !args.has("no-calibrate") && file.get_bool("engine", "calibrate", true)?,
            machine: MachineConfig::default(),
            noise_bw_ghz: 150.0,
            threads: args.get_usize("threads", file.get_usize("engine", "threads", 1)?)?,
            entropy_prefetch: PrefetchMode::parse(&args.get_or(
                "entropy-prefetch",
                &file.get_or("engine", "entropy_prefetch", "off"),
            ))?,
            entropy_block: args
                .get_usize("entropy-block", file.get_usize("engine", "entropy_block", 4096)?)?,
            sampler: parse_sampler(args, &file)?,
            seed: args.get_u64("seed", 42)?,
            health: parse_health(args, &file)?,
            entropy_fallback: parse_entropy_fallback(args, &file)?,
            // created per-engine by EngineHandle::spawn/spawn_multi so /info
            // can read scorecards without an engine round-trip
            health_monitor: None,
            bank_budget_bytes: args
                .get_usize("bank-budget-mb", file.get_usize("engine", "bank_budget_mb", 256)?)?
                << 20,
            // created by spawn_multi; /info reads residency from the handle
            registry_metrics: None,
        })
    };
    let make_svc_cfg = || -> Result<ServiceConfig> {
        let od = photonic_bayes::coordinator::OverloadConfig::default();
        Ok(ServiceConfig {
            max_batch: args.get_usize("max-batch", file.get_usize("batcher", "max_batch", 8)?)?,
            max_wait: std::time::Duration::from_millis(
                args.get_u64("max-wait-ms", file.get_usize("batcher", "max_wait_ms", 2)? as u64)?,
            ),
            queue_depth: file.get_usize("batcher", "queue_depth", 256)?,
            deadline_ms: args
                .get_u64("deadline-ms", file.get_usize("overload", "deadline_ms", 0)? as u64)?,
            overload: photonic_bayes::coordinator::OverloadConfig {
                work_capacity: file.get_usize("overload", "work_capacity", 0)? as u64,
                clamp_pressure: file.get_f64("overload", "clamp_pressure", od.clamp_pressure)?,
                clamp_samples: file.get_usize("overload", "clamp_samples", 0)?,
                brownout_pressure: file
                    .get_f64("overload", "brownout_pressure", od.brownout_pressure)?,
                brownout: args.has("brownout") || file.get_bool("overload", "brownout", false)?,
                ..od
            },
            observe: parse_observe(args, &file)?,
        })
    };
    // multi-model registry: `--models a,b` (or a `[models]` table: model
    // name = artifact subdirectory) virtualizes ONE engine across all
    // listed checkpoints behind a shared LRU bank cache; the first entry is
    // the default model.  Without either, fall back to one engine per
    // dataset (the pre-registry layout).
    let mut specs: Vec<photonic_bayes::coordinator::ModelSpec> =
        match args.get("models").or_else(|| args.get("model")) {
            Some(list) => list
                .split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(photonic_bayes::coordinator::ModelSpec::named)
                .collect(),
            None => file
                .items("models")
                .into_iter()
                .map(|(name, dir)| photonic_bayes::coordinator::ModelSpec {
                    name,
                    dir,
                    params_path: None,
                })
                .collect(),
        };
    let mut router = Router::new();
    if !specs.is_empty() {
        for spec in &mut specs {
            let (params_path, trained) = default_params(&root, &spec.dir);
            if !trained {
                eprintln!("warning: serving '{}' with untrained init params", spec.name);
            }
            spec.params_path = Some(params_path);
        }
        router.register(
            photonic_bayes::coordinator::service::EngineHandle::spawn_multi(
                &root,
                specs,
                make_engine_cfg()?,
                make_svc_cfg()?,
            )?,
        );
    } else {
        for ds in datasets.split(',') {
            let (params_path, trained) = default_params(&root, ds);
            if !trained {
                eprintln!("warning: serving '{ds}' with untrained init params");
            }
            router.register(photonic_bayes::coordinator::service::EngineHandle::spawn(
                &root,
                ds,
                Some(&params_path),
                make_engine_cfg()?,
                make_svc_cfg()?,
            )?);
        }
    }
    let opts = ServerOptions {
        addr: args.get_or("addr", &file.get_or("server", "addr", "127.0.0.1:7878")),
        workers: args.get_usize("workers", file.get_usize("server", "workers", 8)?)?,
        idle_timeout: std::time::Duration::from_millis(args.get_u64(
            "idle-timeout-ms",
            file.get_usize("server", "idle_timeout_ms", 60_000)? as u64,
        )?),
    };
    let cancel = CancelToken::new();
    serve(router, opts, cancel, |addr| println!("listening on {addr}"))
}

/// `pbm worker` — a cluster backend: the synthetic deterministic substrate
/// behind a gateway whose `hello` role is `worker`.  Serves plan-seeded
/// (shard-scoped) classifies bitwise-reproducibly, so any worker is
/// interchangeable with any other for the same `plan_seed`.
fn cmd_worker(args: &Args) -> Result<()> {
    let seed = args.get_u64("seed", 7)?;
    let n_samples = args.get_usize("samples", 8)?;
    let work = std::time::Duration::from_micros(args.get_u64("work-us", 0)?);
    let health = if args.has("health") {
        let hc = parse_health(args, &Config::default())?;
        Some(std::sync::Arc::new(
            photonic_bayes::entropy::health::Monitor::new(hc),
        ))
    } else {
        None
    };
    let svc = ServiceConfig {
        queue_depth: args.get_usize("queue-depth", 256)?,
        observe: parse_observe(args, &Config::default())?,
        ..ServiceConfig::default()
    };
    let handle = photonic_bayes::coordinator::service::EngineHandle::spawn_executor(
        "synth",
        vec!["synth".to_string()],
        health,
        n_samples,
        svc,
        move || {
            let mut e = photonic_bayes::coordinator::SynthExecutor::new(seed, n_samples);
            e.work_per_sample = work;
            Ok(e)
        },
    )?;
    let mut router = Router::new();
    router.set_role("worker");
    router.register(handle);
    let opts = ServerOptions {
        addr: args.get_or("addr", "127.0.0.1:7979"),
        workers: args.get_usize("gateway-workers", 4)?,
        idle_timeout: std::time::Duration::from_millis(args.get_u64("idle-timeout-ms", 60_000)?),
    };
    serve(router, opts, CancelToken::new(), |addr| {
        println!("worker listening on {addr}")
    })
}

/// `pbm cluster` — the coordinator: shard classify traffic across a pool
/// of `pbm worker` processes with health probes, failover, and hedging.
fn cmd_cluster(args: &Args) -> Result<()> {
    use photonic_bayes::cluster;
    let file = match args.get("config") {
        Some(p) => Config::load(Path::new(p))?,
        None => Config::default(),
    };
    let workers_raw = args
        .get("workers")
        .map(str::to_string)
        .or_else(|| file.get("cluster", "workers").map(str::to_string))
        .ok_or_else(|| anyhow!("--workers HOST:PORT[,HOST:PORT...] required"))?;
    let addrs: Vec<String> = workers_raw
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(str::to_string)
        .collect();
    let cfg = cluster::ClusterConfig {
        seed: args.get_u64("seed", file.get_usize("cluster", "seed", 0x00C1_0572)? as u64)?,
        model: args.get_or("model", &file.get_or("cluster", "model", "synth")),
        image_size: args.get_usize("image-size", file.get_usize("cluster", "image_size", 4)?)?,
        n_samples: args.get_usize("samples", file.get_usize("cluster", "n_samples", 8)?)?,
        hedge_factor: args.get_f64("hedge-factor", file.get_f64("cluster", "hedge_factor", 3.0)?)?,
        hedge_min: std::time::Duration::from_millis(
            args.get_u64("hedge-ms", file.get_usize("cluster", "hedge_min_ms", 50)? as u64)?,
        ),
        probe_interval: std::time::Duration::from_millis(args.get_u64(
            "probe-ms",
            file.get_usize("cluster", "probe_interval_ms", 1000)? as u64,
        )?),
        client: photonic_bayes::server::tcp::ClientConfig::default(),
        local_fallback: args.has("local-fallback")
            || file.get_bool("cluster", "local_fallback", false)?,
    };
    let svc = ServiceConfig {
        queue_depth: file.get_usize("batcher", "queue_depth", 256)?,
        observe: parse_observe(args, &file)?,
        ..ServiceConfig::default()
    };
    let probe_interval = cfg.probe_interval;
    let (handle, pool) = cluster::spawn_coordinator(cfg, addrs, svc)?;
    let mut router = Router::new();
    router.set_role("coordinator");
    router.register(handle);
    let cancel = CancelToken::new();
    let probe = (!probe_interval.is_zero())
        .then(|| cluster::spawn_probe_loop(pool, probe_interval, cancel.clone()));
    let opts = ServerOptions {
        addr: args.get_or("addr", &file.get_or("server", "addr", "127.0.0.1:7878")),
        workers: args.get_usize("gateway-workers", 8)?,
        idle_timeout: std::time::Duration::from_millis(args.get_u64("idle-timeout-ms", 60_000)?),
    };
    let res = serve(router, opts, cancel.clone(), |addr| {
        println!("coordinator listening on {addr}")
    });
    cancel.cancel();
    if let Some(p) = probe {
        let _ = p.join();
    }
    res
}

fn cmd_classify(args: &Args) -> Result<()> {
    // `--model` is the modern name for the target; `--dataset` still works
    let dataset = match args.get("model") {
        Some(m) => m.to_string(),
        None => args.get_or("dataset", "digits"),
    };
    let split = args.get_or("split", "test");
    let index = args.get_usize("index", 0)?;
    let ds = load_split(&dataset, &split)?;
    if index >= ds.n {
        return Err(anyhow!("index {index} out of range ({} images)", ds.n));
    }
    // `--local` (or a `--backend` with no gateway address) serves the image
    // in-process through the ProbConvBackend trait instead of a running
    // gateway — the quickest way to compare sampling substrates end-to-end.
    // With a gateway address the backend is the *server's* choice, so
    // `--backend` alongside `--addr` is ignored with a warning, and
    // `--local` alongside `--addr` is a hard conflict.
    if args.has("local") && args.has("addr") {
        return Err(anyhow!("--local and --addr conflict: pick in-process or gateway"));
    }
    let local = args.has("local") || (args.has("backend") && args.get("addr").is_none());
    if !local && args.has("backend") {
        eprintln!("warning: --backend is ignored when classifying against a gateway (use --local)");
    }
    // per-request budget overrides ride the wire (or the local engine call)
    let budget = RequestBudget {
        max_samples: match args.get("max-samples") {
            Some(_) => Some(args.get_usize("max-samples", 0)?),
            None => None,
        },
        target_confidence: match args.get("target-confidence") {
            Some(_) => Some(args.get_f64("target-confidence", 0.0)?),
            None => None,
        },
    };
    budget
        .validate()
        .map_err(|e| anyhow!("sample budget: {e}"))?;
    if local {
        let mut engine = build_engine(args, &dataset)?;
        let r = engine
            .classify_with_budget(ds.image(index), 1, &budget)?
            .into_iter()
            .next()
            .unwrap();
        println!("true label: {}", ds.labels[index]);
        println!(
            "backend {} ({} of max {} passes, rule {}): predicted {} | MI {:.4} SE {:.3} \
             agreement {:.0}% | {:?}",
            engine.backend_kind(),
            r.samples_used,
            engine.samples_per_request(),
            engine.sampler_config().rule.name(),
            r.predictive.predicted,
            r.predictive.mutual_information,
            r.predictive.softmax_entropy,
            r.predictive.agreement * 100.0,
            r.decision,
        );
        println!("{}", engine.report());
        return Ok(());
    }
    let addr = args.get_or("addr", "127.0.0.1:7878");
    let deadline_ms = match args.get("deadline-ms") {
        Some(_) => Some(args.get_u64("deadline-ms", 0)?),
        None => None,
    };
    let mut client = Client::connect(&addr)?;
    let resp = client.classify_opts(&dataset, ds.image(index), &budget, deadline_ms)?;
    println!("true label: {}", ds.labels[index]);
    println!("response:   {}", resp.to_string_pretty());
    Ok(())
}

/// `pbm scrape` — fetch the Prometheus text exposition from a running
/// gateway (the `metrics` protocol verb) and print the body.  `--lint`
/// runs the in-repo exposition-format checker and exits nonzero on any
/// violation — the CI step that keeps the scrape surface well-formed.
fn cmd_scrape(args: &Args) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:7878");
    let mut client = Client::connect(&addr)?;
    let body = client.metrics()?;
    print!("{body}");
    if args.has("lint") {
        let errs = photonic_bayes::observe::expo::lint(&body);
        if !errs.is_empty() {
            for e in &errs {
                eprintln!("lint: {e}");
            }
            return Err(anyhow!("{} exposition lint error(s)", errs.len()));
        }
        eprintln!("lint: ok ({} bytes)", body.len());
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// info
// ---------------------------------------------------------------------------

fn cmd_info(_args: &Args) -> Result<()> {
    let root = artifacts_root();
    println!("artifacts root: {}", root.display());
    for ds in ["digits", "blood"] {
        let dir = root.join(ds);
        if !dir.join("meta.json").exists() {
            println!("  {ds}: MISSING (run `make artifacts`)");
            continue;
        }
        let arts = ModelArtifacts::load(&dir)?;
        let m = &arts.meta;
        let (params, trained) = default_params(&root, ds);
        println!(
            "  {ds}: {} classes, {}x{}x{} inputs, {} params, prob block {}ch@{}x{}, {} entry points, params: {} ({})",
            m.n_classes,
            m.in_channels,
            m.img_hw,
            m.img_hw,
            m.num_params,
            m.prob_ch,
            m.prob_hw,
            m.prob_hw,
            arts.entry_points().len(),
            params.file_name().unwrap().to_string_lossy(),
            if trained { "trained" } else { "INIT ONLY" },
        );
    }
    let h = timing::headline();
    println!(
        "machine: {} channels, {:.1} ps/conv, {:.2} Tbit/s interface",
        timing::NUM_CHANNELS,
        h.symbol_period_ps,
        h.interface_tbit_per_sec
    );
    Ok(())
}
