//! # photonic-bayes
//!
//! Reproduction of *"Uncertainty Reasoning with Photonic Bayesian Machines"*
//! (Brückerhoff-Plückelmann et al., 2025) as a three-layer Rust + JAX +
//! Pallas system:
//!
//! * **L1** — a Pallas kernel modeling the machine's probabilistic nine-tap
//!   convolution (build time, `python/compile/kernels/`),
//! * **L2** — the hybrid Bayesian Neural Network and its SVI training step in
//!   JAX, AOT-lowered to HLO text artifacts (`python/compile/model.py`),
//! * **L3** — this crate: the serving coordinator, the photonic-hardware
//!   simulator substrate, the SVI training driver, and the PJRT runtime that
//!   executes the AOT artifacts.  Python never runs on the request path.
//!
//! The photonic Bayesian machine itself is simulated faithfully in
//! [`photonics`]: a chaotic ASE light source whose per-channel filtered
//! intensity is Gamma-distributed with `M = B·T + 1` degrees of freedom (so
//! channel *power* programs a weight's mean and channel *bandwidth* its
//! standard deviation), an 8-bit 80 GSPS DAC/EOM input path, a chirped
//! grating applying a −93.1 ps/THz frequency-dependent group delay (one
//! symbol per 403 GHz channel), and a photodetector + 8-bit ADC readout.
//!
//! ## Sampling backends
//!
//! The serving coordinator is generic over the *sampling substrate* of the
//! probabilistic block through [`backend::ProbConvBackend`]: one API for
//! programming a Gaussian-weight kernel bank and executing a batched
//! [`backend::SamplePlan`] (all N stochastic samples × B batch items per
//! call).  Pick a backend with `--backend` on the CLI, `backend = ...` in a
//! serving config, or [`coordinator::ExecMode::Split`] in code:
//!
//! | `--backend` | implementation | randomness | N passes | use it for |
//! |-------------|----------------|------------|----------|------------|
//! | `photonic` | [`backend::PhotonicSimBackend`] | chaotic light (Gamma speckle per symbol) | `n_samples` | paper-faithful serving; calibration + hardware-floor studies |
//! | `digital` | [`backend::DigitalBaselineBackend`] | xoshiro256++ + Box–Muller per weight per symbol | `n_samples` | the paper's digital comparison point; PRNG-bottleneck throughput measurements |
//! | `mean` | [`backend::MeanFieldBackend`] | none (mean weights) | 1 | uncertainty-free fast serving; ablation control |
//!
//! `--mode surrogate` bypasses the split path entirely and runs the AOT
//! `fwd_full` HLO with [`backend::EpsSource`] noise — the same
//! photonic-vs-digital seam, applied to the reparameterized `eps` operand
//! instead of the convolution.  `paper_tables` (`backends` section) and
//! `coordinator_micro` report photonic-vs-digital sampling throughput
//! side by side.
//!
//! ## Adaptive sampling
//!
//! [`sampler`] makes inference *anytime*: predictive samples are drawn in
//! chunks and a pluggable [`sampler::StopRule`] stops as soon as the
//! decision is statistically resolved (`--adaptive` /
//! `--target-confidence` on the CLI, `[sampler]` in a serving config,
//! `max_samples` / `target_confidence` per request on the wire).  The
//! `Fixed` compatibility default reproduces the pre-sampler engine
//! bit-for-bit; see the README's "Adaptive sampling" section for the
//! extended `(seed, threads, prefetch, rule)` reproducibility contract.
//!
//! ## Multi-model serving
//!
//! [`registry`] virtualizes the one simulated machine across many named
//! checkpoints: a [`registry::ProgramRegistry`] of models behind one
//! engine, per-model bank state parked in an LRU cache under a byte budget
//! (`--bank-budget-mb`), a `model` field on the wire with typed
//! `unknown_model` errors, and model-aware batch grouping so program
//! switches amortize.  Outputs replay bitwise per
//! `(model, seed, threads, prefetch, rule)`; `/info` reports per-model
//! residency and hit/miss/switch counters.  See the README's "Multi-model
//! serving" section.
//!
//! ## Overload & fault tolerance
//!
//! The request lifecycle is overload-safe end to end: requests carry an
//! optional deadline (`deadline_ms` on the wire, or a server default) and
//! are shed with a typed `deadline_exceeded` error — at dequeue if already
//! expired, or mid-run at an adaptive chunk boundary with the samples
//! actually spent.  Admission control ([`coordinator::OverloadControl`])
//! tracks queued work in estimated samples and rejects beyond capacity
//! with `overloaded` + `retry_after_ms`; sustained pressure first clamps
//! per-request sample budgets, then (opt-in) browns out to the mean-field
//! backend, flagging responses `degraded: true`.  A panic while serving a
//! batch is caught, answered as `internal_error` to that batch only, and
//! the engine rebuilds deterministically — post-recovery outputs replay
//! bitwise against a fresh engine.  The seeded fault-injection harness
//! ([`util::fault`], `--features fault-injection`) drives the chaos suite
//! (`rust/tests/chaos.rs`); see the README's "Overload & fault tolerance"
//! section for the error-code table.
//!
//! ## Cluster mode
//!
//! [`cluster`] scales serving out to N backend worker processes behind one
//! coordinator, all speaking the same line protocol (`pbm worker` serves
//! shards, `pbm cluster` fronts them).  Each request gets a *placement*;
//! its entropy stream is [`cluster::lane_seed`]`(seed, placement)` and
//! ships on the wire as `plan_seed`, so any worker — the primary, a
//! failover target after a crash, a hedge racing a straggler, or the
//! coordinator's own degraded local fallback — produces the bitwise-same
//! answer: the replay contract extends to
//! `(model, seed, threads, prefetch, rule, placement)`.  Worker health
//! (`Healthy → Suspect → Down → Recovering`) folds each worker's
//! entropy-health scorecards and latency percentiles from `/info` into
//! routing: degraded-randomness workers are drained within one probe
//! interval.  An empty pool answers a typed `worker_unavailable` error.
//! The chaos suite (`rust/tests/cluster_chaos.rs`) proves no request is
//! lost or doubled across mid-batch worker kills, stalls, and garbage
//! responses; see the README's "Cluster mode" section.
//!
//! ## Observability
//!
//! [`observe`] threads per-request tracing and one metrics surface
//! through every layer.  With tracing on (`--trace`, `[observe]` in a
//! config), each request is keyed by a `request_id` — minted at the
//! gateway or supplied by the client as a decimal string, and forwarded
//! coordinator → worker so failover/hedging stitches into one trace —
//! and a lock-free [`observe::TraceRecorder`] ring records disjoint
//! spans `admission → queue → batch_form → chunk[k] → respond` (with
//! `sample_conv`/`fwd_post` chunk children and cluster annotations)
//! whose durations sum to wall-clock latency.  Slow requests retain
//! verbatim exemplars, queryable with the `trace` protocol verb.  The
//! `metrics` verb renders one Prometheus text exposition
//! ([`observe::prom`]) over serving counters, latency histograms,
//! registry/health/cluster state, and per-model uncertainty histograms
//! (predictive entropy, mutual information, `samples_used`);
//! `pbm scrape --lint` checks it with the in-repo
//! [`observe::expo::lint`].  Tracing never changes an output byte and
//! the replay contract is untouched; `PBM_LOG_FORMAT=json` switches
//! [`util::logging`] to structured JSON lines carrying `request_id` on
//! the failure paths.  See the README's "Observability" section.
//!
//! See `DESIGN.md` for the full system inventory and the experiment index
//! mapping every paper figure/table to a bench target.

pub mod backend;
pub mod benchkit;
pub mod bnn;
pub mod calibration;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod entropy;
pub mod exec;
pub mod experiments;
pub mod observe;
pub mod photonics;
pub mod proptest_mini;
pub mod registry;
pub mod runtime;
pub mod sampler;
pub mod server;
pub mod svi;
pub mod util;

/// Crate version (mirrors `Cargo.toml`).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
