//! Minimal property-based testing harness (proptest substitute).
//!
//! Seeded generators + a case runner that, on failure, reports the seed and
//! the failing case index so the exact input can be reproduced by rerunning
//! with `PBM_PROPTEST_SEED`.  Used by the L3 invariant tests (routing,
//! batching, uncertainty-metric invariants).

use crate::entropy::{BitSource, Xoshiro256pp};

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        let seed = std::env::var("PBM_PROPTEST_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0FFEE);
        Self { cases: 64, seed }
    }
}

/// A seeded input generator.
pub trait Gen {
    type Output;
    fn generate(&self, rng: &mut Xoshiro256pp) -> Self::Output;
}

impl<T, F: Fn(&mut Xoshiro256pp) -> T> Gen for F {
    type Output = T;
    fn generate(&self, rng: &mut Xoshiro256pp) -> T {
        self(rng)
    }
}

/// Run `prop` over `cfg.cases` generated inputs; panics with seed/case info
/// on the first failure.
pub fn check<G, P>(name: &str, cfg: &Config, gen: G, prop: P)
where
    G: Gen,
    G::Output: std::fmt::Debug,
    P: Fn(&G::Output) -> Result<(), String>,
{
    let mut rng = Xoshiro256pp::new(cfg.seed);
    for case in 0..cfg.cases {
        let input = gen.generate(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed at case {case} (seed {}):\n  input: {:?}\n  {msg}",
                cfg.seed, input
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Common generators
// ---------------------------------------------------------------------------

/// Uniform f32 in [lo, hi).
pub fn f32_in(lo: f32, hi: f32) -> impl Fn(&mut Xoshiro256pp) -> f32 {
    move |rng| lo + rng.next_f32() * (hi - lo)
}

/// usize in [lo, hi).
pub fn usize_in(lo: usize, hi: usize) -> impl Fn(&mut Xoshiro256pp) -> usize {
    move |rng| lo + rng.next_below(hi - lo)
}

/// Vector of f32s with random length in [min_len, max_len).
pub fn vec_f32(
    min_len: usize,
    max_len: usize,
    lo: f32,
    hi: f32,
) -> impl Fn(&mut Xoshiro256pp) -> Vec<f32> {
    move |rng| {
        let n = min_len + rng.next_below(max_len - min_len);
        (0..n).map(|_| lo + rng.next_f32() * (hi - lo)).collect()
    }
}

/// Random probability matrix (n_samples x n_classes), rows sum to 1.
pub fn prob_matrix(
    max_samples: usize,
    max_classes: usize,
) -> impl Fn(&mut Xoshiro256pp) -> Vec<Vec<f32>> {
    move |rng| {
        let n = 1 + rng.next_below(max_samples);
        let c = 2 + rng.next_below(max_classes.saturating_sub(2).max(1));
        (0..n)
            .map(|_| {
                let mut row: Vec<f32> = (0..c).map(|_| rng.next_f32() + 1e-4).collect();
                let s: f32 = row.iter().sum();
                row.iter_mut().for_each(|x| *x /= s);
                row
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let cfg = Config {
            cases: 50,
            seed: 1,
        };
        check("sum-commutes", &cfg, vec_f32(1, 20, -5.0, 5.0), |v| {
            let a: f32 = v.iter().sum();
            let b: f32 = v.iter().rev().sum();
            if (a - b).abs() < 1e-3 {
                Ok(())
            } else {
                Err(format!("{a} != {b}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        let cfg = Config { cases: 5, seed: 2 };
        check("always-fails", &cfg, usize_in(0, 10), |_| Err("nope".into()));
    }

    #[test]
    fn prob_matrix_rows_normalized() {
        let cfg = Config { cases: 30, seed: 3 };
        check("rows-sum-1", &cfg, prob_matrix(12, 10), |m| {
            for row in m {
                let s: f32 = row.iter().sum();
                if (s - 1.0).abs() > 1e-4 {
                    return Err(format!("row sums to {s}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn deterministic_per_seed() {
        let mut r1 = Xoshiro256pp::new(9);
        let mut r2 = Xoshiro256pp::new(9);
        let g = vec_f32(1, 10, 0.0, 1.0);
        assert_eq!(g(&mut r1), g(&mut r2));
    }
}
