//! Tiny declarative flag parser: `--key value`, `--key=value`, bare
//! `--switch`, and positional arguments.

use std::collections::HashMap;

use anyhow::{anyhow, Result};

/// Parsed command-line arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse a token list (not including argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Self {
        let mut out = Args::default();
        let toks: Vec<String> = tokens.into_iter().collect();
        let mut i = 0;
        while i < toks.len() {
            let t = &toks[i];
            if let Some(stripped) = t.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < toks.len() && !toks[i + 1].starts_with("--") {
                    out.flags.insert(stripped.to_string(), toks[i + 1].clone());
                    i += 1;
                } else {
                    out.switches.push(stripped.to_string());
                }
            } else {
                out.positional.push(t.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn has(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key) || self.flags.contains_key(key)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{key} {v}: {e}")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{key} {v}: {e}")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{key} {v}: {e}")),
        }
    }

    /// First positional argument (the subcommand).
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(String::as_str)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_mixed_forms() {
        let a = parse("train --dataset digits --epochs=12 --verbose --lr 0.002");
        assert_eq!(a.subcommand(), Some("train"));
        assert_eq!(a.get("dataset"), Some("digits"));
        assert_eq!(a.get_usize("epochs", 0).unwrap(), 12);
        assert!(a.has("verbose"));
        assert_eq!(a.get_f64("lr", 0.0).unwrap(), 0.002);
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse("x --n abc");
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert!(a.get_usize("n", 0).is_err());
    }

    #[test]
    fn switch_before_flag_not_swallowed() {
        let a = parse("cmd --flag --key value");
        assert!(a.has("flag"));
        assert_eq!(a.get("key"), Some("value"));
    }

    #[test]
    fn multiple_positionals() {
        let a = parse("report fig4 --params x.bin");
        assert_eq!(a.positional, vec!["report", "fig4"]);
    }
}
