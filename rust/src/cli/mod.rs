//! Command-line interface substrate (clap substitute).

pub mod args;

pub use args::Args;
