//! Deterministic fault injection for the chaos test suite.
//!
//! Production code marks interesting failure sites with
//! [`faultpoint`]`("name")`.  Without the `fault-injection` cargo feature
//! the call is an inlined `Ok(())` — the serving path carries no
//! registry lookup, no atomics, nothing.  With the feature enabled,
//! tests arm a named point with a [`Fault`] (panic, IO error, delay)
//! and a [`Trigger`] (always, on the n-th traversal, or seeded
//! pseudo-random), and the next traversal fires it.
//!
//! Triggers are deterministic: `Nth` counts traversals, `Seeded` draws
//! from a splitmix64 stream owned by the armed point.  The same arming
//! plus the same traversal order reproduces the same faults bitwise —
//! which is what lets the chaos suite assert that post-recovery outputs
//! replay against an unfaulted run.
//!
//! Fault points are process-global; concurrent tests must use distinct
//! point names (the suite namespaces them per test).

/// Tiny shared PRNG step (splitmix64).  Also used for client retry
/// jitter — one well-known generator instead of several ad-hoc ones.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// What an armed fault point does when its trigger fires.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Fault {
    /// `panic!` at the fault point (exercises `catch_unwind` recovery).
    Panic,
    /// Return an `std::io::Error` from the fault point.
    IoError,
    /// Sleep for the given milliseconds, then continue normally.
    DelayMs(u64),
}

/// When an armed fault point fires.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Trigger {
    /// Every traversal.
    Always,
    /// Only the n-th traversal after arming (1-based); others pass.
    Nth(u64),
    /// Fire with probability `prob_milli`/1000 per traversal, drawn
    /// from a splitmix64 stream seeded with `seed`.
    Seeded { seed: u64, prob_milli: u32 },
}

/// Traverse the named fault point.  `Err` only ever carries an injected
/// [`Fault::IoError`]; callers on `anyhow` paths map it with `?` via
/// `map_err`.  With the `fault-injection` feature off this is an
/// inlined `Ok(())`.
#[cfg(not(feature = "fault-injection"))]
#[inline(always)]
pub fn faultpoint(_name: &str) -> std::io::Result<()> {
    Ok(())
}

#[cfg(feature = "fault-injection")]
pub use injected::{arm, disarm, disarm_all, faultpoint, hits};

#[cfg(feature = "fault-injection")]
mod injected {
    use super::{splitmix64, Fault, Trigger};
    use std::collections::HashMap;
    use std::sync::{Mutex, OnceLock};

    struct Armed {
        fault: Fault,
        trigger: Trigger,
        traversals: u64,
        rng: u64,
    }

    #[derive(Default)]
    struct Registry {
        armed: HashMap<String, Armed>,
        /// Traversal counts per point name, armed or not.
        hits: HashMap<String, u64>,
    }

    fn registry() -> &'static Mutex<Registry> {
        static REG: OnceLock<Mutex<Registry>> = OnceLock::new();
        REG.get_or_init(|| Mutex::new(Registry::default()))
    }

    fn lock() -> std::sync::MutexGuard<'static, Registry> {
        // a panic injected *after* the guard drops can still poison the
        // mutex via an unlucky unwind elsewhere; the registry state is
        // plain data, so recover it
        registry().lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Arm `name` with a fault and trigger (replacing any previous
    /// arming and resetting its traversal count / RNG stream).
    pub fn arm(name: &str, fault: Fault, trigger: Trigger) {
        let rng = match &trigger {
            Trigger::Seeded { seed, .. } => *seed,
            _ => 0,
        };
        lock().armed.insert(
            name.to_string(),
            Armed {
                fault,
                trigger,
                traversals: 0,
                rng,
            },
        );
    }

    /// Disarm one point (no-op if not armed).
    pub fn disarm(name: &str) {
        lock().armed.remove(name);
    }

    /// Disarm every point and clear traversal counters.
    pub fn disarm_all() {
        let mut reg = lock();
        reg.armed.clear();
        reg.hits.clear();
    }

    /// Times the named point has been traversed since `disarm_all`.
    pub fn hits(name: &str) -> u64 {
        lock().hits.get(name).copied().unwrap_or(0)
    }

    /// Traverse the named fault point (feature-on implementation).
    pub fn faultpoint(name: &str) -> std::io::Result<()> {
        // decide under the lock, act after dropping it, so an injected
        // panic never unwinds while holding the registry mutex
        let action: Option<Fault> = {
            let mut reg = lock();
            *reg.hits.entry(name.to_string()).or_insert(0) += 1;
            match reg.armed.get_mut(name) {
                None => None,
                Some(a) => {
                    a.traversals += 1;
                    let fire = match &a.trigger {
                        Trigger::Always => true,
                        Trigger::Nth(n) => a.traversals == *n,
                        Trigger::Seeded { prob_milli, .. } => {
                            splitmix64(&mut a.rng) % 1000 < u64::from(*prob_milli)
                        }
                    };
                    fire.then(|| a.fault.clone())
                }
            }
        };
        match action {
            None => Ok(()),
            Some(Fault::Panic) => panic!("injected fault at '{name}'"),
            Some(Fault::IoError) => Err(std::io::Error::other(format!(
                "injected IO fault at '{name}'"
            ))),
            Some(Fault::DelayMs(ms)) => {
                std::thread::sleep(std::time::Duration::from_millis(ms));
                Ok(())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_deterministic_and_mixing() {
        let mut a = 42u64;
        let mut b = 42u64;
        let xs: Vec<u64> = (0..4).map(|_| splitmix64(&mut a)).collect();
        let ys: Vec<u64> = (0..4).map(|_| splitmix64(&mut b)).collect();
        assert_eq!(xs, ys);
        assert_ne!(xs[0], xs[1]);
    }

    #[test]
    fn disabled_faultpoint_is_ok() {
        // with the feature off this is the no-op; with it on, an
        // un-armed point passes — either way Ok
        assert!(faultpoint("never.armed").is_ok());
    }

    #[cfg(feature = "fault-injection")]
    mod injected {
        use super::super::*;

        #[test]
        fn nth_trigger_fires_exactly_once() {
            let name = "test.fault.nth";
            arm(name, Fault::IoError, Trigger::Nth(3));
            assert!(faultpoint(name).is_ok());
            assert!(faultpoint(name).is_ok());
            assert!(faultpoint(name).is_err());
            assert!(faultpoint(name).is_ok());
            disarm(name);
        }

        #[test]
        fn always_fires_until_disarmed() {
            let name = "test.fault.always";
            arm(name, Fault::IoError, Trigger::Always);
            assert!(faultpoint(name).is_err());
            assert!(faultpoint(name).is_err());
            disarm(name);
            assert!(faultpoint(name).is_ok());
        }

        #[test]
        fn seeded_trigger_replays() {
            let name = "test.fault.seeded";
            let fire_pattern = |seed: u64| -> Vec<bool> {
                arm(
                    name,
                    Fault::IoError,
                    Trigger::Seeded {
                        seed,
                        prob_milli: 400,
                    },
                );
                let p: Vec<bool> =
                    (0..32).map(|_| faultpoint(name).is_err()).collect();
                disarm(name);
                p
            };
            let a = fire_pattern(7);
            let b = fire_pattern(7);
            assert_eq!(a, b);
            assert!(a.iter().any(|&x| x), "p=0.4 over 32 draws never fired");
            assert!(!a.iter().all(|&x| x), "p=0.4 over 32 draws always fired");
        }

        #[test]
        fn panic_fault_unwinds() {
            let name = "test.fault.panic";
            arm(name, Fault::Panic, Trigger::Always);
            let r = std::panic::catch_unwind(|| faultpoint(name));
            disarm(name);
            assert!(r.is_err());
            // the registry mutex survived the unwind
            assert!(faultpoint(name).is_ok());
        }

        #[test]
        fn hits_counts_traversals() {
            let name = "test.fault.hits";
            let before = hits(name);
            let _ = faultpoint(name);
            let _ = faultpoint(name);
            assert_eq!(hits(name), before + 2);
        }
    }
}
