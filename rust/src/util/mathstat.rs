//! Descriptive statistics, special functions, and small numeric helpers.
//!
//! Shared by the entropy tests (NIST p-values need `erfc` / the regularized
//! incomplete gamma), the calibration loop (moment estimates), the benchmark
//! harness (robust summaries), and the Fig. 2(e) delay fit (least squares).

/// Streaming mean/variance (Welford).  Numerically stable for long streams.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator); 0 for fewer than two samples.
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn mean_f32(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64
}

pub fn std(xs: &[f64]) -> f64 {
    let mut w = Welford::new();
    for &x in xs {
        w.push(x);
    }
    w.std()
}

pub fn std_f32(xs: &[f32]) -> f64 {
    let mut w = Welford::new();
    for &x in xs {
        w.push(x as f64);
    }
    w.std()
}

/// p-th percentile (0..=100) by linear interpolation on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Ordinary least squares fit `y = a + b*x`; returns (intercept, slope, r2).
pub fn linfit(x: &[f64], y: &[f64]) -> (f64, f64, f64) {
    assert_eq!(x.len(), y.len());
    assert!(x.len() >= 2, "linfit needs >= 2 points");
    let mx = mean(x);
    let my = mean(y);
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    let mut syy = 0.0;
    for i in 0..x.len() {
        let dx = x[i] - mx;
        let dy = y[i] - my;
        sxx += dx * dx;
        sxy += dx * dy;
        syy += dy * dy;
    }
    let b = sxy / sxx;
    let a = my - b * mx;
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    (a, b, r2)
}

/// Softmax over a slice (numerically stabilized).
pub fn softmax(logits: &[f32]) -> Vec<f32> {
    let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = logits.iter().map(|&x| (x - mx).exp()).collect();
    let z: f32 = exps.iter().sum();
    exps.iter().map(|&e| e / z).collect()
}

// ---------------------------------------------------------------------------
// Special functions (for NIST p-values)
// ---------------------------------------------------------------------------

/// Complementary error function, Numerical-Recipes-style Chebyshev fit.
/// Absolute error < 1.2e-7 — ample for test thresholds at alpha = 0.01.
pub fn erfc(x: f64) -> f64 {
    let z = x.abs();
    let t = 1.0 / (1.0 + 0.5 * z);
    let ans = t
        * (-z * z - 1.26551223
            + t * (1.00002368
                + t * (0.37409196
                    + t * (0.09678418
                        + t * (-0.18628806
                            + t * (0.27886807
                                + t * (-1.13520398
                                    + t * (1.48851587
                                        + t * (-0.82215223 + t * 0.17087277)))))))))
        .exp();
    if x >= 0.0 {
        ans
    } else {
        2.0 - ans
    }
}

pub fn erf(x: f64) -> f64 {
    1.0 - erfc(x)
}

/// Regularized upper incomplete gamma Q(a, x) = Γ(a, x)/Γ(a).
/// Series for x < a+1, continued fraction otherwise (Numerical Recipes).
pub fn igamc(a: f64, x: f64) -> f64 {
    if x <= 0.0 || a <= 0.0 {
        return 1.0;
    }
    if x < a + 1.0 {
        1.0 - gamma_series(a, x)
    } else {
        gamma_cf(a, x)
    }
}

fn ln_gamma(x: f64) -> f64 {
    // Lanczos approximation (g = 5, n = 6)
    const COF: [f64; 6] = [
        76.18009172947146,
        -86.50532032941677,
        24.01409824083091,
        -1.231739572450155,
        0.1208650973866179e-2,
        -0.5395239384953e-5,
    ];
    let mut y = x;
    let tmp = x + 5.5;
    let tmp = tmp - (x + 0.5) * tmp.ln();
    let mut ser = 1.000000000190015;
    for c in COF {
        y += 1.0;
        ser += c / y;
    }
    -tmp + (2.5066282746310005 * ser / x).ln()
}

fn gamma_series(a: f64, x: f64) -> f64 {
    let gln = ln_gamma(a);
    let mut ap = a;
    let mut sum = 1.0 / a;
    let mut del = sum;
    for _ in 0..500 {
        ap += 1.0;
        del *= x / ap;
        sum += del;
        if del.abs() < sum.abs() * 1e-15 {
            break;
        }
    }
    sum * (-x + a * x.ln() - gln).exp()
}

fn gamma_cf(a: f64, x: f64) -> f64 {
    let gln = ln_gamma(a);
    let tiny = 1e-300;
    let mut b = x + 1.0 - a;
    let mut c = 1.0 / tiny;
    let mut d = 1.0 / b;
    let mut h = d;
    for i in 1..500 {
        let an = -(i as f64) * (i as f64 - a);
        b += 2.0;
        d = an * d + b;
        if d.abs() < tiny {
            d = tiny;
        }
        c = b + an / c;
        if c.abs() < tiny {
            c = tiny;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < 1e-15 {
            break;
        }
    }
    (-x + a * x.ln() - gln).exp() * h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 6.2).abs() < 1e-12);
        let var = xs.iter().map(|x| (x - 6.2) * (x - 6.2)).sum::<f64>() / 4.0;
        assert!((w.var() - var).abs() < 1e-9);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn linfit_recovers_line() {
        let x: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| 3.0 - 2.0 * v).collect();
        let (a, b, r2) = linfit(&x, &y);
        assert!((a - 3.0).abs() < 1e-10);
        assert!((b + 2.0).abs() < 1e-10);
        assert!((r2 - 1.0).abs() < 1e-10);
    }

    #[test]
    fn softmax_sums_to_one() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        let s: f32 = p.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn softmax_handles_extremes() {
        let p = softmax(&[1000.0, 0.0, -1000.0]);
        assert!((p[0] - 1.0).abs() < 1e-6);
        assert!(p.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn erfc_reference_values() {
        // from Abramowitz & Stegun tables
        assert!((erfc(0.0) - 1.0).abs() < 1e-7);
        assert!((erfc(0.5) - 0.4795001).abs() < 1e-6);
        assert!((erfc(1.0) - 0.1572992).abs() < 1e-6);
        assert!((erfc(2.0) - 0.0046777).abs() < 1e-6);
        assert!((erfc(-1.0) - 1.8427008).abs() < 1e-6);
    }

    #[test]
    fn igamc_reference_values() {
        // Q(a, x) checks: Q(0.5, x) = erfc(sqrt(x))
        for x in [0.1, 0.5, 1.0, 2.0, 5.0] {
            let q = igamc(0.5, x);
            let e = erfc(x.sqrt());
            assert!((q - e).abs() < 1e-6, "x={x}: {q} vs {e}");
        }
        // Q(1, x) = exp(-x)
        for x in [0.1, 1.0, 3.0] {
            assert!((igamc(1.0, x) - (-x as f64).exp()).abs() < 1e-12);
        }
    }

    #[test]
    fn igamc_monotone_in_x() {
        let mut prev = 1.0;
        for i in 1..50 {
            let q = igamc(2.5, i as f64 * 0.3);
            assert!(q <= prev + 1e-12);
            prev = q;
        }
    }
}
