//! Minimal leveled logger (stderr), controlled by `PBM_LOG` env var.
//!
//! Levels: `error` < `warn` < `info` (default) < `debug` < `trace`.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(255);

fn start() -> Instant {
    use std::sync::OnceLock;
    static START: OnceLock<Instant> = OnceLock::new();
    *START.get_or_init(Instant::now)
}

fn level() -> u8 {
    let v = LEVEL.load(Ordering::Relaxed);
    if v != 255 {
        return v;
    }
    let parsed = match std::env::var("PBM_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    } as u8;
    LEVEL.store(parsed, Ordering::Relaxed);
    parsed
}

/// Override the log level programmatically (tests, CLI `-v`).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= level()
}

pub fn log(l: Level, module: &str, msg: &str) {
    if !enabled(l) {
        return;
    }
    let t = start().elapsed().as_secs_f64();
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{t:9.3}s {tag} {module}] {msg}");
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), &format!($($arg)*)) };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), &format!($($arg)*)) };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), &format!($($arg)*)) };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), &format!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Trace);
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
