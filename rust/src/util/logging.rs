//! Minimal leveled logger (stderr), controlled by `PBM_LOG` env var.
//!
//! Levels: `error` < `warn` < `info` (default) < `debug` < `trace`;
//! `off` silences everything.  Unrecognized values (typos like `dbug`)
//! fall back to `info` with a one-time warning instead of silently
//! defaulting.
//!
//! `PBM_LOG_FORMAT=json` switches output to JSON lines
//! (`{"t":…,"level":…,"module":…,"msg":…}`); [`event`] adds structured
//! failure events that carry a `request_id` and key/value fields in
//! both formats.

use std::io::Write;
use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

/// Level cache: `UNSET` until the env var is parsed or `set_level`
/// runs; `OFF` silences all levels.
const UNSET: u8 = 255;
const OFF: u8 = 254;

static LEVEL: AtomicU8 = AtomicU8::new(UNSET);

/// Output format cache: `UNSET` until parsed; 0 = text, 1 = JSON lines.
static FORMAT: AtomicU8 = AtomicU8::new(UNSET);

fn start() -> Instant {
    use std::sync::OnceLock;
    static START: OnceLock<Instant> = OnceLock::new();
    *START.get_or_init(Instant::now)
}

/// Parse a `PBM_LOG` value; `None` for unrecognized input.
fn parse_level(s: &str) -> Option<u8> {
    Some(match s {
        "error" => Level::Error as u8,
        "warn" => Level::Warn as u8,
        "info" => Level::Info as u8,
        "debug" => Level::Debug as u8,
        "trace" => Level::Trace as u8,
        "off" | "none" => OFF,
        _ => return None,
    })
}

fn warn_once(flag: &'static AtomicBool, var: &str, value: &str, want: &str) {
    if !flag.swap(true, Ordering::Relaxed) {
        eprintln!("[logging] {var}={value:?} unrecognized (want {want}); using the default");
    }
}

fn level() -> u8 {
    let v = LEVEL.load(Ordering::Relaxed);
    if v != UNSET {
        return v;
    }
    let parsed = match std::env::var("PBM_LOG") {
        // absent: default to info WITHOUT caching, so a test (or late
        // caller) that sets the env var before the first real parse
        // still wins — a failed read must not be sticky
        Err(_) => return Level::Info as u8,
        Ok(s) => match parse_level(&s) {
            Some(l) => l,
            None => {
                static WARNED: AtomicBool = AtomicBool::new(false);
                warn_once(&WARNED, "PBM_LOG", &s, "error|warn|info|debug|trace|off");
                Level::Info as u8
            }
        },
    };
    LEVEL.store(parsed, Ordering::Relaxed);
    parsed
}

fn json_format() -> bool {
    let v = FORMAT.load(Ordering::Relaxed);
    if v != UNSET {
        return v == 1;
    }
    let parsed = match std::env::var("PBM_LOG_FORMAT") {
        Err(_) => return false, // absent: text, uncached (see level())
        Ok(s) => match s.as_str() {
            "json" => 1,
            "text" | "" => 0,
            _ => {
                static WARNED: AtomicBool = AtomicBool::new(false);
                warn_once(&WARNED, "PBM_LOG_FORMAT", &s, "text|json");
                0
            }
        },
    };
    FORMAT.store(parsed, Ordering::Relaxed);
    parsed == 1
}

/// Override the log level programmatically (tests, CLI `-v`).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Override the output format programmatically (tests, CLI).
pub fn set_json(json: bool) {
    FORMAT.store(u8::from(json), Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    let lv = level();
    lv != OFF && (l as u8) <= lv
}

fn tag(l: Level) -> &'static str {
    match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    }
}

fn tag_lower(l: Level) -> &'static str {
    match l {
        Level::Error => "error",
        Level::Warn => "warn",
        Level::Info => "info",
        Level::Debug => "debug",
        Level::Trace => "trace",
    }
}

fn escape_into(s: &str, out: &mut String) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
}

/// Render one JSON log line (without the trailing newline).
fn json_line(
    t: f64,
    l: Level,
    module: &str,
    msg: &str,
    event: Option<&str>,
    request_id: u64,
    fields: &[(&str, &str)],
) -> String {
    let mut line = String::with_capacity(msg.len() + 96);
    line.push_str(&format!("{{\"t\":{t:.3},\"level\":\"{}\"", tag_lower(l)));
    line.push_str(",\"module\":\"");
    escape_into(module, &mut line);
    line.push('"');
    if let Some(ev) = event {
        line.push_str(",\"event\":\"");
        escape_into(ev, &mut line);
        line.push('"');
    }
    if request_id != 0 {
        line.push_str(&format!(",\"request_id\":\"{request_id}\""));
    }
    for (k, v) in fields {
        line.push_str(",\"");
        escape_into(k, &mut line);
        line.push_str("\":\"");
        escape_into(v, &mut line);
        line.push('"');
    }
    if !msg.is_empty() {
        line.push_str(",\"msg\":\"");
        escape_into(msg, &mut line);
        line.push('"');
    }
    line.push('}');
    line
}

pub fn log(l: Level, module: &str, msg: &str) {
    if !enabled(l) {
        return;
    }
    let t = start().elapsed().as_secs_f64();
    let mut err = std::io::stderr().lock();
    if json_format() {
        let _ = writeln!(err, "{}", json_line(t, l, module, msg, None, 0, &[]));
    } else {
        let _ = writeln!(err, "[{t:9.3}s {} {module}] {msg}", tag(l));
    }
}

/// Structured event for the failure paths (shed, deadline, panic
/// recovery, failover, fallback): in JSON mode `event`, `request_id`
/// (when nonzero) and the fields become first-class keys; in text mode
/// they render as `event=… request_id=… k=v`.
pub fn event(l: Level, module: &str, name: &str, request_id: u64, fields: &[(&str, &str)]) {
    if !enabled(l) {
        return;
    }
    let t = start().elapsed().as_secs_f64();
    let mut err = std::io::stderr().lock();
    if json_format() {
        let _ = writeln!(
            err,
            "{}",
            json_line(t, l, module, "", Some(name), request_id, fields)
        );
    } else {
        let mut msg = format!("event={name}");
        if request_id != 0 {
            msg.push_str(&format!(" request_id={request_id}"));
        }
        for (k, v) in fields {
            msg.push_str(&format!(" {k}={v}"));
        }
        let _ = writeln!(err, "[{t:9.3}s {} {module}] {msg}", tag(l));
    }
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), &format!($($arg)*)) };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), &format!($($arg)*)) };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), &format!($($arg)*)) };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), &format!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    // one test owns the global LEVEL (tests run in parallel; two tests
    // poking the same atomic would race)
    #[test]
    fn level_ordering_and_off() {
        assert!(Level::Error < Level::Trace);
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        LEVEL.store(OFF, Ordering::Relaxed);
        assert!(!enabled(Level::Error));
        assert!(!enabled(Level::Trace));
        set_level(Level::Info);
    }

    #[test]
    fn parse_accepts_all_levels_and_off() {
        assert_eq!(parse_level("error"), Some(Level::Error as u8));
        assert_eq!(parse_level("warn"), Some(Level::Warn as u8));
        assert_eq!(parse_level("info"), Some(Level::Info as u8));
        assert_eq!(parse_level("debug"), Some(Level::Debug as u8));
        assert_eq!(parse_level("trace"), Some(Level::Trace as u8));
        assert_eq!(parse_level("off"), Some(OFF));
        assert_eq!(parse_level("none"), Some(OFF));
    }

    #[test]
    fn parse_rejects_typos_instead_of_silent_info() {
        assert_eq!(parse_level("dbug"), None);
        assert_eq!(parse_level("INFO"), None);
        assert_eq!(parse_level(""), None);
    }

    #[test]
    fn json_line_shape() {
        let line = json_line(
            1.5,
            Level::Warn,
            "pbm::x",
            "oops \"quoted\"",
            Some("shed"),
            42,
            &[("reason", "deadline")],
        );
        assert_eq!(
            line,
            "{\"t\":1.500,\"level\":\"warn\",\"module\":\"pbm::x\",\"event\":\"shed\",\
             \"request_id\":\"42\",\"reason\":\"deadline\",\"msg\":\"oops \\\"quoted\\\"\"}"
        );
        // untraced requests omit request_id entirely
        let line = json_line(0.0, Level::Info, "m", "hi", None, 0, &[]);
        assert!(!line.contains("request_id"), "{line}");
    }
}
