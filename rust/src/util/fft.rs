//! Iterative radix-2 FFT (for the NIST SP800-22 spectral test).
//!
//! In-place Cooley–Tukey over interleaved (re, im) f64 pairs; no external
//! dependencies.  Only power-of-two lengths are supported — callers truncate
//! (the NIST spectral test does exactly that).

use std::f64::consts::PI;

/// Complex number as a (re, im) pair.
pub type C64 = (f64, f64);

#[inline]
fn c_mul(a: C64, b: C64) -> C64 {
    (a.0 * b.0 - a.1 * b.1, a.0 * b.1 + a.1 * b.0)
}

#[inline]
fn c_add(a: C64, b: C64) -> C64 {
    (a.0 + b.0, a.1 + b.1)
}

#[inline]
fn c_sub(a: C64, b: C64) -> C64 {
    (a.0 - b.0, a.1 - b.1)
}

/// In-place forward FFT. `data.len()` must be a power of two.
pub fn fft_in_place(data: &mut [C64]) {
    let n = data.len();
    assert!(n.is_power_of_two(), "fft length {n} not a power of two");
    if n <= 1 {
        return;
    }
    // bit-reversal permutation
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            data.swap(i, j);
        }
    }
    // butterflies
    let mut len = 2;
    while len <= n {
        let ang = -2.0 * PI / len as f64;
        let wlen = (ang.cos(), ang.sin());
        let mut i = 0;
        while i < n {
            let mut w = (1.0, 0.0);
            for k in 0..len / 2 {
                let u = data[i + k];
                let v = c_mul(data[i + k + len / 2], w);
                data[i + k] = c_add(u, v);
                data[i + k + len / 2] = c_sub(u, v);
                w = c_mul(w, wlen);
            }
            i += len;
        }
        len <<= 1;
    }
}

/// Magnitudes of the first n/2 bins of the FFT of a real signal.
///
/// Non-power-of-two inputs are truncated to the largest power of two below
/// their length (the NIST spectral test's convention).  An empty signal
/// yields an empty spectrum instead of tripping the FFT's length assert.
pub fn real_fft_magnitudes(signal: &[f64]) -> Vec<f64> {
    if signal.is_empty() {
        return Vec::new();
    }
    // largest power of two <= len: next_power_of_two() overshoots exactly
    // when len is not already a power of two, so shift the overshoot back
    let n = signal.len().next_power_of_two() >> usize::from(!signal.len().is_power_of_two());
    let mut buf: Vec<C64> = signal[..n].iter().map(|&x| (x, 0.0)).collect();
    fft_in_place(&mut buf);
    buf[..n / 2]
        .iter()
        .map(|&(re, im)| (re * re + im * im).sqrt())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fft_of_impulse_is_flat() {
        let mut d = vec![(0.0, 0.0); 8];
        d[0] = (1.0, 0.0);
        fft_in_place(&mut d);
        for &(re, im) in &d {
            assert!((re - 1.0).abs() < 1e-12 && im.abs() < 1e-12);
        }
    }

    #[test]
    fn fft_of_constant_is_delta() {
        let mut d = vec![(1.0, 0.0); 16];
        fft_in_place(&mut d);
        assert!((d[0].0 - 16.0).abs() < 1e-9);
        for &(re, im) in &d[1..] {
            assert!(re.abs() < 1e-9 && im.abs() < 1e-9);
        }
    }

    #[test]
    fn fft_matches_naive_dft() {
        let signal: Vec<f64> = (0..32).map(|i| ((i * 7 + 3) % 11) as f64 - 5.0).collect();
        let mut d: Vec<C64> = signal.iter().map(|&x| (x, 0.0)).collect();
        fft_in_place(&mut d);
        // naive DFT comparison at a few bins
        for k in [0usize, 1, 5, 16, 31] {
            let mut acc = (0.0f64, 0.0f64);
            for (t, &x) in signal.iter().enumerate() {
                let ang = -2.0 * PI * (k * t) as f64 / 32.0;
                acc.0 += x * ang.cos();
                acc.1 += x * ang.sin();
            }
            assert!((acc.0 - d[k].0).abs() < 1e-8, "re bin {k}");
            assert!((acc.1 - d[k].1).abs() < 1e-8, "im bin {k}");
        }
    }

    #[test]
    fn real_magnitudes_handle_empty_and_truncate() {
        assert!(real_fft_magnitudes(&[]).is_empty());
        // 12 samples truncate to 8 -> 4 magnitude bins
        assert_eq!(real_fft_magnitudes(&[1.0; 12]).len(), 4);
        // power-of-two lengths are used in full
        assert_eq!(real_fft_magnitudes(&[1.0; 16]).len(), 8);
    }

    #[test]
    fn sine_concentrates_in_one_bin() {
        let n = 64;
        let signal: Vec<f64> = (0..n)
            .map(|i| (2.0 * PI * 5.0 * i as f64 / n as f64).sin())
            .collect();
        let mags = real_fft_magnitudes(&signal);
        let peak = mags
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(peak, 5);
    }
}
