//! Minimal JSON parser / emitter.
//!
//! The offline crate cache carries no `serde`/`serde_json`, so this module
//! implements the subset of JSON the repo needs: the artifact metadata
//! written by `python/compile/aot.py`, checkpoint/training logs, and the TCP
//! serving protocol.  It is a full RFC 8259 reader (objects, arrays,
//! numbers, strings with escapes, bools, null) with a pretty/compact writer.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.  Object keys are sorted (BTreeMap) so emission is stable.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- constructors -----------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        let mut m = BTreeMap::new();
        for (k, v) in pairs {
            m.insert(k.to_string(), v);
        }
        Json::Obj(m)
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x)).collect())
    }

    pub fn arr_f32(v: &[f32]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x as f64)).collect())
    }

    pub fn arr_usize(v: &[usize]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x as f64)).collect())
    }

    // ---- accessors --------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Fetch a required key, with a readable error.
    pub fn req(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing key '{key}'"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Numeric vector helper (`[1, 2, 3]` -> `vec![1.0, 2.0, 3.0]`).
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(Json::as_f64).collect::<Vec<_>>())
            .filter(|v| Some(v.len()) == self.as_arr().map(<[Json]>::len))
    }

    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_f64_vec()
            .map(|v| v.into_iter().map(|x| x as usize).collect())
    }

    /// Insert into an object (panics if not an object — construction-time use).
    pub fn set(&mut self, key: &str, val: Json) -> &mut Json {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    // ---- emission ----------------------------------------------------------

    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Append the compact encoding to an existing buffer — the server's
    /// per-connection fast path reuses one response `String` across
    /// requests instead of allocating a fresh one per encode.
    pub fn write_compact(&self, out: &mut String) {
        self.write(out, None, 0);
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(1), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        out.push_str(&format!("{}", *x as i64));
                    } else {
                        out.push_str(&format!("{x}"));
                    }
                } else {
                    // JSON has no NaN/Inf; emit null (documented lossy case)
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    v.write(out, indent, depth + 1);
                }
                if indent.is_some() && !a.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if indent.is_some() && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

/// Parse a JSON document.  Returns a readable error with byte offset.
pub fn parse(text: &str) -> Result<Json, String> {
    let mut p = Parser {
        b: text.as_bytes(),
        i: 0,
    };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {} (found {:?})",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|b| b as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number '{s}' at byte {start}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|e| e.to_string())?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|e| format!("bad \\u escape: {e}"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance over one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..]).map_err(|e| e.to_string())?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                other => return Err(format!("expected ',' or ']' found {other:?} at {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            out.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                other => return Err(format!("expected ',' or '}}' found {other:?} at {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"meta":{"n":7,"tags":["x","y"],"ok":true},"v":[0.5,1,2]}"#;
        let v = parse(src).unwrap();
        let again = parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, again);
        let again = parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""éA""#).unwrap();
        assert_eq!(v.as_str(), Some("éA"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("'single'").is_err());
    }

    #[test]
    fn helper_vectors() {
        let v = parse("[1, 2, 3.5]").unwrap();
        assert_eq!(v.as_f64_vec().unwrap(), vec![1.0, 2.0, 3.5]);
        let v = parse("[1, \"x\"]").unwrap();
        assert!(v.as_f64_vec().is_none());
    }

    #[test]
    fn escaped_emission() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(parse(&v.to_string_compact()).unwrap(), v);
    }

    #[test]
    fn deep_structure_roundtrip() {
        let mut obj = Json::obj();
        obj.set("layout", Json::Arr(vec![Json::from_pairs(vec![
            ("name", Json::Str("stem_w".into())),
            ("shape", Json::arr_usize(&[16, 3, 3, 3])),
            ("offset", Json::Num(0.0)),
        ])]));
        let text = obj.to_string_pretty();
        let back = parse(&text).unwrap();
        assert_eq!(back, obj);
    }
}
