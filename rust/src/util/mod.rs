//! Small shared substrates: JSON, descriptive statistics, logging.

pub mod fft;
pub mod json;
pub mod logging;
pub mod mathstat;
