//! Small shared substrates: JSON, descriptive statistics, logging,
//! fault injection.

pub mod fault;
pub mod fft;
pub mod json;
pub mod logging;
pub mod mathstat;
