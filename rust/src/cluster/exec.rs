//! Cluster dispatcher: a [`BatchExecutor`] that shards classify requests
//! across the worker pool over the line protocol.
//!
//! Every request gets a **placement** (a monotonic per-coordinator
//! counter) from which two things derive:
//!
//! - its *lane*, `placement % workers` — only a routing preference;
//! - its *plan seed*, [`lane_seed`]`(base_seed, placement)` — the entropy
//!   stream the serving worker must draw from.
//!
//! Because the seed depends on the placement alone (never on which worker
//! happens to serve it), a request re-routed after a crash, raced by a
//! hedge, or retried over a fresh connection reproduces **bitwise** the
//! output a healthy cluster would have produced — the
//! `(model, seed, threads, prefetch, rule)` replay contract extended with
//! `placement`.  That determinism is what makes failover and hedging
//! *idempotent*: duplicate executions of the same placement are
//! indistinguishable, so first-response-wins cancellation is safe.
//!
//! Failure handling per request: transport errors fail over immediately to
//! the next untried routable worker; a straggling primary gets a hedge
//! after `max(hedge_min, ewma × hedge_factor)`; typed serving errors
//! (`overloaded`, `deadline_exceeded`, …) propagate to the client — the
//! worker answered, so retrying elsewhere would just double the load.
//! When no routable worker remains the dispatcher either degrades into
//! local execution (marked `degraded`) or answers a typed
//! [`ServeError::WorkerUnavailable`].

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::pool::WorkerPool;
use super::{lane_seed, ClusterConfig};
use crate::coordinator::engine::ClassifyResult;
use crate::coordinator::overload::ServeError;
use crate::coordinator::service::{BatchExecutor, SynthExecutor};
use crate::observe::{Stage, TraceRecorder};
use crate::sampler::RequestBudget;
use crate::server::protocol;
use crate::server::tcp::Client;
use crate::util::logging;

/// Outcome of one dispatch attempt on one worker.
enum Outcome {
    /// A well-formed result line.
    Reply(Box<ClassifyResult>),
    /// A typed serving error — the worker is alive; do not fail over.
    Typed(ServeError),
    /// Connect/read/parse failure — the worker is unreliable; fail over.
    Transport(String),
}

struct Attempt {
    worker: usize,
    elapsed_us: f64,
    outcome: Outcome,
}

/// The coordinator's executor: one per coordinator service thread.
pub struct ClusterExecutor {
    cfg: ClusterConfig,
    pool: Arc<WorkerPool>,
    /// Monotonic placement counter.  Deliberately **not** reset by
    /// [`BatchExecutor::recover_after_panic`]: placements must stay unique
    /// for the lifetime of the coordinator so no two requests ever share a
    /// plan seed (the per-request seed derivation is what panic recovery
    /// would otherwise have to rebuild — there is no other mutable state).
    next_placement: u64,
    /// Local degraded-mode executor for an empty pool.  Shares the
    /// cluster's `(n_samples, image_size)` shape so its seeded path is
    /// bitwise-identical to what a worker would have produced for the
    /// same plan seed.
    fallback: SynthExecutor,
    /// Coordinator-side span recorder (None while tracing is off).
    trace: Option<Arc<TraceRecorder>>,
    /// Positional request ids for the current group, aligned with image
    /// order (`0` = untraced).  Kept even without a local recorder: the
    /// nonzero ids still ride the wire so the serving *worker's* recorder
    /// stitches its spans under the same id.
    trace_ids: Vec<u64>,
}

impl ClusterExecutor {
    pub fn new(cfg: ClusterConfig, pool: Arc<WorkerPool>) -> Self {
        let mut fallback = SynthExecutor::new(cfg.seed, cfg.n_samples);
        fallback.image_size = cfg.image_size;
        Self {
            cfg,
            pool,
            next_placement: 0,
            fallback,
            trace: None,
            trace_ids: Vec::new(),
        }
    }

    /// Total placements issued so far (telemetry).
    pub fn placements(&self) -> u64 {
        self.next_placement
    }

    /// Record one span under `request_id` if tracing is on (`record`
    /// itself drops id 0).
    fn trace_span(&self, request_id: u64, stage: Stage, index: u16, start: Instant, dur: Duration) {
        if let Some(t) = &self.trace {
            t.record(request_id, stage, index, start, dur);
        }
    }

    /// Serve one single-image shard: encode, pick, dispatch with
    /// failover + hedging, and fold the outcome into the pool's health.
    #[allow(clippy::too_many_arguments)]
    fn dispatch_one(
        &mut self,
        model: Option<&str>,
        image: &[f32],
        placement: u64,
        plan_seed: u64,
        budget: &RequestBudget,
        deadline: Option<Instant>,
        brownout: bool,
        request_id: u64,
    ) -> Result<ClassifyResult> {
        let mut budget = budget.clone();
        if brownout {
            // tier-2 degradation crosses the wire as a one-sample budget
            budget.max_samples = Some(budget.max_samples.map_or(1, |m| m.min(1)));
        }
        let deadline_ms = match deadline {
            Some(d) => {
                let now = Instant::now();
                if now >= d {
                    return Err(anyhow::Error::new(ServeError::DeadlineExceeded {
                        samples_used: 0,
                    }));
                }
                Some((d - now).as_millis().max(1) as u64)
            }
            None => None,
        };
        // a nonzero request_id rides along so the worker's recorder files
        // its spans under the same trace (stitched across the hop)
        let line = if request_id != 0 {
            protocol::encode_classify_sharded_traced(
                model.unwrap_or(&self.cfg.model),
                image,
                &budget,
                deadline_ms,
                plan_seed,
                request_id,
            )
        } else {
            protocol::encode_classify_sharded(
                model.unwrap_or(&self.cfg.model),
                image,
                &budget,
                deadline_ms,
                plan_seed,
            )
        };
        let lane = (placement % self.pool.len().max(1) as u64) as usize;

        // first-response-wins: attempt threads race into this channel;
        // losers' sends land in the buffer (or fail once the receiver is
        // gone) and are discarded — idempotent because every attempt of
        // one placement computes the identical bytes
        let (tx, rx) = crate::exec::channel::<Attempt>(8);
        let mut tried: Vec<usize> = Vec::new();
        let mut in_flight = 0usize;
        let mut hedged = false;
        let mut last_transport: Option<String> = None;
        let overall = deadline.unwrap_or_else(|| Instant::now() + self.cfg.client.read_timeout);

        loop {
            if in_flight == 0 {
                match self.pool.pick(lane, &tried) {
                    Some(p) => {
                        tried.push(p.index);
                        self.launch(&tx, p.index, p.addr, &line);
                        in_flight += 1;
                    }
                    None => {
                        // pool exhausted for this request: every routable
                        // worker was tried (or none exists)
                        return self.no_route(
                            plan_seed,
                            model,
                            image,
                            &budget,
                            deadline,
                            brownout,
                            last_transport,
                            request_id,
                        );
                    }
                }
            }
            let hedge_after = tried
                .first()
                .and_then(|&i| self.pool.cards().get(i).map(|c| c.latency_ewma_us))
                .map_or(self.cfg.hedge_min, |ewma| {
                    self.cfg
                        .hedge_min
                        .max(Duration::from_micros((ewma * self.cfg.hedge_factor) as u64))
                });
            let now = Instant::now();
            if now >= overall {
                return Err(anyhow::Error::new(ServeError::Internal {
                    detail: format!("cluster dispatch timed out (placement {placement})"),
                }));
            }
            let wait = if hedged { overall - now } else { hedge_after.min(overall - now) };
            match rx.recv_timeout(wait) {
                Ok(Some(att)) => {
                    in_flight -= 1;
                    match att.outcome {
                        Outcome::Reply(r) => {
                            self.pool.note_success(att.worker, att.elapsed_us);
                            return Ok(*r);
                        }
                        Outcome::Typed(se) => {
                            // alive worker, typed refusal: propagate as-is
                            self.pool.note_success(att.worker, att.elapsed_us);
                            return Err(anyhow::Error::new(se));
                        }
                        Outcome::Transport(e) => {
                            self.pool.note_failure(att.worker);
                            // annotate the trace with the failed attempt:
                            // index = worker slot, duration = how long the
                            // attempt burned before failing over
                            let dur = Duration::from_micros(att.elapsed_us as u64);
                            let start = Instant::now().checked_sub(dur).unwrap_or_else(Instant::now);
                            self.trace_span(request_id, Stage::Failover, att.worker as u16, start, dur);
                            let w = att.worker.to_string();
                            logging::event(
                                logging::Level::Warn,
                                module_path!(),
                                "failover",
                                request_id,
                                &[("worker", &w), ("error", &e)],
                            );
                            last_transport = Some(e);
                            // loop: relaunch on the next untried worker
                        }
                    }
                }
                Ok(None) => {
                    // cannot happen while we hold `tx`; treat as transport
                    last_transport = Some("attempt channel closed".into());
                    in_flight = 0;
                }
                Err(()) => {
                    // primary is straggling: hedge once on another worker
                    if !hedged {
                        hedged = true;
                        if let Some(p) = self.pool.pick(lane + 1, &tried) {
                            tried.push(p.index);
                            // zero-duration annotation at the instant the
                            // hedge fired, indexed by the hedge worker
                            self.trace_span(
                                request_id,
                                Stage::Hedge,
                                p.index as u16,
                                Instant::now(),
                                Duration::ZERO,
                            );
                            let w = p.index.to_string();
                            logging::event(
                                logging::Level::Info,
                                module_path!(),
                                "hedge",
                                request_id,
                                &[("worker", &w)],
                            );
                            self.launch(&tx, p.index, p.addr, &line);
                            in_flight += 1;
                        }
                    }
                }
            }
        }
    }

    /// Fire one attempt on a detached thread.  Each attempt dials a fresh
    /// connection, so no attempt can ever read a response left in flight
    /// by another (the client-side single-in-flight rule).
    fn launch(&self, tx: &crate::exec::Sender<Attempt>, worker: usize, addr: String, line: &str) {
        let tx = tx.clone();
        let line = line.to_string();
        let mut ccfg = self.cfg.client.clone();
        ccfg.retries = 0; // the dispatcher owns retry/failover policy
        let _ = std::thread::Builder::new()
            .name("pbm-cluster-attempt".into())
            .spawn(move || {
                let t0 = Instant::now();
                let outcome = match Client::connect_with(&addr, ccfg) {
                    Ok(mut client) => match client.call(&line) {
                        Ok(j) => {
                            if let Some(se) = protocol::decode_serve_error(&j) {
                                Outcome::Typed(se)
                            } else {
                                match protocol::decode_result(&j) {
                                    Ok(r) => Outcome::Reply(Box::new(r)),
                                    Err(e) => Outcome::Transport(format!("{addr}: {e}")),
                                }
                            }
                        }
                        Err(e) => Outcome::Transport(format!("{addr}: {e}")),
                    },
                    Err(e) => Outcome::Transport(format!("{addr}: {e}")),
                };
                let _ = tx.try_send(Attempt {
                    worker,
                    elapsed_us: t0.elapsed().as_micros() as f64,
                    outcome,
                });
            });
    }

    /// No routable worker left for this request.
    #[allow(clippy::too_many_arguments)]
    fn no_route(
        &mut self,
        plan_seed: u64,
        model: Option<&str>,
        image: &[f32],
        budget: &RequestBudget,
        deadline: Option<Instant>,
        brownout: bool,
        last_transport: Option<String>,
        request_id: u64,
    ) -> Result<ClassifyResult> {
        if self.cfg.local_fallback {
            // degrade into local execution: same plan seed, same sample
            // budget, so the answer is bitwise what a worker would have
            // returned — only the `degraded` flag betrays the detour
            logging::event(
                logging::Level::Warn,
                module_path!(),
                "fallback",
                request_id,
                &[("reason", "no_routable_worker")],
            );
            let t0 = Instant::now();
            let mut results = self.fallback.classify_group_seeded(
                plan_seed, model, image, 1, budget, deadline, brownout,
            )?;
            self.trace_span(request_id, Stage::Fallback, 0, t0, t0.elapsed());
            let mut r = results
                .pop()
                .ok_or_else(|| anyhow!("local fallback returned no result"))?;
            r.degraded = true;
            return Ok(r);
        }
        let down = self.pool.down_count();
        crate::log_debug!(
            "no routable worker ({down} down/drained): {}",
            last_transport.unwrap_or_else(|| "pool empty".into())
        );
        Err(anyhow::Error::new(ServeError::WorkerUnavailable { down }))
    }
}

impl BatchExecutor for ClusterExecutor {
    fn default_model(&self) -> &str {
        &self.cfg.model
    }

    fn image_size_for(&self, model: Option<&str>) -> Option<usize> {
        match model {
            None => Some(self.cfg.image_size),
            Some(m) if m == self.cfg.model => Some(self.cfg.image_size),
            Some(_) => None,
        }
    }

    fn model_names(&self) -> Vec<String> {
        vec![self.cfg.model.clone()]
    }

    fn attach_recorder(&mut self, recorder: &Arc<TraceRecorder>) {
        if recorder.enabled() {
            self.trace = Some(recorder.clone());
        }
        // the fallback executor is deliberately NOT attached: the
        // coordinator's Chunk span already covers the whole dispatch, and
        // a second top-level chunk under the same id would double-count
        // the request in `critical_path_us`
    }

    fn begin_group(&mut self, request_ids: &[u64]) {
        // positional (zeros kept): ids must stay aligned with image order
        // so dispatch_one(i) forwards the right id over the wire
        self.trace_ids.clear();
        self.trace_ids.extend_from_slice(request_ids);
    }

    fn classify_group(
        &mut self,
        model: Option<&str>,
        images: &[f32],
        n: usize,
        budget: &RequestBudget,
        deadline: Option<Instant>,
        brownout: bool,
    ) -> Result<Vec<ClassifyResult>> {
        let size = self.cfg.image_size;
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let placement = self.next_placement;
            self.next_placement += 1;
            let plan_seed = lane_seed(self.cfg.seed, placement);
            let image = &images[i * size..(i + 1) * size];
            let rid = self.trace_ids.get(i).copied().unwrap_or(0);
            let t0 = Instant::now();
            let r = self.dispatch_one(
                model, image, placement, plan_seed, budget, deadline, brownout, rid,
            )?;
            // coordinator-side "chunk": the whole remote dispatch,
            // failover and hedging included
            self.trace_span(rid, Stage::Chunk, 0, t0, t0.elapsed());
            out.push(r);
        }
        Ok(out)
    }

    fn classify_group_seeded(
        &mut self,
        plan_seed: u64,
        model: Option<&str>,
        images: &[f32],
        n: usize,
        budget: &RequestBudget,
        deadline: Option<Instant>,
        brownout: bool,
    ) -> Result<Vec<ClassifyResult>> {
        // a client that pinned its own plan seed gets it forwarded
        // verbatim (each image dispatched under the same seed); the
        // placement still advances so lane assignment keeps rotating
        let size = self.cfg.image_size;
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let placement = self.next_placement;
            self.next_placement += 1;
            let image = &images[i * size..(i + 1) * size];
            let rid = self.trace_ids.get(i).copied().unwrap_or(0);
            let t0 = Instant::now();
            let r = self.dispatch_one(
                model, image, placement, plan_seed, budget, deadline, brownout, rid,
            )?;
            self.trace_span(rid, Stage::Chunk, 0, t0, t0.elapsed());
            out.push(r);
        }
        Ok(out)
    }

    fn recover_after_panic(&mut self) -> Result<()> {
        // nothing to rebuild: per-request state derives from the placement
        // counter, which must NOT reset (a reset would reuse plan seeds
        // and break placement uniqueness)
        Ok(())
    }

    fn report_line(&self) -> String {
        format!(
            "cluster(workers={}, placements={})",
            self.pool.len(),
            self.next_placement
        )
    }
}
