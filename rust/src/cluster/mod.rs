//! L4 cluster mode: sharded multi-worker serving with health-checked
//! failover and hedged requests.
//!
//! Topology (one coordinator, N workers, all speaking the line protocol):
//!
//! ```text
//!   clients ──► coordinator gateway (`pbm cluster`)
//!                 │  admission scaled to CLUSTER capacity
//!                 ▼
//!             ClusterExecutor ── placement p ─► plan_seed = lane_seed(seed, p)
//!                 │ lane = p % N (preference only)
//!                 ├──► worker₀ (`pbm worker`)   ◄─ probe: hello + /info
//!                 ├──► worker₁                  ◄─ (entropy health, p50/95/99)
//!                 └──► worker₂    …failover / hedge to any routable worker
//! ```
//!
//! The replay contract: a request's output is a pure function of
//! `(model, seed, threads, prefetch, rule, placement)` — **not** of which
//! worker served it.  [`lane_seed`] mixes the placement into the base seed
//! (splitmix64, the same scheme as the engine's per-shard streams), every
//! attempt ships that `plan_seed` on the wire, and workers serve it from a
//! stateless stream ([`crate::coordinator::BatchExecutor::classify_group_seeded`]).
//! Failover after a worker crash, a hedge racing a straggler, and local
//! degraded execution therefore all reproduce bitwise the same answer.
//!
//! Worker health folds the PR 6 entropy-health scorecards into routing:
//! a worker whose `/info` reports a degraded stream is drained (state
//! `Suspect`) within one probe interval — completing the loop from "true
//! randomness is verified" to "unhealthy sources are routed around."

pub mod exec;
pub mod pool;

use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

pub use exec::ClusterExecutor;
pub use pool::{Pick, WorkerCard, WorkerPool, WorkerState};

use crate::coordinator::service::{EngineHandle, ServiceConfig, SynthExecutor};
use crate::coordinator::Router;
use crate::entropy::health::Monitor;
use crate::exec::CancelToken;
use crate::server::tcp::{serve, ClientConfig, ServerOptions};
use crate::util::fault::splitmix64;

/// Plan seed for `placement` under `base`: splitmix-mix the placement into
/// the base seed (golden-ratio stride, the same per-shard scheme as the
/// engine's entropy streams).  Depends only on `(base, placement)` — never
/// on worker identity — which is the whole failover-replay story.
pub fn lane_seed(base: u64, placement: u64) -> u64 {
    let mut s = base.wrapping_add(placement.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    splitmix64(&mut s)
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Base seed of the extended replay contract.
    pub seed: u64,
    /// Model name the coordinator serves (and forwards shards under).
    pub model: String,
    /// Flat image length for `model`.
    pub image_size: usize,
    /// Per-request stochastic passes (must match the workers' setting for
    /// the local-fallback path to stay bitwise-faithful).
    pub n_samples: usize,
    /// Hedge a straggling primary after `max(hedge_min, ewma × hedge_factor)`.
    pub hedge_factor: f64,
    pub hedge_min: Duration,
    /// Health-probe period for [`spawn_probe_loop`].  `ZERO` = no
    /// automatic probing (tests drive [`WorkerPool::probe_all`] manually).
    pub probe_interval: Duration,
    /// Transport timeouts/backoff for worker connections.
    pub client: ClientConfig,
    /// With the pool empty, degrade into local execution (marked
    /// `degraded`) instead of answering `worker_unavailable`.
    pub local_fallback: bool,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            seed: 0x00C1_0572,
            model: "synth".into(),
            image_size: 4,
            n_samples: 8,
            hedge_factor: 3.0,
            hedge_min: Duration::from_millis(50),
            probe_interval: Duration::from_secs(1),
            client: ClientConfig::default(),
            local_fallback: false,
        }
    }
}

/// A locally spawned worker process stand-in (service thread + TCP
/// gateway with role `"worker"`), used by `pbm worker` internals, the
/// cluster bench, and the chaos suite.  Dropping (or [`stop`]ping) the
/// guard cancels the gateway and joins its thread.
///
/// [`stop`]: WorkerGuard::stop
pub struct WorkerGuard {
    /// Bound address, e.g. `127.0.0.1:41523` (port 0 resolves at bind).
    pub addr: String,
    cancel: CancelToken,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl WorkerGuard {
    /// Cancel the worker's gateway and join it.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.cancel.cancel();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for WorkerGuard {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Options for [`spawn_local_worker`].
#[derive(Debug, Clone)]
pub struct WorkerOptions {
    /// Seed of the worker's *persistent* stream (plan-seeded shards ignore
    /// it — that independence is what makes workers interchangeable).
    pub seed: u64,
    pub n_samples: usize,
    /// Simulated engine work per sample draw.
    pub work_per_sample: Duration,
    /// Entropy-health monitor surfaced in the worker's `/info` (probes
    /// fold it into routing).
    pub health: Option<Arc<Monitor>>,
    pub svc: ServiceConfig,
    /// Gateway bind address (`127.0.0.1:0` = ephemeral port).
    pub addr: String,
}

impl Default for WorkerOptions {
    fn default() -> Self {
        Self {
            seed: 7,
            n_samples: 8,
            work_per_sample: Duration::ZERO,
            health: None,
            svc: ServiceConfig::default(),
            addr: "127.0.0.1:0".into(),
        }
    }
}

/// Spawn a worker: a [`SynthExecutor`] service loop behind a TCP gateway
/// that answers the `hello` handshake with role `"worker"`.
pub fn spawn_local_worker(opts: WorkerOptions) -> Result<WorkerGuard> {
    let health = opts.health.clone();
    let seed = opts.seed;
    let n_samples = opts.n_samples;
    let work = opts.work_per_sample;
    let handle = EngineHandle::spawn_executor(
        "synth",
        vec!["synth".to_string()],
        health,
        n_samples,
        opts.svc.clone(),
        move || {
            let mut e = SynthExecutor::new(seed, n_samples);
            e.work_per_sample = work;
            Ok(e)
        },
    )?;
    let mut router = Router::new();
    router.set_role("worker");
    router.register(handle);
    let cancel = CancelToken::new();
    let cancel2 = cancel.clone();
    let bind_addr = opts.addr.clone();
    let (atx, arx) = std::sync::mpsc::channel();
    let thread = std::thread::Builder::new()
        .name("pbm-worker-gateway".into())
        .spawn(move || {
            let sopts = ServerOptions {
                addr: bind_addr,
                workers: 4,
                ..ServerOptions::default()
            };
            if let Err(e) = serve(router, sopts, cancel2, |a| {
                let _ = atx.send(a);
            }) {
                crate::log_error!("worker gateway failed: {e:#}");
            }
        })
        .map_err(|e| anyhow!("spawning worker gateway: {e}"))?;
    let addr = arx
        .recv_timeout(Duration::from_secs(5))
        .map_err(|_| anyhow!("worker gateway did not bind"))?;
    Ok(WorkerGuard {
        addr: addr.to_string(),
        cancel,
        thread: Some(thread),
    })
}

/// Spawn the coordinator: a [`ClusterExecutor`] service loop whose
/// admission control is scaled to **cluster** capacity.  Returns the
/// engine handle (register it on a [`Router`] / gateway) and the shared
/// pool (drive probes via [`spawn_probe_loop`] or manually).
pub fn spawn_coordinator(
    cfg: ClusterConfig,
    addrs: Vec<String>,
    mut svc: ServiceConfig,
) -> Result<(EngineHandle, Arc<WorkerPool>)> {
    if addrs.is_empty() && !cfg.local_fallback {
        bail!("cluster needs at least one worker address (or local_fallback)");
    }
    let workers = addrs.len().max(1);
    let pool = Arc::new(WorkerPool::new(addrs, cfg.client.clone()));
    // Overload admission reflects what the CLUSTER can absorb, not one
    // worker: scale the queue, and with it the auto work budget
    // (`work_capacity = 0` resolves to queue_depth × default_cost), so a
    // flood sheds with a `retry_after_ms` derived from N-worker drain
    // rate.  An explicit work_capacity scales the same way.
    svc.queue_depth = svc.queue_depth.saturating_mul(workers).max(1);
    svc.overload.work_capacity = svc.overload.work_capacity.saturating_mul(workers as u64);
    // first probe inline: the pool starts with real states, and a worker
    // that is already degraded never takes traffic at all
    pool.probe_all();
    let name = cfg.model.clone();
    let n_samples = cfg.n_samples;
    let pool2 = pool.clone();
    let cfg2 = cfg.clone();
    let mut handle = EngineHandle::spawn_executor(
        &name,
        vec![name.clone()],
        None,
        n_samples,
        svc,
        move || Ok(ClusterExecutor::new(cfg2, pool2)),
    )?;
    handle.cluster = Some(pool.clone());
    Ok((handle, pool))
}

/// Periodic health-probe loop (the coordinator CLI's background thread):
/// probes every `interval` until cancelled, polling the token every 20 ms
/// so shutdown is prompt.
pub fn spawn_probe_loop(
    pool: Arc<WorkerPool>,
    interval: Duration,
    cancel: CancelToken,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name("pbm-cluster-probe".into())
        .spawn(move || {
            while !cancel.is_cancelled() {
                let mut waited = Duration::ZERO;
                while waited < interval && !cancel.is_cancelled() {
                    let tick = Duration::from_millis(20).min(interval - waited);
                    std::thread::sleep(tick);
                    waited += tick;
                }
                if !cancel.is_cancelled() {
                    pool.probe_all();
                }
            }
        })
        .expect("spawn probe loop")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_seed_is_placement_pure_and_distinct() {
        // pure in (base, placement)…
        assert_eq!(lane_seed(42, 7), lane_seed(42, 7));
        // …and placement-sensitive: consecutive placements get distinct,
        // well-mixed streams
        let seeds: std::collections::HashSet<u64> =
            (0..1000).map(|p| lane_seed(42, p)).collect();
        assert_eq!(seeds.len(), 1000);
        assert_ne!(lane_seed(42, 0), lane_seed(43, 0), "base matters");
    }

    #[test]
    fn coordinator_scales_admission_to_cluster_capacity() {
        // no live workers needed: unreachable addresses still register
        let mut client = ClientConfig::default();
        client.connect_timeout = Duration::from_millis(100);
        let cfg = ClusterConfig {
            client,
            ..ClusterConfig::default()
        };
        let svc = ServiceConfig {
            queue_depth: 8,
            ..ServiceConfig::default()
        };
        let (handle, pool) = spawn_coordinator(
            cfg,
            vec!["127.0.0.1:1".into(), "127.0.0.1:2".into()],
            svc,
        )
        .unwrap();
        assert_eq!(pool.len(), 2);
        assert!(handle.cluster.is_some(), "/info can read worker cards");
        handle.shutdown();
    }

    #[test]
    fn empty_pool_without_fallback_is_rejected() {
        let err = spawn_coordinator(
            ClusterConfig::default(),
            vec![],
            ServiceConfig::default(),
        )
        .unwrap_err();
        assert!(format!("{err}").contains("worker address"), "{err}");
    }
}
