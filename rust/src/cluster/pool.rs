//! Worker pool: lifecycle state machine, health probes, and routing picks.
//!
//! Each backend worker moves through `Healthy → Suspect → Down →
//! Recovering` driven by two evidence streams: request outcomes reported
//! by the dispatcher ([`WorkerPool::note_success`] /
//! [`WorkerPool::note_failure`]) and periodic probes
//! ([`WorkerPool::probe_all`]) that dial the worker, run the `hello` role
//! handshake, and fold in the worker's own `/info` — its entropy-health
//! scorecards (a worker whose randomness degrades is *drained*, not just
//! deprioritized) and its serving latency percentiles.  `Down` workers are
//! re-probed on a jittered exponential backoff so a flapping worker cannot
//! absorb the probe loop.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::server::tcp::{Client, ClientConfig};
use crate::util::fault::splitmix64;

/// Worker lifecycle state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkerState {
    /// Serving traffic.
    Healthy,
    /// One strike (transport failure or degraded entropy health): drained
    /// from new picks until a probe clears it.
    Suspect,
    /// Repeated failures: only re-probed, on bounded backoff.
    Down,
    /// A probe succeeded after `Down`; takes traffic again, one more clean
    /// probe (or request) promotes it back to `Healthy`.
    Recovering,
}

impl WorkerState {
    pub fn name(&self) -> &'static str {
        match self {
            WorkerState::Healthy => "healthy",
            WorkerState::Suspect => "suspect",
            WorkerState::Down => "down",
            WorkerState::Recovering => "recovering",
        }
    }

    /// May this worker receive new requests?
    pub fn routable(&self) -> bool {
        matches!(self, WorkerState::Healthy | WorkerState::Recovering)
    }
}

/// Point-in-time card for one worker, surfaced in the coordinator's
/// `/info` (`cluster` section).
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerCard {
    pub addr: String,
    pub state: WorkerState,
    pub consecutive_fails: u32,
    /// EWMA of observed request latency (µs); 0 until first sample.
    pub latency_ewma_us: f64,
    /// The worker's own entropy-health monitor reports a degraded stream.
    pub entropy_degraded: bool,
    /// Serving percentiles scraped from the worker's `/info`.
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
}

/// A routing decision from [`WorkerPool::pick`].
#[derive(Debug, Clone)]
pub struct Pick {
    pub index: usize,
    pub addr: String,
    pub latency_ewma_us: f64,
}

struct Slot {
    addr: String,
    state: WorkerState,
    consecutive_fails: u32,
    latency_ewma_us: f64,
    entropy_degraded: bool,
    p50_us: f64,
    p95_us: f64,
    p99_us: f64,
    /// Reconnect-backoff attempt counter while `Down`.
    backoff_attempt: u32,
    /// `Down` slots are not re-probed before this instant.
    next_probe_at: Option<Instant>,
    /// Jitter stream for the backoff schedule (deterministic per slot).
    rng: u64,
}

impl Slot {
    fn new(addr: String, seed: u64) -> Self {
        Self {
            addr,
            state: WorkerState::Healthy,
            consecutive_fails: 0,
            latency_ewma_us: 0.0,
            entropy_degraded: false,
            p50_us: 0.0,
            p95_us: 0.0,
            p99_us: 0.0,
            backoff_attempt: 0,
            next_probe_at: None,
            rng: seed,
        }
    }

    fn card(&self) -> WorkerCard {
        WorkerCard {
            addr: self.addr.clone(),
            state: self.state,
            consecutive_fails: self.consecutive_fails,
            latency_ewma_us: self.latency_ewma_us,
            entropy_degraded: self.entropy_degraded,
            p50_us: self.p50_us,
            p95_us: self.p95_us,
            p99_us: self.p99_us,
        }
    }
}

/// What one successful probe learned from a worker's `/info`.
#[derive(Debug, Clone, Copy, Default)]
struct ProbeReport {
    entropy_degraded: bool,
    p50_us: f64,
    p95_us: f64,
    p99_us: f64,
}

/// EWMA smoothing for observed request latency.
const LATENCY_ALPHA: f64 = 0.2;
/// Probe-backoff schedule while a worker is `Down`.
const BACKOFF_BASE: Duration = Duration::from_millis(50);
const BACKOFF_CAP: Duration = Duration::from_secs(2);

/// Shared, lock-protected view of the cluster's workers.
pub struct WorkerPool {
    slots: Mutex<Vec<Slot>>,
    client_cfg: ClientConfig,
}

impl WorkerPool {
    pub fn new(addrs: Vec<String>, client_cfg: ClientConfig) -> Self {
        let mut seed = client_cfg.seed ^ 0x5EED_F00D;
        let slots = addrs
            .into_iter()
            .map(|a| Slot::new(a, splitmix64(&mut seed)))
            .collect();
        Self {
            slots: Mutex::new(slots),
            client_cfg,
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Vec<Slot>> {
        self.slots.lock().unwrap_or_else(|p| p.into_inner())
    }

    pub fn len(&self) -> usize {
        self.lock().len()
    }

    pub fn is_empty(&self) -> bool {
        self.lock().is_empty()
    }

    /// Workers currently not routable (Suspect or Down).
    pub fn down_count(&self) -> usize {
        self.lock().iter().filter(|s| !s.state.routable()).count()
    }

    /// Cards for `/info`, in registration order.
    pub fn cards(&self) -> Vec<WorkerCard> {
        self.lock().iter().map(Slot::card).collect()
    }

    /// First routable worker in ring order starting at `lane`, skipping
    /// `exclude` (indices already tried for this request).  Drained
    /// (entropy-degraded) workers are never picked even if nominally
    /// routable.
    pub fn pick(&self, lane: usize, exclude: &[usize]) -> Option<Pick> {
        let slots = self.lock();
        let n = slots.len();
        if n == 0 {
            return None;
        }
        for k in 0..n {
            let i = (lane + k) % n;
            if exclude.contains(&i) {
                continue;
            }
            let s = &slots[i];
            if s.state.routable() && !s.entropy_degraded {
                return Some(Pick {
                    index: i,
                    addr: s.addr.clone(),
                    latency_ewma_us: s.latency_ewma_us,
                });
            }
        }
        None
    }

    /// A request served by worker `i` completed (including typed serving
    /// errors — the worker answered, so it is alive): promote toward
    /// `Healthy` and fold the observed latency into the EWMA.
    pub fn note_success(&self, i: usize, latency_us: f64) {
        let mut slots = self.lock();
        let Some(s) = slots.get_mut(i) else { return };
        s.consecutive_fails = 0;
        s.backoff_attempt = 0;
        s.next_probe_at = None;
        s.state = WorkerState::Healthy;
        s.latency_ewma_us = if s.latency_ewma_us == 0.0 {
            latency_us
        } else {
            (1.0 - LATENCY_ALPHA) * s.latency_ewma_us + LATENCY_ALPHA * latency_us
        };
    }

    /// A transport-level failure talking to worker `i` (connect refused,
    /// dropped mid-response, garbage reply): demote one step and, once
    /// `Down`, schedule the next probe on jittered exponential backoff.
    pub fn note_failure(&self, i: usize) {
        let mut slots = self.lock();
        let Some(s) = slots.get_mut(i) else { return };
        s.consecutive_fails += 1;
        s.state = match s.state {
            WorkerState::Healthy | WorkerState::Recovering => WorkerState::Suspect,
            WorkerState::Suspect | WorkerState::Down => WorkerState::Down,
        };
        if s.state == WorkerState::Down {
            s.backoff_attempt += 1;
            let exp = BACKOFF_BASE
                .saturating_mul(1u32 << s.backoff_attempt.saturating_sub(1).min(16))
                .min(BACKOFF_CAP);
            let frac = 0.5 + (splitmix64(&mut s.rng) >> 11) as f64 / (1u64 << 53) as f64;
            s.next_probe_at = Some(Instant::now() + exp.mul_f64(frac));
        }
    }

    /// A probe reached worker `i` and read its `/info`: clear failure
    /// counters, scrape percentiles, and either drain it (degraded
    /// entropy health → `Suspect`) or promote it one step toward
    /// `Healthy` (`Down → Recovering → Healthy`).
    fn note_probe_ok(&self, i: usize, report: ProbeReport) {
        let mut slots = self.lock();
        let Some(s) = slots.get_mut(i) else { return };
        s.consecutive_fails = 0;
        s.backoff_attempt = 0;
        s.next_probe_at = None;
        s.entropy_degraded = report.entropy_degraded;
        s.p50_us = report.p50_us;
        s.p95_us = report.p95_us;
        s.p99_us = report.p99_us;
        s.state = if report.entropy_degraded {
            // reachable but its randomness is suspect: drain it from
            // routing until its monitor clears
            WorkerState::Suspect
        } else {
            match s.state {
                WorkerState::Down => WorkerState::Recovering,
                _ => WorkerState::Healthy,
            }
        };
    }

    /// Probe every worker once (skipping `Down` workers still inside their
    /// backoff window).  Runs the network round-trips without holding the
    /// pool lock, so routing picks never stall behind a slow probe.
    pub fn probe_all(&self) {
        let n = self.lock().len();
        for i in 0..n {
            let (addr, due) = {
                let slots = self.lock();
                let Some(s) = slots.get(i) else { break };
                let due = s.state != WorkerState::Down
                    || s.next_probe_at.map_or(true, |t| Instant::now() >= t);
                (s.addr.clone(), due)
            };
            if !due {
                continue;
            }
            match self.probe_one(&addr) {
                Ok(report) => self.note_probe_ok(i, report),
                Err(e) => {
                    crate::log_debug!("probe {addr}: {e}");
                    self.note_failure(i);
                }
            }
        }
    }

    /// One probe round-trip: dial, `hello` role handshake (the peer must
    /// be a worker — routing shards at another coordinator or a bare
    /// server would be a deployment error worth failing loudly), then
    /// `/info` for entropy health and serving percentiles.
    fn probe_one(&self, addr: &str) -> Result<ProbeReport> {
        let mut cfg = self.client_cfg.clone();
        cfg.retries = 0; // the pool's own backoff owns retry policy
        let mut client = Client::connect_with(addr, cfg)?;
        let role = client.hello("coordinator")?;
        if role != "worker" {
            bail!("peer at {addr} answered hello as '{role}', not 'worker'");
        }
        let info = client.info()?;
        if info.get("ok").and_then(|v| v.as_bool()) != Some(true) {
            return Err(anyhow!("worker {addr} info returned not-ok"));
        }
        let mut report = ProbeReport::default();
        // any degraded stream on any shard drains the worker
        if let Some(health) = info.get("entropy_health").and_then(|h| h.as_obj()) {
            report.entropy_degraded = health.values().any(|cards| {
                cards.as_arr().is_some_and(|cs| {
                    cs.iter()
                        .any(|c| c.get("degraded").and_then(|d| d.as_bool()) == Some(true))
                })
            });
        }
        // aggregate percentiles: worst (max) across the worker's engines
        if let Some(serving) = info.get("serving").and_then(|s| s.as_obj()) {
            for snap in serving.values() {
                let f = |k: &str| snap.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
                report.p50_us = report.p50_us.max(f("p50_us"));
                report.p95_us = report.p95_us.max(f("p95_us"));
                report.p99_us = report.p99_us.max(f("p99_us"));
            }
        }
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(n: usize) -> WorkerPool {
        let addrs = (0..n).map(|i| format!("127.0.0.1:{}", 40000 + i)).collect();
        WorkerPool::new(addrs, ClientConfig::default())
    }

    #[test]
    fn lifecycle_demotes_and_promotes_stepwise() {
        let p = pool(1);
        assert_eq!(p.cards()[0].state, WorkerState::Healthy);
        p.note_failure(0);
        assert_eq!(p.cards()[0].state, WorkerState::Suspect);
        p.note_failure(0);
        assert_eq!(p.cards()[0].state, WorkerState::Down);
        assert_eq!(p.down_count(), 1);
        // a clean probe promotes Down → Recovering (routable), not
        // straight to Healthy
        p.note_probe_ok(0, ProbeReport::default());
        assert_eq!(p.cards()[0].state, WorkerState::Recovering);
        assert!(p.cards()[0].state.routable());
        p.note_probe_ok(0, ProbeReport::default());
        assert_eq!(p.cards()[0].state, WorkerState::Healthy);
    }

    #[test]
    fn success_heals_and_tracks_latency_ewma() {
        let p = pool(1);
        p.note_failure(0);
        p.note_success(0, 1000.0);
        let c = &p.cards()[0];
        assert_eq!(c.state, WorkerState::Healthy);
        assert_eq!(c.consecutive_fails, 0);
        assert_eq!(c.latency_ewma_us, 1000.0, "first sample seeds the EWMA");
        p.note_success(0, 2000.0);
        let e = p.cards()[0].latency_ewma_us;
        assert!(e > 1000.0 && e < 2000.0, "smoothed, not replaced: {e}");
    }

    #[test]
    fn degraded_entropy_drains_worker_from_picks() {
        let p = pool(2);
        p.note_probe_ok(
            0,
            ProbeReport {
                entropy_degraded: true,
                ..Default::default()
            },
        );
        let c = &p.cards()[0];
        assert_eq!(c.state, WorkerState::Suspect);
        assert!(c.entropy_degraded);
        // lane 0 would prefer worker 0; the drain reroutes to 1
        let pick = p.pick(0, &[]).unwrap();
        assert_eq!(pick.index, 1);
        // the monitor clearing restores routing
        p.note_probe_ok(0, ProbeReport::default());
        assert_eq!(p.pick(0, &[]).unwrap().index, 0);
    }

    #[test]
    fn pick_walks_ring_and_honors_exclusions() {
        let p = pool(3);
        assert_eq!(p.pick(1, &[]).unwrap().index, 1);
        assert_eq!(p.pick(1, &[1]).unwrap().index, 2);
        assert_eq!(p.pick(1, &[1, 2]).unwrap().index, 0);
        assert!(p.pick(1, &[0, 1, 2]).is_none(), "all tried");
        p.note_failure(1);
        assert_eq!(p.pick(1, &[]).unwrap().index, 2, "suspect skipped");
    }

    #[test]
    fn down_worker_backs_off_between_probes() {
        let p = pool(1);
        p.note_failure(0);
        p.note_failure(0); // → Down, backoff scheduled
        let slots = p.lock();
        let s = &slots[0];
        assert_eq!(s.state, WorkerState::Down);
        assert!(s.next_probe_at.is_some(), "Down schedules a re-probe time");
        assert!(s.backoff_attempt >= 1);
    }

    #[test]
    fn probe_all_marks_unreachable_workers() {
        // nothing listens on these addresses: both probes must fail fast
        // and demote (connect_timeout bounds the worst case)
        let mut cfg = ClientConfig::default();
        cfg.connect_timeout = Duration::from_millis(200);
        let p = WorkerPool::new(
            vec!["127.0.0.1:1".into(), "127.0.0.1:2".into()],
            cfg,
        );
        p.probe_all();
        for c in p.cards() {
            assert_eq!(c.state, WorkerState::Suspect, "{c:?}");
        }
        assert!(p.pick(0, &[]).is_none());
    }
}
