//! Dynamic batching: coalesce queued requests up to `max_batch`, waiting at
//! most `max_wait` after the first arrival (the classic latency/throughput
//! knob of serving systems).

use std::time::{Duration, Instant};

use crate::exec::channel::Receiver;

/// Pulls batches from a request channel.
pub struct DynamicBatcher<T> {
    rx: Receiver<T>,
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl<T> DynamicBatcher<T> {
    pub fn new(rx: Receiver<T>, max_batch: usize, max_wait: Duration) -> Self {
        Self {
            rx,
            max_batch: max_batch.max(1),
            max_wait,
        }
    }

    /// Block for the next batch; `None` when the channel is closed and
    /// drained.  Returns as soon as `max_batch` items are collected or
    /// `max_wait` has elapsed since the first item arrived.
    pub fn next_batch(&self) -> Option<Vec<T>> {
        self.next_batch_weighted(|_| 0, 0)
    }

    /// Like [`next_batch`](Self::next_batch), but also closes the batch
    /// once the summed `cost` of its items reaches `max_work` (0 disables
    /// the work cap).  Lets the service loop bound a batch by estimated
    /// samples, not just request count: `max_batch` heavyweight requests
    /// are `max_batch × default_cost` samples of engine work, which is a
    /// very different latency envelope from `max_batch` cheap ones.
    pub fn next_batch_weighted(
        &self,
        cost: impl Fn(&T) -> u64,
        max_work: u64,
    ) -> Option<Vec<T>> {
        let first = self.rx.recv()?;
        let mut work = cost(&first);
        let mut batch = vec![first];
        let deadline = Instant::now() + self.max_wait;
        while batch.len() < self.max_batch && (max_work == 0 || work < max_work) {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(Some(item)) => {
                    work = work.saturating_add(cost(&item));
                    batch.push(item);
                }
                Ok(None) => break, // closed: ship what we have
                Err(()) => break,  // timed out
            }
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::channel::channel;
    use std::thread;

    #[test]
    fn batches_up_to_max() {
        let (tx, rx) = channel(64);
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let b = DynamicBatcher::new(rx, 4, Duration::from_millis(5));
        assert_eq!(b.next_batch().unwrap(), vec![0, 1, 2, 3]);
        assert_eq!(b.next_batch().unwrap(), vec![4, 5, 6, 7]);
        assert_eq!(b.next_batch().unwrap().len(), 2);
    }

    #[test]
    fn flushes_partial_batch_after_max_wait() {
        let (tx, rx) = channel(64);
        let b = DynamicBatcher::new(rx, 32, Duration::from_millis(30));
        let h = thread::spawn(move || {
            tx.send(1u32).unwrap();
            thread::sleep(Duration::from_millis(5));
            tx.send(2).unwrap();
            // the third arrives after the window closes
            thread::sleep(Duration::from_millis(60));
            tx.send(3).unwrap();
            tx.close();
        });
        let t0 = Instant::now();
        let first = b.next_batch().unwrap();
        assert_eq!(first, vec![1, 2]);
        assert!(t0.elapsed() < Duration::from_millis(200));
        assert_eq!(b.next_batch().unwrap(), vec![3]);
        assert!(b.next_batch().is_none());
        h.join().unwrap();
    }

    #[test]
    fn none_on_closed_empty() {
        let (tx, rx) = channel::<u8>(4);
        tx.close();
        let b = DynamicBatcher::new(rx, 4, Duration::from_millis(1));
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn weighted_batch_closes_on_work_cap() {
        let (tx, rx) = channel(64);
        for cost in [10u64, 10, 10, 10] {
            tx.send(cost).unwrap();
        }
        // count cap of 8 never binds; the 25-sample work cap closes the
        // batch at the item that crosses it
        let b = DynamicBatcher::new(rx, 8, Duration::from_millis(50));
        let batch = b.next_batch_weighted(|&c| c, 25).unwrap();
        assert_eq!(batch, vec![10, 10, 10]);
        // the fourth item is still queued for the next batch
        let batch = b.next_batch_weighted(|&c| c, 25).unwrap();
        assert_eq!(batch, vec![10]);
    }

    #[test]
    fn full_batch_returns_immediately() {
        let (tx, rx) = channel(64);
        for i in 0..4 {
            tx.send(i).unwrap();
        }
        // huge max_wait must not delay a full batch
        let b = DynamicBatcher::new(rx, 4, Duration::from_secs(10));
        let t0 = Instant::now();
        assert_eq!(b.next_batch().unwrap().len(), 4);
        assert!(t0.elapsed() < Duration::from_millis(100));
    }
}
