//! The inference engine: PJRT for deterministic layers, a pluggable
//! [`ProbConvBackend`] for the probabilistic block, uncertainty aggregation
//! on top.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::backend::{
    self, BackendKind, EpsSource, PipelineOptions, PrefetchMode, ProbConvBackend, SamplePlan,
};
use crate::bnn::{Decision, Predictive, UncertaintyPolicy};
use crate::entropy::health::{HealthConfig, HealthEvent, Monitor};
use crate::exec::scratch::{grow, ScratchArena};
use crate::exec::ThreadPool;
use crate::{log_info, log_warn};
use crate::observe::{Stage, TraceRecorder};
use crate::photonics::MachineConfig;
use crate::registry::{ModelCheckpoint, ProgramKey, ProgramRegistry, RegistryMetrics, UnknownModel};
use crate::runtime::{Arg, CompiledFn, ModelArtifacts, ParamStore};
use crate::sampler::{
    ChunkSchedule, PredictiveAccum, RequestBudget, ResolvedSampler, SamplerConfig, StopReason,
    StopRule, StopState, Verdict,
};
use crate::util::{fault, logging};

use super::overload::ServeError;

/// Where the probabilistic block executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// The AOT surrogate (`fwd_full` HLO) with chaotic noise fed as `eps`.
    Surrogate,
    /// The split path: `fwd_pre` → batched [`ProbConvBackend`] sample plan
    /// → `fwd_post`, on the chosen sampling substrate.
    Split(BackendKind),
}

impl ExecMode {
    /// The paper's serving configuration: split path on the photonic machine.
    pub fn photonic() -> Self {
        ExecMode::Split(BackendKind::Photonic)
    }

    /// Parse a CLI/config token: `photonic|digital|mean|surrogate`.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "surrogate" => Ok(ExecMode::Surrogate),
            other => Ok(ExecMode::Split(BackendKind::parse(other).map_err(|_| {
                anyhow!("mode must be photonic|digital|mean|surrogate, got {other}")
            })?)),
        }
    }

    /// The backend kind the split path would use (the photonic machine is
    /// also kept programmed behind the surrogate, for parity probes).
    pub fn backend_kind(&self) -> BackendKind {
        match self {
            ExecMode::Surrogate => BackendKind::Photonic,
            ExecMode::Split(kind) => *kind,
        }
    }
}

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Stochastic forward passes per request (paper: N = 10).  A
    /// deterministic backend collapses this to 1 at serving time.
    pub n_samples: usize,
    pub mode: ExecMode,
    pub policy: UncertaintyPolicy,
    /// Run feedback calibration on every kernel at load time.
    pub calibrate: bool,
    pub machine: MachineConfig,
    /// Channel bandwidth used when drawing surrogate `eps` noise (GHz).
    pub noise_bw_ghz: f64,
    /// Worker threads for the sampling hot path.  Each `SamplePlan` is
    /// sharded across this many pool workers, each with its own
    /// deterministic entropy stream: results are reproducible for a fixed
    /// `(seed, threads)` and statistically equivalent across thread counts.
    /// `1` = sequential in-thread sampling (bit-compatible with the
    /// pre-pool engine); `0` = one worker per available core.
    pub threads: usize,
    /// Decoupled entropy pipeline: `Off` draws entropy inline in the
    /// historical stream organization; `Sync` switches to the pipeline's
    /// banked streams drawn synchronously; `On` additionally prefetches
    /// them with background producer threads.  `Sync` and `On` are bitwise
    /// identical for a fixed `(seed, threads)`.
    pub entropy_prefetch: PrefetchMode,
    /// Draws per prefetched entropy block (ring transfer granularity).
    pub entropy_block: usize,
    /// Adaptive sequential sampling: stop rule, `min`/`max` clamps, and
    /// chunk size.  The default (`StopRule::Fixed(0)`) spends the whole
    /// `n_samples` budget in one batched round — bitwise identical to the
    /// pre-sampler engine.  Per-request [`RequestBudget`] overrides refine
    /// this (they can lower the budget or request a confidence target,
    /// never raise the budget).
    pub sampler: SamplerConfig,
    /// Online entropy-health monitoring: duty-cycled taps on the backend's
    /// producer streams feed the hardened NIST battery plus min-entropy and
    /// serial-correlation estimators into per-(shard, stream) scorecards.
    /// Disabled by default; taps observe by copy, so enabling the monitor
    /// never changes sampled outputs.
    pub health: HealthConfig,
    /// Backend to switch to when the health monitor reports sustained
    /// degradation (`[engine] entropy_fallback = "digital"`).  `None` (the
    /// default) logs and exposes scorecards but never swaps backends.
    pub entropy_fallback: Option<BackendKind>,
    /// Pre-built monitor shared with the serving layer so `/info` can read
    /// scorecards without an engine round-trip.  When `None` and
    /// `health.enabled`, the engine builds its own.
    pub health_monitor: Option<Arc<Monitor>>,
    /// Byte budget for the per-model bank cache of a multi-model engine
    /// ([`Engine::with_registry`]): parked models' machines, shard
    /// front-ends, and prefetched weight-plane banks are LRU-evicted once
    /// their combined estimated size exceeds this.  Ignored by single-model
    /// engines.
    pub bank_budget_bytes: usize,
    /// Pre-built registry metrics shared with the serving layer so `/info`
    /// can read residency and hit/miss/switch counters without an engine
    /// round-trip.  When `None`, a multi-model engine builds its own.
    pub registry_metrics: Option<Arc<RegistryMetrics>>,
    pub seed: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            n_samples: 10,
            mode: ExecMode::photonic(),
            policy: UncertaintyPolicy::ood_only(0.0185),
            calibrate: true,
            machine: MachineConfig::default(),
            noise_bw_ghz: 150.0,
            threads: 1,
            entropy_prefetch: PrefetchMode::Off,
            entropy_block: 4096,
            sampler: SamplerConfig::default(),
            health: HealthConfig::default(),
            entropy_fallback: None,
            health_monitor: None,
            bank_budget_bytes: 256 << 20,
            registry_metrics: None,
            seed: 42,
        }
    }
}

impl EngineConfig {
    /// Resolve `threads` to a concrete worker count (`0` = auto).
    pub fn resolved_threads(&self) -> usize {
        match self.threads {
            0 => std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            n => n,
        }
    }
}

/// Outcome for one classified image.
#[derive(Debug, Clone)]
pub struct ClassifyResult {
    pub predictive: Predictive,
    pub decision: Decision,
    pub latency_us: f64,
    /// Stochastic passes folded into this image's predictive (== the fixed
    /// budget on the `Fixed` rule; fewer when its adaptive rule resolved
    /// early).  For a single-image request this is also the compute
    /// actually spent; in a multi-image batch the plan keeps drawing until
    /// the *whole batch* resolves, so per-image compute is bounded by the
    /// batch's slowest image even though frozen images fold in no more
    /// samples.
    pub samples_used: usize,
    /// Served under overload degradation (clamped budget and/or the
    /// mean-field brownout backend): the answer is best-effort, with
    /// reduced or absent sampling-based uncertainty.  Surfaces as
    /// `degraded:true` on the wire.
    pub degraded: bool,
}

/// The engine.  Owns non-`Send` PJRT state — confine to one thread (see
/// [`super::service`]).
pub struct Engine {
    pub arts: ModelArtifacts,
    pub params: ParamStore,
    backend: Box<dyn ProbConvBackend>,
    noise: EpsSource,
    cfg: EngineConfig,
    /// Reusable request buffers (padded input, eps, sample plans, pass
    /// staging): steady-state classification allocates only its results.
    scratch: ScratchArena,
    /// Resolved machine config / pipeline options / worker pool, retained so
    /// an entropy-health fallback can rebuild the backend identically.
    mcfg: MachineConfig,
    popts: PipelineOptions,
    pool: Option<Arc<ThreadPool>>,
    /// Entropy-health monitor tapping the backend's producer streams.
    monitor: Option<Arc<Monitor>>,
    /// Set once an entropy-health fallback has swapped the backend: the
    /// swap is one-way (a recovered source does not swap back — operators
    /// restart the engine after fixing the hardware).
    fell_back: bool,
    /// Parked mean-field backend for the overload brownout tier, built
    /// lazily on first brownout and kept programmed for `brownout_model`.
    standby_mean: Option<Box<dyn ProbConvBackend>>,
    /// Model `standby_mean` is programmed for (rebuilt on mismatch).
    brownout_model: String,
    /// Whether the mean-field backend is currently swapped in.
    brownout: bool,
    /// Inactive checkpoints of a multi-model engine; the active one lives
    /// in `arts`/`params`.  Empty on single-model engines.
    standby: Vec<ModelCheckpoint>,
    /// Serving name of the active checkpoint (the dataset name on legacy
    /// single-model engines).
    active_model: String,
    /// Model serving requests that carry no `model` field (the registry's
    /// first entry).
    default_model: String,
    /// Residency/hit/miss accounting, shared with the backend's model
    /// cache and the serving layer.  `None` on single-model engines.
    reg_metrics: Option<Arc<RegistryMetrics>>,
    /// Trace recorder (present when tracing is on) + the traced ids of
    /// the group currently being classified, set by the service loop
    /// through [`super::service::BatchExecutor::begin_group`].
    trace: Option<Arc<TraceRecorder>>,
    trace_ids: Vec<u64>,
    pub metrics: super::metrics::EngineMetrics,
}

impl Engine {
    /// Build an engine: programs the backend's kernel bank from the trained
    /// probabilistic parameters (one 9-tap kernel per depthwise channel)
    /// and optionally runs feedback calibration on each.
    pub fn new(arts: ModelArtifacts, params: ParamStore, cfg: EngineConfig) -> Result<Self> {
        Self::build(arts, params, cfg, true)
    }

    /// Build a multi-model engine over a loaded [`ProgramRegistry`].  The
    /// first model is the default; the backend gets a model cache under
    /// `cfg.bank_budget_bytes` and is program-switched (not plain
    /// programmed), so each model's streams are seeded from its model-mixed
    /// seed and the bitwise replay contract holds per `(model, seed,
    /// threads, prefetch, rule)`.
    pub fn with_registry(registry: ProgramRegistry, cfg: EngineConfig) -> Result<Self> {
        let mut models = registry.models;
        if models.is_empty() {
            return Err(anyhow!("model registry is empty"));
        }
        let metrics = cfg
            .registry_metrics
            .clone()
            .unwrap_or_else(|| Arc::new(RegistryMetrics::default()));
        let budget = cfg.bank_budget_bytes;
        let first = models.remove(0);
        let first_name = first.name.clone();
        // skip the legacy program() call: the registry path programs the
        // backend through switch_program below, against the model-mixed key
        let mut engine = Self::build(first.arts, first.params, cfg, false)?;
        engine.backend.enable_model_cache(budget, metrics.clone());
        metrics.register(&first_name);
        for m in &models {
            metrics.register(&m.name);
        }
        engine.standby = models;
        engine.active_model = first_name.clone();
        engine.default_model = first_name;
        engine.reg_metrics = Some(metrics);
        engine.program_active()?;
        Ok(engine)
    }

    fn build(
        arts: ModelArtifacts,
        params: ParamStore,
        cfg: EngineConfig,
        program_now: bool,
    ) -> Result<Self> {
        if cfg.n_samples == 0 {
            return Err(anyhow!(
                "n_samples: {}",
                crate::sampler::BudgetError::ZeroSamples
            ));
        }
        cfg.sampler
            .validate()
            .map_err(|e| anyhow!("sampler config: {e}"))?;
        let mut mcfg = cfg.machine.clone();
        mcfg.scale_dac = arts.meta.scale_dac;
        mcfg.scale_adc = arts.meta.scale_adc;
        mcfg.seed = cfg.seed;
        let threads = cfg.resolved_threads();
        let pool = (threads > 1).then(|| Arc::new(ThreadPool::new(threads)));
        let popts = PipelineOptions {
            mode: cfg.entropy_prefetch,
            block: cfg.entropy_block,
            ..PipelineOptions::default()
        }
        .sanitized();
        // a monitor handed in by the serving layer wins (it is what /info
        // reads); otherwise build one here when health checking is enabled
        let monitor = cfg.health_monitor.clone().or_else(|| {
            cfg.health
                .enabled
                .then(|| Arc::new(Monitor::new(cfg.health)))
        });
        let mut backend = backend::build_with_opts_monitored(
            cfg.mode.backend_kind(),
            &mcfg,
            pool.clone(),
            popts,
            monitor.clone(),
        );
        if program_now {
            let kernels = params.prob_kernels()?;
            let t0 = Instant::now();
            backend.program(&kernels, cfg.calibrate)?;
            log_info!(
                "engine[{}]: programmed {} kernels on '{}' backend in {:.2}s (calibrate={}, \
                 threads={}, prefetch={})",
                arts.meta.dataset,
                kernels.len(),
                backend.name(),
                t0.elapsed().as_secs_f64(),
                cfg.calibrate,
                threads,
                popts.mode
            );
        }
        let active_model = arts.meta.dataset.clone();
        Ok(Self {
            noise: EpsSource::chaotic(cfg.seed.wrapping_add(77), cfg.noise_bw_ghz),
            backend,
            arts,
            params,
            cfg,
            scratch: ScratchArena::default(),
            mcfg,
            popts,
            pool,
            monitor,
            fell_back: false,
            standby_mean: None,
            brownout_model: String::new(),
            brownout: false,
            standby: Vec::new(),
            default_model: active_model.clone(),
            active_model,
            reg_metrics: None,
            trace: None,
            trace_ids: Vec::new(),
            metrics: Default::default(),
        })
    }

    /// Program-switch the backend to the engine's active checkpoint
    /// (registry path).  The key carries the model-mixed seed and the
    /// checkpoint's own DAC/ADC scales; the retained machine config is kept
    /// in step so a later entropy-health fallback rebuild sees the right
    /// quantization ranges.
    fn program_active(&mut self) -> Result<()> {
        let key = ProgramKey::new(
            &self.active_model,
            self.cfg.seed,
            self.arts.meta.scale_dac,
            self.arts.meta.scale_adc,
        );
        self.mcfg.scale_dac = self.arts.meta.scale_dac;
        self.mcfg.scale_adc = self.arts.meta.scale_adc;
        let kernels = self.params.prob_kernels()?;
        self.backend.switch_program(&key, &kernels, self.cfg.calibrate)
    }

    /// All served model names, default (active slot's registry order) first.
    pub fn model_names(&self) -> Vec<String> {
        let mut names = vec![self.active_model.clone()];
        names.extend(self.standby.iter().map(|s| s.name.clone()));
        names
    }

    /// The default model (requests without a `model` field go here).
    pub fn default_model(&self) -> &str {
        &self.default_model
    }

    /// Expected flat image length for `model`, if it is served here.
    pub fn image_size_of(&self, model: &str) -> Option<usize> {
        if model == self.active_model {
            return Some(self.arts.meta.image_size());
        }
        self.standby
            .iter()
            .find(|s| s.name == model)
            .map(|s| s.arts.meta.image_size())
    }

    /// Switch the active checkpoint to `model` (no-op when already active).
    /// The previous checkpoint parks in a standby slot; the backend swaps
    /// its sampling state through the registry's LRU cache.  Switch latency
    /// lands in the engine metrics.
    pub fn switch_model(&mut self, model: &str) -> Result<()> {
        if model == self.active_model {
            return Ok(());
        }
        let idx = self
            .standby
            .iter()
            .position(|s| s.name == model)
            .ok_or_else(|| {
                anyhow::Error::new(UnknownModel {
                    model: model.to_string(),
                    known: self.model_names(),
                })
            })?;
        let t0 = Instant::now();
        let slot = &mut self.standby[idx];
        std::mem::swap(&mut self.arts, &mut slot.arts);
        std::mem::swap(&mut self.params, &mut slot.params);
        slot.name = std::mem::replace(&mut self.active_model, model.to_string());
        self.program_active()?;
        self.metrics.record_model_switch(t0.elapsed());
        Ok(())
    }

    /// [`Self::classify_with_budget`] against a named model (`None` = the
    /// registry default), switching first if needed.
    pub fn classify_model(
        &mut self,
        model: Option<&str>,
        images: &[f32],
        n: usize,
        budget: &RequestBudget,
    ) -> Result<Vec<ClassifyResult>> {
        self.classify_opts(model, images, n, budget, None, false)
    }

    /// The service loop's entry point: switch to `model`, optionally brown
    /// out to the mean-field backend for this one call (the tier-2
    /// overload degradation), and classify under `budget` / `deadline`.
    /// Brownout results come back flagged [`ClassifyResult::degraded`].
    pub fn classify_opts(
        &mut self,
        model: Option<&str>,
        images: &[f32],
        n: usize,
        budget: &RequestBudget,
        deadline: Option<Instant>,
        brownout: bool,
    ) -> Result<Vec<ClassifyResult>> {
        let target = model.unwrap_or(&self.default_model).to_string();
        self.switch_model(&target)?;
        if brownout {
            self.enter_brownout()?;
        }
        let res = self.classify_with_deadline(images, n, budget, deadline);
        let was_browned = self.brownout;
        // exit even on error: the next call decides its own tier
        self.exit_brownout();
        res.map(|mut results| {
            if was_browned {
                for r in &mut results {
                    r.degraded = true;
                }
            }
            results
        })
    }

    /// Enter overload brownout: swap in a lazily-built mean-field backend
    /// programmed with the active model's kernels.  One deterministic pass
    /// per request, and — crucially — no entropy consumed from the real
    /// backend's persistent shard streams, so exiting brownout resumes
    /// them exactly where they left off and the bitwise replay contract
    /// per `(model, seed, threads, prefetch, rule)` survives the episode.
    fn enter_brownout(&mut self) -> Result<()> {
        if self.brownout {
            return Ok(());
        }
        if self.standby_mean.is_none() || self.brownout_model != self.active_model {
            let mut be = backend::build_with_opts_monitored(
                BackendKind::Mean,
                &self.mcfg,
                self.pool.clone(),
                self.popts,
                None,
            );
            // no calibration: the brownout backend is a cheap shelter
            // under pressure, not a calibrated serving substrate
            be.program(&self.params.prob_kernels()?, false)?;
            self.standby_mean = Some(be);
            self.brownout_model = self.active_model.clone();
            log_warn!(
                "engine[{}]: brownout backend programmed for '{}'",
                self.arts.meta.dataset,
                self.active_model
            );
        }
        std::mem::swap(&mut self.backend, self.standby_mean.as_mut().unwrap());
        self.brownout = true;
        Ok(())
    }

    /// Exit brownout (no-op when not browned out).
    fn exit_brownout(&mut self) {
        if !self.brownout {
            return;
        }
        std::mem::swap(&mut self.backend, self.standby_mean.as_mut().unwrap());
        self.brownout = false;
    }

    /// Deterministically rebuild the sampling substrate after a panic
    /// escaped a classify call (the service loop's `catch_unwind`
    /// recovery path).  A panic can leave the backend mid-plan — entropy
    /// streams partially advanced, prefetched banks half-consumed — so
    /// the backend is rebuilt from the engine's retained `(machine
    /// config, pool, pipeline options)` exactly as at construction:
    /// post-recovery outputs replay bitwise against a freshly-built
    /// engine per `(model, seed, threads, prefetch, rule)`.  Scratch
    /// arenas are length-addressed lanes re-filled by every request and
    /// need no reset.
    pub fn recover_after_panic(&mut self) -> Result<()> {
        // a panic mid-call may have left a brownout swap un-unwound;
        // discard the parked backend (cheap to rebuild) and recompute
        // which substrate is current truth
        self.brownout = false;
        self.standby_mean = None;
        self.brownout_model.clear();
        let target = if self.fell_back {
            self.cfg
                .entropy_fallback
                .unwrap_or_else(|| self.cfg.mode.backend_kind())
        } else {
            self.cfg.mode.backend_kind()
        };
        let kernels = self.params.prob_kernels()?;
        let mut backend = backend::build_with_opts_monitored(
            target,
            &self.mcfg,
            self.pool.clone(),
            self.popts,
            self.monitor.clone(),
        );
        if let Some(metrics) = &self.reg_metrics {
            // registry mode: fresh (empty) model cache, programmed through
            // the switch path so the active model keeps its model-mixed seed
            backend.enable_model_cache(self.cfg.bank_budget_bytes, metrics.clone());
        } else {
            backend.program(&kernels, self.cfg.calibrate)?;
        }
        let old = std::mem::replace(&mut self.backend, backend);
        drop(old); // joins the poisoned backend's entropy producers
        if self.reg_metrics.is_some() {
            self.program_active()?;
        }
        // the surrogate eps stream may also be mid-draw: rebuild from seed
        self.noise = EpsSource::chaotic(self.cfg.seed.wrapping_add(77), self.cfg.noise_bw_ghz);
        log_warn!(
            "engine[{}]: rebuilt '{}' backend after an isolated panic",
            self.arts.meta.dataset,
            target
        );
        Ok(())
    }

    pub fn n_classes(&self) -> usize {
        self.arts.meta.n_classes
    }

    pub fn image_size(&self) -> usize {
        self.arts.meta.image_size()
    }

    pub fn mode(&self) -> ExecMode {
        self.cfg.mode
    }

    /// The sampling substrate behind the probabilistic block.
    pub fn backend_kind(&self) -> BackendKind {
        self.backend.kind()
    }

    /// Stochastic passes actually executed per request: 1 on a
    /// deterministic backend, `n_samples` otherwise.
    pub fn samples_per_request(&self) -> usize {
        if matches!(self.cfg.mode, ExecMode::Split(_)) && self.backend.is_deterministic() {
            1
        } else {
            self.cfg.n_samples
        }
    }

    /// Classify a batch of images (`images.len() == n * image_size`) under
    /// the engine's default sample budget.  Returns one result per image.
    pub fn classify(&mut self, images: &[f32], n: usize) -> Result<Vec<ClassifyResult>> {
        self.classify_with_budget(images, n, &RequestBudget::default())
    }

    /// [`Self::classify`] with per-request budget overrides (protocol
    /// `max_samples` / `target_confidence` fields).  The fixed-rule path is
    /// bitwise identical to the pre-sampler engine; adaptive rules draw in
    /// chunks and stop each image as soon as its stop rule resolves.
    pub fn classify_with_budget(
        &mut self,
        images: &[f32],
        n: usize,
        budget: &RequestBudget,
    ) -> Result<Vec<ClassifyResult>> {
        self.classify_with_deadline(images, n, budget, None)
    }

    /// [`Self::classify_with_budget`] under an absolute deadline: checked
    /// at entry and again between adaptive chunks, so an expired request
    /// stops burning samples at the next chunk boundary and returns a
    /// typed [`ServeError::DeadlineExceeded`] carrying the stochastic
    /// work spent so far.
    pub fn classify_with_deadline(
        &mut self,
        images: &[f32],
        n: usize,
        budget: &RequestBudget,
        deadline: Option<Instant>,
    ) -> Result<Vec<ClassifyResult>> {
        if images.len() != n * self.image_size() {
            return Err(anyhow!(
                "batch buffer {} != {} images x {}",
                images.len(),
                n,
                self.image_size()
            ));
        }
        if n == 0 {
            return Ok(Vec::new());
        }
        if deadline_expired(deadline) {
            return Err(anyhow::Error::new(ServeError::DeadlineExceeded {
                samples_used: 0,
            }));
        }
        fault::faultpoint("engine.classify").map_err(|e| anyhow!("{e}"))?;
        self.check_entropy_health()?;
        let mut resolved = self
            .cfg
            .sampler
            .resolve(self.samples_per_request(), budget)
            .map_err(|e| anyhow!("sample budget: {e}"))?;
        if matches!(self.cfg.mode, ExecMode::Split(_)) && self.backend.is_deterministic() {
            // identical passes carry no information: a deterministic
            // backend always collapses to one, whatever the configured max
            resolved = ResolvedSampler {
                rule: StopRule::Fixed(1),
                min: 1,
                max: 1,
                chunk: resolved.chunk,
            };
        }
        let t0 = Instant::now();
        let results = if resolved.single_round() {
            self.classify_fixed(images, n, resolved.fixed_samples(), t0)?
        } else {
            match self.cfg.mode {
                ExecMode::Surrogate => {
                    self.classify_adaptive_surrogate(images, n, &resolved, t0, deadline)?
                }
                ExecMode::Split(_) => {
                    self.classify_adaptive_split(images, n, &resolved, t0, deadline)?
                }
            }
        };
        self.metrics.record_batch(n, t0.elapsed(), &results);
        Ok(results)
    }

    /// The legacy one-round path: a single batched sample plan of exactly
    /// `passes_n` passes — the same calls, in the same order, as the
    /// pre-sampler engine (bitwise identical per `(seed, threads,
    /// prefetch)`).
    fn classify_fixed(
        &mut self,
        images: &[f32],
        n: usize,
        passes_n: usize,
        t0: Instant,
    ) -> Result<Vec<ClassifyResult>> {
        let logits = match self.cfg.mode {
            ExecMode::Surrogate => self.forward_surrogate(images, n, passes_n)?,
            ExecMode::Split(_) => self.forward_split(images, n, passes_n)?,
        };
        // logits: per pass, per image
        let per_image_latency = t0.elapsed().as_micros() as f64 / n as f64;
        let nc = self.n_classes();
        let results = (0..n)
            .map(|i| {
                // strided aggregation straight off the pass buffers — no
                // per-image re-staging of N logit rows
                let predictive = Predictive::from_batched_logits(&logits, i, nc);
                let decision = self.cfg.policy.decide(&predictive);
                ClassifyResult {
                    predictive,
                    decision,
                    latency_us: per_image_latency,
                    samples_used: passes_n,
                    degraded: false,
                }
            })
            .collect::<Vec<_>>();
        Ok(results)
    }

    /// Stage one split-path request: pick the batch entry points, pad the
    /// input into the arena, run `fwd_pre`, and zero the pass-lane batch
    /// padding.  The one copy of the padding/shape logic — shared by the
    /// fixed and adaptive paths so they cannot diverge.  Returns owned
    /// state (`Arc` executables, `x3q`), leaving `self` unborrowed.
    fn stage_split(&mut self, images: &[f32], n: usize) -> Result<SplitStage> {
        let meta = &self.arts.meta;
        let b = self.arts.pick_batch("fwd_pre", n);
        let pre = self.arts.get(&format!("fwd_pre_b{b}"))?;
        let post = self.arts.get(&format!("fwd_post_b{b}"))?;
        // scratch-arena input staging: copy the batch, zero the padding
        let x = grow(&mut self.scratch.input, b * meta.image_size());
        x[..images.len()].copy_from_slice(images);
        x[images.len()..].fill(0.0);
        let x_shape = [
            b as i64,
            meta.in_channels as i64,
            meta.img_hw as i64,
            meta.img_hw as i64,
        ];
        let np = meta.num_params as i64;
        let x3q = pre
            .call(&[Arg::F32(&self.params.theta, &[np]), Arg::F32(x, &x_shape)])?
            .into_iter()
            .next()
            .unwrap();
        let act = meta.act_size();
        let act_shape = [
            b as i64,
            meta.prob_ch as i64,
            meta.prob_hw as i64,
            meta.prob_hw as i64,
        ];
        // zero the batch padding of the pass-staging lane once per request
        grow(&mut self.scratch.pass, b * act)[n * act..].fill(0.0);
        Ok(SplitStage {
            post,
            x3q,
            act_shape,
            np,
            b,
            act,
        })
    }

    /// One `fwd_post` round: stage pass `s` out of the all-samples buffer
    /// and run the deterministic tail, returning the pass logits.
    fn post_pass(&mut self, st: &SplitStage, n: usize, d_all_off: usize) -> Result<Vec<f32>> {
        let d3 = grow(&mut self.scratch.pass, st.b * st.act);
        d3[..n * st.act]
            .copy_from_slice(&self.scratch.samples[d_all_off..d_all_off + n * st.act]);
        let out = st.post.call(&[
            Arg::F32(&self.params.theta, &[st.np]),
            Arg::F32(&st.x3q, &st.act_shape),
            Arg::F32(d3, &st.act_shape),
        ])?;
        Ok(out.into_iter().next().unwrap())
    }

    /// Adaptive split path: one `fwd_pre`, then chunked backend sample
    /// plans with stop-rule checks at every chunk boundary.  Each image's
    /// accumulator freezes when its rule fires; the round loop ends when
    /// every image is frozen or the budget is spent.  Chunk sizes come
    /// from [`ChunkSchedule`] (shard-aligned), and the backend's
    /// persistent shard streams make the whole run deterministic per
    /// `(seed, threads, prefetch)`.
    fn classify_adaptive_split(
        &mut self,
        images: &[f32],
        n: usize,
        r: &ResolvedSampler,
        t0: Instant,
        deadline: Option<Instant>,
    ) -> Result<Vec<ClassifyResult>> {
        let st = self.stage_split(images, n)?;
        let meta = &self.arts.meta;
        let nc = meta.n_classes;
        let (prob_ch, prob_hw) = (meta.prob_ch, meta.prob_hw);

        let mut accums: Vec<PredictiveAccum> = (0..n).map(|_| PredictiveAccum::new(nc)).collect();
        let mut states: Vec<StopState> = vec![StopState::default(); n];
        let mut verdicts: Vec<Option<Verdict>> = vec![None; n];
        let mut sched = ChunkSchedule::new(r, self.cfg.resolved_threads());
        let mut k: u16 = 0;
        while let Some(chunk) = sched.next_chunk() {
            if deadline_expired(deadline) {
                return Err(deadline_error(&accums));
            }
            fault::faultpoint("engine.chunk").map_err(|e| anyhow!("{e}"))?;
            let t_chunk = Instant::now();
            let plan = SamplePlan::new(chunk, n, prob_ch, prob_hw, prob_hw);
            let d_all = grow(&mut self.scratch.samples, plan.total_size());
            self.backend.sample_conv(&plan, &st.x3q[..n * st.act], d_all)?;
            let t_post = Instant::now();
            self.trace_span(
                Stage::SampleConv,
                k,
                t_chunk,
                t_post.saturating_duration_since(t_chunk),
            );
            for s in 0..chunk {
                let pass = self.post_pass(&st, n, s * n * st.act)?;
                push_pass(&mut accums, &pass, nc);
            }
            self.trace_span(Stage::FwdPost, k, t_post, t_post.elapsed());
            self.trace_span(Stage::Chunk, k, t_chunk, t_chunk.elapsed());
            if check_stops(r, &mut accums, &mut states, &mut verdicts) {
                break;
            }
            k = k.saturating_add(1);
        }
        Ok(assemble_results(accums, verdicts, &self.cfg.policy, n, t0))
    }

    /// Stage one surrogate-path request: pick the `fwd_full` entry point,
    /// pad the input, and size the `eps` lane.  Shared by the fixed and
    /// adaptive surrogate paths.
    fn stage_surrogate(&mut self, images: &[f32], n: usize) -> Result<SurrogateStage> {
        let meta = &self.arts.meta;
        let b = self.arts.pick_batch("fwd_full", n);
        let f = self.arts.get(&format!("fwd_full_b{b}"))?;
        // scratch-arena input staging: copy the batch, zero the padding
        // (previous requests leave residue past `images.len()`)
        let x = grow(&mut self.scratch.input, b * meta.image_size());
        x[..images.len()].copy_from_slice(images);
        x[images.len()..].fill(0.0);
        let x_shape = [
            b as i64,
            meta.in_channels as i64,
            meta.img_hw as i64,
            meta.img_hw as i64,
        ];
        let eps_shape = [
            b as i64,
            meta.prob_ch as i64,
            meta.prob_hw as i64,
            meta.prob_hw as i64,
            meta.num_taps as i64,
        ];
        Ok(SurrogateStage {
            f,
            x_shape,
            eps_shape,
            np: meta.num_params as i64,
            x_len: b * meta.image_size(),
            eps_len: b * meta.eps_size(),
        })
    }

    /// One `fwd_full` pass with fresh chaotic `eps` noise.
    fn surrogate_pass(&mut self, st: &SurrogateStage) -> Result<Vec<f32>> {
        let x = grow(&mut self.scratch.input, st.x_len);
        let eps = grow(&mut self.scratch.noise, st.eps_len);
        self.noise.fill(eps);
        let out = st.f.call(&[
            Arg::F32(&self.params.theta, &[st.np]),
            Arg::F32(x, &st.x_shape),
            Arg::F32(eps, &st.eps_shape),
        ])?;
        Ok(out.into_iter().next().unwrap())
    }

    /// Adaptive surrogate path: chunked `fwd_full` rounds with fresh
    /// chaotic `eps` noise per pass and the same stop-rule loop as the
    /// split path.
    fn classify_adaptive_surrogate(
        &mut self,
        images: &[f32],
        n: usize,
        r: &ResolvedSampler,
        t0: Instant,
        deadline: Option<Instant>,
    ) -> Result<Vec<ClassifyResult>> {
        let st = self.stage_surrogate(images, n)?;
        let nc = self.arts.meta.n_classes;

        let mut accums: Vec<PredictiveAccum> = (0..n).map(|_| PredictiveAccum::new(nc)).collect();
        let mut states: Vec<StopState> = vec![StopState::default(); n];
        let mut verdicts: Vec<Option<Verdict>> = vec![None; n];
        // align 1: the surrogate path draws per pass with no sharding, so
        // thread-aligned chunks would only inflate the stop granularity
        let mut sched = ChunkSchedule::new(r, 1);
        while let Some(chunk) = sched.next_chunk() {
            if deadline_expired(deadline) {
                return Err(deadline_error(&accums));
            }
            fault::faultpoint("engine.chunk").map_err(|e| anyhow!("{e}"))?;
            for _ in 0..chunk {
                let pass = self.surrogate_pass(&st)?;
                push_pass(&mut accums, &pass, nc);
            }
            if check_stops(r, &mut accums, &mut states, &mut verdicts) {
                break;
            }
        }
        Ok(assemble_results(accums, verdicts, &self.cfg.policy, n, t0))
    }

    /// Surrogate path: `passes_n` calls of `fwd_full` with fresh chaotic
    /// noise as the `eps` operand.
    fn forward_surrogate(
        &mut self,
        images: &[f32],
        n: usize,
        passes_n: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let st = self.stage_surrogate(images, n)?;
        let mut passes = Vec::with_capacity(passes_n);
        for _ in 0..passes_n {
            passes.push(self.surrogate_pass(&st)?);
        }
        Ok(passes)
    }

    /// Split path: one `fwd_pre`, then a single batched backend sample plan
    /// (all passes × all images in one call), then one `fwd_post` per pass.
    fn forward_split(
        &mut self,
        images: &[f32],
        n: usize,
        passes_n: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let st = self.stage_split(images, n)?;
        let meta = &self.arts.meta;
        let plan = SamplePlan::new(passes_n, n, meta.prob_ch, meta.prob_hw, meta.prob_hw);
        // the backend is the only source of randomness on this path; all
        // N x B stochastic convolutions happen in this one call, sharded
        // across the worker pool and written into reusable arena lanes
        let t_chunk = Instant::now();
        let d_all = grow(&mut self.scratch.samples, plan.total_size());
        self.backend.sample_conv(&plan, &st.x3q[..n * st.act], d_all)?;
        let t_post = Instant::now();
        self.trace_span(
            Stage::SampleConv,
            0,
            t_chunk,
            t_post.saturating_duration_since(t_chunk),
        );
        let mut passes = Vec::with_capacity(passes_n);
        for s in 0..passes_n {
            passes.push(self.post_pass(&st, n, s * n * st.act)?);
        }
        self.trace_span(Stage::FwdPost, 0, t_post, t_post.elapsed());
        self.trace_span(Stage::Chunk, 0, t_chunk, t_chunk.elapsed());
        Ok(passes)
    }

    /// Share the trace recorder (service-loop wiring; observational only).
    pub fn attach_trace(&mut self, recorder: &Arc<TraceRecorder>) {
        if recorder.enabled() {
            self.trace = Some(recorder.clone());
        }
    }

    /// Set the traced ids of the group about to be classified (0s — the
    /// untraced members — are filtered here).
    pub fn begin_trace_group(&mut self, ids: &[u64]) {
        self.trace_ids.clear();
        if self.trace.is_some() {
            self.trace_ids.extend(ids.iter().copied().filter(|&id| id != 0));
        }
    }

    /// Record one span under every traced id of the current group.  A
    /// group is one plan, so its stage timings are shared by members.
    fn trace_span(&self, stage: Stage, index: u16, start: Instant, dur: Duration) {
        if let Some(rec) = &self.trace {
            for &id in &self.trace_ids {
                rec.record(id, stage, index, start, dur);
            }
        }
    }

    /// The engine's sampler configuration (effective stop rule).
    pub fn sampler_config(&self) -> &SamplerConfig {
        &self.cfg.sampler
    }

    /// The entropy-health monitor observing this engine's backend, if any.
    pub fn entropy_health(&self) -> Option<Arc<Monitor>> {
        self.monitor.clone()
    }

    /// Whether an entropy-health fallback has swapped the backend.
    pub fn fell_back(&self) -> bool {
        self.fell_back
    }

    /// Drain health events (always logged) and, when `entropy_fallback` is
    /// configured and the monitor reports sustained degradation, rebuild the
    /// backend on the fallback substrate.  The swap is deterministic: the
    /// replacement is built from the engine's retained `(machine config,
    /// pool, pipeline options)` and programmed from the same trained
    /// kernels, and dropping the old backend joins its entropy producers —
    /// prefetched photonic weight-plane banks retire before the first
    /// fallback plan runs, never leaking stale draws.
    fn check_entropy_health(&mut self) -> Result<()> {
        if self.brownout {
            // the real backend is parked; a fallback swap now would
            // replace the mean-field stand-in and corrupt the un-swap.
            // Events stay queued for the next non-brownout call.
            return Ok(());
        }
        let Some(monitor) = self.monitor.clone() else {
            return Ok(());
        };
        for ev in monitor.take_events() {
            match ev {
                HealthEvent::Degraded { shard, stream, score } => log_warn!(
                    "engine[{}]: entropy stream (shard {shard}, \"{stream}\") degraded \
                     (score ewma {score:.3})",
                    self.arts.meta.dataset
                ),
                HealthEvent::Recovered { shard, stream, score } => log_info!(
                    "engine[{}]: entropy stream (shard {shard}, \"{stream}\") recovered \
                     (score ewma {score:.3})",
                    self.arts.meta.dataset
                ),
            }
        }
        let Some(target) = self.cfg.entropy_fallback else {
            return Ok(());
        };
        if self.fell_back || !monitor.any_degraded() {
            return Ok(());
        }
        self.fell_back = true;
        if self.backend.kind() == target {
            log_warn!(
                "engine[{}]: entropy degraded but already on '{}' — nothing to swap",
                self.arts.meta.dataset,
                target
            );
            return Ok(());
        }
        let kernels = self.params.prob_kernels()?;
        let mut backend = backend::build_with_opts_monitored(
            target,
            &self.mcfg,
            self.pool.clone(),
            self.popts,
            self.monitor.clone(),
        );
        if let Some(metrics) = &self.reg_metrics {
            // registry mode: the replacement starts with an empty model
            // cache (all parked models go cold — their banks died with the
            // degraded backend) and is programmed through the switch path
            // so the active model keeps its model-mixed seed
            backend.enable_model_cache(self.cfg.bank_budget_bytes, metrics.clone());
        } else {
            backend.program(&kernels, self.cfg.calibrate)?;
        }
        let old = std::mem::replace(&mut self.backend, backend);
        let old_name = old.name();
        drop(old); // joins the degraded backend's entropy producers
        if self.reg_metrics.is_some() {
            self.program_active()?;
        }
        log_warn!(
            "engine[{}]: entropy health fallback: '{}' -> '{}' ({} kernels reprogrammed)",
            self.arts.meta.dataset,
            old_name,
            target,
            kernels.len()
        );
        let to = target.to_string();
        logging::event(
            logging::Level::Warn,
            module_path!(),
            "entropy_fallback",
            0,
            &[
                ("engine", &self.arts.meta.dataset),
                ("from", old_name),
                ("to", &to),
            ],
        );
        Ok(())
    }

    /// Simulated-optical-time / substrate + host telemetry line.
    pub fn report(&self) -> String {
        format!(
            "{} | backend[{}]: {}",
            self.metrics.report(),
            self.backend.name(),
            self.backend.report()
        )
    }
}

/// Owned staging of one split-path request (see [`Engine::stage_split`]):
/// `Arc` executables and the quantized activations, so holding it borrows
/// nothing from the engine.
struct SplitStage {
    post: Arc<CompiledFn>,
    x3q: Vec<f32>,
    act_shape: [i64; 4],
    np: i64,
    b: usize,
    act: usize,
}

/// Owned staging of one surrogate-path request (see
/// [`Engine::stage_surrogate`]).  The padded input and `eps` operand live
/// in the engine's scratch lanes, addressed by length.
struct SurrogateStage {
    f: Arc<CompiledFn>,
    x_shape: [i64; 4],
    eps_shape: [i64; 5],
    np: i64,
    x_len: usize,
    eps_len: usize,
}

/// Whether an optional absolute deadline has passed.
fn deadline_expired(deadline: Option<Instant>) -> bool {
    deadline.is_some_and(|d| Instant::now() >= d)
}

/// Typed deadline error carrying the largest per-image sample spend so
/// far (the batch's information budget actually consumed).
fn deadline_error(accums: &[PredictiveAccum]) -> anyhow::Error {
    let spent = accums.iter().map(|a| a.n()).max().unwrap_or(0);
    anyhow::Error::new(ServeError::DeadlineExceeded {
        samples_used: spent,
    })
}

/// Fold one pass's batch logits into every still-sampling image.
fn push_pass(accums: &mut [PredictiveAccum], pass: &[f32], nc: usize) {
    for (i, acc) in accums.iter_mut().enumerate() {
        if !acc.is_frozen() {
            acc.push_logits(&pass[i * nc..(i + 1) * nc]);
        }
    }
}

/// Chunk-boundary stop-rule sweep: freeze every unfrozen image whose rule
/// fired and record its verdict.  Returns `true` once every image is
/// frozen (the round loop can end early).
fn check_stops(
    r: &ResolvedSampler,
    accums: &mut [PredictiveAccum],
    states: &mut [StopState],
    verdicts: &mut [Option<Verdict>],
) -> bool {
    let mut all_frozen = true;
    for ((acc, st), verdict) in accums.iter_mut().zip(states).zip(verdicts) {
        if acc.is_frozen() {
            continue;
        }
        let stats = acc.stats();
        if let Some(reason) = st.update(&r.rule, &stats, acc.n(), r.min) {
            *verdict = Some(Verdict {
                samples_used: acc.n(),
                reason,
            });
            acc.freeze();
        } else {
            all_frozen = false;
        }
    }
    all_frozen
}

/// Finalize an adaptive round loop into per-image results.  Unfrozen
/// accumulators spent the whole budget ([`StopReason::BudgetExhausted`]);
/// each predictive is built by the exact one-shot aggregation path over
/// the samples its accumulator saw.
fn assemble_results(
    accums: Vec<PredictiveAccum>,
    verdicts: Vec<Option<Verdict>>,
    policy: &UncertaintyPolicy,
    n: usize,
    t0: Instant,
) -> Vec<ClassifyResult> {
    let per_image_latency = t0.elapsed().as_micros() as f64 / n as f64;
    accums
        .into_iter()
        .zip(verdicts)
        .map(|(acc, verdict)| {
            let verdict = verdict.unwrap_or(Verdict {
                samples_used: acc.n(),
                reason: StopReason::BudgetExhausted,
            });
            let predictive = acc.into_predictive();
            let decision = policy.decide(&predictive);
            ClassifyResult {
                predictive,
                decision,
                latency_us: per_image_latency,
                samples_used: verdict.samples_used,
                degraded: false,
            }
        })
        .collect()
}
