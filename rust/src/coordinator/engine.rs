//! The inference engine: PJRT for deterministic layers, a pluggable
//! [`ProbConvBackend`] for the probabilistic block, uncertainty aggregation
//! on top.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::backend::{
    self, BackendKind, EpsSource, PipelineOptions, PrefetchMode, ProbConvBackend, SamplePlan,
};
use crate::bnn::{Decision, Predictive, UncertaintyPolicy};
use crate::exec::scratch::{grow, ScratchArena};
use crate::exec::ThreadPool;
use crate::log_info;
use crate::photonics::MachineConfig;
use crate::runtime::{Arg, ModelArtifacts, ParamStore};

/// Where the probabilistic block executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// The AOT surrogate (`fwd_full` HLO) with chaotic noise fed as `eps`.
    Surrogate,
    /// The split path: `fwd_pre` → batched [`ProbConvBackend`] sample plan
    /// → `fwd_post`, on the chosen sampling substrate.
    Split(BackendKind),
}

impl ExecMode {
    /// The paper's serving configuration: split path on the photonic machine.
    pub fn photonic() -> Self {
        ExecMode::Split(BackendKind::Photonic)
    }

    /// Parse a CLI/config token: `photonic|digital|mean|surrogate`.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "surrogate" => Ok(ExecMode::Surrogate),
            other => Ok(ExecMode::Split(BackendKind::parse(other).map_err(|_| {
                anyhow!("mode must be photonic|digital|mean|surrogate, got {other}")
            })?)),
        }
    }

    /// The backend kind the split path would use (the photonic machine is
    /// also kept programmed behind the surrogate, for parity probes).
    pub fn backend_kind(&self) -> BackendKind {
        match self {
            ExecMode::Surrogate => BackendKind::Photonic,
            ExecMode::Split(kind) => *kind,
        }
    }
}

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Stochastic forward passes per request (paper: N = 10).  A
    /// deterministic backend collapses this to 1 at serving time.
    pub n_samples: usize,
    pub mode: ExecMode,
    pub policy: UncertaintyPolicy,
    /// Run feedback calibration on every kernel at load time.
    pub calibrate: bool,
    pub machine: MachineConfig,
    /// Channel bandwidth used when drawing surrogate `eps` noise (GHz).
    pub noise_bw_ghz: f64,
    /// Worker threads for the sampling hot path.  Each `SamplePlan` is
    /// sharded across this many pool workers, each with its own
    /// deterministic entropy stream: results are reproducible for a fixed
    /// `(seed, threads)` and statistically equivalent across thread counts.
    /// `1` = sequential in-thread sampling (bit-compatible with the
    /// pre-pool engine); `0` = one worker per available core.
    pub threads: usize,
    /// Decoupled entropy pipeline: `Off` draws entropy inline in the
    /// historical stream organization; `Sync` switches to the pipeline's
    /// banked streams drawn synchronously; `On` additionally prefetches
    /// them with background producer threads.  `Sync` and `On` are bitwise
    /// identical for a fixed `(seed, threads)`.
    pub entropy_prefetch: PrefetchMode,
    /// Draws per prefetched entropy block (ring transfer granularity).
    pub entropy_block: usize,
    pub seed: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            n_samples: 10,
            mode: ExecMode::photonic(),
            policy: UncertaintyPolicy::ood_only(0.0185),
            calibrate: true,
            machine: MachineConfig::default(),
            noise_bw_ghz: 150.0,
            threads: 1,
            entropy_prefetch: PrefetchMode::Off,
            entropy_block: 4096,
            seed: 42,
        }
    }
}

impl EngineConfig {
    /// Resolve `threads` to a concrete worker count (`0` = auto).
    pub fn resolved_threads(&self) -> usize {
        match self.threads {
            0 => std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            n => n,
        }
    }
}

/// Outcome for one classified image.
#[derive(Debug, Clone)]
pub struct ClassifyResult {
    pub predictive: Predictive,
    pub decision: Decision,
    pub latency_us: f64,
}

/// The engine.  Owns non-`Send` PJRT state — confine to one thread (see
/// [`super::service`]).
pub struct Engine {
    pub arts: ModelArtifacts,
    pub params: ParamStore,
    backend: Box<dyn ProbConvBackend>,
    noise: EpsSource,
    cfg: EngineConfig,
    /// Reusable request buffers (padded input, eps, sample plans, pass
    /// staging): steady-state classification allocates only its results.
    scratch: ScratchArena,
    pub metrics: super::metrics::EngineMetrics,
}

impl Engine {
    /// Build an engine: programs the backend's kernel bank from the trained
    /// probabilistic parameters (one 9-tap kernel per depthwise channel)
    /// and optionally runs feedback calibration on each.
    pub fn new(arts: ModelArtifacts, params: ParamStore, cfg: EngineConfig) -> Result<Self> {
        if cfg.n_samples == 0 {
            return Err(anyhow!("n_samples must be >= 1"));
        }
        let mut mcfg = cfg.machine.clone();
        mcfg.scale_dac = arts.meta.scale_dac;
        mcfg.scale_adc = arts.meta.scale_adc;
        mcfg.seed = cfg.seed;
        let threads = cfg.resolved_threads();
        let pool = (threads > 1).then(|| Arc::new(ThreadPool::new(threads)));
        let popts = PipelineOptions {
            mode: cfg.entropy_prefetch,
            block: cfg.entropy_block,
            ..PipelineOptions::default()
        }
        .sanitized();
        let mut backend = backend::build_with_opts(cfg.mode.backend_kind(), &mcfg, pool, popts);
        let kernels = params.prob_kernels()?;
        let t0 = Instant::now();
        backend.program(&kernels, cfg.calibrate)?;
        log_info!(
            "engine[{}]: programmed {} kernels on '{}' backend in {:.2}s (calibrate={}, \
             threads={}, prefetch={})",
            arts.meta.dataset,
            kernels.len(),
            backend.name(),
            t0.elapsed().as_secs_f64(),
            cfg.calibrate,
            threads,
            popts.mode
        );
        Ok(Self {
            noise: EpsSource::chaotic(cfg.seed.wrapping_add(77), cfg.noise_bw_ghz),
            backend,
            arts,
            params,
            cfg,
            scratch: ScratchArena::default(),
            metrics: Default::default(),
        })
    }

    pub fn n_classes(&self) -> usize {
        self.arts.meta.n_classes
    }

    pub fn image_size(&self) -> usize {
        self.arts.meta.image_size()
    }

    pub fn mode(&self) -> ExecMode {
        self.cfg.mode
    }

    /// The sampling substrate behind the probabilistic block.
    pub fn backend_kind(&self) -> BackendKind {
        self.backend.kind()
    }

    /// Stochastic passes actually executed per request: 1 on a
    /// deterministic backend, `n_samples` otherwise.
    pub fn samples_per_request(&self) -> usize {
        if matches!(self.cfg.mode, ExecMode::Split(_)) && self.backend.is_deterministic() {
            1
        } else {
            self.cfg.n_samples
        }
    }

    /// Classify a batch of images (`images.len() == n * image_size`).
    /// Returns one result per image.
    pub fn classify(&mut self, images: &[f32], n: usize) -> Result<Vec<ClassifyResult>> {
        if images.len() != n * self.image_size() {
            return Err(anyhow!(
                "batch buffer {} != {} images x {}",
                images.len(),
                n,
                self.image_size()
            ));
        }
        if n == 0 {
            return Ok(Vec::new());
        }
        let t0 = Instant::now();
        let logits = match self.cfg.mode {
            ExecMode::Surrogate => self.forward_surrogate(images, n)?,
            ExecMode::Split(_) => self.forward_split(images, n)?,
        };
        // logits: per pass, per image
        let per_image_latency = t0.elapsed().as_micros() as f64 / n as f64;
        let nc = self.n_classes();
        let results = (0..n)
            .map(|i| {
                // strided aggregation straight off the pass buffers — no
                // per-image re-staging of N logit rows
                let predictive = Predictive::from_batched_logits(&logits, i, nc);
                let decision = self.cfg.policy.decide(&predictive);
                ClassifyResult {
                    predictive,
                    decision,
                    latency_us: per_image_latency,
                }
            })
            .collect::<Vec<_>>();
        self.metrics.record_batch(n, t0.elapsed(), &results);
        Ok(results)
    }

    /// Surrogate path: `n_samples` calls of `fwd_full` with fresh chaotic
    /// noise as the `eps` operand.
    fn forward_surrogate(&mut self, images: &[f32], n: usize) -> Result<Vec<Vec<f32>>> {
        let meta = &self.arts.meta;
        let b = self.arts.pick_batch("fwd_full", n);
        let f = self.arts.get(&format!("fwd_full_b{b}"))?;
        // scratch-arena input staging: copy the batch, zero the padding
        // (previous requests leave residue past `images.len()`)
        let x = grow(&mut self.scratch.input, b * meta.image_size());
        x[..images.len()].copy_from_slice(images);
        x[images.len()..].fill(0.0);
        let x_shape = [
            b as i64,
            meta.in_channels as i64,
            meta.img_hw as i64,
            meta.img_hw as i64,
        ];
        let eps_shape = [
            b as i64,
            meta.prob_ch as i64,
            meta.prob_hw as i64,
            meta.prob_hw as i64,
            meta.num_taps as i64,
        ];
        let np = meta.num_params as i64;
        let eps = grow(&mut self.scratch.noise, b * meta.eps_size());
        let mut passes = Vec::with_capacity(self.cfg.n_samples);
        for _ in 0..self.cfg.n_samples {
            self.noise.fill(eps);
            let out = f.call(&[
                Arg::F32(&self.params.theta, &[np]),
                Arg::F32(x, &x_shape),
                Arg::F32(eps, &eps_shape),
            ])?;
            passes.push(out.into_iter().next().unwrap());
        }
        Ok(passes)
    }

    /// Split path: one `fwd_pre`, then a single batched backend sample plan
    /// (all passes × all images in one call), then one `fwd_post` per pass.
    fn forward_split(&mut self, images: &[f32], n: usize) -> Result<Vec<Vec<f32>>> {
        let meta = &self.arts.meta;
        let b = self.arts.pick_batch("fwd_pre", n);
        let pre = self.arts.get(&format!("fwd_pre_b{b}"))?;
        let post = self.arts.get(&format!("fwd_post_b{b}"))?;
        // scratch-arena input staging: copy the batch, zero the padding
        let x = grow(&mut self.scratch.input, b * meta.image_size());
        x[..images.len()].copy_from_slice(images);
        x[images.len()..].fill(0.0);
        let x_shape = [
            b as i64,
            meta.in_channels as i64,
            meta.img_hw as i64,
            meta.img_hw as i64,
        ];
        let np = meta.num_params as i64;
        let x3q = pre
            .call(&[Arg::F32(&self.params.theta, &[np]), Arg::F32(x, &x_shape)])?
            .into_iter()
            .next()
            .unwrap();
        let act = meta.act_size();
        let act_shape = [
            b as i64,
            meta.prob_ch as i64,
            meta.prob_hw as i64,
            meta.prob_hw as i64,
        ];
        let passes_n = self.samples_per_request();
        let plan = SamplePlan::new(passes_n, n, meta.prob_ch, meta.prob_hw, meta.prob_hw);
        // the backend is the only source of randomness on this path; all
        // N x B stochastic convolutions happen in this one call, sharded
        // across the worker pool and written into reusable arena lanes
        let d_all = grow(&mut self.scratch.samples, plan.total_size());
        self.backend.sample_conv(&plan, &x3q[..n * act], d_all)?;
        let mut passes = Vec::with_capacity(passes_n);
        let d3 = grow(&mut self.scratch.pass, b * act);
        d3[n * act..].fill(0.0); // zero the batch padding once per request
        for s in 0..passes_n {
            d3[..n * act].copy_from_slice(&d_all[s * n * act..(s + 1) * n * act]);
            let out = post.call(&[
                Arg::F32(&self.params.theta, &[np]),
                Arg::F32(&x3q, &act_shape),
                Arg::F32(d3, &act_shape),
            ])?;
            passes.push(out.into_iter().next().unwrap());
        }
        Ok(passes)
    }

    /// Simulated-optical-time / substrate + host telemetry line.
    pub fn report(&self) -> String {
        format!(
            "{} | backend[{}]: {}",
            self.metrics.report(),
            self.backend.name(),
            self.backend.report()
        )
    }
}
