//! Engine/serving telemetry: counters and latency histogram.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::bnn::Decision;
use crate::observe::buckets;
use crate::util::json::Json;

use super::engine::ClassifyResult;

/// Log-scaled latency histogram (1 us .. ~1 s, 2x buckets).
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// bucket i covers [2^i, 2^(i+1)) microseconds
    buckets: Vec<u64>,
    count: u64,
    sum_us: f64,
    max_us: f64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self {
            buckets: vec![0; buckets::NUM_BUCKETS],
            count: 0,
            sum_us: 0.0,
            max_us: 0.0,
        }
    }
}

impl LatencyHistogram {
    pub fn record(&mut self, us: f64) {
        let b = buckets::bucket_index(us, self.buckets.len());
        self.buckets[b] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us / self.count as f64
        }
    }

    /// Approximate percentile from bucket boundaries (upper edge), clamped
    /// to the maximum recorded value — see
    /// [`crate::observe::buckets::percentile_us`].
    pub fn percentile_us(&self, p: f64) -> f64 {
        buckets::percentile_us(self.buckets.iter().copied(), self.count, self.max_us, p)
    }
}

/// Lock-free variant of [`LatencyHistogram`] for the serving layer:
/// the service loop records and `/info` reads concurrently, so buckets
/// and aggregates are relaxed atomics.  Same bucket geometry
/// (`[2^i, 2^(i+1))` us) and the same max-clamped percentile read as the
/// single-threaded histogram.  Reads are racy across fields — a gauge,
/// not an invariant.
#[derive(Debug)]
pub struct AtomicLatencyHistogram {
    buckets: [AtomicU64; buckets::NUM_BUCKETS],
    count: AtomicU64,
    /// Sum in whole microseconds (f64 precision is irrelevant at the
    /// >=1us granularity the buckets already impose).
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for AtomicLatencyHistogram {
    fn default() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }
}

impl AtomicLatencyHistogram {
    pub fn record(&self, us: f64) {
        let b = buckets::bucket_index(us, self.buckets.len());
        self.buckets[b].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us.max(0.0) as u64, Ordering::Relaxed);
        self.max_us.fetch_max(us.max(0.0) as u64, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    /// Bucket-edge percentile clamped to the recorded maximum (same
    /// contract as [`LatencyHistogram::percentile_us`], same shared
    /// bucket math).
    pub fn percentile_us(&self, p: f64) -> f64 {
        buckets::percentile_us(
            self.buckets.iter().map(|c| c.load(Ordering::Relaxed)),
            self.count(),
            self.max_us.load(Ordering::Relaxed) as f64,
            p,
        )
    }

    /// Raw bucket view for the `/metrics` exposition (per-bucket counts
    /// with `2^(i+1)` us upper edges, plus the running sum/max).
    pub fn raw(&self) -> LatencyBuckets {
        LatencyBuckets {
            counts: self
                .buckets
                .iter()
                .map(|c| c.load(Ordering::Relaxed))
                .collect(),
            sum_us: self.sum_us.load(Ordering::Relaxed),
            max_us: self.max_us.load(Ordering::Relaxed),
        }
    }
}

/// Plain-data copy of an [`AtomicLatencyHistogram`]'s buckets.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LatencyBuckets {
    /// Per-bucket counts; bucket `i` covers `[2^i, 2^(i+1))` us.
    pub counts: Vec<u64>,
    pub sum_us: u64,
    pub max_us: u64,
}

/// Lock-free serving/robustness counters shared between the admission
/// path (gateway workers), the engine service loop, and `/info`.
#[derive(Debug, Default)]
pub struct ServeCounters {
    /// Requests answered with a typed error instead of being served
    /// (deadline sheds + overload rejects).
    pub requests_shed: AtomicU64,
    /// Requests whose deadline passed (at dequeue or mid-run).
    pub deadline_expired: AtomicU64,
    /// Requests rejected at admission (queue/work budget full).
    pub overload_rejects: AtomicU64,
    /// Batch panics isolated and recovered from.
    pub panics_recovered: AtomicU64,
    /// Queue-depth gauge (last observed at admission/dequeue).
    pub queue_depth: AtomicU64,
    /// Per-request service latency (batch wall-clock attributed to each
    /// served member, Ok path only) — feeds the `/info` percentiles and,
    /// in cluster mode, the coordinator's per-worker probe scrape.
    pub latency: AtomicLatencyHistogram,
}

/// Point-in-time copy of [`ServeCounters`] (counters plus derived
/// latency percentiles; `Eq` is off the table because of the `f64`s).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ServeSnapshot {
    pub requests_shed: u64,
    pub deadline_expired: u64,
    pub overload_rejects: u64,
    pub panics_recovered: u64,
    pub queue_depth: u64,
    /// Mean/percentile service latency in microseconds (0 until the
    /// first request is served).
    pub mean_latency_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
}

impl ServeCounters {
    pub fn snapshot(&self) -> ServeSnapshot {
        ServeSnapshot {
            requests_shed: self.requests_shed.load(Ordering::Relaxed),
            deadline_expired: self.deadline_expired.load(Ordering::Relaxed),
            overload_rejects: self.overload_rejects.load(Ordering::Relaxed),
            panics_recovered: self.panics_recovered.load(Ordering::Relaxed),
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            mean_latency_us: self.latency.mean_us(),
            p50_us: self.latency.percentile_us(50.0),
            p95_us: self.latency.percentile_us(95.0),
            p99_us: self.latency.percentile_us(99.0),
        }
    }
}

impl ServeSnapshot {
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("requests_shed", Json::Num(self.requests_shed as f64)),
            ("deadline_expired", Json::Num(self.deadline_expired as f64)),
            ("overload_rejects", Json::Num(self.overload_rejects as f64)),
            ("panics_recovered", Json::Num(self.panics_recovered as f64)),
            ("queue_depth", Json::Num(self.queue_depth as f64)),
            ("mean_latency_us", Json::Num(self.mean_latency_us)),
            ("p50_us", Json::Num(self.p50_us)),
            ("p95_us", Json::Num(self.p95_us)),
            ("p99_us", Json::Num(self.p99_us)),
        ])
    }
}

/// Aggregated engine metrics.
#[derive(Debug, Clone, Default)]
pub struct EngineMetrics {
    pub requests: u64,
    pub batches: u64,
    pub accepted: u64,
    pub rejected_ood: u64,
    pub flagged_ambiguous: u64,
    /// Stochastic passes folded into predictives across all requests — the
    /// adaptive sampler's economy shows up as `samples_drawn / requests`
    /// falling below the configured `n_samples`.  Counts per-image
    /// information budgets (`ClassifyResult::samples_used`): for
    /// single-image requests that equals backend compute; in multi-image
    /// batches the backend additionally draws for already-frozen images
    /// until the whole batch resolves.
    pub samples_drawn: u64,
    /// Program switches between registered models (multi-model engines).
    pub model_switches: u64,
    pub batch_latency: LatencyHistogram,
    pub request_latency: LatencyHistogram,
    /// Wall time of each model switch (checkpoint swap + program switch,
    /// including any cold bank rebuild) — the cost model-coalesced batching
    /// amortizes.
    pub switch_latency: LatencyHistogram,
    /// Shed/deadline/overload/panic counters, shared (`Arc`) with the
    /// service loop and the admission path so `to_json` surfaces live
    /// robustness state alongside the throughput counters.
    pub serving: Arc<ServeCounters>,
}

impl EngineMetrics {
    pub fn record_batch(&mut self, n: usize, elapsed: Duration, results: &[ClassifyResult]) {
        self.requests += n as u64;
        self.batches += 1;
        self.batch_latency.record(elapsed.as_micros() as f64);
        for r in results {
            self.request_latency.record(r.latency_us);
            self.samples_drawn += r.samples_used as u64;
            match r.decision {
                Decision::Accept { .. } => self.accepted += 1,
                Decision::RejectOod { .. } => self.rejected_ood += 1,
                Decision::FlagAmbiguous { .. } => self.flagged_ambiguous += 1,
            }
        }
    }

    pub fn record_model_switch(&mut self, elapsed: Duration) {
        self.model_switches += 1;
        self.switch_latency.record(elapsed.as_micros() as f64);
    }

    /// Mean stochastic passes per request.
    pub fn mean_samples(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.samples_drawn as f64 / self.requests as f64
        }
    }

    pub fn report(&self) -> String {
        let s = self.serving.snapshot();
        format!(
            "requests={} batches={} accept={} reject_ood={} ambiguous={} mean_samples={:.2} \
             mean_batch={:.0}us p95_batch={:.0}us model_switches={} mean_switch={:.0}us \
             shed={} deadline_expired={} overload_rejects={} panics_recovered={}",
            self.requests,
            self.batches,
            self.accepted,
            self.rejected_ood,
            self.flagged_ambiguous,
            self.mean_samples(),
            self.batch_latency.mean_us(),
            self.batch_latency.percentile_us(95.0),
            self.model_switches,
            self.switch_latency.mean_us(),
            s.requests_shed,
            s.deadline_expired,
            s.overload_rejects,
            s.panics_recovered,
        )
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("requests", Json::Num(self.requests as f64)),
            ("batches", Json::Num(self.batches as f64)),
            ("accepted", Json::Num(self.accepted as f64)),
            ("rejected_ood", Json::Num(self.rejected_ood as f64)),
            ("flagged_ambiguous", Json::Num(self.flagged_ambiguous as f64)),
            ("samples_drawn", Json::Num(self.samples_drawn as f64)),
            ("mean_samples_per_request", Json::Num(self.mean_samples())),
            ("mean_batch_us", Json::Num(self.batch_latency.mean_us())),
            (
                "p95_batch_us",
                Json::Num(self.batch_latency.percentile_us(95.0)),
            ),
            (
                "mean_request_us",
                Json::Num(self.request_latency.mean_us()),
            ),
            (
                "p50_request_us",
                Json::Num(self.request_latency.percentile_us(50.0)),
            ),
            (
                "p95_request_us",
                Json::Num(self.request_latency.percentile_us(95.0)),
            ),
            ("model_switches", Json::Num(self.model_switches as f64)),
            (
                "mean_switch_us",
                Json::Num(self.switch_latency.mean_us()),
            ),
            (
                "requests_shed",
                Json::Num(self.serving.requests_shed.load(Ordering::Relaxed) as f64),
            ),
            (
                "deadline_expired",
                Json::Num(self.serving.deadline_expired.load(Ordering::Relaxed) as f64),
            ),
            (
                "overload_rejects",
                Json::Num(self.serving.overload_rejects.load(Ordering::Relaxed) as f64),
            ),
            (
                "panics_recovered",
                Json::Num(self.serving.panics_recovered.load(Ordering::Relaxed) as f64),
            ),
            (
                "queue_depth",
                Json::Num(self.serving.queue_depth.load(Ordering::Relaxed) as f64),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles_ordered() {
        let mut h = LatencyHistogram::default();
        for i in 1..=1000u64 {
            h.record(i as f64);
        }
        assert_eq!(h.count(), 1000);
        assert!((h.mean_us() - 500.5).abs() < 1.0);
        assert!(h.percentile_us(50.0) <= h.percentile_us(95.0));
        // clamped to the recorded maximum: no percentile may exceed it
        assert!(h.percentile_us(95.0) <= 1000.0);
        assert_eq!(h.percentile_us(100.0), 1000.0);
    }

    #[test]
    fn percentile_never_exceeds_recorded_max() {
        // a single 700us sample falls in bucket [512, 1024): the raw upper
        // edge would report 1024us for every percentile
        let mut h = LatencyHistogram::default();
        h.record(700.0);
        for p in [50.0, 95.0, 99.0, 100.0] {
            assert_eq!(h.percentile_us(p), 700.0, "p{p}");
        }
    }

    #[test]
    fn empty_histogram_safe() {
        let h = LatencyHistogram::default();
        assert_eq!(h.mean_us(), 0.0);
        assert_eq!(h.percentile_us(99.0), 0.0);
    }

    #[test]
    fn metrics_json_well_formed() {
        let m = EngineMetrics::default();
        let j = m.to_json();
        assert_eq!(j.get("requests").unwrap().as_f64(), Some(0.0));
        assert_eq!(j.get("mean_samples_per_request").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn model_switches_surface_in_report_and_json() {
        let mut m = EngineMetrics::default();
        m.record_model_switch(Duration::from_micros(300));
        m.record_model_switch(Duration::from_micros(500));
        assert_eq!(m.model_switches, 2);
        assert!((m.switch_latency.mean_us() - 400.0).abs() < 1.0);
        assert!(m.report().contains("model_switches=2"), "{}", m.report());
        let j = m.to_json();
        assert_eq!(j.get("model_switches").unwrap().as_f64(), Some(2.0));
        assert!(j.get("mean_switch_us").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn serving_counters_surface_in_json_and_report() {
        let m = EngineMetrics::default();
        m.serving.requests_shed.store(5, Ordering::Relaxed);
        m.serving.deadline_expired.store(2, Ordering::Relaxed);
        m.serving.overload_rejects.store(3, Ordering::Relaxed);
        m.serving.panics_recovered.store(1, Ordering::Relaxed);
        m.serving.queue_depth.store(7, Ordering::Relaxed);
        let j = m.to_json();
        assert_eq!(j.get("requests_shed").unwrap().as_f64(), Some(5.0));
        assert_eq!(j.get("deadline_expired").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("overload_rejects").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.get("panics_recovered").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("queue_depth").unwrap().as_f64(), Some(7.0));
        assert!(m.report().contains("panics_recovered=1"), "{}", m.report());
        // clones share the counters (the engine thread and the handle
        // must see one set of atomics)
        let c = m.clone();
        c.serving.requests_shed.store(9, Ordering::Relaxed);
        assert_eq!(m.serving.snapshot().requests_shed, 9);
    }

    #[test]
    fn serve_snapshot_json_well_formed() {
        let c = ServeCounters::default();
        c.overload_rejects.store(4, Ordering::Relaxed);
        let j = c.snapshot().to_json();
        assert_eq!(j.get("overload_rejects").unwrap().as_f64(), Some(4.0));
        assert_eq!(j.get("queue_depth").unwrap().as_f64(), Some(0.0));
        // percentiles ride along, zero before any request is served
        assert_eq!(j.get("p95_us").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn atomic_histogram_matches_scalar_contract() {
        let h = AtomicLatencyHistogram::default();
        for i in 1..=1000u64 {
            h.record(i as f64);
        }
        assert_eq!(h.count(), 1000);
        assert!((h.mean_us() - 500.5).abs() < 1.0);
        assert!(h.percentile_us(50.0) <= h.percentile_us(95.0));
        // clamped to the recorded maximum, like LatencyHistogram
        assert!(h.percentile_us(99.0) <= 1000.0);
        assert_eq!(h.percentile_us(100.0), 1000.0);
        let empty = AtomicLatencyHistogram::default();
        assert_eq!(empty.mean_us(), 0.0);
        assert_eq!(empty.percentile_us(99.0), 0.0);
    }

    #[test]
    fn snapshot_surfaces_latency_percentiles() {
        let c = ServeCounters::default();
        c.latency.record(700.0);
        let s = c.snapshot();
        assert_eq!(s.p50_us, 700.0);
        assert_eq!(s.p99_us, 700.0);
        assert!((s.mean_latency_us - 700.0).abs() < 1.0);
        let j = s.to_json();
        assert_eq!(j.get("p95_us").unwrap().as_f64(), Some(700.0));
        assert_eq!(j.get("mean_latency_us").unwrap().as_f64(), Some(700.0));
    }

    #[test]
    fn mean_samples_tracks_adaptive_spend() {
        let pred = crate::bnn::Predictive::from_logits(&vec![vec![3.0, 0.0]; 2]);
        let decision = crate::bnn::UncertaintyPolicy::ood_only(0.5).decide(&pred);
        let r = |samples_used| ClassifyResult {
            predictive: pred.clone(),
            decision: decision.clone(),
            latency_us: 10.0,
            samples_used,
            degraded: false,
        };
        let mut m = EngineMetrics::default();
        m.record_batch(2, Duration::from_micros(100), &[r(4), r(10)]);
        assert_eq!(m.samples_drawn, 14);
        assert!((m.mean_samples() - 7.0).abs() < 1e-12);
        assert!(m.report().contains("mean_samples=7.00"), "{}", m.report());
    }
}
