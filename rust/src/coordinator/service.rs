//! Engine service: confines the non-`Send` engine to a dedicated thread
//! and exposes a channel-based request API with an overload-safe
//! lifecycle — cost-aware admission at the queue ([`submit_with_admission`]),
//! deadline shedding at dequeue and between adaptive chunks, tiered
//! degradation under sustained pressure, and per-batch panic isolation
//! with deterministic engine recovery ([`run_service_loop`]).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::Path;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::batcher::DynamicBatcher;
use super::engine::{ClassifyResult, Engine, EngineConfig};
use super::metrics::{ServeCounters, ServeSnapshot};
use super::overload::{OverloadConfig, OverloadControl, ServeError, Tier};
use crate::bnn::{Predictive, UncertaintyPolicy};
use crate::entropy::health::Monitor;
use crate::exec::channel::{channel, Receiver, Sender, TrySendError};
use crate::log_info;
use crate::observe::{ObserveConfig, Stage, TraceRecorder, UncertaintyTelemetry};
use crate::registry::{ModelSpec, ProgramRegistry, RegistryMetrics, UnknownModel};
use crate::runtime::{ModelArtifacts, ParamStore};
use crate::sampler::RequestBudget;
use crate::util::{fault, logging};

/// One classification request: an image, the model it targets (`None` =
/// the engine's default), its per-request sample budget, an optional
/// absolute deadline, and a one-shot reply channel.
pub struct ClassifyRequest {
    pub image: Vec<f32>,
    pub model: Option<String>,
    pub budget: RequestBudget,
    /// Absolute deadline (protocol `deadline_ms`, or the server default
    /// applied at admission).  `None` = wait forever.  Expired requests
    /// are shed at dequeue and between adaptive chunks with a typed
    /// `deadline_exceeded` error.
    pub deadline: Option<Instant>,
    /// Estimated work (stochastic samples) charged against the overload
    /// budget at admission; 0 until admitted.
    pub cost: u64,
    /// Shard-scoped plan seed (cluster mode): when set, the executor must
    /// draw from a stream derived from exactly this seed instead of its
    /// own persistent stream, making the request *stateless* — any worker
    /// (or a retry on the same worker) reproduces the answer bitwise.
    /// This is the `placement` extension of the replay contract.
    pub plan_seed: Option<u64>,
    /// Trace key (0 = untraced): minted at the gateway when tracing is on,
    /// or supplied by the client / a forwarding coordinator so one request
    /// stitches into a single trace across cluster hops.  Purely
    /// observational — never feeds any computation.
    pub request_id: u64,
    /// When the request entered the queue (re-stamped at admission):
    /// attributes queue-wait vs batch-formation time in the trace.
    pub enqueued: Instant,
    pub reply: Sender<Result<ClassifyResult>>,
}

impl ClassifyRequest {
    /// Build a request + the receiver for its reply.
    pub fn new(image: Vec<f32>) -> (Self, Receiver<Result<ClassifyResult>>) {
        Self::with_budget(image, RequestBudget::default())
    }

    /// Build a request carrying budget overrides (`max_samples` /
    /// `target_confidence` protocol fields).
    pub fn with_budget(
        image: Vec<f32>,
        budget: RequestBudget,
    ) -> (Self, Receiver<Result<ClassifyResult>>) {
        Self::with_model(None, image, budget)
    }

    /// Build a request targeting a named model (the wire protocol's
    /// `model` field; `None` = the engine's default model).
    pub fn with_model(
        model: Option<String>,
        image: Vec<f32>,
        budget: RequestBudget,
    ) -> (Self, Receiver<Result<ClassifyResult>>) {
        let (tx, rx) = channel(1);
        (
            Self {
                image,
                model,
                budget,
                deadline: None,
                cost: 0,
                plan_seed: None,
                request_id: 0,
                enqueued: Instant::now(),
                reply: tx,
            },
            rx,
        )
    }
}

/// What makes two requests batchable into one engine plan: same target
/// model (a program switch between them would thrash the machine) and same
/// sample budget (budgets are variable-cost — a 3-sample request batched
/// with a 20-sample one would either overspend or starve).
#[derive(Debug, Clone, PartialEq)]
pub struct GroupKey {
    /// `None` groups with `None`: default-model requests coalesce with
    /// each other, not with requests naming the default explicitly (the
    /// engine resolves both to the same program, so the only cost of the
    /// distinction is one extra no-op switch check).
    pub model: Option<String>,
    pub budget: RequestBudget,
    /// Shard-scoped plan seed: requests pinned to different seeds must
    /// not batch together (each seed is its own deterministic stream).
    pub plan_seed: Option<u64>,
}

/// Partition one dynamic batch into same-(model, budget) groups, preserving
/// arrival order within each group (and of first appearance across groups).
/// Same-model requests coalesce so program switches amortize across the
/// group instead of hitting every request.  Distinct keys on a batch are
/// few in practice, so a linear scan wins over hashing.
fn group_requests(batch: Vec<ClassifyRequest>) -> Vec<(GroupKey, Vec<ClassifyRequest>)> {
    let mut groups: Vec<(GroupKey, Vec<ClassifyRequest>)> = Vec::new();
    for req in batch {
        match groups.iter_mut().find(|(k, _)| {
            k.model == req.model && k.budget == req.budget && k.plan_seed == req.plan_seed
        }) {
            Some((_, members)) => members.push(req),
            None => {
                let key = GroupKey {
                    model: req.model.clone(),
                    budget: req.budget,
                    plan_seed: req.plan_seed,
                };
                groups.push((key, vec![req]));
            }
        }
    }
    groups
}

/// Batching + overload knobs for the service loop.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    pub queue_depth: usize,
    /// Server-default deadline applied at admission to requests that
    /// carry none (protocol `deadline_ms` wins).  0 = no default.
    pub deadline_ms: u64,
    /// Cost-aware admission and tiered-degradation knobs.
    pub overload: OverloadConfig,
    /// Request tracing / exemplar knobs ([`crate::observe`]).
    pub observe: ObserveConfig,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            queue_depth: 256,
            deadline_ms: 0,
            overload: OverloadConfig::default(),
            observe: ObserveConfig::default(),
        }
    }
}

/// The per-batch work surface of the service loop, factored out of
/// [`Engine`] so the overload/deadline/panic machinery — and the chaos
/// suite and serving bench driving it — runs without model artifacts
/// (see [`SynthExecutor`]).
pub trait BatchExecutor {
    /// Model serving requests that carry no `model` field.
    fn default_model(&self) -> &str;
    /// Expected flat image length for `model` (`None` = the default);
    /// `None` return = the model is not served here.
    fn image_size_for(&self, model: Option<&str>) -> Option<usize>;
    /// Every servable model name (for typed `unknown_model` errors).
    fn model_names(&self) -> Vec<String>;
    /// Classify one same-(model, budget) group.  `brownout` requests the
    /// degraded mean-field path (tier-2 overload).
    fn classify_group(
        &mut self,
        model: Option<&str>,
        images: &[f32],
        n: usize,
        budget: &RequestBudget,
        deadline: Option<Instant>,
        brownout: bool,
    ) -> Result<Vec<ClassifyResult>>;
    /// Classify one group from a *shard-scoped* plan seed (cluster mode):
    /// draw every stochastic pass from a stream derived from `plan_seed`
    /// alone, without consuming the executor's persistent stream, so the
    /// same `(model, plan_seed, budget)` reproduces bitwise on any
    /// executor instance — the property failover and hedging rely on.
    /// Default: a typed refusal (the artifact-backed [`Engine`] keeps its
    /// persistent per-shard streams and does not serve seeded plans yet).
    fn classify_group_seeded(
        &mut self,
        _plan_seed: u64,
        _model: Option<&str>,
        _images: &[f32],
        _n: usize,
        _budget: &RequestBudget,
        _deadline: Option<Instant>,
        _brownout: bool,
    ) -> Result<Vec<ClassifyResult>> {
        Err(anyhow!(
            "this executor does not serve shard-scoped (plan_seed) requests"
        ))
    }
    /// Share the serving counters with the executor's own telemetry
    /// (called once on the engine thread before the loop starts).
    fn attach_counters(&mut self, _counters: &Arc<ServeCounters>) {}
    /// Share the trace recorder so the executor can attribute per-chunk
    /// spans (called once on the engine thread before the loop starts).
    /// Default: ignore — tracing degrades to the service-loop spans.
    fn attach_recorder(&mut self, _recorder: &Arc<TraceRecorder>) {}
    /// Announce the positional `request_id`s (0 = untraced) of the group
    /// about to be classified, aligned with the group's image order, so
    /// the executor's spans land under the right trace keys.  Called
    /// right before `classify_group`/`classify_group_seeded`; the ids are
    /// valid only for that one call.  Default: ignore.
    fn begin_group(&mut self, _request_ids: &[u64]) {}
    /// Deterministically rebuild internal state after a panic escaped
    /// `classify_group` (the `catch_unwind` recovery path).
    fn recover_after_panic(&mut self) -> Result<()>;
    /// One-line telemetry for the exit log.
    fn report_line(&self) -> String;
}

impl BatchExecutor for Engine {
    fn default_model(&self) -> &str {
        Engine::default_model(self)
    }

    fn image_size_for(&self, model: Option<&str>) -> Option<usize> {
        match model {
            None => Some(self.image_size()),
            Some(m) => self.image_size_of(m),
        }
    }

    fn model_names(&self) -> Vec<String> {
        Engine::model_names(self)
    }

    fn classify_group(
        &mut self,
        model: Option<&str>,
        images: &[f32],
        n: usize,
        budget: &RequestBudget,
        deadline: Option<Instant>,
        brownout: bool,
    ) -> Result<Vec<ClassifyResult>> {
        self.classify_opts(model, images, n, budget, deadline, brownout)
    }

    fn attach_counters(&mut self, counters: &Arc<ServeCounters>) {
        // the engine's metrics JSON surfaces the same counters
        self.metrics.serving = counters.clone();
    }

    fn attach_recorder(&mut self, recorder: &Arc<TraceRecorder>) {
        Engine::attach_trace(self, recorder);
    }

    fn begin_group(&mut self, request_ids: &[u64]) {
        Engine::begin_trace_group(self, request_ids);
    }

    fn recover_after_panic(&mut self) -> Result<()> {
        Engine::recover_after_panic(self)
    }

    fn report_line(&self) -> String {
        self.report()
    }
}

/// Cost-aware admission: estimate the request's work, charge it against
/// the overload budget, apply the server-default deadline, and enqueue
/// *without blocking*.  A full queue or exhausted work budget answers a
/// typed [`ServeError::Overloaded`] with a drain-time `retry_after_ms`
/// hint — overload sheds instead of backpressuring into the gateway's
/// worker pool (where a blocked worker is itself an outage amplifier).
pub fn submit_with_admission(
    tx: &Sender<ClassifyRequest>,
    ctrl: &OverloadControl,
    counters: &ServeCounters,
    default_deadline_ms: u64,
    mut req: ClassifyRequest,
) -> Result<()> {
    req.enqueued = Instant::now();
    if req.deadline.is_none() && default_deadline_ms > 0 {
        req.deadline = Some(req.enqueued + Duration::from_millis(default_deadline_ms));
    }
    let cost = ctrl.estimate_cost(&req.budget);
    if let Err(e) = ctrl.try_admit(cost) {
        counters.overload_rejects.fetch_add(1, Ordering::Relaxed);
        counters.requests_shed.fetch_add(1, Ordering::Relaxed);
        logging::event(
            logging::Level::Warn,
            module_path!(),
            "shed",
            req.request_id,
            &[("reason", "work_budget"), ("where", "admission")],
        );
        return Err(anyhow::Error::new(e));
    }
    req.cost = cost;
    let rid = req.request_id;
    match tx.try_send(req) {
        Ok(()) => Ok(()),
        Err(TrySendError::Full(_)) => {
            // work budget admitted it but the queue (request count) is
            // full — refund and shed
            ctrl.on_dequeue(cost);
            counters.overload_rejects.fetch_add(1, Ordering::Relaxed);
            counters.requests_shed.fetch_add(1, Ordering::Relaxed);
            logging::event(
                logging::Level::Warn,
                module_path!(),
                "shed",
                rid,
                &[("reason", "queue_full"), ("where", "admission")],
            );
            Err(anyhow::Error::new(ServeError::Overloaded {
                retry_after_ms: ctrl.retry_after_ms(),
            }))
        }
        Err(TrySendError::Closed(_)) => {
            ctrl.on_dequeue(cost);
            Err(anyhow!("engine is shut down"))
        }
    }
}

/// Run the service loop over `rx` until the channel closes: cost-weighted
/// dynamic batching, deadline shedding at dequeue, tier-based budget
/// clamping / brownout, and `catch_unwind` panic isolation around
/// per-group executor work.  Public so the chaos suite and the serving
/// bench can drive it with a [`SynthExecutor`]; engine threads spawned
/// by [`EngineHandle`] run exactly this loop.
pub fn run_service_loop<E: BatchExecutor>(
    exec: &mut E,
    rx: Receiver<ClassifyRequest>,
    svc: &ServiceConfig,
    ctrl: &OverloadControl,
    counters: &ServeCounters,
) {
    run_service_loop_traced(exec, rx, svc, ctrl, counters, &Arc::new(TraceRecorder::disabled()));
}

/// [`run_service_loop`] with a shared [`TraceRecorder`]: per traced
/// request it attributes `queue` (enqueue → batch window opening) and
/// `batch_form` (batch window) spans, and hands the recorder to the
/// executor for per-chunk attribution.  With a disabled recorder this is
/// exactly the untraced loop — the fast path is one atomic load per span.
pub fn run_service_loop_traced<E: BatchExecutor>(
    exec: &mut E,
    rx: Receiver<ClassifyRequest>,
    svc: &ServiceConfig,
    ctrl: &OverloadControl,
    counters: &ServeCounters,
    recorder: &Arc<TraceRecorder>,
) {
    exec.attach_recorder(recorder);
    let batcher = DynamicBatcher::new(rx.clone(), svc.max_batch, svc.max_wait);
    // close batches on estimated work, not just count: max_batch
    // heavyweight requests are max_batch × default_cost samples of work
    let max_work = (svc.max_batch as u64).saturating_mul(ctrl.default_cost());
    'serve: loop {
        // the instant the batch window opens: for requests already queued,
        // everything before this is queue wait and everything after is
        // batch formation; requests arriving *during* the window have no
        // queue wait at all
        let t_batch_start = Instant::now();
        let Some(batch) = batcher.next_batch_weighted(|r| r.cost.max(1), max_work) else {
            break 'serve;
        };
        let t_batch_done = Instant::now();
        if recorder.enabled() {
            for req in &batch {
                if req.request_id == 0 {
                    continue;
                }
                if req.enqueued <= t_batch_start {
                    recorder.record(
                        req.request_id,
                        Stage::Queue,
                        0,
                        req.enqueued,
                        t_batch_start.saturating_duration_since(req.enqueued),
                    );
                    recorder.record(
                        req.request_id,
                        Stage::BatchForm,
                        0,
                        t_batch_start,
                        t_batch_done.saturating_duration_since(t_batch_start),
                    );
                } else {
                    recorder.record(req.request_id, Stage::Queue, 0, req.enqueued, Duration::ZERO);
                    recorder.record(
                        req.request_id,
                        Stage::BatchForm,
                        0,
                        req.enqueued,
                        t_batch_done.saturating_duration_since(req.enqueued),
                    );
                }
            }
        }
        let cost_sum: u64 = batch.iter().map(|r| r.cost).sum();
        ctrl.on_dequeue(cost_sum);
        counters
            .queue_depth
            .store(rx.len() as u64, Ordering::Relaxed);
        // one tier decision per batch: requests admitted together degrade
        // together (and grouping stays stable)
        let tier = ctrl.tier();
        for (key, group) in group_requests(batch) {
            if let Err(e) = serve_group(exec, ctrl, counters, tier, key, group) {
                crate::log_error!("engine thread unrecoverable: {e:#}");
                break 'serve;
            }
        }
    }
    log_info!("engine thread exiting: {}", exec.report_line());
}

/// Serve one same-(model, budget) group.  `Err` only for unrecoverable
/// states (panic recovery itself failed) — per-request failures answer
/// their reply channels and return `Ok`.
fn serve_group<E: BatchExecutor>(
    exec: &mut E,
    ctrl: &OverloadControl,
    counters: &ServeCounters,
    tier: Tier,
    key: GroupKey,
    group: Vec<ClassifyRequest>,
) -> Result<()> {
    // deadline shed at dequeue: expired requests answer immediately
    // instead of burning engine samples
    let now = Instant::now();
    let mut live = Vec::with_capacity(group.len());
    for req in group {
        match req.deadline {
            Some(d) if now >= d => {
                counters.requests_shed.fetch_add(1, Ordering::Relaxed);
                counters.deadline_expired.fetch_add(1, Ordering::Relaxed);
                logging::event(
                    logging::Level::Warn,
                    module_path!(),
                    "deadline_expired",
                    req.request_id,
                    &[("where", "dequeue")],
                );
                let _ = req.reply.send(Err(anyhow::Error::new(
                    ServeError::DeadlineExceeded { samples_used: 0 },
                )));
            }
            _ => live.push(req),
        }
    }
    if live.is_empty() {
        return Ok(());
    }
    // validate image size against the *target* model, not whichever is
    // active; an unservable model is a typed routing error for the group
    let Some(image_size) = exec.image_size_for(key.model.as_deref()) else {
        let err = UnknownModel {
            model: key
                .model
                .clone()
                .unwrap_or_else(|| exec.default_model().to_string()),
            known: exec.model_names(),
        };
        for req in live {
            let _ = req.reply.send(Err(anyhow::Error::new(err.clone())));
        }
        return Ok(());
    };
    let mut images = Vec::with_capacity(live.len() * image_size);
    let mut ok = Vec::with_capacity(live.len());
    // positional trace keys aligned with `images` (0 = untraced), handed
    // to the executor so its chunk spans land under the right requests
    let mut ids = Vec::with_capacity(live.len());
    // the group's effective deadline is its earliest member's: one round
    // loop serves the whole group, so the tightest member binds it
    let mut deadline: Option<Instant> = None;
    for req in live {
        if req.image.len() == image_size {
            images.extend_from_slice(&req.image);
            deadline = match (deadline, req.deadline) {
                (Some(a), Some(b)) => Some(a.min(b)),
                (a, b) => a.or(b),
            };
            ids.push(req.request_id);
            ok.push(req.reply);
        } else {
            let _ = req.reply.send(Err(anyhow!(
                "image size {} != expected {}",
                req.image.len(),
                image_size
            )));
        }
    }
    if ok.is_empty() {
        return Ok(());
    }
    // tiered degradation: clamp the group's sample budget under sustained
    // pressure; brown out to the mean-field backend at the opt-in tier
    let mut budget = key.budget;
    let mut degraded = false;
    if tier >= Tier::Clamped {
        let clamp = ctrl.clamp_samples();
        budget.max_samples = Some(budget.max_samples.map_or(clamp, |m| m.min(clamp)));
        degraded = true;
    }
    let brownout = tier >= Tier::Brownout;
    let n = ok.len();
    exec.begin_group(&ids);
    let t0 = Instant::now();
    let outcome = catch_unwind(AssertUnwindSafe(|| match key.plan_seed {
        // shard-scoped plan (cluster mode): the stream derives from the
        // request's seed, not the executor's persistent one
        Some(ps) => exec.classify_group_seeded(
            ps,
            key.model.as_deref(),
            &images,
            n,
            &budget,
            deadline,
            brownout,
        ),
        None => {
            exec.classify_group(key.model.as_deref(), &images, n, &budget, deadline, brownout)
        }
    }));
    match outcome {
        Ok(Ok(mut results)) => {
            let work: u64 = results.iter().map(|r| r.samples_used as u64).sum();
            let elapsed = t0.elapsed();
            ctrl.on_work_done(work.max(1), elapsed);
            // per-request service latency (batch wall-clock attributed to
            // each served member) — feeds the /info percentiles
            let us = elapsed.as_micros() as f64;
            for _ in 0..n {
                counters.latency.record(us);
            }
            if degraded {
                for r in &mut results {
                    r.degraded = true;
                }
            }
            for (reply, res) in ok.into_iter().zip(results) {
                let _ = reply.send(Ok(res));
            }
        }
        Ok(Err(e)) => {
            // typed lifecycle errors are `Clone` and fan out per reply;
            // anything else flattens to a message (anyhow isn't Clone)
            if let Some(se) = e.downcast_ref::<ServeError>() {
                if matches!(se, ServeError::DeadlineExceeded { .. }) {
                    counters
                        .requests_shed
                        .fetch_add(n as u64, Ordering::Relaxed);
                    counters
                        .deadline_expired
                        .fetch_add(n as u64, Ordering::Relaxed);
                    for &rid in &ids {
                        logging::event(
                            logging::Level::Warn,
                            module_path!(),
                            "deadline_expired",
                            rid,
                            &[("where", "mid_run")],
                        );
                    }
                }
                for reply in ok {
                    let _ = reply.send(Err(anyhow::Error::new(se.clone())));
                }
            } else if let Some(um) = e.downcast_ref::<UnknownModel>() {
                for reply in ok {
                    let _ = reply.send(Err(anyhow::Error::new(um.clone())));
                }
            } else {
                for reply in ok {
                    let _ = reply.send(Err(anyhow!("engine error: {e}")));
                }
            }
        }
        Err(_panic) => {
            // a poisoned batch answers its replies and dies alone: the
            // executor rebuilds deterministically and keeps serving
            for &rid in &ids {
                logging::event(
                    logging::Level::Error,
                    module_path!(),
                    "panic_recovered",
                    rid,
                    &[("model", key.model.as_deref().unwrap_or("default"))],
                );
            }
            for reply in ok {
                let _ = reply.send(Err(anyhow::Error::new(ServeError::Internal {
                    detail: "engine panicked serving this batch; state was rebuilt".into(),
                })));
            }
            exec.recover_after_panic()
                .map_err(|e| anyhow!("rebuilding engine after panic: {e}"))?;
            counters.panics_recovered.fetch_add(1, Ordering::Relaxed);
        }
    }
    Ok(())
}

/// Handle to a running engine thread.
pub struct EngineHandle {
    /// Primary serving name (the dataset of a single-model engine; the
    /// default model of a multi-model engine).
    pub dataset: String,
    /// Every model this engine serves (`[dataset]` on single-model
    /// engines; registry order on multi-model engines, default first).
    pub models: Vec<String>,
    /// Entropy-health monitor shared with the engine (present when
    /// `EngineConfig::health.enabled`): `/info` reads scorecards from here
    /// without a round-trip through the engine thread.
    pub health: Option<Arc<Monitor>>,
    /// Registry residency/hit/miss counters shared with a multi-model
    /// engine's backend cache; `/info` reads them from here.
    pub registry: Option<Arc<RegistryMetrics>>,
    /// Shed/deadline/overload/panic counters shared with the service
    /// loop, the admission path, and the engine's metrics.
    pub counters: Arc<ServeCounters>,
    /// Cluster-mode worker pool (present when this handle fronts a
    /// [`crate::cluster::ClusterExecutor`]): `/info` reads per-worker
    /// health/latency cards from here without a round-trip through the
    /// coordinator thread.
    pub cluster: Option<Arc<crate::cluster::WorkerPool>>,
    /// Lock-free span ring shared with the service loop and executor
    /// (disabled unless `ServiceConfig::observe.trace`): the gateway
    /// mints `request_id`s here and the `trace` verb / `/metrics` read
    /// spans and counters without a round-trip through the engine thread.
    pub recorder: Arc<TraceRecorder>,
    /// Per-model uncertainty histograms (predictive entropy, mutual
    /// information, samples used), recorded by the gateway on successful
    /// replies and rendered by `/metrics`.
    pub uncertainty: Arc<UncertaintyTelemetry>,
    ctrl: Arc<OverloadControl>,
    deadline_ms: u64,
    tx: Sender<ClassifyRequest>,
    /// Probe clone of the request queue for the live depth gauge.
    rx_probe: Receiver<ClassifyRequest>,
    thread: Option<JoinHandle<()>>,
}

impl EngineHandle {
    /// Spawn an engine thread for `dataset` under `artifacts_root`, loading
    /// parameters from `params_path` (or `params_init.bin` if `None`).
    pub fn spawn(
        artifacts_root: &Path,
        dataset: &str,
        params_path: Option<&Path>,
        engine_cfg: EngineConfig,
        svc_cfg: ServiceConfig,
    ) -> Result<Self> {
        // the engine is built inside its thread, so create the monitor here
        // and hand it in: the serving layer keeps the other reference for
        // lock-free-on-the-engine /info scorecard reads
        let mut engine_cfg = engine_cfg;
        if engine_cfg.health.enabled && engine_cfg.health_monitor.is_none() {
            engine_cfg.health_monitor = Some(Arc::new(Monitor::new(engine_cfg.health)));
        }
        let health = engine_cfg.health_monitor.clone();
        let dir = artifacts_root.join(dataset);
        let params_path = params_path.map(|p| p.to_path_buf());
        let dataset_name = dataset.to_string();
        let n_samples = engine_cfg.n_samples;
        Self::spawn_loop(
            dataset_name.clone(),
            vec![dataset_name],
            health,
            None,
            n_samples,
            svc_cfg,
            move || {
                let arts = ModelArtifacts::load(&dir)?;
                let params = match &params_path {
                    Some(p) => ParamStore::load_bin(&arts.meta, p)?,
                    None => ParamStore::load_init(&arts.meta, &dir)?,
                };
                Engine::new(arts, params, engine_cfg)
            },
        )
    }

    /// Spawn one engine thread serving every model in `specs` through a
    /// shared [`ProgramRegistry`]: the first spec is the default model,
    /// requests name others via [`ClassifyRequest::model`], and the
    /// batcher's [`GroupKey`] coalesces same-model traffic so program
    /// switches amortize across whole groups.
    pub fn spawn_multi(
        artifacts_root: &Path,
        specs: Vec<ModelSpec>,
        engine_cfg: EngineConfig,
        svc_cfg: ServiceConfig,
    ) -> Result<Self> {
        if specs.is_empty() {
            anyhow::bail!("spawn_multi needs at least one model spec");
        }
        let mut engine_cfg = engine_cfg;
        if engine_cfg.health.enabled && engine_cfg.health_monitor.is_none() {
            engine_cfg.health_monitor = Some(Arc::new(Monitor::new(engine_cfg.health)));
        }
        let health = engine_cfg.health_monitor.clone();
        // the registry metrics live outside the engine thread so /info can
        // read residency without a round-trip
        if engine_cfg.registry_metrics.is_none() {
            engine_cfg.registry_metrics = Some(Arc::new(RegistryMetrics::default()));
        }
        let registry = engine_cfg.registry_metrics.clone();
        let model_names: Vec<String> = specs.iter().map(|s| s.name.clone()).collect();
        let default_name = model_names[0].clone();
        let root = artifacts_root.to_path_buf();
        let n_samples = engine_cfg.n_samples;
        Self::spawn_loop(
            default_name,
            model_names,
            health,
            registry,
            n_samples,
            svc_cfg,
            move || {
                let reg = ProgramRegistry::load(&root, &specs)?;
                Engine::with_registry(reg, engine_cfg)
            },
        )
    }

    /// Spawn a service thread over *any* [`BatchExecutor`] (the executor
    /// is built inside the thread, so it needs no `Send`): the seam that
    /// lets one gateway front an artifact engine, a [`SynthExecutor`]
    /// worker substrate (`pbm worker`), or a
    /// [`crate::cluster::ClusterExecutor`] coordinator.
    pub fn spawn_executor<E: BatchExecutor>(
        name: &str,
        models: Vec<String>,
        health: Option<Arc<Monitor>>,
        n_samples: usize,
        svc_cfg: ServiceConfig,
        build: impl FnOnce() -> Result<E> + Send + 'static,
    ) -> Result<Self> {
        Self::spawn_loop(name.to_string(), models, health, None, n_samples, svc_cfg, build)
    }

    /// Shared spawn core: wire the overload control + counters, start the
    /// engine thread (all PJRT + machine state is created inside `build`,
    /// on that thread), and run [`run_service_loop`] until shutdown.
    fn spawn_loop<E: BatchExecutor>(
        name: String,
        models: Vec<String>,
        health: Option<Arc<Monitor>>,
        registry: Option<Arc<RegistryMetrics>>,
        n_samples: usize,
        svc_cfg: ServiceConfig,
        build: impl FnOnce() -> Result<E> + Send + 'static,
    ) -> Result<Self> {
        let mut ocfg = svc_cfg.overload.clone();
        if ocfg.default_cost == 0 {
            ocfg.default_cost = n_samples.max(1) as u64;
        }
        let ctrl = Arc::new(OverloadControl::new(ocfg, svc_cfg.queue_depth));
        let counters = Arc::new(ServeCounters::default());
        let recorder = Arc::new(TraceRecorder::new(&svc_cfg.observe));
        let uncertainty = Arc::new(UncertaintyTelemetry::new(&models));
        let (tx, rx) = channel::<ClassifyRequest>(svc_cfg.queue_depth);
        let rx_probe = rx.clone();
        let (ctrl2, counters2, svc2) = (ctrl.clone(), counters.clone(), svc_cfg.clone());
        let rec2 = recorder.clone();
        let thread = std::thread::Builder::new()
            .name(format!("pbm-engine-{name}"))
            .spawn(move || {
                let run = || -> Result<()> {
                    let mut exec = build()?;
                    exec.attach_counters(&counters2);
                    run_service_loop_traced(&mut exec, rx, &svc2, &ctrl2, &counters2, &rec2);
                    Ok(())
                };
                if let Err(e) = run() {
                    crate::log_error!("engine thread failed: {e:#}");
                }
            })
            .map_err(|e| anyhow!("spawning engine thread: {e}"))?;
        Ok(Self {
            dataset: name,
            models,
            health,
            registry,
            counters,
            cluster: None,
            recorder,
            uncertainty,
            ctrl,
            deadline_ms: svc_cfg.deadline_ms,
            tx,
            rx_probe,
            thread: Some(thread),
        })
    }

    /// Submit a request through cost-aware admission.  Never blocks: a
    /// full queue or exhausted work budget answers a typed
    /// [`ServeError::Overloaded`] immediately (shed, don't backpressure).
    pub fn submit(&self, req: ClassifyRequest) -> Result<()> {
        let rid = req.request_id;
        let t0 = Instant::now();
        let res = submit_with_admission(
            &self.tx,
            &self.ctrl,
            &self.counters,
            self.deadline_ms,
            req,
        );
        if res.is_ok() {
            // sub-microsecond cost-estimate + try_send work; recorded so
            // every traced request starts at its admission instant
            self.recorder.record(rid, Stage::Admission, 0, t0, t0.elapsed());
        }
        self.counters
            .queue_depth
            .store(self.rx_probe.len() as u64, Ordering::Relaxed);
        res.map_err(|e| match e.downcast_ref::<ServeError>() {
            Some(_) => e,
            None => anyhow!("engine '{}': {e}", self.dataset),
        })
    }

    /// Point-in-time serving/robustness counters (refreshes the
    /// queue-depth gauge from the live queue first).
    pub fn serve_snapshot(&self) -> ServeSnapshot {
        self.counters
            .queue_depth
            .store(self.rx_probe.len() as u64, Ordering::Relaxed);
        self.counters.snapshot()
    }

    /// Convenience: classify one image synchronously.
    pub fn classify_blocking(&self, image: Vec<f32>) -> Result<ClassifyResult> {
        let (req, rx) = ClassifyRequest::new(image);
        self.submit(req)?;
        rx.recv().ok_or_else(|| anyhow!("engine dropped reply"))?
    }

    /// Shut the engine down and join its thread.
    pub fn shutdown(mut self) {
        self.tx.close();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for EngineHandle {
    fn drop(&mut self) {
        self.tx.close();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Deterministic, artifact-free [`BatchExecutor`] for the chaos suite
/// and the `paper_tables -- serving` bench.  Per-sample pseudo-logits
/// come from a seeded splitmix64 stream that persists across calls —
/// mirroring the engine's persistent per-shard entropy streams — and
/// [`BatchExecutor::recover_after_panic`] rebuilds the stream from the
/// seed, mirroring the engine's deterministic backend rebuild, so the
/// post-recovery bitwise-replay contract is testable without model
/// artifacts.  Budgets, deadlines (checked between simulated draws),
/// and brownout (one deterministic pass) behave like the real engine;
/// `work_per_sample` emulates engine time.
pub struct SynthExecutor {
    seed: u64,
    state: u64,
    /// Samples per request when the budget doesn't cap it.
    pub n_samples: usize,
    /// Simulated engine work per sample draw (sleep).
    pub work_per_sample: Duration,
    pub classes: usize,
    pub image_size: usize,
    policy: UncertaintyPolicy,
    /// Trace recorder (present when tracing is on) + the traced ids of
    /// the group currently being classified.
    trace: Option<Arc<TraceRecorder>>,
    trace_ids: Vec<u64>,
}

impl SynthExecutor {
    pub fn new(seed: u64, n_samples: usize) -> Self {
        Self {
            seed,
            state: seed,
            n_samples: n_samples.max(1),
            work_per_sample: Duration::ZERO,
            classes: 3,
            image_size: 4,
            // accept-everything policy: decisions are not under test here
            policy: UncertaintyPolicy::ood_only(f64::MAX),
            trace: None,
            trace_ids: Vec::new(),
        }
    }

    /// Record one `chunk` span (the synthetic executor draws all samples
    /// in a single chunk) under every traced id of the current group.
    fn trace_chunk(&self, start: Instant) {
        if let Some(rec) = &self.trace {
            let dur = start.elapsed();
            for &id in &self.trace_ids {
                rec.record(id, Stage::Chunk, 0, start, dur);
            }
        }
    }

    /// One deterministic logit row: a function of the stream position
    /// (`state`) and the image content (so distinct inputs get distinct
    /// predictives).
    fn logit_row(classes: usize, state: &mut u64, image: &[f32]) -> Vec<f32> {
        let mut h = 0xABCD_EF01u64;
        for &v in image {
            h = h.rotate_left(13) ^ u64::from(v.to_bits());
        }
        let mut local = fault::splitmix64(state) ^ h;
        (0..classes)
            .map(|_| {
                let z = fault::splitmix64(&mut local);
                ((z >> 11) as f64 / (1u64 << 53) as f64 * 4.0) as f32
            })
            .collect()
    }

    /// The classify core, parameterized by the entropy stream it draws
    /// from: the persistent `self.state` for normal traffic, a local
    /// seed-derived state for stateless shard-scoped plans.
    fn classify_stream(
        &self,
        state: &mut u64,
        images: &[f32],
        n: usize,
        budget: &RequestBudget,
        deadline: Option<Instant>,
        brownout: bool,
    ) -> Result<Vec<ClassifyResult>> {
        let t0 = Instant::now();
        fault::faultpoint("synth.classify").map_err(|e| anyhow!("{e}"))?;
        let samples = if brownout {
            1
        } else {
            budget
                .max_samples
                .map_or(self.n_samples, |m| m.min(self.n_samples))
                .max(1)
        };
        let mut rows: Vec<Vec<Vec<f32>>> = vec![Vec::with_capacity(samples); n];
        // sample-major loop so a mid-run deadline reports partial spend,
        // exactly like the engine's chunk-boundary checks
        for s in 0..samples {
            if deadline.is_some_and(|d| Instant::now() >= d) {
                return Err(anyhow::Error::new(ServeError::DeadlineExceeded {
                    samples_used: s,
                }));
            }
            fault::faultpoint("synth.sample").map_err(|e| anyhow!("{e}"))?;
            if !self.work_per_sample.is_zero() {
                std::thread::sleep(self.work_per_sample);
            }
            for (i, img_rows) in rows.iter_mut().enumerate() {
                let row = Self::logit_row(
                    self.classes,
                    state,
                    &images[i * self.image_size..(i + 1) * self.image_size],
                );
                img_rows.push(row);
            }
        }
        let per_image_latency = t0.elapsed().as_micros() as f64 / n as f64;
        Ok(rows
            .into_iter()
            .map(|r| {
                let predictive = Predictive::from_logits(&r);
                let decision = self.policy.decide(&predictive);
                ClassifyResult {
                    predictive,
                    decision,
                    latency_us: per_image_latency,
                    samples_used: samples,
                    degraded: brownout,
                }
            })
            .collect())
    }
}

impl BatchExecutor for SynthExecutor {
    fn default_model(&self) -> &str {
        "synth"
    }

    fn image_size_for(&self, model: Option<&str>) -> Option<usize> {
        match model {
            None | Some("synth") => Some(self.image_size),
            Some(_) => None,
        }
    }

    fn model_names(&self) -> Vec<String> {
        vec!["synth".to_string()]
    }

    fn classify_group(
        &mut self,
        _model: Option<&str>,
        images: &[f32],
        n: usize,
        budget: &RequestBudget,
        deadline: Option<Instant>,
        brownout: bool,
    ) -> Result<Vec<ClassifyResult>> {
        // the persistent stream advances by however much was drawn, even
        // when a mid-run deadline errors out (same as mutating in place)
        let mut state = self.state;
        let t0 = Instant::now();
        let res = self.classify_stream(&mut state, images, n, budget, deadline, brownout);
        self.trace_chunk(t0);
        self.state = state;
        res
    }

    fn classify_group_seeded(
        &mut self,
        plan_seed: u64,
        _model: Option<&str>,
        images: &[f32],
        n: usize,
        budget: &RequestBudget,
        deadline: Option<Instant>,
        brownout: bool,
    ) -> Result<Vec<ClassifyResult>> {
        // stateless: the stream derives from the plan seed alone and the
        // persistent stream is untouched, so re-executing (failover,
        // hedging, replay) is free of side effects
        let mut state = plan_seed;
        let t0 = Instant::now();
        let res = self.classify_stream(&mut state, images, n, budget, deadline, brownout);
        self.trace_chunk(t0);
        res
    }

    fn attach_recorder(&mut self, recorder: &Arc<TraceRecorder>) {
        if recorder.enabled() {
            self.trace = Some(recorder.clone());
        }
    }

    fn begin_group(&mut self, request_ids: &[u64]) {
        self.trace_ids.clear();
        if self.trace.is_some() {
            self.trace_ids.extend(request_ids.iter().copied().filter(|&id| id != 0));
        }
    }

    fn recover_after_panic(&mut self) -> Result<()> {
        // rebuild from seed, like the engine rebuilding its backend: the
        // post-recovery stream equals a freshly-built executor's
        self.state = self.seed;
        Ok(())
    }

    fn report_line(&self) -> String {
        format!("synth(seed={}, n_samples={})", self.seed, self.n_samples)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(pixel: f32, budget: RequestBudget) -> ClassifyRequest {
        ClassifyRequest::with_budget(vec![pixel], budget).0
    }

    fn req_for(model: &str, pixel: f32) -> ClassifyRequest {
        ClassifyRequest::with_model(
            Some(model.to_string()),
            vec![pixel],
            RequestBudget::default(),
        )
        .0
    }

    #[test]
    fn grouping_preserves_order_and_separates_budgets() {
        let small = RequestBudget {
            max_samples: Some(3),
            target_confidence: None,
        };
        let conf = RequestBudget {
            max_samples: None,
            target_confidence: Some(0.9),
        };
        let batch = vec![
            req(0.0, RequestBudget::default()),
            req(1.0, small),
            req(2.0, RequestBudget::default()),
            req(3.0, conf),
            req(4.0, small),
        ];
        let groups = group_requests(batch);
        assert_eq!(groups.len(), 3);
        assert_eq!(groups[0].0.budget, RequestBudget::default());
        assert_eq!(
            groups[0].1.iter().map(|r| r.image[0]).collect::<Vec<_>>(),
            vec![0.0, 2.0]
        );
        assert_eq!(groups[1].0.budget, small);
        assert_eq!(
            groups[1].1.iter().map(|r| r.image[0]).collect::<Vec<_>>(),
            vec![1.0, 4.0]
        );
        assert_eq!(groups[2].0.budget, conf);
        assert_eq!(groups[2].1.len(), 1);
    }

    #[test]
    fn uniform_batch_stays_one_group() {
        let batch: Vec<ClassifyRequest> =
            (0..5).map(|i| req(i as f32, RequestBudget::default())).collect();
        let groups = group_requests(batch);
        assert_eq!(groups.len(), 1);
        assert!(groups[0].0.model.is_none());
        assert_eq!(groups[0].1.len(), 5);
    }

    #[test]
    fn grouping_coalesces_same_model_and_keeps_arrival_order() {
        // interleaved a/b/default traffic: one group per model, arrival
        // order preserved within each and by first appearance across
        let batch = vec![
            req_for("a", 0.0),
            req_for("b", 1.0),
            req(2.0, RequestBudget::default()),
            req_for("a", 3.0),
            req_for("b", 4.0),
            req_for("a", 5.0),
        ];
        let groups = group_requests(batch);
        assert_eq!(groups.len(), 3);
        assert_eq!(groups[0].0.model.as_deref(), Some("a"));
        assert_eq!(
            groups[0].1.iter().map(|r| r.image[0]).collect::<Vec<_>>(),
            vec![0.0, 3.0, 5.0]
        );
        assert_eq!(groups[1].0.model.as_deref(), Some("b"));
        assert_eq!(
            groups[1].1.iter().map(|r| r.image[0]).collect::<Vec<_>>(),
            vec![1.0, 4.0]
        );
        assert!(groups[2].0.model.is_none());
        assert_eq!(groups[2].1.len(), 1);
    }

    #[test]
    fn same_model_different_budget_splits() {
        let small = RequestBudget {
            max_samples: Some(3),
            target_confidence: None,
        };
        let batch = vec![
            req_for("a", 0.0),
            ClassifyRequest::with_model(Some("a".into()), vec![1.0], small).0,
        ];
        let groups = group_requests(batch);
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[0].0.model.as_deref(), Some("a"));
        assert_eq!(groups[1].0.model.as_deref(), Some("a"));
        assert_ne!(groups[0].0.budget, groups[1].0.budget);
    }

    // ---- synthetic service-loop tests (no model artifacts needed) ----

    fn synth_req(pixels: Vec<f32>) -> (ClassifyRequest, Receiver<Result<ClassifyResult>>) {
        ClassifyRequest::new(pixels)
    }

    /// Spin up a full service loop over a SynthExecutor; returns the
    /// sender side plus the shared control/counters and a join guard.
    fn synth_service(
        svc: ServiceConfig,
        n_samples: usize,
    ) -> (
        Sender<ClassifyRequest>,
        Arc<OverloadControl>,
        Arc<ServeCounters>,
        JoinHandle<()>,
    ) {
        let mut ocfg = svc.overload.clone();
        if ocfg.default_cost == 0 {
            ocfg.default_cost = n_samples as u64;
        }
        let ctrl = Arc::new(OverloadControl::new(ocfg, svc.queue_depth));
        let counters = Arc::new(ServeCounters::default());
        let (tx, rx) = channel::<ClassifyRequest>(svc.queue_depth);
        let (c2, k2) = (ctrl.clone(), counters.clone());
        let h = std::thread::spawn(move || {
            let mut exec = SynthExecutor::new(7, n_samples);
            run_service_loop(&mut exec, rx, &svc, &c2, &k2);
        });
        (tx, ctrl, counters, h)
    }

    #[test]
    fn synth_loop_round_trip() {
        let (tx, _ctrl, _k, h) = synth_service(ServiceConfig::default(), 6);
        let (req, rx) = synth_req(vec![0.1, 0.2, 0.3, 0.4]);
        tx.send(req).unwrap();
        let res = rx.recv().unwrap().unwrap();
        assert_eq!(res.samples_used, 6);
        assert!(!res.degraded);
        tx.close();
        h.join().unwrap();
    }

    #[test]
    fn expired_deadline_is_shed_at_dequeue() {
        let (tx, _ctrl, counters, h) = synth_service(ServiceConfig::default(), 6);
        let (mut req, rx) = synth_req(vec![0.0; 4]);
        req.deadline = Some(Instant::now() - Duration::from_millis(5));
        tx.send(req).unwrap();
        let err = rx.recv().unwrap().unwrap_err();
        let se = err.downcast_ref::<ServeError>().expect("typed error");
        assert_eq!(
            se,
            &ServeError::DeadlineExceeded { samples_used: 0 },
            "shed at dequeue must not burn samples"
        );
        tx.close();
        h.join().unwrap();
        assert_eq!(counters.deadline_expired.load(Ordering::Relaxed), 1);
        assert_eq!(counters.requests_shed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn deadline_mid_run_reports_partial_spend() {
        let svc = ServiceConfig::default();
        let ctrl = OverloadControl::new(
            OverloadConfig {
                default_cost: 50,
                ..OverloadConfig::default()
            },
            svc.queue_depth,
        );
        let counters = ServeCounters::default();
        let mut exec = SynthExecutor::new(3, 50);
        exec.work_per_sample = Duration::from_millis(2);
        let (req, rx) = synth_req(vec![0.0; 4]);
        let mut req = req;
        req.deadline = Some(Instant::now() + Duration::from_millis(10));
        let key = GroupKey {
            model: None,
            budget: req.budget,
            plan_seed: None,
        };
        serve_group(&mut exec, &ctrl, &counters, Tier::Normal, key, vec![req]).unwrap();
        let err = rx.recv().unwrap().unwrap_err();
        match err.downcast_ref::<ServeError>() {
            Some(ServeError::DeadlineExceeded { samples_used }) => {
                assert!(
                    *samples_used > 0 && *samples_used < 50,
                    "partial spend expected, got {samples_used}"
                );
            }
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
    }

    #[test]
    fn unknown_model_answers_typed_error() {
        let (tx, _ctrl, _k, h) = synth_service(ServiceConfig::default(), 4);
        let (req, rx) = ClassifyRequest::with_model(
            Some("nope".into()),
            vec![0.0; 4],
            RequestBudget::default(),
        );
        tx.send(req).unwrap();
        let err = rx.recv().unwrap().unwrap_err();
        let um = err.downcast_ref::<UnknownModel>().expect("typed error");
        assert_eq!(um.model, "nope");
        assert_eq!(um.known, vec!["synth".to_string()]);
        tx.close();
        h.join().unwrap();
    }

    #[test]
    fn admission_sheds_when_work_budget_exhausts() {
        // no loop draining the queue: admission alone must bound it
        let ctrl = OverloadControl::new(
            OverloadConfig {
                default_cost: 10,
                ..OverloadConfig::default()
            },
            2, // capacity: 2 × 10 samples
        );
        let counters = ServeCounters::default();
        let (tx, rx) = channel::<ClassifyRequest>(2);
        let mut admitted = 0;
        let mut shed = 0;
        for _ in 0..5 {
            let (req, _rx) = synth_req(vec![0.0; 4]);
            match submit_with_admission(&tx, &ctrl, &counters, 0, req) {
                Ok(()) => admitted += 1,
                Err(e) => {
                    let se = e.downcast_ref::<ServeError>().expect("typed");
                    assert!(matches!(se, ServeError::Overloaded { .. }));
                    shed += 1;
                }
            }
        }
        assert_eq!(admitted, 2);
        assert_eq!(shed, 3);
        assert_eq!(counters.overload_rejects.load(Ordering::Relaxed), 3);
        assert_eq!(rx.len(), 2, "queue depth stays bounded");
    }

    #[test]
    fn default_deadline_applies_at_admission() {
        let ctrl = OverloadControl::new(OverloadConfig::default(), 8);
        let counters = ServeCounters::default();
        let (tx, rx) = channel::<ClassifyRequest>(8);
        let (req, _rx) = synth_req(vec![0.0; 4]);
        assert!(req.deadline.is_none());
        submit_with_admission(&tx, &ctrl, &counters, 250, req).unwrap();
        let queued = rx.recv().unwrap();
        let d = queued.deadline.expect("server default deadline applied");
        assert!(d > Instant::now());
        assert!(queued.cost > 0, "admission stamped the estimated cost");
    }

    #[test]
    fn clamp_tier_degrades_and_clamps_budget() {
        let svc = ServiceConfig {
            overload: OverloadConfig {
                default_cost: 8,
                clamp_pressure: 0.0, // always at least Clamped
                ..OverloadConfig::default()
            },
            ..ServiceConfig::default()
        };
        let (tx, _ctrl, _k, h) = synth_service(svc, 8);
        let (req, rx) = synth_req(vec![0.5; 4]);
        tx.send(req).unwrap();
        let res = rx.recv().unwrap().unwrap();
        assert!(res.degraded, "clamp tier must flag degraded");
        assert_eq!(res.samples_used, 4, "budget clamped to default_cost/2");
        tx.close();
        h.join().unwrap();
    }

    #[test]
    fn grouping_separates_plan_seeds() {
        let mut a = req(0.0, RequestBudget::default());
        a.plan_seed = Some(7);
        let mut b = req(1.0, RequestBudget::default());
        b.plan_seed = Some(8);
        let mut c = req(2.0, RequestBudget::default());
        c.plan_seed = Some(7);
        let d = req(3.0, RequestBudget::default());
        let groups = group_requests(vec![a, b, c, d]);
        assert_eq!(groups.len(), 3);
        assert_eq!(groups[0].0.plan_seed, Some(7));
        assert_eq!(groups[0].1.len(), 2);
        assert_eq!(groups[1].0.plan_seed, Some(8));
        assert_eq!(groups[2].0.plan_seed, None);
    }

    #[test]
    fn seeded_classify_is_stateless_and_deterministic() {
        let imgs = vec![0.3f32; 4];
        let budget = RequestBudget::default();
        let mut a = SynthExecutor::new(11, 5);
        let mut b = SynthExecutor::new(999, 5); // different persistent seed
        let bits = |r: &ClassifyResult| -> Vec<u32> {
            r.predictive.mean_probs.iter().map(|p| p.to_bits()).collect()
        };
        let s1 = a
            .classify_group_seeded(42, None, &imgs, 1, &budget, None, false)
            .unwrap();
        // a different executor instance with a different own-seed
        // reproduces the plan bitwise — the failover/hedging property
        let s2 = b
            .classify_group_seeded(42, None, &imgs, 1, &budget, None, false)
            .unwrap();
        assert_eq!(bits(&s1[0]), bits(&s2[0]), "seeded plans replay on any worker");
        // and the persistent stream is untouched by seeded traffic
        let n1 = a.classify_group(None, &imgs, 1, &budget, None, false).unwrap();
        let mut fresh = SynthExecutor::new(11, 5);
        let n2 = fresh
            .classify_group(None, &imgs, 1, &budget, None, false)
            .unwrap();
        assert_eq!(bits(&n1[0]), bits(&n2[0]), "seeded traffic is side-effect free");
    }

    #[test]
    fn service_loop_serves_plan_seeded_requests() {
        let (tx, _ctrl, _k, h) = synth_service(ServiceConfig::default(), 5);
        let (mut req, rx) = synth_req(vec![0.1, 0.2, 0.3, 0.4]);
        req.plan_seed = Some(1234);
        tx.send(req).unwrap();
        let res = rx.recv().unwrap().unwrap();
        tx.close();
        h.join().unwrap();
        // the reply equals a direct seeded classify on a fresh executor
        let mut exec = SynthExecutor::new(777, 5);
        let direct = exec
            .classify_group_seeded(
                1234,
                None,
                &[0.1, 0.2, 0.3, 0.4],
                1,
                &RequestBudget::default(),
                None,
                false,
            )
            .unwrap();
        let bits = |r: &ClassifyResult| -> Vec<u32> {
            r.predictive.mean_probs.iter().map(|p| p.to_bits()).collect()
        };
        assert_eq!(bits(&res), bits(&direct[0]));
    }

    #[test]
    fn synth_executor_streams_replay_after_recover() {
        let imgs = vec![0.3f32; 4];
        let budget = RequestBudget::default();
        let mut a = SynthExecutor::new(11, 5);
        let r1 = a.classify_group(None, &imgs, 1, &budget, None, false).unwrap();
        // advance the stream, then recover: back to the seed state
        let _ = a.classify_group(None, &imgs, 1, &budget, None, false).unwrap();
        a.recover_after_panic().unwrap();
        let r2 = a.classify_group(None, &imgs, 1, &budget, None, false).unwrap();
        let bits = |r: &ClassifyResult| -> Vec<u32> {
            r.predictive.mean_probs.iter().map(|p| p.to_bits()).collect()
        };
        assert_eq!(bits(&r1[0]), bits(&r2[0]), "post-recover replay is bitwise");
    }
}
