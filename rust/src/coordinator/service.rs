//! Engine service: confines the non-`Send` engine to a dedicated thread and
//! exposes a channel-based request API.

use std::path::Path;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, Result};

use super::batcher::DynamicBatcher;
use super::engine::{ClassifyResult, Engine, EngineConfig};
use crate::entropy::health::Monitor;
use crate::exec::channel::{channel, Receiver, Sender};
use crate::log_info;
use crate::runtime::{ModelArtifacts, ParamStore};
use crate::sampler::RequestBudget;

/// One classification request: an image, its per-request sample budget,
/// and a one-shot reply channel.
pub struct ClassifyRequest {
    pub image: Vec<f32>,
    pub budget: RequestBudget,
    pub reply: Sender<Result<ClassifyResult>>,
}

impl ClassifyRequest {
    /// Build a request + the receiver for its reply.
    pub fn new(image: Vec<f32>) -> (Self, Receiver<Result<ClassifyResult>>) {
        Self::with_budget(image, RequestBudget::default())
    }

    /// Build a request carrying budget overrides (`max_samples` /
    /// `target_confidence` protocol fields).
    pub fn with_budget(
        image: Vec<f32>,
        budget: RequestBudget,
    ) -> (Self, Receiver<Result<ClassifyResult>>) {
        let (tx, rx) = channel(1);
        (
            Self {
                image,
                budget,
                reply: tx,
            },
            rx,
        )
    }
}

/// Partition one dynamic batch into same-budget groups, preserving arrival
/// order within each group (and of first appearance across groups).  The
/// engine classifies each group as one batched plan: requests with
/// different budgets are *variable-cost* and must not share a plan — a
/// 3-sample request batched with a 20-sample one would either overspend or
/// starve.  Budgets on a batch are few in practice, so a linear scan wins
/// over hashing.
fn group_by_budget(batch: Vec<ClassifyRequest>) -> Vec<(RequestBudget, Vec<ClassifyRequest>)> {
    let mut groups: Vec<(RequestBudget, Vec<ClassifyRequest>)> = Vec::new();
    for req in batch {
        match groups.iter_mut().find(|(b, _)| *b == req.budget) {
            Some((_, members)) => members.push(req),
            None => groups.push((req.budget, vec![req])),
        }
    }
    groups
}

/// Handle to a running engine thread.
pub struct EngineHandle {
    pub dataset: String,
    /// Entropy-health monitor shared with the engine (present when
    /// `EngineConfig::health.enabled`): `/info` reads scorecards from here
    /// without a round-trip through the engine thread.
    pub health: Option<Arc<Monitor>>,
    tx: Sender<ClassifyRequest>,
    thread: Option<JoinHandle<()>>,
}

/// Batching knobs for the service loop.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    pub queue_depth: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            queue_depth: 256,
        }
    }
}

impl EngineHandle {
    /// Spawn an engine thread for `dataset` under `artifacts_root`, loading
    /// parameters from `params_path` (or `params_init.bin` if `None`).
    pub fn spawn(
        artifacts_root: &Path,
        dataset: &str,
        params_path: Option<&Path>,
        engine_cfg: EngineConfig,
        svc_cfg: ServiceConfig,
    ) -> Result<Self> {
        // the engine is built inside its thread, so create the monitor here
        // and hand it in: the serving layer keeps the other reference for
        // lock-free-on-the-engine /info scorecard reads
        let mut engine_cfg = engine_cfg;
        if engine_cfg.health.enabled && engine_cfg.health_monitor.is_none() {
            engine_cfg.health_monitor = Some(Arc::new(Monitor::new(engine_cfg.health)));
        }
        let health = engine_cfg.health_monitor.clone();
        let (tx, rx) = channel::<ClassifyRequest>(svc_cfg.queue_depth);
        let dir = artifacts_root.join(dataset);
        let params_path = params_path.map(|p| p.to_path_buf());
        let dataset_name = dataset.to_string();
        let thread = std::thread::Builder::new()
            .name(format!("pbm-engine-{dataset}"))
            .spawn(move || {
                // all PJRT + machine state is created on this thread
                let run = || -> Result<()> {
                    let arts = ModelArtifacts::load(&dir)?;
                    let params = match &params_path {
                        Some(p) => ParamStore::load_bin(&arts.meta, p)?,
                        None => ParamStore::load_init(&arts.meta, &dir)?,
                    };
                    let mut engine = Engine::new(arts, params, engine_cfg)?;
                    let image_size = engine.image_size();
                    let batcher = DynamicBatcher::new(rx, svc_cfg.max_batch, svc_cfg.max_wait);
                    while let Some(batch) = batcher.next_batch() {
                        // same-budget requests share one batched plan;
                        // mixed budgets split into per-budget sub-batches
                        for (budget, group) in group_by_budget(batch) {
                            let mut images = Vec::with_capacity(group.len() * image_size);
                            let mut ok = Vec::with_capacity(group.len());
                            for req in group {
                                if req.image.len() == image_size {
                                    images.extend_from_slice(&req.image);
                                    ok.push(req.reply);
                                } else {
                                    let _ = req.reply.send(Err(anyhow!(
                                        "image size {} != expected {}",
                                        req.image.len(),
                                        image_size
                                    )));
                                }
                            }
                            if ok.is_empty() {
                                continue;
                            }
                            match engine.classify_with_budget(&images, ok.len(), &budget) {
                                Ok(results) => {
                                    for (reply, res) in ok.into_iter().zip(results) {
                                        let _ = reply.send(Ok(res));
                                    }
                                }
                                Err(e) => {
                                    for reply in ok {
                                        let _ = reply.send(Err(anyhow!("engine error: {e}")));
                                    }
                                }
                            }
                        }
                    }
                    log_info!("engine thread exiting: {}", engine.report());
                    Ok(())
                };
                if let Err(e) = run() {
                    crate::log_error!("engine thread failed: {e:#}");
                }
            })
            .map_err(|e| anyhow!("spawning engine thread: {e}"))?;
        Ok(Self {
            dataset: dataset_name,
            health,
            tx,
            thread: Some(thread),
        })
    }

    /// Submit a request (non-blocking on the engine; blocks only if the
    /// queue is full — backpressure).
    pub fn submit(&self, req: ClassifyRequest) -> Result<()> {
        self.tx
            .send(req)
            .map_err(|_| anyhow!("engine '{}' is shut down", self.dataset))
    }

    /// Convenience: classify one image synchronously.
    pub fn classify_blocking(&self, image: Vec<f32>) -> Result<ClassifyResult> {
        let (req, rx) = ClassifyRequest::new(image);
        self.submit(req)?;
        rx.recv().ok_or_else(|| anyhow!("engine dropped reply"))?
    }

    /// Shut the engine down and join its thread.
    pub fn shutdown(mut self) {
        self.tx.close();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for EngineHandle {
    fn drop(&mut self) {
        self.tx.close();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(pixel: f32, budget: RequestBudget) -> ClassifyRequest {
        ClassifyRequest::with_budget(vec![pixel], budget).0
    }

    #[test]
    fn grouping_preserves_order_and_separates_budgets() {
        let small = RequestBudget {
            max_samples: Some(3),
            target_confidence: None,
        };
        let conf = RequestBudget {
            max_samples: None,
            target_confidence: Some(0.9),
        };
        let batch = vec![
            req(0.0, RequestBudget::default()),
            req(1.0, small),
            req(2.0, RequestBudget::default()),
            req(3.0, conf),
            req(4.0, small),
        ];
        let groups = group_by_budget(batch);
        assert_eq!(groups.len(), 3);
        assert_eq!(groups[0].0, RequestBudget::default());
        assert_eq!(
            groups[0].1.iter().map(|r| r.image[0]).collect::<Vec<_>>(),
            vec![0.0, 2.0]
        );
        assert_eq!(groups[1].0, small);
        assert_eq!(
            groups[1].1.iter().map(|r| r.image[0]).collect::<Vec<_>>(),
            vec![1.0, 4.0]
        );
        assert_eq!(groups[2].0, conf);
        assert_eq!(groups[2].1.len(), 1);
    }

    #[test]
    fn uniform_batch_stays_one_group() {
        let batch: Vec<ClassifyRequest> =
            (0..5).map(|i| req(i as f32, RequestBudget::default())).collect();
        let groups = group_by_budget(batch);
        assert_eq!(groups.len(), 1);
        assert_eq!(groups[0].1.len(), 5);
    }
}
