//! Engine service: confines the non-`Send` engine to a dedicated thread and
//! exposes a channel-based request API.

use std::path::Path;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, Result};

use super::batcher::DynamicBatcher;
use super::engine::{ClassifyResult, Engine, EngineConfig};
use crate::entropy::health::Monitor;
use crate::exec::channel::{channel, Receiver, Sender};
use crate::log_info;
use crate::registry::{ModelSpec, ProgramRegistry, RegistryMetrics};
use crate::runtime::{ModelArtifacts, ParamStore};
use crate::sampler::RequestBudget;

/// One classification request: an image, the model it targets (`None` =
/// the engine's default), its per-request sample budget, and a one-shot
/// reply channel.
pub struct ClassifyRequest {
    pub image: Vec<f32>,
    pub model: Option<String>,
    pub budget: RequestBudget,
    pub reply: Sender<Result<ClassifyResult>>,
}

impl ClassifyRequest {
    /// Build a request + the receiver for its reply.
    pub fn new(image: Vec<f32>) -> (Self, Receiver<Result<ClassifyResult>>) {
        Self::with_budget(image, RequestBudget::default())
    }

    /// Build a request carrying budget overrides (`max_samples` /
    /// `target_confidence` protocol fields).
    pub fn with_budget(
        image: Vec<f32>,
        budget: RequestBudget,
    ) -> (Self, Receiver<Result<ClassifyResult>>) {
        Self::with_model(None, image, budget)
    }

    /// Build a request targeting a named model (the wire protocol's
    /// `model` field; `None` = the engine's default model).
    pub fn with_model(
        model: Option<String>,
        image: Vec<f32>,
        budget: RequestBudget,
    ) -> (Self, Receiver<Result<ClassifyResult>>) {
        let (tx, rx) = channel(1);
        (
            Self {
                image,
                model,
                budget,
                reply: tx,
            },
            rx,
        )
    }
}

/// What makes two requests batchable into one engine plan: same target
/// model (a program switch between them would thrash the machine) and same
/// sample budget (budgets are variable-cost — a 3-sample request batched
/// with a 20-sample one would either overspend or starve).
#[derive(Debug, Clone, PartialEq)]
pub struct GroupKey {
    /// `None` groups with `None`: default-model requests coalesce with
    /// each other, not with requests naming the default explicitly (the
    /// engine resolves both to the same program, so the only cost of the
    /// distinction is one extra no-op switch check).
    pub model: Option<String>,
    pub budget: RequestBudget,
}

/// Partition one dynamic batch into same-(model, budget) groups, preserving
/// arrival order within each group (and of first appearance across groups).
/// Same-model requests coalesce so program switches amortize across the
/// group instead of hitting every request.  Distinct keys on a batch are
/// few in practice, so a linear scan wins over hashing.
fn group_requests(batch: Vec<ClassifyRequest>) -> Vec<(GroupKey, Vec<ClassifyRequest>)> {
    let mut groups: Vec<(GroupKey, Vec<ClassifyRequest>)> = Vec::new();
    for req in batch {
        match groups
            .iter_mut()
            .find(|(k, _)| k.model == req.model && k.budget == req.budget)
        {
            Some((_, members)) => members.push(req),
            None => {
                let key = GroupKey {
                    model: req.model.clone(),
                    budget: req.budget,
                };
                groups.push((key, vec![req]));
            }
        }
    }
    groups
}

/// Handle to a running engine thread.
pub struct EngineHandle {
    /// Primary serving name (the dataset of a single-model engine; the
    /// default model of a multi-model engine).
    pub dataset: String,
    /// Every model this engine serves (`[dataset]` on single-model
    /// engines; registry order on multi-model engines, default first).
    pub models: Vec<String>,
    /// Entropy-health monitor shared with the engine (present when
    /// `EngineConfig::health.enabled`): `/info` reads scorecards from here
    /// without a round-trip through the engine thread.
    pub health: Option<Arc<Monitor>>,
    /// Registry residency/hit/miss counters shared with a multi-model
    /// engine's backend cache; `/info` reads them from here.
    pub registry: Option<Arc<RegistryMetrics>>,
    tx: Sender<ClassifyRequest>,
    thread: Option<JoinHandle<()>>,
}

/// Batching knobs for the service loop.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    pub queue_depth: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            queue_depth: 256,
        }
    }
}

impl EngineHandle {
    /// Spawn an engine thread for `dataset` under `artifacts_root`, loading
    /// parameters from `params_path` (or `params_init.bin` if `None`).
    pub fn spawn(
        artifacts_root: &Path,
        dataset: &str,
        params_path: Option<&Path>,
        engine_cfg: EngineConfig,
        svc_cfg: ServiceConfig,
    ) -> Result<Self> {
        // the engine is built inside its thread, so create the monitor here
        // and hand it in: the serving layer keeps the other reference for
        // lock-free-on-the-engine /info scorecard reads
        let mut engine_cfg = engine_cfg;
        if engine_cfg.health.enabled && engine_cfg.health_monitor.is_none() {
            engine_cfg.health_monitor = Some(Arc::new(Monitor::new(engine_cfg.health)));
        }
        let health = engine_cfg.health_monitor.clone();
        let (tx, rx) = channel::<ClassifyRequest>(svc_cfg.queue_depth);
        let dir = artifacts_root.join(dataset);
        let params_path = params_path.map(|p| p.to_path_buf());
        let dataset_name = dataset.to_string();
        let dataset_name2 = dataset_name.clone();
        let thread = std::thread::Builder::new()
            .name(format!("pbm-engine-{dataset}"))
            .spawn(move || {
                // all PJRT + machine state is created on this thread
                let run = || -> Result<()> {
                    let arts = ModelArtifacts::load(&dir)?;
                    let params = match &params_path {
                        Some(p) => ParamStore::load_bin(&arts.meta, p)?,
                        None => ParamStore::load_init(&arts.meta, &dir)?,
                    };
                    let mut engine = Engine::new(arts, params, engine_cfg)?;
                    let image_size = engine.image_size();
                    let name = dataset_name2;
                    let batcher = DynamicBatcher::new(rx, svc_cfg.max_batch, svc_cfg.max_wait);
                    while let Some(batch) = batcher.next_batch() {
                        // same-(model, budget) requests share one batched
                        // plan; mixed keys split into sub-batches
                        for (key, group) in group_requests(batch) {
                            // single-model engine: a request naming any
                            // other model is a routing error, not a switch
                            if key.model.as_deref().is_some_and(|m| m != name) {
                                let m = key.model.as_deref().unwrap_or("");
                                for req in group {
                                    let _ = req.reply.send(Err(anyhow!(
                                        "unknown model '{m}' (this engine serves '{name}')"
                                    )));
                                }
                                continue;
                            }
                            let mut images = Vec::with_capacity(group.len() * image_size);
                            let mut ok = Vec::with_capacity(group.len());
                            for req in group {
                                if req.image.len() == image_size {
                                    images.extend_from_slice(&req.image);
                                    ok.push(req.reply);
                                } else {
                                    let _ = req.reply.send(Err(anyhow!(
                                        "image size {} != expected {}",
                                        req.image.len(),
                                        image_size
                                    )));
                                }
                            }
                            if ok.is_empty() {
                                continue;
                            }
                            match engine.classify_with_budget(&images, ok.len(), &key.budget) {
                                Ok(results) => {
                                    for (reply, res) in ok.into_iter().zip(results) {
                                        let _ = reply.send(Ok(res));
                                    }
                                }
                                Err(e) => {
                                    for reply in ok {
                                        let _ = reply.send(Err(anyhow!("engine error: {e}")));
                                    }
                                }
                            }
                        }
                    }
                    log_info!("engine thread exiting: {}", engine.report());
                    Ok(())
                };
                if let Err(e) = run() {
                    crate::log_error!("engine thread failed: {e:#}");
                }
            })
            .map_err(|e| anyhow!("spawning engine thread: {e}"))?;
        Ok(Self {
            models: vec![dataset_name.clone()],
            dataset: dataset_name,
            health,
            registry: None,
            tx,
            thread: Some(thread),
        })
    }

    /// Spawn one engine thread serving every model in `specs` through a
    /// shared [`ProgramRegistry`]: the first spec is the default model,
    /// requests name others via [`ClassifyRequest::model`], and the
    /// batcher's [`GroupKey`] coalesces same-model traffic so program
    /// switches amortize across whole groups.
    pub fn spawn_multi(
        artifacts_root: &Path,
        specs: Vec<ModelSpec>,
        engine_cfg: EngineConfig,
        svc_cfg: ServiceConfig,
    ) -> Result<Self> {
        if specs.is_empty() {
            anyhow::bail!("spawn_multi needs at least one model spec");
        }
        let mut engine_cfg = engine_cfg;
        if engine_cfg.health.enabled && engine_cfg.health_monitor.is_none() {
            engine_cfg.health_monitor = Some(Arc::new(Monitor::new(engine_cfg.health)));
        }
        let health = engine_cfg.health_monitor.clone();
        // the registry metrics live outside the engine thread so /info can
        // read residency without a round-trip
        if engine_cfg.registry_metrics.is_none() {
            engine_cfg.registry_metrics = Some(Arc::new(RegistryMetrics::default()));
        }
        let registry = engine_cfg.registry_metrics.clone();
        let model_names: Vec<String> = specs.iter().map(|s| s.name.clone()).collect();
        let default_name = model_names[0].clone();
        let (tx, rx) = channel::<ClassifyRequest>(svc_cfg.queue_depth);
        let root = artifacts_root.to_path_buf();
        let thread_default = default_name.clone();
        let thread = std::thread::Builder::new()
            .name(format!("pbm-engine-{thread_default}"))
            .spawn(move || {
                // all PJRT + machine state is created on this thread
                let run = || -> Result<()> {
                    let reg = ProgramRegistry::load(&root, &specs)?;
                    let mut engine = Engine::with_registry(reg, engine_cfg)?;
                    let batcher = DynamicBatcher::new(rx, svc_cfg.max_batch, svc_cfg.max_wait);
                    while let Some(batch) = batcher.next_batch() {
                        for (key, group) in group_requests(batch) {
                            let name = key.model.as_deref().unwrap_or(&thread_default);
                            // image size is per-model: validate against the
                            // target model, not whichever is active
                            let Some(image_size) = engine.image_size_of(name) else {
                                let err = crate::registry::UnknownModel {
                                    model: name.to_string(),
                                    known: engine.model_names(),
                                };
                                for req in group {
                                    let _ =
                                        req.reply.send(Err(anyhow::Error::new(err.clone())));
                                }
                                continue;
                            };
                            let mut images = Vec::with_capacity(group.len() * image_size);
                            let mut ok = Vec::with_capacity(group.len());
                            for req in group {
                                if req.image.len() == image_size {
                                    images.extend_from_slice(&req.image);
                                    ok.push(req.reply);
                                } else {
                                    let _ = req.reply.send(Err(anyhow!(
                                        "image size {} != expected {}",
                                        req.image.len(),
                                        image_size
                                    )));
                                }
                            }
                            if ok.is_empty() {
                                continue;
                            }
                            match engine.classify_model(Some(name), &images, ok.len(), &key.budget)
                            {
                                Ok(results) => {
                                    for (reply, res) in ok.into_iter().zip(results) {
                                        let _ = reply.send(Ok(res));
                                    }
                                }
                                Err(e) => {
                                    for reply in ok {
                                        let _ = reply.send(Err(anyhow!("engine error: {e}")));
                                    }
                                }
                            }
                        }
                    }
                    log_info!("engine thread exiting: {}", engine.report());
                    Ok(())
                };
                if let Err(e) = run() {
                    crate::log_error!("engine thread failed: {e:#}");
                }
            })
            .map_err(|e| anyhow!("spawning engine thread: {e}"))?;
        Ok(Self {
            dataset: default_name,
            models: model_names,
            health,
            registry,
            tx,
            thread: Some(thread),
        })
    }

    /// Submit a request (non-blocking on the engine; blocks only if the
    /// queue is full — backpressure).
    pub fn submit(&self, req: ClassifyRequest) -> Result<()> {
        self.tx
            .send(req)
            .map_err(|_| anyhow!("engine '{}' is shut down", self.dataset))
    }

    /// Convenience: classify one image synchronously.
    pub fn classify_blocking(&self, image: Vec<f32>) -> Result<ClassifyResult> {
        let (req, rx) = ClassifyRequest::new(image);
        self.submit(req)?;
        rx.recv().ok_or_else(|| anyhow!("engine dropped reply"))?
    }

    /// Shut the engine down and join its thread.
    pub fn shutdown(mut self) {
        self.tx.close();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for EngineHandle {
    fn drop(&mut self) {
        self.tx.close();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(pixel: f32, budget: RequestBudget) -> ClassifyRequest {
        ClassifyRequest::with_budget(vec![pixel], budget).0
    }

    fn req_for(model: &str, pixel: f32) -> ClassifyRequest {
        ClassifyRequest::with_model(
            Some(model.to_string()),
            vec![pixel],
            RequestBudget::default(),
        )
        .0
    }

    #[test]
    fn grouping_preserves_order_and_separates_budgets() {
        let small = RequestBudget {
            max_samples: Some(3),
            target_confidence: None,
        };
        let conf = RequestBudget {
            max_samples: None,
            target_confidence: Some(0.9),
        };
        let batch = vec![
            req(0.0, RequestBudget::default()),
            req(1.0, small),
            req(2.0, RequestBudget::default()),
            req(3.0, conf),
            req(4.0, small),
        ];
        let groups = group_requests(batch);
        assert_eq!(groups.len(), 3);
        assert_eq!(groups[0].0.budget, RequestBudget::default());
        assert_eq!(
            groups[0].1.iter().map(|r| r.image[0]).collect::<Vec<_>>(),
            vec![0.0, 2.0]
        );
        assert_eq!(groups[1].0.budget, small);
        assert_eq!(
            groups[1].1.iter().map(|r| r.image[0]).collect::<Vec<_>>(),
            vec![1.0, 4.0]
        );
        assert_eq!(groups[2].0.budget, conf);
        assert_eq!(groups[2].1.len(), 1);
    }

    #[test]
    fn uniform_batch_stays_one_group() {
        let batch: Vec<ClassifyRequest> =
            (0..5).map(|i| req(i as f32, RequestBudget::default())).collect();
        let groups = group_requests(batch);
        assert_eq!(groups.len(), 1);
        assert!(groups[0].0.model.is_none());
        assert_eq!(groups[0].1.len(), 5);
    }

    #[test]
    fn grouping_coalesces_same_model_and_keeps_arrival_order() {
        // interleaved a/b/default traffic: one group per model, arrival
        // order preserved within each and by first appearance across
        let batch = vec![
            req_for("a", 0.0),
            req_for("b", 1.0),
            req(2.0, RequestBudget::default()),
            req_for("a", 3.0),
            req_for("b", 4.0),
            req_for("a", 5.0),
        ];
        let groups = group_requests(batch);
        assert_eq!(groups.len(), 3);
        assert_eq!(groups[0].0.model.as_deref(), Some("a"));
        assert_eq!(
            groups[0].1.iter().map(|r| r.image[0]).collect::<Vec<_>>(),
            vec![0.0, 3.0, 5.0]
        );
        assert_eq!(groups[1].0.model.as_deref(), Some("b"));
        assert_eq!(
            groups[1].1.iter().map(|r| r.image[0]).collect::<Vec<_>>(),
            vec![1.0, 4.0]
        );
        assert!(groups[2].0.model.is_none());
        assert_eq!(groups[2].1.len(), 1);
    }

    #[test]
    fn same_model_different_budget_splits() {
        let small = RequestBudget {
            max_samples: Some(3),
            target_confidence: None,
        };
        let batch = vec![
            req_for("a", 0.0),
            ClassifyRequest::with_model(Some("a".into()), vec![1.0], small).0,
        ];
        let groups = group_requests(batch);
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[0].0.model.as_deref(), Some("a"));
        assert_eq!(groups[1].0.model.as_deref(), Some("a"));
        assert_ne!(groups[0].0.budget, groups[1].0.budget);
    }
}
