//! Engine service: confines the non-`Send` engine to a dedicated thread and
//! exposes a channel-based request API.

use std::path::Path;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, Result};

use super::batcher::DynamicBatcher;
use super::engine::{ClassifyResult, Engine, EngineConfig};
use crate::exec::channel::{channel, Receiver, Sender};
use crate::log_info;
use crate::runtime::{ModelArtifacts, ParamStore};

/// One classification request: an image plus a one-shot reply channel.
pub struct ClassifyRequest {
    pub image: Vec<f32>,
    pub reply: Sender<Result<ClassifyResult>>,
}

impl ClassifyRequest {
    /// Build a request + the receiver for its reply.
    pub fn new(image: Vec<f32>) -> (Self, Receiver<Result<ClassifyResult>>) {
        let (tx, rx) = channel(1);
        (Self { image, reply: tx }, rx)
    }
}

/// Handle to a running engine thread.
pub struct EngineHandle {
    pub dataset: String,
    tx: Sender<ClassifyRequest>,
    thread: Option<JoinHandle<()>>,
}

/// Batching knobs for the service loop.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    pub queue_depth: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            queue_depth: 256,
        }
    }
}

impl EngineHandle {
    /// Spawn an engine thread for `dataset` under `artifacts_root`, loading
    /// parameters from `params_path` (or `params_init.bin` if `None`).
    pub fn spawn(
        artifacts_root: &Path,
        dataset: &str,
        params_path: Option<&Path>,
        engine_cfg: EngineConfig,
        svc_cfg: ServiceConfig,
    ) -> Result<Self> {
        let (tx, rx) = channel::<ClassifyRequest>(svc_cfg.queue_depth);
        let dir = artifacts_root.join(dataset);
        let params_path = params_path.map(|p| p.to_path_buf());
        let dataset_name = dataset.to_string();
        let thread = std::thread::Builder::new()
            .name(format!("pbm-engine-{dataset}"))
            .spawn(move || {
                // all PJRT + machine state is created on this thread
                let run = || -> Result<()> {
                    let arts = ModelArtifacts::load(&dir)?;
                    let params = match &params_path {
                        Some(p) => ParamStore::load_bin(&arts.meta, p)?,
                        None => ParamStore::load_init(&arts.meta, &dir)?,
                    };
                    let mut engine = Engine::new(arts, params, engine_cfg)?;
                    let image_size = engine.image_size();
                    let batcher = DynamicBatcher::new(rx, svc_cfg.max_batch, svc_cfg.max_wait);
                    while let Some(batch) = batcher.next_batch() {
                        let mut images = Vec::with_capacity(batch.len() * image_size);
                        let mut ok = Vec::with_capacity(batch.len());
                        for req in batch {
                            if req.image.len() == image_size {
                                images.extend_from_slice(&req.image);
                                ok.push(req.reply);
                            } else {
                                let _ = req.reply.send(Err(anyhow!(
                                    "image size {} != expected {}",
                                    req.image.len(),
                                    image_size
                                )));
                            }
                        }
                        if ok.is_empty() {
                            continue;
                        }
                        match engine.classify(&images, ok.len()) {
                            Ok(results) => {
                                for (reply, res) in ok.into_iter().zip(results) {
                                    let _ = reply.send(Ok(res));
                                }
                            }
                            Err(e) => {
                                for reply in ok {
                                    let _ = reply.send(Err(anyhow!("engine error: {e}")));
                                }
                            }
                        }
                    }
                    log_info!("engine thread exiting: {}", engine.report());
                    Ok(())
                };
                if let Err(e) = run() {
                    crate::log_error!("engine thread failed: {e:#}");
                }
            })
            .map_err(|e| anyhow!("spawning engine thread: {e}"))?;
        Ok(Self {
            dataset: dataset_name,
            tx,
            thread: Some(thread),
        })
    }

    /// Submit a request (non-blocking on the engine; blocks only if the
    /// queue is full — backpressure).
    pub fn submit(&self, req: ClassifyRequest) -> Result<()> {
        self.tx
            .send(req)
            .map_err(|_| anyhow!("engine '{}' is shut down", self.dataset))
    }

    /// Convenience: classify one image synchronously.
    pub fn classify_blocking(&self, image: Vec<f32>) -> Result<ClassifyResult> {
        let (req, rx) = ClassifyRequest::new(image);
        self.submit(req)?;
        rx.recv().ok_or_else(|| anyhow!("engine dropped reply"))?
    }

    /// Shut the engine down and join its thread.
    pub fn shutdown(mut self) {
        self.tx.close();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for EngineHandle {
    fn drop(&mut self) {
        self.tx.close();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}
