//! Request router: dispatch by model/dataset name to the owning engine.

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use super::service::{ClassifyRequest, EngineHandle};
use crate::entropy::health::Scorecard;

/// Routes requests to per-dataset engines.
#[derive(Default)]
pub struct Router {
    engines: HashMap<String, EngineHandle>,
}

impl Router {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn register(&mut self, handle: EngineHandle) {
        self.engines.insert(handle.dataset.clone(), handle);
    }

    pub fn datasets(&self) -> Vec<&str> {
        self.engines.keys().map(String::as_str).collect()
    }

    pub fn get(&self, dataset: &str) -> Result<&EngineHandle> {
        self.engines
            .get(dataset)
            .ok_or_else(|| anyhow!("unknown dataset '{dataset}' (have: {:?})", self.datasets()))
    }

    /// Route one request.
    pub fn route(&self, dataset: &str, req: ClassifyRequest) -> Result<()> {
        self.get(dataset)?.submit(req)
    }

    /// Per-dataset entropy-health scorecards (datasets sorted by name;
    /// engines without a monitor are omitted).  Reads the shared monitors
    /// directly — no round-trip through any engine thread.
    pub fn health_snapshot(&self) -> Vec<(String, Vec<Scorecard>)> {
        let mut snap: Vec<(String, Vec<Scorecard>)> = self
            .engines
            .iter()
            .filter_map(|(name, h)| h.health.as_ref().map(|m| (name.clone(), m.scorecards())))
            .collect();
        snap.sort_by(|a, b| a.0.cmp(&b.0));
        snap
    }

    /// Shut down every engine.
    pub fn shutdown(self) {
        for (_, h) in self.engines {
            h.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_dataset_is_error() {
        let r = Router::new();
        let (req, _rx) = ClassifyRequest::new(vec![0.0; 4]);
        assert!(r.route("nope", req).is_err());
    }
}
