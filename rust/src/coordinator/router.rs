//! Request router: dispatch by model name to the owning engine.
//!
//! One [`EngineHandle`] may serve several models (a multi-model engine built
//! from a [`crate::registry::ProgramRegistry`]); the router maps every model
//! name an engine advertises back to that handle, so routing stays a flat
//! name → engine lookup whether the deployment is one engine per model or
//! one engine virtualizing all of them.

use std::collections::HashMap;

use anyhow::Result;

use super::metrics::{LatencyBuckets, ServeSnapshot};
use super::service::{ClassifyRequest, EngineHandle};
use crate::entropy::health::Scorecard;
use crate::observe::{Exemplar, Span, TraceStats, UncertaintySnapshot};
use crate::registry::{RegistrySnapshot, UnknownModel};

/// Routes requests to the engine serving each model.
pub struct Router {
    engines: Vec<EngineHandle>,
    /// model name → index into `engines`; every name in
    /// [`EngineHandle::models`] is a key.
    by_model: HashMap<String, usize>,
    /// Role announced in the `hello` handshake (`"server"`, `"worker"`,
    /// `"coordinator"`): a cluster coordinator probing its pool checks the
    /// peer really is a worker before routing shards at it.
    role: String,
}

impl Default for Router {
    fn default() -> Self {
        Self {
            engines: Vec::new(),
            by_model: HashMap::new(),
            role: "server".to_string(),
        }
    }
}

impl Router {
    pub fn new() -> Self {
        Self::default()
    }

    /// Set the role announced in the `hello` handshake.
    pub fn set_role(&mut self, role: &str) {
        self.role = role.to_string();
    }

    pub fn role(&self) -> &str {
        &self.role
    }

    pub fn register(&mut self, handle: EngineHandle) {
        let idx = self.engines.len();
        for name in &handle.models {
            self.by_model.insert(name.clone(), idx);
        }
        self.engines.push(handle);
    }

    /// Every servable model name, sorted (stable for `/info`).
    pub fn datasets(&self) -> Vec<&str> {
        let mut names: Vec<&str> = self.by_model.keys().map(String::as_str).collect();
        names.sort_unstable();
        names
    }

    pub fn get(&self, model: &str) -> Result<&EngineHandle> {
        self.by_model
            .get(model)
            .map(|&i| &self.engines[i])
            .ok_or_else(|| {
                anyhow::Error::new(UnknownModel {
                    model: model.to_string(),
                    known: self.datasets().iter().map(|s| s.to_string()).collect(),
                })
            })
    }

    /// Route one request.
    pub fn route(&self, model: &str, req: ClassifyRequest) -> Result<()> {
        self.get(model)?.submit(req)
    }

    /// Per-dataset entropy-health scorecards (datasets sorted by name;
    /// engines without a monitor are omitted).  Reads the shared monitors
    /// directly — no round-trip through any engine thread.
    pub fn health_snapshot(&self) -> Vec<(String, Vec<Scorecard>)> {
        let mut snap: Vec<(String, Vec<Scorecard>)> = self
            .engines
            .iter()
            .filter_map(|h| h.health.as_ref().map(|m| (h.dataset.clone(), m.scorecards())))
            .collect();
        snap.sort_by(|a, b| a.0.cmp(&b.0));
        snap
    }

    /// Per-engine model-registry snapshots (bank residency, hit/miss/switch
    /// counters), keyed by the engine's primary name and sorted.  Engines
    /// without a registry (single-model) are omitted.  Reads the shared
    /// [`crate::registry::RegistryMetrics`] directly — no round-trip
    /// through any engine thread.
    pub fn registry_snapshot(&self) -> Vec<(String, RegistrySnapshot)> {
        let mut snap: Vec<(String, RegistrySnapshot)> = self
            .engines
            .iter()
            .filter_map(|h| h.registry.as_ref().map(|r| (h.dataset.clone(), r.snapshot())))
            .collect();
        snap.sort_by(|a, b| a.0.cmp(&b.0));
        snap
    }

    /// Per-engine serving/robustness counters (shed, deadline-expired,
    /// overload rejects, recovered panics, live queue depth), keyed by the
    /// engine's primary name and sorted.  Reads the shared
    /// [`super::metrics::ServeCounters`] directly — no round-trip through
    /// any engine thread.
    pub fn serving_snapshot(&self) -> Vec<(String, ServeSnapshot)> {
        let mut snap: Vec<(String, ServeSnapshot)> = self
            .engines
            .iter()
            .map(|h| (h.dataset.clone(), h.serve_snapshot()))
            .collect();
        snap.sort_by(|a, b| a.0.cmp(&b.0));
        snap
    }

    /// Per-engine cluster worker cards (coordinator engines only; plain
    /// engines have no pool and are omitted), keyed by the engine's primary
    /// name and sorted.  Reads the shared [`crate::cluster::WorkerPool`]
    /// directly — no round-trip through any engine thread.
    pub fn cluster_snapshot(&self) -> Vec<(String, Vec<crate::cluster::WorkerCard>)> {
        let mut snap: Vec<(String, Vec<crate::cluster::WorkerCard>)> = self
            .engines
            .iter()
            .filter_map(|h| h.cluster.as_ref().map(|p| (h.dataset.clone(), p.cards())))
            .collect();
        snap.sort_by(|a, b| a.0.cmp(&b.0));
        snap
    }

    /// Per-engine raw service-latency histogram buckets (for the
    /// `/metrics` exposition — `/info` reports only percentiles), keyed
    /// by the engine's primary name and sorted.
    pub fn serving_latency(&self) -> Vec<(String, LatencyBuckets)> {
        let mut snap: Vec<(String, LatencyBuckets)> = self
            .engines
            .iter()
            .map(|h| (h.dataset.clone(), h.counters.latency.raw()))
            .collect();
        snap.sort_by(|a, b| a.0.cmp(&b.0));
        snap
    }

    /// Per-engine trace-recorder counters (enabled flag, ring capacity,
    /// spans recorded/dropped, retained exemplars), keyed by the engine's
    /// primary name and sorted.
    pub fn trace_stats(&self) -> Vec<(String, TraceStats)> {
        let mut snap: Vec<(String, TraceStats)> = self
            .engines
            .iter()
            .map(|h| (h.dataset.clone(), h.recorder.stats()))
            .collect();
        snap.sort_by(|a, b| a.0.cmp(&b.0));
        snap
    }

    /// Every span recorded for `request_id`, merged across engines (a
    /// cluster coordinator records gateway + dispatch spans while its
    /// local-fallback engine may record execution spans for the same id)
    /// and sorted by start time.  Empty when the id was never traced or
    /// its ring slots have been overwritten without an exemplar.
    pub fn trace_spans(&self, request_id: u64) -> Vec<Span> {
        let mut spans: Vec<Span> = self
            .engines
            .iter()
            .flat_map(|h| h.recorder.spans_for(request_id))
            .collect();
        spans.sort_by_key(|s| (s.start_us, s.start_us + s.dur_us));
        spans
    }

    /// Retained slow-request exemplars per engine, keyed by the engine's
    /// primary name and sorted (engines with none are omitted).
    pub fn trace_exemplars(&self) -> Vec<(String, Vec<Exemplar>)> {
        let mut snap: Vec<(String, Vec<Exemplar>)> = self
            .engines
            .iter()
            .filter_map(|h| {
                let ex = h.recorder.exemplars();
                (!ex.is_empty()).then(|| (h.dataset.clone(), ex))
            })
            .collect();
        snap.sort_by(|a, b| a.0.cmp(&b.0));
        snap
    }

    /// Per-engine, per-model uncertainty telemetry (predictive-entropy /
    /// mutual-information / samples-used histograms), keyed by the
    /// engine's primary name and sorted.
    pub fn uncertainty_snapshot(&self) -> Vec<(String, Vec<(String, UncertaintySnapshot)>)> {
        let mut snap: Vec<(String, Vec<(String, UncertaintySnapshot)>)> = self
            .engines
            .iter()
            .map(|h| (h.dataset.clone(), h.uncertainty.snapshot()))
            .collect();
        snap.sort_by(|a, b| a.0.cmp(&b.0));
        snap
    }

    /// Shut down every engine.
    pub fn shutdown(self) {
        for h in self.engines {
            h.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_model_is_typed_error() {
        let r = Router::new();
        let (req, _rx) = ClassifyRequest::new(vec![0.0; 4]);
        let err = r.route("nope", req).unwrap_err();
        let um = err.downcast_ref::<UnknownModel>().expect("typed UnknownModel");
        assert_eq!(um.model, "nope");
        assert!(um.known.is_empty());
    }

    #[test]
    fn empty_router_has_no_models_or_snapshots() {
        let r = Router::new();
        assert!(r.datasets().is_empty());
        assert!(r.health_snapshot().is_empty());
        assert!(r.registry_snapshot().is_empty());
        assert!(r.serving_snapshot().is_empty());
        assert!(r.cluster_snapshot().is_empty());
        assert!(r.serving_latency().is_empty());
        assert!(r.trace_stats().is_empty());
        assert!(r.trace_spans(1).is_empty());
        assert!(r.trace_exemplars().is_empty());
        assert!(r.uncertainty_snapshot().is_empty());
    }

    #[test]
    fn role_defaults_to_server_and_is_settable() {
        let mut r = Router::new();
        assert_eq!(r.role(), "server");
        r.set_role("worker");
        assert_eq!(r.role(), "worker");
    }
}
