//! Overload control for the serving queue: typed request-lifecycle
//! errors, cost-aware admission, and tiered graceful degradation.
//!
//! Admission charges each request an *estimated work* cost (its sample
//! budget, capped at the engine's configured `n_samples`) against a
//! bounded work budget.  A request that would overflow the budget — or
//! the bounded queue itself — is rejected immediately with a typed
//! [`ServeError::Overloaded`] carrying a drain-time `retry_after_ms`
//! hint, instead of blocking the gateway worker (shed, don't
//! backpressure).
//!
//! A pressure EWMA (queued work / capacity, updated at admit and
//! dequeue) drives three degradation tiers:
//!
//! | tier | pressure | behavior |
//! |------|----------|----------|
//! | `Normal` | low | full budgets |
//! | `Clamped` | ≥ `clamp_pressure` | request sample budgets clamped; responses flagged `degraded` |
//! | `Brownout` | ≥ `brownout_pressure` (opt-in) | mean-field backend, 1 deterministic pass; `degraded` |
//!
//! All state is atomics — the submit side (many gateway workers) and
//! the engine thread share one [`OverloadControl`] without locks.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::sampler::RequestBudget;

/// Typed request-lifecycle error.  Carried through `anyhow` from the
/// engine/service layer to the gateway, which maps it onto coded wire
/// errors (`code:"deadline_exceeded"` etc.).  `Clone` so one engine
/// error can fan out to every reply channel of a batch.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The request's deadline passed before (or while) serving it;
    /// `samples_used` is the stochastic work spent before giving up
    /// (0 when shed at dequeue without touching the engine).
    DeadlineExceeded { samples_used: usize },
    /// Admission control rejected the request; retry after the hinted
    /// backoff (estimated queue drain time).
    Overloaded { retry_after_ms: u64 },
    /// A panic was isolated while serving this batch; the engine
    /// rebuilt itself and the request is safe to retry.
    Internal { detail: String },
    /// Cluster mode: no routable backend worker was available (and the
    /// coordinator's local fallback is disabled).  `down` is the number
    /// of pool workers currently drained from routing, so clients can
    /// distinguish a collapsed pool from a misconfigured empty one.
    WorkerUnavailable { down: usize },
}

impl ServeError {
    /// Stable wire error code (the protocol's `code` field).
    pub fn code(&self) -> &'static str {
        match self {
            ServeError::DeadlineExceeded { .. } => "deadline_exceeded",
            ServeError::Overloaded { .. } => "overloaded",
            ServeError::Internal { .. } => "internal_error",
            ServeError::WorkerUnavailable { .. } => "worker_unavailable",
        }
    }
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::DeadlineExceeded { samples_used } => write!(
                f,
                "deadline exceeded after {samples_used} samples"
            ),
            ServeError::Overloaded { retry_after_ms } => write!(
                f,
                "server overloaded; retry after {retry_after_ms} ms"
            ),
            ServeError::Internal { detail } => {
                write!(f, "internal error: {detail}")
            }
            ServeError::WorkerUnavailable { down } => {
                write!(f, "no routable cluster worker ({down} drained)")
            }
        }
    }
}

impl std::error::Error for ServeError {}

/// Degradation tier derived from the pressure EWMA (ordered: each tier
/// includes the measures of the ones below it).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Tier {
    Normal,
    /// Clamp per-request sample budgets.
    Clamped,
    /// Additionally swap in the mean-field backend (opt-in).
    Brownout,
}

/// Admission-control and degradation knobs ([`ServiceConfig`] embeds
/// one; `[overload]` in a serving config file).
///
/// [`ServiceConfig`]: super::ServiceConfig
#[derive(Debug, Clone)]
pub struct OverloadConfig {
    /// Admission ceiling on total queued estimated work (samples).
    /// 0 = auto: `queue_depth × default_cost`.
    pub work_capacity: u64,
    /// Estimated samples for a request without an explicit
    /// `max_samples`, and the per-request cost cap.  0 = resolved from
    /// the engine's `n_samples` at spawn.
    pub default_cost: u64,
    /// Pressure EWMA at or above which budgets are clamped.
    pub clamp_pressure: f64,
    /// Clamped per-request sample budget.  0 = `default_cost / 2`.
    pub clamp_samples: usize,
    /// Pressure EWMA at or above which serving browns out to the
    /// mean-field backend (only when `brownout` is set).
    pub brownout_pressure: f64,
    /// Opt-in for the brownout tier.
    pub brownout: bool,
    /// EWMA smoothing factor for the pressure estimate.
    pub alpha: f64,
}

impl Default for OverloadConfig {
    fn default() -> Self {
        Self {
            work_capacity: 0,
            default_cost: 0,
            clamp_pressure: 0.75,
            clamp_samples: 0,
            brownout_pressure: 0.92,
            brownout: false,
            alpha: 0.1,
        }
    }
}

/// Shared overload state: queued-work accounting, the pressure EWMA,
/// and a service-rate estimate for `retry_after_ms` hints.
#[derive(Debug)]
pub struct OverloadControl {
    cfg: OverloadConfig,
    capacity: u64,
    work_queued: AtomicU64,
    /// Pressure EWMA in milli-units (0..=1000).  Plain load/store — a
    /// lost race between two updates only smudges a gauge.
    pressure_milli: AtomicU64,
    /// EWMA of engine service time per unit work, nanoseconds.
    ns_per_sample: AtomicU64,
}

impl OverloadControl {
    /// Build from config; `queue_depth` sizes the auto work capacity.
    /// A zero `default_cost` falls back to 1 (callers resolve it from
    /// the engine's `n_samples` before constructing the control).
    pub fn new(mut cfg: OverloadConfig, queue_depth: usize) -> Self {
        cfg.default_cost = cfg.default_cost.max(1);
        let capacity = if cfg.work_capacity > 0 {
            cfg.work_capacity
        } else {
            (queue_depth.max(1) as u64).saturating_mul(cfg.default_cost)
        };
        Self {
            cfg,
            capacity,
            work_queued: AtomicU64::new(0),
            pressure_milli: AtomicU64::new(0),
            ns_per_sample: AtomicU64::new(0),
        }
    }

    /// Per-request work cost for a cost-aware admission decision: its
    /// sample budget, capped at the engine default (a request asking
    /// for more than the engine runs still costs one engine run).
    pub fn estimate_cost(&self, budget: &RequestBudget) -> u64 {
        budget
            .max_samples
            .map_or(self.cfg.default_cost, |m| m as u64)
            .min(self.cfg.default_cost)
            .max(1)
    }

    /// Engine default work per request (samples).
    pub fn default_cost(&self) -> u64 {
        self.cfg.default_cost
    }

    /// Charge `cost` against the work budget; a budget overflow is a
    /// typed overload rejection (the caller refunds with
    /// [`Self::on_dequeue`] if its enqueue fails afterwards).
    pub fn try_admit(&self, cost: u64) -> Result<(), ServeError> {
        let prev = self.work_queued.fetch_add(cost, Ordering::Relaxed);
        if prev.saturating_add(cost) > self.capacity {
            self.work_queued.fetch_sub(cost, Ordering::Relaxed);
            self.update_pressure(self.capacity);
            return Err(ServeError::Overloaded {
                retry_after_ms: self.retry_after_ms(),
            });
        }
        self.update_pressure(prev + cost);
        Ok(())
    }

    /// Return dequeued (or failed-to-enqueue) work to the budget.
    pub fn on_dequeue(&self, cost: u64) {
        let _ = self.work_queued.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |w| Some(w.saturating_sub(cost)),
        );
        self.update_pressure(self.work_queued.load(Ordering::Relaxed));
    }

    /// Record a finished batch so `retry_after_ms` tracks the actual
    /// service rate.
    pub fn on_work_done(&self, work: u64, elapsed: Duration) {
        if work == 0 {
            return;
        }
        let ns = (elapsed.as_nanos() as u64) / work;
        let prev = self.ns_per_sample.load(Ordering::Relaxed);
        let next = if prev == 0 {
            ns
        } else {
            // same EWMA shape as pressure, fixed-point in ns
            let a = self.cfg.alpha.clamp(0.01, 1.0);
            ((prev as f64) * (1.0 - a) + (ns as f64) * a) as u64
        };
        self.ns_per_sample.store(next.max(1), Ordering::Relaxed);
    }

    /// Suggested client backoff: estimated time to drain the queued
    /// work at the observed service rate, clamped to [1, 5000] ms.
    pub fn retry_after_ms(&self) -> u64 {
        let queued = self.work_queued.load(Ordering::Relaxed);
        let ns = self.ns_per_sample.load(Ordering::Relaxed);
        if ns == 0 {
            return 50; // no service-rate observation yet
        }
        (queued.saturating_mul(ns) / 1_000_000).clamp(1, 5000)
    }

    /// Smoothed utilization in [0, 1].
    pub fn pressure(&self) -> f64 {
        self.pressure_milli.load(Ordering::Relaxed) as f64 / 1000.0
    }

    /// Instantaneous queued work (samples).
    pub fn work_queued(&self) -> u64 {
        self.work_queued.load(Ordering::Relaxed)
    }

    /// Current degradation tier.  `Brownout` is only ever returned when
    /// the config opts in; otherwise sustained extreme pressure stays
    /// `Clamped`.
    pub fn tier(&self) -> Tier {
        let p = self.pressure();
        if self.cfg.brownout && p >= self.cfg.brownout_pressure {
            Tier::Brownout
        } else if p >= self.cfg.clamp_pressure {
            Tier::Clamped
        } else {
            Tier::Normal
        }
    }

    /// Per-request sample budget applied at the `Clamped` tier.
    pub fn clamp_samples(&self) -> usize {
        if self.cfg.clamp_samples > 0 {
            self.cfg.clamp_samples
        } else {
            ((self.cfg.default_cost / 2) as usize).max(1)
        }
    }

    fn update_pressure(&self, queued: u64) {
        let util = (queued as f64 / self.capacity as f64).clamp(0.0, 1.0);
        let a = self.cfg.alpha.clamp(0.01, 1.0);
        let prev = self.pressure_milli.load(Ordering::Relaxed) as f64;
        let next = prev * (1.0 - a) + util * 1000.0 * a;
        self.pressure_milli
            .store(next.round() as u64, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn budget(max: Option<usize>) -> RequestBudget {
        RequestBudget {
            max_samples: max,
            target_confidence: None,
        }
    }

    fn cfg(default_cost: u64) -> OverloadConfig {
        OverloadConfig {
            default_cost,
            ..OverloadConfig::default()
        }
    }

    #[test]
    fn cost_estimate_caps_at_engine_default() {
        let ctrl = OverloadControl::new(cfg(10), 4);
        assert_eq!(ctrl.estimate_cost(&budget(None)), 10);
        assert_eq!(ctrl.estimate_cost(&budget(Some(3))), 3);
        assert_eq!(ctrl.estimate_cost(&budget(Some(500))), 10);
        assert_eq!(ctrl.estimate_cost(&budget(Some(0))), 1);
    }

    #[test]
    fn admission_rejects_past_capacity_and_refunds() {
        // capacity = 4 × 10 = 40
        let ctrl = OverloadControl::new(cfg(10), 4);
        for _ in 0..4 {
            assert!(ctrl.try_admit(10).is_ok());
        }
        let err = ctrl.try_admit(10).unwrap_err();
        assert!(matches!(err, ServeError::Overloaded { .. }));
        assert_eq!(err.code(), "overloaded");
        assert_eq!(ctrl.work_queued(), 40);
        ctrl.on_dequeue(10);
        assert!(ctrl.try_admit(10).is_ok());
    }

    #[test]
    fn explicit_work_capacity_overrides_auto() {
        let ctrl = OverloadControl::new(
            OverloadConfig {
                work_capacity: 5,
                ..cfg(10)
            },
            1000,
        );
        assert!(ctrl.try_admit(5).is_ok());
        assert!(ctrl.try_admit(1).is_err());
    }

    #[test]
    fn tier_rises_under_sustained_pressure_and_recovers() {
        let mut c = cfg(10);
        c.brownout = true;
        c.alpha = 0.5; // fast EWMA for the test
        let ctrl = OverloadControl::new(c, 4);
        assert_eq!(ctrl.tier(), Tier::Normal);
        for _ in 0..4 {
            ctrl.try_admit(10).unwrap();
        }
        // saturate the EWMA with rejected admissions at full pressure
        for _ in 0..16 {
            let _ = ctrl.try_admit(10);
        }
        assert_eq!(ctrl.tier(), Tier::Brownout);
        for _ in 0..4 {
            ctrl.on_dequeue(10);
        }
        for _ in 0..16 {
            ctrl.on_dequeue(0);
        }
        assert_eq!(ctrl.tier(), Tier::Normal);
    }

    #[test]
    fn brownout_tier_requires_opt_in() {
        let mut c = cfg(10);
        c.alpha = 1.0;
        let ctrl = OverloadControl::new(c, 1);
        let _ = ctrl.try_admit(100); // rejected, pressure pinned to 1.0
        assert_eq!(ctrl.tier(), Tier::Clamped);
    }

    #[test]
    fn retry_after_tracks_service_rate_and_clamps() {
        let ctrl = OverloadControl::new(cfg(10), 4);
        assert_eq!(ctrl.retry_after_ms(), 50); // no observation yet
        ctrl.try_admit(20).unwrap();
        // 1 ms per sample → 20 queued samples ≈ 20 ms
        ctrl.on_work_done(10, Duration::from_millis(10));
        let hint = ctrl.retry_after_ms();
        assert!((1..=5000).contains(&hint), "hint {hint} out of range");
        assert!(hint >= 10, "hint {hint} ignores queued work");
    }

    #[test]
    fn clamp_samples_defaults_to_half_engine_budget() {
        let ctrl = OverloadControl::new(cfg(20), 4);
        assert_eq!(ctrl.clamp_samples(), 10);
        let ctrl = OverloadControl::new(
            OverloadConfig {
                clamp_samples: 3,
                ..cfg(20)
            },
            4,
        );
        assert_eq!(ctrl.clamp_samples(), 3);
    }

    #[test]
    fn serve_error_codes_and_display() {
        let d = ServeError::DeadlineExceeded { samples_used: 7 };
        assert_eq!(d.code(), "deadline_exceeded");
        assert!(format!("{d}").contains('7'));
        let o = ServeError::Overloaded { retry_after_ms: 12 };
        assert_eq!(o.code(), "overloaded");
        assert!(format!("{o}").contains("12"));
        let i = ServeError::Internal {
            detail: "x".into(),
        };
        assert_eq!(i.code(), "internal_error");
        let w = ServeError::WorkerUnavailable { down: 3 };
        assert_eq!(w.code(), "worker_unavailable");
        assert!(format!("{w}").contains('3'));
    }
}
