//! L3 serving coordinator — the systems half of the reproduction.
//!
//! Request lifecycle (`vLLM-router`-shaped, adapted to probabilistic
//! inference):
//!
//! ```text
//!   clients ──► Router ──► per-model queue ──► DynamicBatcher
//!                                                    │ (max_batch / max_wait)
//!                                                    ▼
//!                                             Engine (dedicated thread)
//!                    fwd_pre (PJRT) ─► photonic machine (N-sample fan-out,
//!                    one probabilistic depthwise conv per pass) ─► fwd_post
//!                    (PJRT) ─► Predictive aggregation ─► UncertaintyPolicy
//! ```
//!
//! The engine thread owns all non-`Send` state (PJRT client/executables and
//! the photonic machine); everything upstream communicates over MPMC
//! channels.  Each request is expanded into `n_samples` stochastic forward
//! passes (paper: N = 10) whose randomness comes from the machine's chaotic
//! light — there is no PRNG on the photonic request path.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod router;
pub mod service;

pub use batcher::DynamicBatcher;
pub use engine::{ClassifyResult, Engine, EngineConfig, ExecMode};
pub use router::Router;
pub use service::{ClassifyRequest, EngineHandle};
