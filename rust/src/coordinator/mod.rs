//! L3 serving coordinator — the systems half of the reproduction.
//!
//! Request lifecycle (`vLLM-router`-shaped, adapted to probabilistic
//! inference):
//!
//! ```text
//!   clients ──► Router ──► per-model queue ──► DynamicBatcher
//!                                                    │ (max_batch / max_wait)
//!                                                    ▼
//!                                             Engine (dedicated thread)
//!                    fwd_pre (PJRT) ─► photonic machine (N-sample fan-out,
//!                    one probabilistic depthwise conv per pass) ─► fwd_post
//!                    (PJRT) ─► Predictive aggregation ─► UncertaintyPolicy
//! ```
//!
//! The engine thread owns all non-`Send` state (PJRT client/executables and
//! the sampling backend); everything upstream communicates over MPMC
//! channels.  Each request is expanded into `n_samples` stochastic forward
//! passes (paper: N = 10) executed as one batched
//! [`crate::backend::SamplePlan`] on the configured
//! [`crate::backend::ProbConvBackend`] — chaotic light on the photonic
//! backend (no PRNG on the request path), xoshiro256++ + Box–Muller on the
//! digital baseline, or a single deterministic pass on the mean-field
//! backend.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod router;
pub mod service;

pub use crate::backend::{BackendKind, PrefetchMode};
pub use batcher::DynamicBatcher;
pub use engine::{ClassifyResult, Engine, EngineConfig, ExecMode};
pub use router::Router;
pub use service::{ClassifyRequest, EngineHandle};
