//! L3 serving coordinator — the systems half of the reproduction.
//!
//! Request lifecycle (`vLLM-router`-shaped, adapted to probabilistic
//! inference):
//!
//! ```text
//!   clients ──► Router ──► per-model queue ──► DynamicBatcher
//!                                                    │ (max_batch / max_wait)
//!                                                    ▼
//!                                             Engine (dedicated thread)
//!                    fwd_pre (PJRT) ─► photonic machine (N-sample fan-out,
//!                    one probabilistic depthwise conv per pass) ─► fwd_post
//!                    (PJRT) ─► Predictive aggregation ─► UncertaintyPolicy
//! ```
//!
//! The engine thread owns all non-`Send` state (PJRT client/executables and
//! the sampling backend); everything upstream communicates over MPMC
//! channels.  Each request is expanded into up to `n_samples` stochastic
//! forward passes (paper: N = 10) executed as batched
//! [`crate::backend::SamplePlan`]s on the configured
//! [`crate::backend::ProbConvBackend`] — chaotic light on the photonic
//! backend (no PRNG on the request path), xoshiro256++ + Box–Muller on the
//! digital baseline, or a single deterministic pass on the mean-field
//! backend.  With an adaptive [`crate::sampler::StopRule`] the passes are
//! drawn in chunks and each request stops as soon as its decision is
//! statistically resolved; requests carry optional budgets
//! ([`RequestBudget`]), and the service loop batches same-budget requests
//! together so variable-cost requests never cross-contaminate a plan.
//!
//! The lifecycle is overload-safe end to end: [`overload`] adds cost-aware
//! admission with typed `overloaded` shedding, per-request deadlines
//! (checked at dequeue and between adaptive chunks), tiered degradation
//! (budget clamping, opt-in mean-field brownout), and `catch_unwind` panic
//! isolation with deterministic engine rebuild — see
//! [`service::run_service_loop`].

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod overload;
pub mod router;
pub mod service;

pub use crate::backend::{BackendKind, PrefetchMode};
pub use crate::sampler::{RequestBudget, SamplerConfig, StopRule};
pub use batcher::DynamicBatcher;
pub use engine::{ClassifyResult, Engine, EngineConfig, ExecMode};
pub use crate::registry::{ModelSpec, ProgramRegistry, RegistryMetrics, UnknownModel};
pub use metrics::{LatencyBuckets, ServeCounters, ServeSnapshot};
pub use overload::{OverloadConfig, OverloadControl, ServeError, Tier};
pub use router::Router;
pub use service::{
    run_service_loop, run_service_loop_traced, submit_with_admission, BatchExecutor,
    ClassifyRequest, EngineHandle, GroupKey, ServiceConfig, SynthExecutor,
};
