//! [`DigitalBaselineBackend`] — the paper's digital comparison point.
//!
//! Every weight of every output symbol is drawn from `N(mu, sigma)` with a
//! xoshiro256++ PRNG and a Box–Muller (polar) Gaussian transform — the exact
//! pseudo-random-number pipeline the paper argues chaotic light removes from
//! the Bayesian hot path.  The signal chain around the draws mirrors the
//! photonic datapath's digital interface (8-bit DAC on activations, 8-bit
//! ADC on readouts) so throughput and accuracy comparisons isolate the
//! sampling substrate, not the quantization.
//!
//! The backend deliberately draws all `num_taps` weights per output pixel,
//! including pixels whose activations are zero: a digital sampler has to
//! materialize the weight tensor before it can know what the data looks
//! like, and that PRNG volume is precisely the cost being measured.
//!
//! ## Threading and determinism
//!
//! With a worker pool attached, `sample_conv` shards the flattened
//! `n_samples x batch` grid across the workers.  Each shard owns a
//! xoshiro256++ stream forked (2^128-jump) from the backend seed at
//! construction, so outputs are bitwise-deterministic for a fixed
//! `(seed, n_threads)` and statistically equivalent across thread counts.
//! Weight draws happen in bulk — one plane of normals per (item, channel,
//! sample) — into per-shard scratch, so the steady-state loop performs no
//! heap allocation.
//!
//! With the entropy pipeline enabled (`PrefetchMode::On`), each shard's
//! Box–Muller work moves to a dedicated background producer that pre-draws
//! normal planes into an SPSC block ring; the conv loop then reduces to
//! `mu + sigma·z` FMAs over prefetched blocks.  Because the shard stream
//! and draw order are unchanged, outputs are bitwise identical across all
//! prefetch modes.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::Result;

use super::{BackendKind, PipelineOptions, ProbConvBackend, SamplePlan};
use crate::entropy::gaussian::Gaussian;
use crate::entropy::health::Monitor;
use crate::entropy::pipeline::{EntropyStream, NormalGen};
use crate::entropy::Xoshiro256pp;
use crate::exec::scratch::{grow, ScratchArena};
use crate::exec::ThreadPool;
use crate::photonics::converters::Quantizer;
use crate::photonics::machine::im2col_3x3;
use crate::photonics::TapTarget;
use crate::registry::{ModelCache, ProgramKey, RegistryMetrics};

/// One worker's private entropy stream + draw scratch.  The stream is the
/// shard's forked xoshiro256++ either drawn inline (prefetch off/sync —
/// identical draws, identical order) or pre-drawn by a background producer
/// (prefetch on) — bitwise the same weight planes in every mode.
struct DigitalShard {
    stream: EntropyStream<NormalGen>,
    scratch: ScratchArena,
}

impl DigitalShard {
    /// Convolve rows `[g0, g0 + out.len()/item)` of the flattened
    /// `(sample, batch)` grid, reading shared im2col planes and writing the
    /// shard's disjoint output window.
    #[allow(clippy::too_many_arguments)]
    fn run(
        &mut self,
        kernels: &[Vec<TapTarget>],
        patches: &[f32],
        c: usize,
        hw: usize,
        batch: usize,
        g0: usize,
        dac: &Quantizer,
        adc: &Quantizer,
        out: &mut [f32],
    ) {
        let hw9 = hw * 9;
        let item = c * hw;
        let rows = out.len() / item;
        for r in 0..rows {
            let b = (g0 + r) % batch;
            for (ch, kern) in kernels.iter().enumerate().take(c) {
                let plane = &patches[(b * c + ch) * hw9..(b * c + ch + 1) * hw9];
                // one whole weight plane per (item, channel, sample): drawn
                // inline, or copied out of a producer-prefetched block —
                // either way the same draws in the same order
                let z = grow(&mut self.scratch.draws, hw9);
                self.stream.fill(z);
                super::conv_plane_quantized(
                    plane,
                    hw,
                    dac,
                    adc,
                    |p, tap| kern[tap].mu as f64 + kern[tap].sigma as f64 * z[p * 9 + tap],
                    &mut out[r * item + ch * hw..r * item + (ch + 1) * hw],
                );
            }
        }
    }
}

/// PRNG + Box–Muller sampling substrate.
pub struct DigitalBaselineBackend {
    kernels: Vec<Vec<TapTarget>>,
    rng: Xoshiro256pp,
    gauss: Gaussian,
    dac: Quantizer,
    adc: Quantizer,
    pool: Option<Arc<ThreadPool>>,
    shards: Vec<DigitalShard>,
    arena: ScratchArena,
    popts: PipelineOptions,
    /// Draws produced by background entropy producers (prefetch on only).
    produced: Arc<AtomicU64>,
    /// Entropy-health monitor tapping the shard streams, if attached.
    monitor: Option<Arc<Monitor>>,
    /// Multi-model registry cache: parked per-model shard streams keyed by
    /// model name (`None` until the first switch).  Weight planes are
    /// `mu + sigma·z` at consumption, so the streams are the only per-model
    /// sampling state.
    models: Option<ModelCache<Vec<DigitalShard>>>,
    /// Output pixels computed (one probabilistic convolution each).
    pub convolutions: u64,
    /// Gaussian weight draws consumed (the PRNG bottleneck being measured).
    pub weight_draws: u64,
}

impl DigitalBaselineBackend {
    pub fn new(scale_dac: f32, scale_adc: f32, seed: u64) -> Self {
        Self::with_pool(scale_dac, scale_adc, seed, None)
    }

    /// Backend whose `sample_conv` shards plans across `pool` (sequential
    /// when `None` or single-worker).  Shard streams are forked from the
    /// seed at construction and persist across calls, so a fixed
    /// `(seed, n_threads)` replays bit-identically.
    pub fn with_pool(
        scale_dac: f32,
        scale_adc: f32,
        seed: u64,
        pool: Option<Arc<ThreadPool>>,
    ) -> Self {
        Self::with_opts(scale_dac, scale_adc, seed, pool, PipelineOptions::default())
    }

    /// Full-control constructor: pool sharding plus the decoupled-entropy
    /// pipeline options.  The digital backend's weight draws depend only on
    /// the shard streams — not on the programmed targets — so its outputs
    /// are bitwise identical across all three prefetch modes for a fixed
    /// `(seed, n_threads)` (the `mu + sigma·z` mapping happens at
    /// consumption time).
    pub fn with_opts(
        scale_dac: f32,
        scale_adc: f32,
        seed: u64,
        pool: Option<Arc<ThreadPool>>,
        popts: PipelineOptions,
    ) -> Self {
        Self::with_opts_monitored(scale_dac, scale_adc, seed, pool, popts, None)
    }

    /// [`Self::with_opts`] with an optional entropy-health monitor: each
    /// shard stream `dig-s{i}` gets a duty-cycled tap reporting to scorecard
    /// `(i, "dig-s{i}")`.  Taps observe produced blocks by copy — monitored
    /// and unmonitored backends replay bitwise-identically.
    pub fn with_opts_monitored(
        scale_dac: f32,
        scale_adc: f32,
        seed: u64,
        pool: Option<Arc<ThreadPool>>,
        popts: PipelineOptions,
        monitor: Option<Arc<Monitor>>,
    ) -> Self {
        let n_shards = pool.as_ref().map(|p| p.worker_count()).unwrap_or(1).max(1);
        let produced = Arc::new(AtomicU64::new(0));
        // offset the fork root so shard streams never alias the probe rng
        let mut root = Xoshiro256pp::new(seed ^ 0xD161_7A15_7EAD_5EED);
        let shards = (0..n_shards)
            .map(|i| DigitalShard {
                stream: EntropyStream::new_monitored(
                    NormalGen::new(root.fork()),
                    &popts,
                    &format!("dig-s{i}"),
                    produced.clone(),
                    monitor.as_ref().map(|m| (m.clone(), i)),
                ),
                scratch: ScratchArena::default(),
            })
            .collect();
        Self {
            kernels: Vec::new(),
            rng: Xoshiro256pp::new(seed),
            gauss: Gaussian::new(),
            dac: Quantizer::new(scale_dac),
            adc: Quantizer::new(scale_adc),
            pool,
            shards,
            arena: ScratchArena::default(),
            popts,
            produced,
            monitor,
            models: None,
            convolutions: 0,
            weight_draws: 0,
        }
    }
}

impl ProbConvBackend for DigitalBaselineBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Digital
    }

    fn program(&mut self, kernels: &[Vec<TapTarget>], _calibrate: bool) -> Result<()> {
        // an exact substrate: programming realizes targets perfectly, so the
        // calibrate flag is a no-op
        super::validate_kernels9("digital", kernels)?;
        self.kernels = kernels.to_vec();
        Ok(())
    }

    fn num_kernels(&self) -> usize {
        self.kernels.len()
    }

    fn sample_weight(&mut self, kernel: usize, tap: usize) -> f64 {
        let t = self.kernels[kernel][tap];
        self.weight_draws += 1;
        t.mu as f64 + t.sigma as f64 * self.gauss.sample(&mut self.rng)
    }

    fn sample_conv(&mut self, plan: &SamplePlan, x: &[f32], out: &mut [f32]) -> Result<()> {
        plan.check(x.len(), out.len(), self.kernels.len())?;
        let (c, h, w) = (plan.channels, plan.height, plan.width);
        let hw = h * w;
        let hw9 = hw * 9;
        let item = plan.item_size();
        // im2col once per (item, channel) into the shared read-only arena;
        // only the weight draws repeat per sample — the measured digital
        // cost is the sampling, not the patch extraction
        let patches = grow(&mut self.arena.patches, plan.batch * c * hw9);
        for b in 0..plan.batch {
            for ch in 0..c {
                im2col_3x3(
                    &x[b * item + ch * hw..b * item + (ch + 1) * hw],
                    h,
                    w,
                    &mut patches[(b * c + ch) * hw9..(b * c + ch + 1) * hw9],
                );
            }
        }
        let patches: &[f32] = patches;
        let grid = plan.n_samples * plan.batch;
        let out = &mut out[..grid * item];
        let kernels = &self.kernels;
        let (dac, adc) = (&self.dac, &self.adc);
        let batch = plan.batch;
        match &self.pool {
            Some(pool) if self.shards.len() > 1 => {
                let ranges = super::shard_ranges(grid, self.shards.len());
                let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                    Vec::with_capacity(self.shards.len());
                let mut rest = out;
                for (shard, range) in self.shards.iter_mut().zip(ranges) {
                    if range.is_empty() {
                        continue;
                    }
                    let (head, tail) = rest.split_at_mut(range.len() * item);
                    rest = tail;
                    let g0 = range.start;
                    jobs.push(Box::new(move || {
                        shard.run(kernels, patches, c, hw, batch, g0, dac, adc, head);
                    }));
                }
                pool.scope_run(jobs);
            }
            _ => {
                self.shards[0].run(kernels, patches, c, hw, batch, 0, dac, adc, out);
            }
        }
        let pixels = plan.convolutions();
        self.convolutions += pixels;
        self.weight_draws += pixels * 9;
        Ok(())
    }

    fn report(&self) -> String {
        format!(
            "convolutions={} weight_draws={} shards={} prefetch={} produced_draws={} \
             (xoshiro256++ / Box-Muller)",
            self.convolutions,
            self.weight_draws,
            self.shards.len(),
            self.popts.mode,
            self.produced.load(Ordering::Relaxed)
        )
    }

    fn entropy_health(&self) -> Option<Arc<Monitor>> {
        self.monitor.clone()
    }

    fn enable_model_cache(&mut self, budget_bytes: usize, metrics: Arc<RegistryMetrics>) {
        self.models = Some(ModelCache::new(budget_bytes, metrics));
    }

    /// Swap the per-model shard streams through the registry cache.  A hit
    /// resumes the model's streams where they left off; a miss re-forks
    /// them from `key.seed` — so an eviction-then-reload replays the model
    /// bitwise from the start, exactly like a cold backend seeded with the
    /// same model-mixed seed.  Kernels and DAC/ADC quantizers always come
    /// from the new model's checkpoint.
    fn switch_program(
        &mut self,
        key: &ProgramKey,
        kernels: &[Vec<TapTarget>],
        _calibrate: bool,
    ) -> Result<()> {
        super::validate_kernels9("digital", kernels)?;
        if self.models.is_none() {
            self.models = Some(ModelCache::new(
                usize::MAX,
                Arc::new(RegistryMetrics::default()),
            ));
        }
        self.kernels = kernels.to_vec();
        self.dac = Quantizer::new(key.scale_dac);
        self.adc = Quantizer::new(key.scale_adc);
        if self.models.as_ref().unwrap().is_active(&key.model) {
            return Ok(());
        }
        let mut cache = self.models.take().unwrap();
        let had_active = cache.active_model().is_some();
        let (shards, bytes) = match cache.checkout(&key.model) {
            Some(hit) => hit,
            None => {
                let n_shards = self.shards.len().max(1);
                let mut root = Xoshiro256pp::new(key.seed ^ 0xD161_7A15_7EAD_5EED);
                let shards: Vec<DigitalShard> = (0..n_shards)
                    .map(|i| DigitalShard {
                        stream: EntropyStream::new_monitored(
                            NormalGen::new(root.fork()),
                            &self.popts,
                            &format!("dig-s{i}"),
                            self.produced.clone(),
                            self.monitor.as_ref().map(|m| (m.clone(), i)),
                        ),
                        scratch: ScratchArena::default(),
                    })
                    .collect();
                let per_stream = if self.popts.mode.banked() {
                    (self.popts.depth + 2) * self.popts.block * 8
                } else {
                    256
                };
                (shards, n_shards * per_stream + 1024)
            }
        };
        let prev = std::mem::replace(&mut self.shards, shards);
        cache.commit(&key.model, bytes, had_active.then_some(prev));
        self.models = Some(cache);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::mathstat::{std_f32, Welford};

    fn targets9(mu: f32, sigma: f32) -> Vec<TapTarget> {
        vec![TapTarget { mu, sigma }; 9]
    }

    #[test]
    fn rejects_non_nine_tap_kernels() {
        let mut be = DigitalBaselineBackend::new(4.0, 8.0, 1);
        assert!(be.program(&[vec![TapTarget { mu: 0.0, sigma: 0.1 }; 5]], false).is_err());
        assert!(be.program(&[targets9(0.0, 0.1)], false).is_ok());
    }

    #[test]
    fn sampled_weights_have_programmed_moments() {
        let mut be = DigitalBaselineBackend::new(4.0, 8.0, 7);
        be.program(&[targets9(-0.4, 0.22)], false).unwrap();
        let mut w = Welford::new();
        for _ in 0..50_000 {
            w.push(be.sample_weight(0, 8));
        }
        assert!((w.mean() + 0.4).abs() < 0.01, "mean {}", w.mean());
        assert!((w.std() - 0.22).abs() < 0.01, "std {}", w.std());
        assert_eq!(be.weight_draws, 50_000);
    }

    #[test]
    fn conv_output_variance_tracks_sigma() {
        let mut be = DigitalBaselineBackend::new(4.0, 8.0, 3);
        be.program(&[targets9(0.4, 0.1), targets9(0.4, 0.5)], false).unwrap();
        let plan = SamplePlan::new(1500, 1, 1, 1, 1);
        // height/width 1: a single-pixel map isolates one patch per sample
        let x = vec![1.0f32];
        let mut lo = vec![0.0f32; plan.total_size()];
        be.sample_conv(&plan, &x, &mut lo).unwrap();
        let mut be_hi = DigitalBaselineBackend::new(4.0, 8.0, 3);
        be_hi
            .program(&[targets9(0.4, 0.5)], false)
            .unwrap();
        let mut hi = vec![0.0f32; plan.total_size()];
        be_hi.sample_conv(&plan, &x, &mut hi).unwrap();
        assert!(
            std_f32(&hi) > 2.0 * std_f32(&lo),
            "lo {} hi {}",
            std_f32(&lo),
            std_f32(&hi)
        );
    }

    #[test]
    fn counters_account_for_plan_volume() {
        let mut be = DigitalBaselineBackend::new(4.0, 8.0, 2);
        be.program(&[targets9(0.1, 0.1), targets9(0.1, 0.1)], false).unwrap();
        let plan = SamplePlan::new(4, 3, 2, 5, 5);
        let x = vec![0.3f32; plan.sample_size()];
        let mut out = vec![0.0f32; plan.total_size()];
        be.sample_conv(&plan, &x, &mut out).unwrap();
        assert_eq!(be.convolutions, plan.convolutions());
        assert_eq!(be.weight_draws, plan.convolutions() * 9);
    }

    #[test]
    fn repeated_calls_continue_the_stream() {
        // two calls on one backend must differ (the shard streams advance),
        // while two identically-seeded backends replay bit-identically
        let plan = SamplePlan::new(2, 1, 1, 3, 3);
        let x = vec![0.5f32; plan.sample_size()];
        let mut a = DigitalBaselineBackend::new(4.0, 8.0, 9);
        a.program(&[targets9(0.3, 0.3)], false).unwrap();
        let mut first = vec![0.0f32; plan.total_size()];
        a.sample_conv(&plan, &x, &mut first).unwrap();
        let mut second = vec![0.0f32; plan.total_size()];
        a.sample_conv(&plan, &x, &mut second).unwrap();
        assert_ne!(first, second);

        let mut b = DigitalBaselineBackend::new(4.0, 8.0, 9);
        b.program(&[targets9(0.3, 0.3)], false).unwrap();
        let mut replay = vec![0.0f32; plan.total_size()];
        b.sample_conv(&plan, &x, &mut replay).unwrap();
        assert_eq!(first, replay);
    }

    #[test]
    fn model_switch_keeps_per_model_streams() {
        let plan = SamplePlan::new(2, 1, 1, 3, 3);
        let x = vec![0.5f32; plan.sample_size()];
        let key_a = ProgramKey::new("a", 11, 4.0, 8.0);
        let key_b = ProgramKey::new("b", 11, 4.0, 8.0);
        let sample = |be: &mut DigitalBaselineBackend| {
            let mut out = vec![0.0f32; plan.total_size()];
            be.sample_conv(&plan, &x, &mut out).unwrap();
            out
        };
        let mut be = DigitalBaselineBackend::new(4.0, 8.0, 1);
        be.switch_program(&key_a, &[targets9(0.3, 0.3)], false).unwrap();
        let a1 = sample(&mut be);
        be.switch_program(&key_b, &[targets9(-0.3, 0.3)], false).unwrap();
        let _b1 = sample(&mut be);
        be.switch_program(&key_a, &[targets9(0.3, 0.3)], false).unwrap();
        let a2 = sample(&mut be);
        assert_ne!(a1, a2, "a's stream advanced across the detour via b");
        // reference never switched away from a; its constructor seed is
        // different on purpose — the model-mixed key seed is what governs
        let mut rf = DigitalBaselineBackend::new(4.0, 8.0, 99);
        rf.switch_program(&key_a, &[targets9(0.3, 0.3)], false).unwrap();
        assert_eq!(a1, sample(&mut rf), "first pass replays from the key seed");
        assert_eq!(a2, sample(&mut rf), "cache hit continues the stream");
    }

    #[test]
    fn monitored_backend_replays_bitwise_and_reports_health() {
        use crate::entropy::health::{HealthConfig, Monitor};
        let plan = SamplePlan::new(6, 2, 1, 5, 5);
        let x = vec![0.4f32; plan.sample_size()];
        let popts = PipelineOptions::default();

        let mut plain = DigitalBaselineBackend::with_opts(4.0, 8.0, 13, None, popts);
        plain.program(&[targets9(0.2, 0.4)], false).unwrap();
        let mut want = vec![0.0f32; plan.total_size()];
        plain.sample_conv(&plan, &x, &mut want).unwrap();
        assert!(plain.entropy_health().is_none());

        let monitor = Arc::new(Monitor::new(HealthConfig {
            enabled: true,
            window_bits: 256,
            duty: 1.0,
            ..HealthConfig::default()
        }));
        let mut tapped = DigitalBaselineBackend::with_opts_monitored(
            4.0,
            8.0,
            13,
            None,
            popts,
            Some(monitor.clone()),
        );
        tapped.program(&[targets9(0.2, 0.4)], false).unwrap();
        let mut got = vec![0.0f32; plan.total_size()];
        tapped.sample_conv(&plan, &x, &mut got).unwrap();
        assert_eq!(want, got, "health tap changed the sampled outputs");
        assert!(tapped.entropy_health().is_some());
        assert!(monitor.observed_blocks() >= 1, "tap saw no blocks");
        assert!(!monitor.any_degraded(), "healthy PRNG flagged as degraded");
    }
}
