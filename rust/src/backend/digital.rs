//! [`DigitalBaselineBackend`] — the paper's digital comparison point.
//!
//! Every weight of every output symbol is drawn from `N(mu, sigma)` with a
//! xoshiro256++ PRNG and a Box–Muller (polar) Gaussian transform — the exact
//! pseudo-random-number pipeline the paper argues chaotic light removes from
//! the Bayesian hot path.  The signal chain around the draws mirrors the
//! photonic datapath's digital interface (8-bit DAC on activations, 8-bit
//! ADC on readouts) so throughput and accuracy comparisons isolate the
//! sampling substrate, not the quantization.
//!
//! The backend deliberately draws all `num_taps` weights per output pixel,
//! including pixels whose activations are zero: a digital sampler has to
//! materialize the weight tensor before it can know what the data looks
//! like, and that PRNG volume is precisely the cost being measured.

use anyhow::Result;

use super::{BackendKind, ProbConvBackend, SamplePlan};
use crate::entropy::gaussian::Gaussian;
use crate::entropy::Xoshiro256pp;
use crate::photonics::converters::Quantizer;
use crate::photonics::machine::im2col_3x3;
use crate::photonics::TapTarget;

/// PRNG + Box–Muller sampling substrate.
pub struct DigitalBaselineBackend {
    kernels: Vec<Vec<TapTarget>>,
    rng: Xoshiro256pp,
    gauss: Gaussian,
    dac: Quantizer,
    adc: Quantizer,
    patches: Vec<f32>,
    /// Output pixels computed (one probabilistic convolution each).
    pub convolutions: u64,
    /// Gaussian weight draws consumed (the PRNG bottleneck being measured).
    pub weight_draws: u64,
}

impl DigitalBaselineBackend {
    pub fn new(scale_dac: f32, scale_adc: f32, seed: u64) -> Self {
        Self {
            kernels: Vec::new(),
            rng: Xoshiro256pp::new(seed),
            gauss: Gaussian::new(),
            dac: Quantizer::new(scale_dac),
            adc: Quantizer::new(scale_adc),
            patches: Vec::new(),
            convolutions: 0,
            weight_draws: 0,
        }
    }
}

impl ProbConvBackend for DigitalBaselineBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Digital
    }

    fn program(&mut self, kernels: &[Vec<TapTarget>], _calibrate: bool) -> Result<()> {
        // an exact substrate: programming realizes targets perfectly, so the
        // calibrate flag is a no-op
        super::validate_kernels9("digital", kernels)?;
        self.kernels = kernels.to_vec();
        Ok(())
    }

    fn num_kernels(&self) -> usize {
        self.kernels.len()
    }

    fn sample_weight(&mut self, kernel: usize, tap: usize) -> f64 {
        let t = self.kernels[kernel][tap];
        self.weight_draws += 1;
        t.mu as f64 + t.sigma as f64 * self.gauss.sample(&mut self.rng)
    }

    fn sample_conv(&mut self, plan: &SamplePlan, x: &[f32], out: &mut [f32]) -> Result<()> {
        plan.check(x.len(), out.len(), self.kernels.len())?;
        let (c, h, w) = (plan.channels, plan.height, plan.width);
        let item = plan.item_size();
        self.patches.resize(h * w * 9, 0.0);
        // im2col once per (item, channel); only the weight draws repeat per
        // sample — the measured digital cost is the sampling, not the
        // patch extraction
        for b in 0..plan.batch {
            let xi = &x[b * item..(b + 1) * item];
            for ch in 0..c {
                im2col_3x3(&xi[ch * h * w..(ch + 1) * h * w], h, w, &mut self.patches);
                let kern = &self.kernels[ch];
                for s in 0..plan.n_samples {
                    let oi = (s * plan.batch + b) * item + ch * h * w;
                    super::conv_plane_quantized(
                        &self.patches,
                        h * w,
                        &self.dac,
                        &self.adc,
                        |tap| {
                            kern[tap].mu as f64
                                + kern[tap].sigma as f64 * self.gauss.sample(&mut self.rng)
                        },
                        &mut out[oi..oi + h * w],
                    );
                }
            }
        }
        let pixels = plan.convolutions();
        self.convolutions += pixels;
        self.weight_draws += pixels * 9;
        Ok(())
    }

    fn report(&self) -> String {
        format!(
            "convolutions={} weight_draws={} (xoshiro256++ / Box-Muller)",
            self.convolutions, self.weight_draws
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::mathstat::{std_f32, Welford};

    fn targets9(mu: f32, sigma: f32) -> Vec<TapTarget> {
        vec![TapTarget { mu, sigma }; 9]
    }

    #[test]
    fn rejects_non_nine_tap_kernels() {
        let mut be = DigitalBaselineBackend::new(4.0, 8.0, 1);
        assert!(be.program(&[vec![TapTarget { mu: 0.0, sigma: 0.1 }; 5]], false).is_err());
        assert!(be.program(&[targets9(0.0, 0.1)], false).is_ok());
    }

    #[test]
    fn sampled_weights_have_programmed_moments() {
        let mut be = DigitalBaselineBackend::new(4.0, 8.0, 7);
        be.program(&[targets9(-0.4, 0.22)], false).unwrap();
        let mut w = Welford::new();
        for _ in 0..50_000 {
            w.push(be.sample_weight(0, 8));
        }
        assert!((w.mean() + 0.4).abs() < 0.01, "mean {}", w.mean());
        assert!((w.std() - 0.22).abs() < 0.01, "std {}", w.std());
        assert_eq!(be.weight_draws, 50_000);
    }

    #[test]
    fn conv_output_variance_tracks_sigma() {
        let mut be = DigitalBaselineBackend::new(4.0, 8.0, 3);
        be.program(&[targets9(0.4, 0.1), targets9(0.4, 0.5)], false).unwrap();
        let plan = SamplePlan::new(1500, 1, 1, 1, 1);
        // height/width 1: a single-pixel map isolates one patch per sample
        let x = vec![1.0f32];
        let mut lo = vec![0.0f32; plan.total_size()];
        be.sample_conv(&plan, &x, &mut lo).unwrap();
        let mut be_hi = DigitalBaselineBackend::new(4.0, 8.0, 3);
        be_hi
            .program(&[targets9(0.4, 0.5)], false)
            .unwrap();
        let mut hi = vec![0.0f32; plan.total_size()];
        be_hi.sample_conv(&plan, &x, &mut hi).unwrap();
        assert!(
            std_f32(&hi) > 2.0 * std_f32(&lo),
            "lo {} hi {}",
            std_f32(&lo),
            std_f32(&hi)
        );
    }

    #[test]
    fn counters_account_for_plan_volume() {
        let mut be = DigitalBaselineBackend::new(4.0, 8.0, 2);
        be.program(&[targets9(0.1, 0.1), targets9(0.1, 0.1)], false).unwrap();
        let plan = SamplePlan::new(4, 3, 2, 5, 5);
        let x = vec![0.3f32; plan.sample_size()];
        let mut out = vec![0.0f32; plan.total_size()];
        be.sample_conv(&plan, &x, &mut out).unwrap();
        assert_eq!(be.convolutions, plan.convolutions());
        assert_eq!(be.weight_draws, plan.convolutions() * 9);
    }
}
