//! [`PhotonicSimBackend`] — the photonic Bayesian machine simulator behind
//! the backend-agnostic probabilistic-convolution API.
//!
//! Randomness comes from the machine's chaotic light (Gamma-distributed
//! speckle intensity per tap per symbol); there is no PRNG on the request
//! path.  Programming goes through the physics inversion plus, optionally,
//! the feedback-calibration loop that corrects spectral-shaper actuator
//! error (paper, Supplement).

use anyhow::Result;

use super::{BackendKind, ProbConvBackend, SamplePlan};
use crate::calibration::{calibrate_kernel, CalibrationOptions};
use crate::photonics::{MachineConfig, PhotonicMachine, TapTarget};

/// The chaotic-light substrate (simulator).
pub struct PhotonicSimBackend {
    machine: PhotonicMachine,
    calibration: CalibrationOptions,
}

impl PhotonicSimBackend {
    pub fn new(cfg: MachineConfig) -> Self {
        Self {
            machine: PhotonicMachine::new(cfg),
            calibration: CalibrationOptions::default(),
        }
    }

    pub fn with_defaults(seed: u64) -> Self {
        Self::new(MachineConfig {
            seed,
            ..MachineConfig::default()
        })
    }

    /// Override the feedback-calibration options used by [`ProbConvBackend::program`].
    pub fn set_calibration_options(&mut self, opts: CalibrationOptions) {
        self.calibration = opts;
    }

    /// Direct access to the simulated hardware (calibration experiments,
    /// telemetry).  The kernel bank it holds is owned by this backend.
    pub fn machine(&mut self) -> &mut PhotonicMachine {
        &mut self.machine
    }
}

impl ProbConvBackend for PhotonicSimBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Photonic
    }

    fn program(&mut self, kernels: &[Vec<TapTarget>], calibrate: bool) -> Result<()> {
        self.machine.clear_bank();
        for targets in kernels {
            let idx = self.machine.load_kernel(targets);
            if calibrate {
                calibrate_kernel(&mut self.machine, idx, targets, &self.calibration);
            }
        }
        Ok(())
    }

    fn num_kernels(&self) -> usize {
        self.machine.bank_len()
    }

    fn sample_weight(&mut self, kernel: usize, tap: usize) -> f64 {
        self.machine.sample_weight(kernel, tap)
    }

    fn sample_conv(&mut self, plan: &SamplePlan, x: &[f32], out: &mut [f32]) -> Result<()> {
        plan.check(x.len(), out.len(), self.machine.bank_len())?;
        let item = plan.item_size();
        // Sample-major, batch-minor: the exact machine-RNG consumption order
        // of the old per-sample engine loop, so outputs are bit-identical.
        for s in 0..plan.n_samples {
            for b in 0..plan.batch {
                let y = self.machine.depthwise_conv(
                    0,
                    &x[b * item..(b + 1) * item],
                    plan.channels,
                    plan.height,
                    plan.width,
                );
                out[(s * plan.batch + b) * item..(s * plan.batch + b + 1) * item]
                    .copy_from_slice(&y);
            }
        }
        Ok(())
    }

    fn report(&self) -> String {
        self.machine.throughput_report()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::mathstat::Welford;

    fn quiet(seed: u64) -> PhotonicSimBackend {
        PhotonicSimBackend::new(MachineConfig {
            rx_noise: 0.0,
            actuator_sigma: 0.0,
            actuator_jitter: 0.0,
            ripple_rms_ps: 0.0,
            seed,
            ..MachineConfig::default()
        })
    }

    #[test]
    fn program_replaces_bank() {
        let mut be = quiet(3);
        let k1 = vec![vec![TapTarget { mu: 0.2, sigma: 0.2 }; 9]; 3];
        be.program(&k1, false).unwrap();
        assert_eq!(be.num_kernels(), 3);
        let k2 = vec![vec![TapTarget { mu: -0.1, sigma: 0.3 }; 9]; 2];
        be.program(&k2, false).unwrap();
        assert_eq!(be.num_kernels(), 2);
    }

    #[test]
    fn calibration_improves_noisy_realization() {
        let cfg = MachineConfig {
            actuator_sigma: 0.05,
            actuator_jitter: 0.005,
            rx_noise: 0.0,
            seed: 12,
            ..MachineConfig::default()
        };
        let targets = vec![vec![TapTarget { mu: 0.5, sigma: 0.25 }; 9]];
        let measure = |be: &mut PhotonicSimBackend| -> f64 {
            let mut w = Welford::new();
            for _ in 0..4000 {
                w.push(be.sample_weight(0, 2));
            }
            (w.mean() - 0.5).abs()
        };
        let mut open_loop = PhotonicSimBackend::new(cfg.clone());
        open_loop.program(&targets, false).unwrap();
        let mut closed_loop = PhotonicSimBackend::new(cfg);
        closed_loop.program(&targets, true).unwrap();
        // identical machines, so any improvement is the feedback loop's
        let err_open = measure(&mut open_loop);
        let err_closed = measure(&mut closed_loop);
        assert!(
            err_closed < err_open + 0.01,
            "open {err_open} closed {err_closed}"
        );
    }

    #[test]
    fn sample_conv_rejects_bad_shapes() {
        let mut be = quiet(4);
        be.program(&[vec![TapTarget { mu: 0.1, sigma: 0.2 }; 9]], false)
            .unwrap();
        let plan = SamplePlan::new(2, 1, 1, 3, 3);
        let x = vec![0.1f32; plan.sample_size()];
        let mut small = vec![0.0f32; plan.total_size() - 1];
        assert!(be.sample_conv(&plan, &x, &mut small).is_err());
        let wide = SamplePlan::new(2, 1, 2, 3, 3); // needs 2 kernels, bank has 1
        let x2 = vec![0.1f32; wide.sample_size()];
        let mut out = vec![0.0f32; wide.total_size()];
        assert!(be.sample_conv(&wide, &x2, &mut out).is_err());
    }
}
