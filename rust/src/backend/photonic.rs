//! [`PhotonicSimBackend`] — the photonic Bayesian machine simulator behind
//! the backend-agnostic probabilistic-convolution API.
//!
//! Randomness comes from the machine's chaotic light (Gamma-distributed
//! speckle intensity per tap per symbol); there is no PRNG on the request
//! path.  Programming goes through the physics inversion plus, optionally,
//! the feedback-calibration loop that corrects spectral-shaper actuator
//! error (paper, Supplement).
//!
//! ## Threading and determinism
//!
//! With a worker pool attached, `sample_conv` shards the flattened
//! `n_samples x batch` grid across the workers.  Each shard owns an
//! independent [`ChaoticLightSource`] (its own 9 decorrelated spectral
//! streams) and receiver, seeded deterministically from the machine seed —
//! the software analogue of splitting the ASE spectrum across parallel
//! readout channels.  Outputs are bitwise-deterministic for a fixed
//! `(seed, n_threads)` and statistically equivalent across thread counts.
//! Without a pool the machine's own streams are used, bit-identical to the
//! historical per-sample loop.
//!
//! ## The entropy pipeline (prefetched weight-plane banks)
//!
//! The pipeline modes (`PrefetchMode::Sync`/`On`) mirror the paper's
//! source/detector split one level higher: each (shard, kernel, tap) gets
//! its own deterministic weight stream emitting *realized* weights
//! `gain·(I⁺ − I⁻)` at that tap's programmed operating point.  `On` runs
//! one background producer per shard that keeps every tap's SPSC block
//! ring full, so the conv inner loop is a pure FMA over prefetched planes;
//! `Sync` draws the identical streams inline (the verification fallback).
//! Banks are generation-keyed against `programs_loaded`: any reprogram or
//! calibration pass retires the prefetched planes and reseeds the streams.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::Result;

use super::{BackendKind, PipelineOptions, ProbConvBackend, SamplePlan};
use crate::calibration::{calibrate_kernel, CalibrationOptions};
use crate::entropy::chaotic::ChaoticLightSource;
use crate::entropy::gaussian::Gaussian;
use crate::entropy::health::Monitor;
use crate::entropy::pipeline::{spawn_group_monitored, stream_seed, EntropyStream, WeightGen};
use crate::entropy::xoshiro::splitmix64;
use crate::entropy::Xoshiro256pp;
use crate::exec::scratch::{grow, ScratchArena};
use crate::exec::ThreadPool;
use crate::photonics::detector::Detector;
use crate::photonics::eom::Eom;
use crate::photonics::machine::{conv_patches_banked, conv_patches_core, im2col_3x3};
use crate::photonics::{MachineConfig, PhotonicMachine, TapTarget};
use crate::registry::{ModelCache, ProgramKey, RegistryMetrics};

/// One worker's private optical front-end: an independent chaotic source,
/// receiver, and conv scratch.  The kernel bank stays shared (read-only).
struct PhotonicShard {
    eom: Eom,
    src: ChaoticLightSource,
    det: Detector,
    scratch: ScratchArena,
}

impl PhotonicShard {
    /// Convolve rows `[g0, g0 + out.len()/item)` of the flattened
    /// `(sample, batch)` grid against the machine's programmed bank.
    fn run(
        &mut self,
        machine: &PhotonicMachine,
        patches: &[f32],
        plan: SamplePlan,
        g0: usize,
        out: &mut [f32],
    ) {
        let c = plan.channels;
        let hw = plan.height * plan.width;
        let hw9 = hw * 9;
        let item = c * hw;
        let rows = out.len() / item;
        let scale_dac = machine.cfg.scale_dac;
        for r in 0..rows {
            let b = (g0 + r) % plan.batch;
            for ch in 0..c {
                conv_patches_core(
                    machine.kernel(ch).flat(),
                    &patches[(b * c + ch) * hw9..(b * c + ch + 1) * hw9],
                    9,
                    scale_dac,
                    &self.eom,
                    &mut self.src,
                    &mut self.det,
                    &mut self.scratch,
                    &mut out[r * item + ch * hw..r * item + (ch + 1) * hw],
                );
            }
        }
    }

    /// Bank-aware variant of [`Self::run`]: realized tap weights come from
    /// this shard's prefetched weight-plane bank (or its synchronous
    /// fallback streams) instead of inline rail sampling — the conv inner
    /// loop is a pure FMA over pre-drawn planes.
    fn run_banked(
        &mut self,
        bank: &mut ShardBank,
        nt: usize,
        scale_dac: f32,
        patches: &[f32],
        plan: SamplePlan,
        g0: usize,
        out: &mut [f32],
    ) {
        let c = plan.channels;
        let hw = plan.height * plan.width;
        let hw9 = hw * 9;
        let item = c * hw;
        let rows = out.len() / item;
        for r in 0..rows {
            let b = (g0 + r) % plan.batch;
            for ch in 0..c {
                let streams = &mut bank.streams[ch * nt..(ch + 1) * nt];
                conv_patches_banked(
                    &patches[(b * c + ch) * hw9..(b * c + ch + 1) * hw9],
                    nt,
                    scale_dac,
                    &self.eom,
                    |k, w| streams[k].fill(w),
                    &mut self.det,
                    &mut self.scratch,
                    &mut out[r * item + ch * hw..r * item + (ch + 1) * hw],
                );
            }
        }
    }
}

/// One shard's slice of the weight-plane bank: per (kernel, tap) entropy
/// streams in kernel-major order, each emitting realized weights at that
/// tap's programmed operating point.
struct ShardBank {
    streams: Vec<EntropyStream<WeightGen>>,
}

/// The prefetched weight-plane bank of a photonic backend: one
/// [`ShardBank`] per worker shard, tagged with the machine program
/// generation it was drawn against.  Any (re)programming bumps
/// `PhotonicMachine::stats::programs_loaded`, so a stale bank is detected
/// and rebuilt — with fresh generation-keyed stream seeds — before the next
/// `sample_conv` (prefetched planes never survive a reprogram).
struct WeightBank {
    shards: Vec<ShardBank>,
    generation: u64,
}

impl WeightBank {
    fn build(
        machine: &PhotonicMachine,
        n_shards: usize,
        popts: &PipelineOptions,
        produced: &Arc<AtomicU64>,
        monitor: Option<&Arc<Monitor>>,
    ) -> Self {
        let generation = machine.stats.programs_loaded;
        let nt = machine.num_taps();
        let seed = machine.cfg.seed;
        // the bank holds shards x kernels x taps streams, each buffering up
        // to (depth + 2) blocks: cap the per-stream block so prefetched
        // memory stays bounded (block size does not affect draw order, so
        // the sync/on equivalence is untouched)
        let popts = &PipelineOptions {
            block: popts.block.min(1024),
            ..*popts
        };
        let shards = (0..n_shards)
            .map(|s| {
                // one generator per (kernel, tap), one producer thread per
                // shard: spawn_group multiplexes all of this shard's rings
                let gens: Vec<WeightGen> = (0..machine.bank_len())
                    .flat_map(|kernel| (0..nt).map(move |tap| (kernel, tap)))
                    .map(|(kernel, tap)| {
                        let flat = machine.kernel(kernel).flat()[tap];
                        let sseed = stream_seed(seed, generation, s, kernel, tap);
                        WeightGen {
                            rng: Xoshiro256pp::new(sseed),
                            gauss: Gaussian::new(),
                            p_plus: flat.p_plus,
                            p_minus: flat.p_minus,
                            dof: flat.dof,
                            gain_eff: flat.gain_eff,
                        }
                    })
                    .collect();
                // every stream of the group reports under the shard label,
                // so the whole (kernel x tap) bank rolls up into one
                // (shard, "pho-s{s}") scorecard
                ShardBank {
                    streams: spawn_group_monitored(
                        gens,
                        popts,
                        &format!("pho-s{s}"),
                        produced.clone(),
                        monitor.map(|m| (m.clone(), s)),
                    ),
                }
            })
            .collect();
        Self { shards, generation }
    }
}

/// One model's resident substrate state in a multi-model backend: its own
/// machine (programmed kernels + chaotic-light rails seeded from the
/// model-mixed seed), per-shard optical front-ends, and any prefetched
/// weight-plane bank.  The whole triple moves between the backend's working
/// slots and the registry's LRU as a unit, so a cache hit resumes every
/// entropy stream exactly where the model left off; dropping an evicted
/// state joins that model's background producers.
struct ModelState {
    machine: PhotonicMachine,
    shards: Vec<PhotonicShard>,
    bank: Option<WeightBank>,
}

/// Rough resident-size estimate of one model's cached state.  The dominant
/// term is the prefetched weight-plane rings: shards x kernels x taps
/// streams, each buffering up to `depth + 2` blocks of (capped) `block`
/// f64 draws; the machine and front-ends are small change.
fn estimate_state_bytes(
    n_shards: usize,
    n_kernels: usize,
    nt: usize,
    popts: &PipelineOptions,
) -> usize {
    let per_stream = if popts.mode.banked() {
        (popts.depth + 2) * popts.block.min(1024) * 8
    } else {
        64
    };
    n_shards.max(1) * n_kernels.max(1) * nt.max(1) * per_stream + (1 << 12)
}

/// Deterministic per-shard optical front-ends for a machine configuration.
fn build_shards(cfg: &MachineConfig, n: usize) -> Vec<PhotonicShard> {
    let mut st = cfg.seed ^ 0x5EED_0F_C0A7_1C57;
    (0..n)
        .map(|_| {
            let src_seed = splitmix64(&mut st);
            let det_seed = splitmix64(&mut st);
            PhotonicShard {
                eom: Eom::new(cfg.scale_dac, cfg.extinction_db),
                src: ChaoticLightSource::new(cfg.source.clone(), src_seed),
                det: Detector::new(cfg.scale_adc, cfg.rx_noise, det_seed),
                scratch: ScratchArena::default(),
            }
        })
        .collect()
}

/// The chaotic-light substrate (simulator).
pub struct PhotonicSimBackend {
    machine: PhotonicMachine,
    calibration: CalibrationOptions,
    pool: Option<Arc<ThreadPool>>,
    shards: Vec<PhotonicShard>,
    arena: ScratchArena,
    popts: PipelineOptions,
    /// Prefetched weight-plane banks (pipeline modes only; rebuilt lazily
    /// whenever the machine program generation moves).
    bank: Option<WeightBank>,
    /// Draws produced by background entropy producers (prefetch on only).
    produced: Arc<AtomicU64>,
    /// Entropy-health monitor tapping the bank streams, if attached.
    monitor: Option<Arc<Monitor>>,
    /// Multi-model registry cache: parked [`ModelState`]s keyed by model
    /// name (`None` until the first `switch_program`/`enable_model_cache`).
    models: Option<ModelCache<ModelState>>,
}

impl PhotonicSimBackend {
    pub fn new(cfg: MachineConfig) -> Self {
        Self::with_pool(cfg, None)
    }

    /// Backend whose `sample_conv` shards plans across `pool` (sequential
    /// and bit-identical to the historical loop when `None` or
    /// single-worker).
    pub fn with_pool(cfg: MachineConfig, pool: Option<Arc<ThreadPool>>) -> Self {
        Self::with_opts(cfg, pool, PipelineOptions::default())
    }

    /// Full-control constructor: pool sharding plus the decoupled-entropy
    /// pipeline options.  With `PrefetchMode::Off` (default) the entropy
    /// organization is the historical one (machine streams sequentially,
    /// per-shard sources when sharded).  The pipeline modes (`Sync`/`On`)
    /// switch to per-(shard, kernel, tap) weight streams — `Sync` draws
    /// them inline, `On` prefetches them via background producers — and are
    /// bitwise identical to *each other* for a fixed `(seed, threads)`.
    pub fn with_opts(
        cfg: MachineConfig,
        pool: Option<Arc<ThreadPool>>,
        popts: PipelineOptions,
    ) -> Self {
        Self::with_opts_monitored(cfg, pool, popts, None)
    }

    /// [`Self::with_opts`] with an optional entropy-health monitor: in the
    /// banked modes (`Sync`/`On`) every weight-plane stream of shard `s`
    /// gets a duty-cycled tap rolling up into scorecard `(s, "pho-s{s}")`.
    /// Taps observe produced blocks by copy, so monitored and unmonitored
    /// backends replay bitwise-identically.  `PrefetchMode::Off` draws
    /// weights inline on the machine's own rails and is not tapped.
    pub fn with_opts_monitored(
        cfg: MachineConfig,
        pool: Option<Arc<ThreadPool>>,
        popts: PipelineOptions,
        monitor: Option<Arc<Monitor>>,
    ) -> Self {
        let n_shards = pool.as_ref().map(|p| p.worker_count()).unwrap_or(1).max(1);
        let shards = if n_shards > 1 || popts.mode.banked() {
            // banked modes use a shard front-end (EOM/detector/scratch)
            // even sequentially, so build at least one
            build_shards(&cfg, n_shards)
        } else {
            Vec::new()
        };
        Self {
            machine: PhotonicMachine::new(cfg),
            calibration: CalibrationOptions::default(),
            pool,
            shards,
            arena: ScratchArena::default(),
            popts,
            bank: None,
            produced: Arc::new(AtomicU64::new(0)),
            monitor,
            models: None,
        }
    }

    /// (Re)build the weight-plane bank if the machine program moved since
    /// it was last drawn: any `load_kernel`/`reprogram_kernel`/calibration
    /// pass bumps `programs_loaded`, which both invalidates prefetched
    /// planes and reseeds the per-tap streams (generation-keyed).
    fn ensure_bank(&mut self) {
        let generation = self.machine.stats.programs_loaded;
        if let Some(bank) = &self.bank {
            if bank.generation == generation {
                return;
            }
        }
        self.bank = None; // drop first: joins any old producers
        self.bank = Some(WeightBank::build(
            &self.machine,
            self.shards.len().max(1),
            &self.popts,
            &self.produced,
            self.monitor.as_ref(),
        ));
    }

    pub fn with_defaults(seed: u64) -> Self {
        Self::new(MachineConfig {
            seed,
            ..MachineConfig::default()
        })
    }

    /// Override the feedback-calibration options used by [`ProbConvBackend::program`].
    pub fn set_calibration_options(&mut self, opts: CalibrationOptions) {
        self.calibration = opts;
    }

    /// Direct access to the simulated hardware (calibration experiments,
    /// telemetry).  The kernel bank it holds is owned by this backend.
    pub fn machine(&mut self) -> &mut PhotonicMachine {
        &mut self.machine
    }
}

impl ProbConvBackend for PhotonicSimBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Photonic
    }

    fn program(&mut self, kernels: &[Vec<TapTarget>], calibrate: bool) -> Result<()> {
        // retire any prefetched weight planes immediately: they were drawn
        // against the outgoing program (lazy rebuild would catch it too,
        // via the generation check, but the producers would keep drawing
        // stale planes in the meantime)
        self.bank = None;
        self.machine.clear_bank();
        for targets in kernels {
            let idx = self.machine.load_kernel(targets);
            if calibrate {
                calibrate_kernel(&mut self.machine, idx, targets, &self.calibration);
            }
        }
        Ok(())
    }

    fn num_kernels(&self) -> usize {
        self.machine.bank_len()
    }

    fn sample_weight(&mut self, kernel: usize, tap: usize) -> f64 {
        self.machine.sample_weight(kernel, tap)
    }

    fn sample_conv(&mut self, plan: &SamplePlan, x: &[f32], out: &mut [f32]) -> Result<()> {
        plan.check(x.len(), out.len(), self.machine.bank_len())?;
        let item = plan.item_size();
        let banked = self.popts.mode.banked();
        if banked {
            self.ensure_bank();
        }
        if !banked && (self.shards.len() <= 1 || self.pool.is_none()) {
            // Sample-major, batch-minor on the machine's own streams: the
            // exact RNG consumption order of the old per-sample engine
            // loop, so outputs are bit-identical.
            for s in 0..plan.n_samples {
                for b in 0..plan.batch {
                    self.machine.depthwise_conv_into(
                        0,
                        &x[b * item..(b + 1) * item],
                        plan.channels,
                        plan.height,
                        plan.width,
                        &mut out[(s * plan.batch + b) * item..(s * plan.batch + b + 1) * item],
                    );
                }
            }
            return Ok(());
        }
        let (c, h, w) = (plan.channels, plan.height, plan.width);
        let hw = h * w;
        let hw9 = hw * 9;
        // shared read-only im2col planes, one per (item, channel)
        let patches = grow(&mut self.arena.patches, plan.batch * c * hw9);
        for b in 0..plan.batch {
            for ch in 0..c {
                im2col_3x3(
                    &x[b * item + ch * hw..b * item + (ch + 1) * hw],
                    h,
                    w,
                    &mut patches[(b * c + ch) * hw9..(b * c + ch + 1) * hw9],
                );
            }
        }
        let patches: &[f32] = patches;
        let grid = plan.n_samples * plan.batch;
        let plan_v = *plan;
        let nt = self.machine.num_taps();
        let scale_dac = self.machine.cfg.scale_dac;
        if banked && (self.shards.len() <= 1 || self.pool.is_none()) {
            // sequential banked path: shard 0's front-end + bank streams
            let shard = &mut self.shards[0];
            let sb = &mut self.bank.as_mut().unwrap().shards[0];
            shard.run_banked(sb, nt, scale_dac, patches, plan_v, 0, &mut out[..grid * item]);
        } else {
            let machine = &self.machine;
            let ranges = super::shard_ranges(grid, self.shards.len());
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                Vec::with_capacity(self.shards.len());
            let mut rest = &mut out[..grid * item];
            let mut banks = self
                .bank
                .as_mut()
                .map(|b| b.shards.iter_mut())
                .into_iter()
                .flatten();
            for (shard, range) in self.shards.iter_mut().zip(ranges) {
                let sb = banks.next();
                if range.is_empty() {
                    continue;
                }
                let (head, tail) = rest.split_at_mut(range.len() * item);
                rest = tail;
                let g0 = range.start;
                if banked {
                    let sb = sb.expect("bank has one shard bank per shard");
                    jobs.push(Box::new(move || {
                        shard.run_banked(sb, nt, scale_dac, patches, plan_v, g0, head);
                    }));
                } else {
                    jobs.push(Box::new(move || {
                        shard.run(machine, patches, plan_v, g0, head);
                    }));
                }
            }
            self.pool.as_ref().unwrap().scope_run(jobs);
        }
        // account the work on the machine's optical clock
        let convs = (grid * item) as u64;
        self.machine.stats.convolutions += convs;
        self.machine.stats.clock.advance_symbols(convs * nt as u64);
        Ok(())
    }

    fn report(&self) -> String {
        format!(
            "{} shards={} prefetch={} produced_draws={}",
            self.machine.throughput_report(),
            self.shards.len().max(1),
            self.popts.mode,
            self.produced.load(Ordering::Relaxed)
        )
    }

    fn entropy_health(&self) -> Option<Arc<Monitor>> {
        self.monitor.clone()
    }

    fn enable_model_cache(&mut self, budget_bytes: usize, metrics: Arc<RegistryMetrics>) {
        self.models = Some(ModelCache::new(budget_bytes, metrics));
    }

    /// Swap the active [`ModelState`] through the registry cache.  A hit
    /// restores the model's machine, front-ends, and prefetched bank intact
    /// (its entropy streams continue where they left off — identical to a
    /// single-model engine that never switched away); a miss rebuilds
    /// everything from `key.seed`, so an eviction-then-reload replays the
    /// model bitwise from the start.  The per-model machine keeps its own
    /// `programs_loaded` generation, so the existing generation-keyed bank
    /// invalidation works unchanged within each model.
    fn switch_program(
        &mut self,
        key: &ProgramKey,
        kernels: &[Vec<TapTarget>],
        calibrate: bool,
    ) -> Result<()> {
        if self.models.is_none() {
            // switching without an explicit cache: attach an unbounded
            // private one so per-model determinism still holds
            self.models = Some(ModelCache::new(
                usize::MAX,
                Arc::new(RegistryMetrics::default()),
            ));
        }
        if self.models.as_ref().unwrap().is_active(&key.model) {
            return Ok(());
        }
        let mut cache = self.models.take().unwrap();
        let had_active = cache.active_model().is_some();
        let (state, bytes) = match cache.checkout(&key.model) {
            Some(hit) => hit,
            None => {
                // cold load: a fresh machine seeded from the model-mixed
                // seed, programmed (and optionally calibrated) exactly as a
                // cold single-model backend would be
                let cfg = MachineConfig {
                    seed: key.seed,
                    scale_dac: key.scale_dac,
                    scale_adc: key.scale_adc,
                    ..self.machine.cfg.clone()
                };
                let mut machine = PhotonicMachine::new(cfg.clone());
                for targets in kernels {
                    let idx = machine.load_kernel(targets);
                    if calibrate {
                        calibrate_kernel(&mut machine, idx, targets, &self.calibration);
                    }
                }
                let n_shards = self.pool.as_ref().map(|p| p.worker_count()).unwrap_or(1).max(1);
                let shards = if n_shards > 1 || self.popts.mode.banked() {
                    build_shards(&cfg, n_shards)
                } else {
                    Vec::new()
                };
                let bytes =
                    estimate_state_bytes(n_shards, kernels.len(), machine.num_taps(), &self.popts);
                (
                    ModelState {
                        machine,
                        shards,
                        bank: None, // prefetched lazily by ensure_bank
                    },
                    bytes,
                )
            }
        };
        let prev = ModelState {
            machine: std::mem::replace(&mut self.machine, state.machine),
            shards: std::mem::replace(&mut self.shards, state.shards),
            bank: std::mem::replace(&mut self.bank, state.bank),
        };
        // the constructor's placeholder state (no model was active yet) is
        // not worth caching — drop it instead of stashing
        cache.commit(&key.model, bytes, had_active.then_some(prev));
        self.models = Some(cache);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::PrefetchMode;
    use crate::util::mathstat::Welford;

    fn quiet(seed: u64) -> PhotonicSimBackend {
        PhotonicSimBackend::new(MachineConfig {
            rx_noise: 0.0,
            actuator_sigma: 0.0,
            actuator_jitter: 0.0,
            ripple_rms_ps: 0.0,
            seed,
            ..MachineConfig::default()
        })
    }

    #[test]
    fn program_replaces_bank() {
        let mut be = quiet(3);
        let k1 = vec![vec![TapTarget { mu: 0.2, sigma: 0.2 }; 9]; 3];
        be.program(&k1, false).unwrap();
        assert_eq!(be.num_kernels(), 3);
        let k2 = vec![vec![TapTarget { mu: -0.1, sigma: 0.3 }; 9]; 2];
        be.program(&k2, false).unwrap();
        assert_eq!(be.num_kernels(), 2);
    }

    #[test]
    fn calibration_improves_noisy_realization() {
        let cfg = MachineConfig {
            actuator_sigma: 0.05,
            actuator_jitter: 0.005,
            rx_noise: 0.0,
            seed: 12,
            ..MachineConfig::default()
        };
        let targets = vec![vec![TapTarget { mu: 0.5, sigma: 0.25 }; 9]];
        let measure = |be: &mut PhotonicSimBackend| -> f64 {
            let mut w = Welford::new();
            for _ in 0..4000 {
                w.push(be.sample_weight(0, 2));
            }
            (w.mean() - 0.5).abs()
        };
        let mut open_loop = PhotonicSimBackend::new(cfg.clone());
        open_loop.program(&targets, false).unwrap();
        let mut closed_loop = PhotonicSimBackend::new(cfg);
        closed_loop.program(&targets, true).unwrap();
        // identical machines, so any improvement is the feedback loop's
        let err_open = measure(&mut open_loop);
        let err_closed = measure(&mut closed_loop);
        assert!(
            err_closed < err_open + 0.01,
            "open {err_open} closed {err_closed}"
        );
    }

    fn banked_backend(seed: u64, mode: PrefetchMode) -> PhotonicSimBackend {
        PhotonicSimBackend::with_opts(
            MachineConfig {
                rx_noise: 0.0,
                actuator_sigma: 0.0,
                actuator_jitter: 0.0,
                ripple_rms_ps: 0.0,
                seed,
                ..MachineConfig::default()
            },
            None,
            PipelineOptions {
                mode,
                block: 128,
                depth: 2,
            },
        )
    }

    #[test]
    fn banked_sync_and_prefetched_agree_bitwise() {
        let kernels = vec![vec![TapTarget { mu: 0.4, sigma: 0.3 }; 9]; 2];
        let plan = SamplePlan::new(3, 2, 2, 4, 4);
        let x: Vec<f32> = (0..plan.sample_size()).map(|i| 0.25 * (i % 5) as f32).collect();
        let run = |mode| {
            let mut be = banked_backend(31, mode);
            be.program(&kernels, false).unwrap();
            let mut out = vec![0.0f32; plan.total_size()];
            be.sample_conv(&plan, &x, &mut out).unwrap();
            out
        };
        let sync = run(PrefetchMode::Sync);
        let piped = run(PrefetchMode::On);
        assert_eq!(sync, piped);
        assert!(sync.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn weight_bank_invalidated_on_reprogram() {
        let plan = SamplePlan::new(8, 1, 1, 4, 4);
        let x = vec![2.0f32; plan.sample_size()];
        let mean_of = |out: &[f32]| out.iter().map(|&v| v as f64).sum::<f64>() / out.len() as f64;
        for mode in [PrefetchMode::Sync, PrefetchMode::On] {
            let mut be = banked_backend(5, mode);
            be.program(&[vec![TapTarget { mu: 0.6, sigma: 0.2 }; 9]], false).unwrap();
            let mut hi = vec![0.0f32; plan.total_size()];
            be.sample_conv(&plan, &x, &mut hi).unwrap();
            // reprogram to a strongly negative kernel: prefetched planes
            // drawn against the old program must not leak into the output
            be.program(&[vec![TapTarget { mu: -0.6, sigma: 0.2 }; 9]], false).unwrap();
            let mut lo = vec![0.0f32; plan.total_size()];
            be.sample_conv(&plan, &x, &mut lo).unwrap();
            assert!(
                mean_of(&hi) > 0.5 && mean_of(&lo) < -0.5,
                "{mode}: hi {} lo {}",
                mean_of(&hi),
                mean_of(&lo)
            );
        }
    }

    #[test]
    fn model_switch_continues_streams_like_an_unswitched_engine() {
        let plan = SamplePlan::new(3, 1, 1, 4, 4);
        let x = vec![1.5f32; plan.sample_size()];
        let ka = vec![vec![TapTarget { mu: 0.5, sigma: 0.2 }; 9]];
        let kb = vec![vec![TapTarget { mu: -0.5, sigma: 0.2 }; 9]];
        let mean_of = |out: &[f32]| out.iter().map(|&v| v as f64).sum::<f64>() / out.len() as f64;
        for mode in [PrefetchMode::Sync, PrefetchMode::On] {
            let mk = |model: &str, be: &PhotonicSimBackend| {
                let cfg = &be.machine.cfg;
                ProgramKey::new(model, 77, cfg.scale_dac, cfg.scale_adc)
            };
            let sample = |be: &mut PhotonicSimBackend| {
                let mut out = vec![0.0f32; plan.total_size()];
                be.sample_conv(&plan, &x, &mut out).unwrap();
                out
            };
            // interleaved: a, b, a again (default unbounded cache -> hit)
            let mut be = banked_backend(8, mode);
            let (key_a, key_b) = (mk("a", &be), mk("b", &be));
            be.switch_program(&key_a, &ka, false).unwrap();
            let a1 = sample(&mut be);
            be.switch_program(&key_b, &kb, false).unwrap();
            let b1 = sample(&mut be);
            be.switch_program(&key_a, &ka, false).unwrap();
            let a2 = sample(&mut be);
            assert!(mean_of(&b1) < -0.4, "b serves its own program, not a's");
            // reference: same backend config, never switched away from a
            let mut rf = banked_backend(8, mode);
            let key_a_rf = mk("a", &rf);
            rf.switch_program(&key_a_rf, &ka, false).unwrap();
            assert_eq!(a1, sample(&mut rf), "{mode}: first pass replays");
            assert_eq!(a2, sample(&mut rf), "{mode}: hit continues the stream");
        }
    }

    #[test]
    fn sample_conv_rejects_bad_shapes() {
        let mut be = quiet(4);
        be.program(&[vec![TapTarget { mu: 0.1, sigma: 0.2 }; 9]], false)
            .unwrap();
        let plan = SamplePlan::new(2, 1, 1, 3, 3);
        let x = vec![0.1f32; plan.sample_size()];
        let mut small = vec![0.0f32; plan.total_size() - 1];
        assert!(be.sample_conv(&plan, &x, &mut small).is_err());
        let wide = SamplePlan::new(2, 1, 2, 3, 3); // needs 2 kernels, bank has 1
        let x2 = vec![0.1f32; wide.sample_size()];
        let mut out = vec![0.0f32; wide.total_size()];
        assert!(be.sample_conv(&wide, &x2, &mut out).is_err());
    }
}
