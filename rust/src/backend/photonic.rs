//! [`PhotonicSimBackend`] — the photonic Bayesian machine simulator behind
//! the backend-agnostic probabilistic-convolution API.
//!
//! Randomness comes from the machine's chaotic light (Gamma-distributed
//! speckle intensity per tap per symbol); there is no PRNG on the request
//! path.  Programming goes through the physics inversion plus, optionally,
//! the feedback-calibration loop that corrects spectral-shaper actuator
//! error (paper, Supplement).
//!
//! ## Threading and determinism
//!
//! With a worker pool attached, `sample_conv` shards the flattened
//! `n_samples x batch` grid across the workers.  Each shard owns an
//! independent [`ChaoticLightSource`] (its own 9 decorrelated spectral
//! streams) and receiver, seeded deterministically from the machine seed —
//! the software analogue of splitting the ASE spectrum across parallel
//! readout channels.  Outputs are bitwise-deterministic for a fixed
//! `(seed, n_threads)` and statistically equivalent across thread counts.
//! Without a pool the machine's own streams are used, bit-identical to the
//! historical per-sample loop.

use std::sync::Arc;

use anyhow::Result;

use super::{BackendKind, ProbConvBackend, SamplePlan};
use crate::calibration::{calibrate_kernel, CalibrationOptions};
use crate::entropy::chaotic::ChaoticLightSource;
use crate::entropy::xoshiro::splitmix64;
use crate::exec::scratch::{grow, ScratchArena};
use crate::exec::ThreadPool;
use crate::photonics::detector::Detector;
use crate::photonics::eom::Eom;
use crate::photonics::machine::{conv_patches_core, im2col_3x3};
use crate::photonics::{MachineConfig, PhotonicMachine, TapTarget};

/// One worker's private optical front-end: an independent chaotic source,
/// receiver, and conv scratch.  The kernel bank stays shared (read-only).
struct PhotonicShard {
    eom: Eom,
    src: ChaoticLightSource,
    det: Detector,
    scratch: ScratchArena,
}

impl PhotonicShard {
    /// Convolve rows `[g0, g0 + out.len()/item)` of the flattened
    /// `(sample, batch)` grid against the machine's programmed bank.
    fn run(
        &mut self,
        machine: &PhotonicMachine,
        patches: &[f32],
        plan: SamplePlan,
        g0: usize,
        out: &mut [f32],
    ) {
        let c = plan.channels;
        let hw = plan.height * plan.width;
        let hw9 = hw * 9;
        let item = c * hw;
        let rows = out.len() / item;
        let scale_dac = machine.cfg.scale_dac;
        for r in 0..rows {
            let b = (g0 + r) % plan.batch;
            for ch in 0..c {
                conv_patches_core(
                    machine.kernel(ch).flat(),
                    &patches[(b * c + ch) * hw9..(b * c + ch + 1) * hw9],
                    9,
                    scale_dac,
                    &self.eom,
                    &mut self.src,
                    &mut self.det,
                    &mut self.scratch,
                    &mut out[r * item + ch * hw..r * item + (ch + 1) * hw],
                );
            }
        }
    }
}

/// Deterministic per-shard optical front-ends for a machine configuration.
fn build_shards(cfg: &MachineConfig, n: usize) -> Vec<PhotonicShard> {
    let mut st = cfg.seed ^ 0x5EED_0F_C0A7_1C57;
    (0..n)
        .map(|_| {
            let src_seed = splitmix64(&mut st);
            let det_seed = splitmix64(&mut st);
            PhotonicShard {
                eom: Eom::new(cfg.scale_dac, cfg.extinction_db),
                src: ChaoticLightSource::new(cfg.source.clone(), src_seed),
                det: Detector::new(cfg.scale_adc, cfg.rx_noise, det_seed),
                scratch: ScratchArena::default(),
            }
        })
        .collect()
}

/// The chaotic-light substrate (simulator).
pub struct PhotonicSimBackend {
    machine: PhotonicMachine,
    calibration: CalibrationOptions,
    pool: Option<Arc<ThreadPool>>,
    shards: Vec<PhotonicShard>,
    arena: ScratchArena,
}

impl PhotonicSimBackend {
    pub fn new(cfg: MachineConfig) -> Self {
        Self::with_pool(cfg, None)
    }

    /// Backend whose `sample_conv` shards plans across `pool` (sequential
    /// and bit-identical to the historical loop when `None` or
    /// single-worker).
    pub fn with_pool(cfg: MachineConfig, pool: Option<Arc<ThreadPool>>) -> Self {
        let n_shards = pool.as_ref().map(|p| p.worker_count()).unwrap_or(1).max(1);
        let shards = if n_shards > 1 {
            build_shards(&cfg, n_shards)
        } else {
            Vec::new()
        };
        Self {
            machine: PhotonicMachine::new(cfg),
            calibration: CalibrationOptions::default(),
            pool,
            shards,
            arena: ScratchArena::default(),
        }
    }

    pub fn with_defaults(seed: u64) -> Self {
        Self::new(MachineConfig {
            seed,
            ..MachineConfig::default()
        })
    }

    /// Override the feedback-calibration options used by [`ProbConvBackend::program`].
    pub fn set_calibration_options(&mut self, opts: CalibrationOptions) {
        self.calibration = opts;
    }

    /// Direct access to the simulated hardware (calibration experiments,
    /// telemetry).  The kernel bank it holds is owned by this backend.
    pub fn machine(&mut self) -> &mut PhotonicMachine {
        &mut self.machine
    }
}

impl ProbConvBackend for PhotonicSimBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Photonic
    }

    fn program(&mut self, kernels: &[Vec<TapTarget>], calibrate: bool) -> Result<()> {
        self.machine.clear_bank();
        for targets in kernels {
            let idx = self.machine.load_kernel(targets);
            if calibrate {
                calibrate_kernel(&mut self.machine, idx, targets, &self.calibration);
            }
        }
        Ok(())
    }

    fn num_kernels(&self) -> usize {
        self.machine.bank_len()
    }

    fn sample_weight(&mut self, kernel: usize, tap: usize) -> f64 {
        self.machine.sample_weight(kernel, tap)
    }

    fn sample_conv(&mut self, plan: &SamplePlan, x: &[f32], out: &mut [f32]) -> Result<()> {
        plan.check(x.len(), out.len(), self.machine.bank_len())?;
        let item = plan.item_size();
        if self.shards.len() <= 1 || self.pool.is_none() {
            // Sample-major, batch-minor on the machine's own streams: the
            // exact RNG consumption order of the old per-sample engine
            // loop, so outputs are bit-identical.
            for s in 0..plan.n_samples {
                for b in 0..plan.batch {
                    self.machine.depthwise_conv_into(
                        0,
                        &x[b * item..(b + 1) * item],
                        plan.channels,
                        plan.height,
                        plan.width,
                        &mut out[(s * plan.batch + b) * item..(s * plan.batch + b + 1) * item],
                    );
                }
            }
            return Ok(());
        }
        let (c, h, w) = (plan.channels, plan.height, plan.width);
        let hw = h * w;
        let hw9 = hw * 9;
        // shared read-only im2col planes, one per (item, channel)
        let patches = grow(&mut self.arena.patches, plan.batch * c * hw9);
        for b in 0..plan.batch {
            for ch in 0..c {
                im2col_3x3(
                    &x[b * item + ch * hw..b * item + (ch + 1) * hw],
                    h,
                    w,
                    &mut patches[(b * c + ch) * hw9..(b * c + ch + 1) * hw9],
                );
            }
        }
        let patches: &[f32] = patches;
        let grid = plan.n_samples * plan.batch;
        let machine = &self.machine;
        let plan_v = *plan;
        let ranges = super::shard_ranges(grid, self.shards.len());
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(self.shards.len());
        let mut rest = &mut out[..grid * item];
        for (shard, range) in self.shards.iter_mut().zip(ranges) {
            if range.is_empty() {
                continue;
            }
            let (head, tail) = rest.split_at_mut(range.len() * item);
            rest = tail;
            let g0 = range.start;
            jobs.push(Box::new(move || {
                shard.run(machine, patches, plan_v, g0, head);
            }));
        }
        self.pool.as_ref().unwrap().scope_run(jobs);
        // account the sharded work on the machine's optical clock
        let convs = (grid * item) as u64;
        let nt = self.machine.num_taps() as u64;
        self.machine.stats.convolutions += convs;
        self.machine.stats.clock.advance_symbols(convs * nt);
        Ok(())
    }

    fn report(&self) -> String {
        format!(
            "{} shards={}",
            self.machine.throughput_report(),
            self.shards.len().max(1)
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::mathstat::Welford;

    fn quiet(seed: u64) -> PhotonicSimBackend {
        PhotonicSimBackend::new(MachineConfig {
            rx_noise: 0.0,
            actuator_sigma: 0.0,
            actuator_jitter: 0.0,
            ripple_rms_ps: 0.0,
            seed,
            ..MachineConfig::default()
        })
    }

    #[test]
    fn program_replaces_bank() {
        let mut be = quiet(3);
        let k1 = vec![vec![TapTarget { mu: 0.2, sigma: 0.2 }; 9]; 3];
        be.program(&k1, false).unwrap();
        assert_eq!(be.num_kernels(), 3);
        let k2 = vec![vec![TapTarget { mu: -0.1, sigma: 0.3 }; 9]; 2];
        be.program(&k2, false).unwrap();
        assert_eq!(be.num_kernels(), 2);
    }

    #[test]
    fn calibration_improves_noisy_realization() {
        let cfg = MachineConfig {
            actuator_sigma: 0.05,
            actuator_jitter: 0.005,
            rx_noise: 0.0,
            seed: 12,
            ..MachineConfig::default()
        };
        let targets = vec![vec![TapTarget { mu: 0.5, sigma: 0.25 }; 9]];
        let measure = |be: &mut PhotonicSimBackend| -> f64 {
            let mut w = Welford::new();
            for _ in 0..4000 {
                w.push(be.sample_weight(0, 2));
            }
            (w.mean() - 0.5).abs()
        };
        let mut open_loop = PhotonicSimBackend::new(cfg.clone());
        open_loop.program(&targets, false).unwrap();
        let mut closed_loop = PhotonicSimBackend::new(cfg);
        closed_loop.program(&targets, true).unwrap();
        // identical machines, so any improvement is the feedback loop's
        let err_open = measure(&mut open_loop);
        let err_closed = measure(&mut closed_loop);
        assert!(
            err_closed < err_open + 0.01,
            "open {err_open} closed {err_closed}"
        );
    }

    #[test]
    fn sample_conv_rejects_bad_shapes() {
        let mut be = quiet(4);
        be.program(&[vec![TapTarget { mu: 0.1, sigma: 0.2 }; 9]], false)
            .unwrap();
        let plan = SamplePlan::new(2, 1, 1, 3, 3);
        let x = vec![0.1f32; plan.sample_size()];
        let mut small = vec![0.0f32; plan.total_size() - 1];
        assert!(be.sample_conv(&plan, &x, &mut small).is_err());
        let wide = SamplePlan::new(2, 1, 2, 3, 3); // needs 2 kernels, bank has 1
        let x2 = vec![0.1f32; wide.sample_size()];
        let mut out = vec![0.0f32; wide.total_size()];
        assert!(be.sample_conv(&wide, &x2, &mut out).is_err());
    }
}
