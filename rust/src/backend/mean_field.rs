//! [`MeanFieldBackend`] — deterministic mean-weight serving.
//!
//! Collapses every programmed weight distribution to its mean, so a request
//! needs exactly one forward pass (N = 1): the engine detects
//! [`ProbConvBackend::is_deterministic`] and skips the sample fan-out
//! entirely.  No uncertainty estimates survive (MI and sample variance are
//! identically zero) — this is the fast path for traffic that only wants
//! the point prediction, and the control in photonic-vs-digital ablations
//! (how much accuracy/uncertainty the stochastic passes actually buy).

use anyhow::Result;

use super::{BackendKind, ProbConvBackend, SamplePlan};
use crate::exec::scratch::{grow, ScratchArena};
use crate::photonics::converters::Quantizer;
use crate::photonics::machine::im2col_3x3;
use crate::photonics::TapTarget;

/// Deterministic mean-weight substrate.
pub struct MeanFieldBackend {
    kernels: Vec<Vec<TapTarget>>,
    dac: Quantizer,
    adc: Quantizer,
    arena: ScratchArena,
    pub convolutions: u64,
}

impl MeanFieldBackend {
    pub fn new(scale_dac: f32, scale_adc: f32) -> Self {
        Self {
            kernels: Vec::new(),
            dac: Quantizer::new(scale_dac),
            adc: Quantizer::new(scale_adc),
            arena: ScratchArena::default(),
            convolutions: 0,
        }
    }
}

impl ProbConvBackend for MeanFieldBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::MeanField
    }

    fn is_deterministic(&self) -> bool {
        true
    }

    fn program(&mut self, kernels: &[Vec<TapTarget>], _calibrate: bool) -> Result<()> {
        super::validate_kernels9("mean-field", kernels)?;
        self.kernels = kernels.to_vec();
        Ok(())
    }

    fn num_kernels(&self) -> usize {
        self.kernels.len()
    }

    fn sample_weight(&mut self, kernel: usize, tap: usize) -> f64 {
        self.kernels[kernel][tap].mu as f64
    }

    fn sample_conv(&mut self, plan: &SamplePlan, x: &[f32], out: &mut [f32]) -> Result<()> {
        plan.check(x.len(), out.len(), self.kernels.len())?;
        let (c, h, w) = (plan.channels, plan.height, plan.width);
        let item = plan.item_size();
        let patches = grow(&mut self.arena.patches, h * w * 9);
        // compute the first sample, then replicate: identical by definition
        for b in 0..plan.batch {
            let xi = &x[b * item..(b + 1) * item];
            for ch in 0..c {
                im2col_3x3(&xi[ch * h * w..(ch + 1) * h * w], h, w, patches);
                let kern = &self.kernels[ch];
                let oi = b * item + ch * h * w;
                super::conv_plane_quantized(
                    patches,
                    h * w,
                    &self.dac,
                    &self.adc,
                    |_, tap| kern[tap].mu as f64,
                    &mut out[oi..oi + h * w],
                );
            }
        }
        let sample = plan.sample_size();
        for s in 1..plan.n_samples {
            out.copy_within(0..sample, s * sample);
        }
        self.convolutions += plan.sample_size() as u64;
        Ok(())
    }

    fn report(&self) -> String {
        format!("convolutions={} (deterministic mean weights, N = 1)", self.convolutions)
    }

    /// Stateless across models (no streams, no banks) — a switch is just a
    /// reprogram, but the per-model DAC/ADC ranges on the key still apply.
    fn switch_program(
        &mut self,
        key: &crate::registry::ProgramKey,
        kernels: &[Vec<TapTarget>],
        calibrate: bool,
    ) -> Result<()> {
        self.dac = Quantizer::new(key.scale_dac);
        self.adc = Quantizer::new(key.scale_adc);
        self.program(kernels, calibrate)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn targets9(mu: f32, sigma: f32) -> Vec<TapTarget> {
        vec![TapTarget { mu, sigma }; 9]
    }

    #[test]
    fn is_deterministic_and_ignores_sigma() {
        let mut be = MeanFieldBackend::new(4.0, 8.0);
        be.program(&[targets9(0.7, 0.9)], false).unwrap();
        assert!(be.is_deterministic());
        assert_eq!(be.sample_weight(0, 0), be.sample_weight(0, 0));
        assert!((be.sample_weight(0, 3) - 0.7).abs() < 1e-9);
    }

    #[test]
    fn replicated_samples_are_identical() {
        let mut be = MeanFieldBackend::new(4.0, 8.0);
        be.program(&[targets9(0.3, 0.4)], false).unwrap();
        let plan = SamplePlan::new(5, 2, 1, 4, 4);
        let x: Vec<f32> = (0..plan.sample_size()).map(|i| 0.1 * (i % 7) as f32).collect();
        let mut out = vec![0.0f32; plan.total_size()];
        be.sample_conv(&plan, &x, &mut out).unwrap();
        let first = &out[..plan.sample_size()];
        for s in 1..plan.n_samples {
            assert_eq!(first, &out[s * plan.sample_size()..(s + 1) * plan.sample_size()]);
        }
        // only the first sample's pixels are counted as real convolutions
        assert_eq!(be.convolutions, plan.sample_size() as u64);
    }

    #[test]
    fn rejects_non_nine_tap_kernels() {
        let mut be = MeanFieldBackend::new(4.0, 8.0);
        assert!(be.program(&[vec![TapTarget { mu: 0.0, sigma: 0.0 }; 4]], false).is_err());
    }
}
