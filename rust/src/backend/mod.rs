//! Backend-agnostic probabilistic-convolution API.
//!
//! The paper's central comparison — chaotic light vs a digital PRNG as the
//! sampling substrate of Bayesian inference — needs a seam where the two can
//! be swapped without touching the serving coordinator.  [`ProbConvBackend`]
//! is that seam: the single API for programming a Gaussian-weight kernel
//! bank and executing a **batched sample plan** (all `N` stochastic samples
//! × `B` batch items of one request in a single call, replacing the
//! coordinator's old per-sample loops).
//!
//! Three implementations ship:
//!
//! | backend | substrate | randomness | when to use |
//! |---------|-----------|------------|-------------|
//! | [`PhotonicSimBackend`] | photonic machine simulator | chaotic light (Gamma speckle) | paper-faithful serving, calibration studies |
//! | [`DigitalBaselineBackend`] | CPU | xoshiro256++ + Box–Muller | the paper's digital comparison point |
//! | [`MeanFieldBackend`] | CPU | none (mean weights) | uncertainty-free fast serving, N = 1 |
//!
//! [`EpsSource`] is the same seam for the *surrogate* execution path and the
//! SVI trainer: a pluggable provider of the unit-variance `eps` noise
//! operand, backed by either the chaotic source or the digital PRNG.

pub mod digital;
pub mod mean_field;
pub mod photonic;

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::entropy::chaotic::ChaoticLightSource;
use crate::entropy::gaussian::Gaussian;
use crate::entropy::health::Monitor;
use crate::entropy::Xoshiro256pp;
use crate::exec::ThreadPool;
use crate::photonics::{MachineConfig, TapTarget};

pub use crate::entropy::pipeline::{PipelineOptions, PrefetchMode};
pub use digital::DigitalBaselineBackend;
pub use mean_field::MeanFieldBackend;
pub use photonic::PhotonicSimBackend;

/// Which probabilistic-convolution substrate to serve from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// The photonic Bayesian machine simulator (chaotic-light sampling).
    Photonic,
    /// xoshiro256++ + Box–Muller weight draws — the digital baseline.
    Digital,
    /// Deterministic mean weights — the uncertainty-free fast path.
    MeanField,
}

impl BackendKind {
    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Photonic => "photonic",
            BackendKind::Digital => "digital",
            BackendKind::MeanField => "mean",
        }
    }

    /// Parse a CLI/config token (`photonic|digital|mean`).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "photonic" => Ok(BackendKind::Photonic),
            "digital" => Ok(BackendKind::Digital),
            "mean" | "mean-field" | "meanfield" => Ok(BackendKind::MeanField),
            other => Err(anyhow!("backend must be photonic|digital|mean, got {other}")),
        }
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// A batched sampling plan: `n_samples` stochastic forward samples of a
/// `batch`-item depthwise-convolution workload over `(channels, height,
/// width)` activation maps.
///
/// Input layout: `(batch, channels, height, width)` row-major, length
/// [`SamplePlan::sample_size`].  Output layout: `(n_samples, batch,
/// channels, height, width)` row-major, length [`SamplePlan::total_size`] —
/// sample-major so each sample block can feed one `fwd_post` call directly.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SamplePlan {
    pub n_samples: usize,
    pub batch: usize,
    pub channels: usize,
    pub height: usize,
    pub width: usize,
}

impl SamplePlan {
    pub fn new(
        n_samples: usize,
        batch: usize,
        channels: usize,
        height: usize,
        width: usize,
    ) -> Self {
        Self {
            n_samples,
            batch,
            channels,
            height,
            width,
        }
    }

    /// Activations per batch item.
    pub fn item_size(&self) -> usize {
        self.channels * self.height * self.width
    }

    /// Input buffer length (one full batch).
    pub fn sample_size(&self) -> usize {
        self.batch * self.item_size()
    }

    /// Output buffer length (all samples of all batch items).
    pub fn total_size(&self) -> usize {
        self.n_samples * self.sample_size()
    }

    /// Overflow-checked [`Self::item_size`] — plans can arrive from
    /// untrusted request fields, so size math must not wrap.
    pub fn checked_item_size(&self) -> Option<usize> {
        self.channels
            .checked_mul(self.height)?
            .checked_mul(self.width)
    }

    /// Overflow-checked [`Self::sample_size`].
    pub fn checked_sample_size(&self) -> Option<usize> {
        self.batch.checked_mul(self.checked_item_size()?)
    }

    /// Overflow-checked [`Self::total_size`].
    pub fn checked_total_size(&self) -> Option<usize> {
        self.n_samples.checked_mul(self.checked_sample_size()?)
    }

    /// Total probe convolutions (output pixels) the plan executes.
    pub fn convolutions(&self) -> u64 {
        (self.total_size()) as u64
    }

    /// Validate buffer shapes against this plan and a backend's kernel bank.
    /// All size math is overflow-checked: a hostile plan is rejected with a
    /// clear error instead of wrapping into a tiny (or enormous) buffer.
    pub fn check(&self, x_len: usize, out_len: usize, bank_len: usize) -> Result<()> {
        if self.n_samples == 0 || self.batch == 0 {
            return Err(anyhow!("empty sample plan: {self:?}"));
        }
        if self.channels == 0 || self.height == 0 || self.width == 0 {
            return Err(anyhow!("degenerate sample plan (zero-sized item): {self:?}"));
        }
        let total = self
            .checked_total_size()
            .ok_or_else(|| anyhow!("sample plan size overflows usize: {self:?}"))?;
        let sample = self.sample_size(); // safe: total checked above
        if x_len != sample {
            return Err(anyhow!(
                "plan input {} != batch {} x item {}",
                x_len,
                self.batch,
                self.item_size()
            ));
        }
        if out_len < total {
            return Err(anyhow!("plan output {} < required {}", out_len, total));
        }
        if bank_len < self.channels {
            return Err(anyhow!(
                "kernel bank has {} kernels, plan needs {}",
                bank_len,
                self.channels
            ));
        }
        Ok(())
    }
}

/// Split `0..n` into at most `shards` contiguous near-equal ranges (the
/// leading ranges absorb the remainder; trailing ranges may be empty).
/// Deterministic: the same `(n, shards)` always yields the same partition —
/// one half of the `(seed, n_threads)` reproducibility contract of sharded
/// sampling.
pub(crate) fn shard_ranges(n: usize, shards: usize) -> Vec<std::ops::Range<usize>> {
    let shards = shards.max(1);
    let base = n / shards;
    let rem = n % shards;
    let mut start = 0usize;
    (0..shards)
        .map(|i| {
            let len = base + usize::from(i < rem);
            let r = start..start + len;
            start += len;
            r
        })
        .collect()
}

/// The single API every sampling substrate implements: program a bank of
/// Gaussian weight kernels, then execute batched sample plans against it.
///
/// Implementations are used from the engine's dedicated thread and need not
/// be `Send`; all state (PRNGs, simulated hardware) is owned by the backend.
pub trait ProbConvBackend {
    fn kind(&self) -> BackendKind;

    fn name(&self) -> &'static str {
        self.kind().name()
    }

    /// A deterministic backend produces identical samples, so the engine
    /// collapses a request's `N` passes to a single one.
    fn is_deterministic(&self) -> bool {
        false
    }

    /// Program the kernel bank (one 9-tap kernel per depthwise channel),
    /// replacing any previous program.  `calibrate` requests feedback
    /// calibration where the substrate has actuator error; exact substrates
    /// ignore it.
    fn program(&mut self, kernels: &[Vec<TapTarget>], calibrate: bool) -> Result<()>;

    /// Number of kernels currently programmed.
    fn num_kernels(&self) -> usize;

    /// Draw one instantaneous weight sample of tap `tap` of kernel `kernel`
    /// (a probe measurement; statistical-equivalence tests are built on it).
    fn sample_weight(&mut self, kernel: usize, tap: usize) -> f64;

    /// Execute a batched sample plan: all `plan.n_samples` × `plan.batch`
    /// depthwise probabilistic convolutions in one call.  See [`SamplePlan`]
    /// for buffer layouts.
    fn sample_conv(&mut self, plan: &SamplePlan, x: &[f32], out: &mut [f32]) -> Result<()>;

    /// One-line substrate telemetry (counters, simulated optical time, ...).
    fn report(&self) -> String;

    /// The entropy-health monitor observing this backend's producer streams,
    /// if one was attached at construction.  Deterministic substrates (mean
    /// field) and unmonitored builds return `None`.
    fn entropy_health(&self) -> Option<Arc<Monitor>> {
        None
    }

    /// Program-switch to a named model.  Stateful substrates swap the
    /// model's machine/stream/bank state through their model cache,
    /// reseeding streams deterministically from `key.seed` on a cold load
    /// so outputs replay bitwise per `(model, seed, threads, prefetch)`.
    /// The default treats every switch as a plain reprogram — correct for
    /// substrates with no per-model stream state.
    fn switch_program(
        &mut self,
        _key: &crate::registry::ProgramKey,
        kernels: &[Vec<TapTarget>],
        calibrate: bool,
    ) -> Result<()> {
        self.program(kernels, calibrate)
    }

    /// Attach a model cache (byte budget + shared residency metrics) ahead
    /// of [`ProbConvBackend::switch_program`] use.  Substrates without
    /// cacheable per-model state ignore it.
    fn enable_model_cache(
        &mut self,
        _budget_bytes: usize,
        _metrics: Arc<crate::registry::RegistryMetrics>,
    ) {
    }
}

/// Reject kernels the 3x3 depthwise conv path cannot execute.
pub(crate) fn validate_kernels9(backend: &str, kernels: &[Vec<TapTarget>]) -> Result<()> {
    for (i, k) in kernels.iter().enumerate() {
        if k.len() != 9 {
            return Err(anyhow!(
                "kernel {i}: {backend} backend needs 9 taps, got {}",
                k.len()
            ));
        }
    }
    Ok(())
}

/// Shared inner loop of the CPU substrates: convolve one im2col'd plane
/// with per-tap weights from `weight(pixel, tap)`, mirroring the photonic
/// signal chain's digital interface — DAC quantization on the (post-ReLU)
/// activations, ADC quantization on the readout.  Keeping digital and
/// mean-field on this one code path is what the
/// `digital_and_mean_conv_agree_in_expectation` test relies on; the digital
/// backend reads pre-drawn bulk normals indexed by `(pixel, tap)`.
pub(crate) fn conv_plane_quantized<W: FnMut(usize, usize) -> f64>(
    patches: &[f32],
    n_pixels: usize,
    dac: &crate::photonics::converters::Quantizer,
    adc: &crate::photonics::converters::Quantizer,
    mut weight: W,
    out: &mut [f32],
) {
    for (p, o) in out.iter_mut().take(n_pixels).enumerate() {
        let patch = &patches[p * 9..(p + 1) * 9];
        let mut acc = 0.0f64;
        for (k, &xv) in patch.iter().enumerate() {
            acc += weight(p, k) * dac.quantize(xv.max(0.0)) as f64;
        }
        *o = adc.quantize(acc as f32);
    }
}

/// Build a backend of `kind` from a machine configuration.  Digital backends
/// reuse the config's DAC/ADC scales and seed so all substrates see the same
/// quantized signal chain.  No worker pool: `sample_conv` runs sequentially
/// on the caller (bit-compatible with the pre-pool engine).
pub fn build(kind: BackendKind, cfg: &MachineConfig) -> Box<dyn ProbConvBackend> {
    build_with_pool(kind, cfg, None)
}

/// Build a backend that shards every [`SamplePlan`] across `pool`'s workers
/// (one deterministic entropy stream per worker; see the crate README's
/// Performance section for the `(seed, n_threads)` contract).  `None` — or
/// a single-worker pool — selects the sequential path.
pub fn build_with_pool(
    kind: BackendKind,
    cfg: &MachineConfig,
    pool: Option<Arc<ThreadPool>>,
) -> Box<dyn ProbConvBackend> {
    build_with_opts(kind, cfg, pool, PipelineOptions::default())
}

/// Build a backend with full pipeline control: worker pool sharding plus
/// the decoupled-entropy options (`PrefetchMode::{Off, Sync, On}` and the
/// block/depth knobs).  See the crate README's Performance section for the
/// `(seed, threads, prefetch)` reproducibility contract.
pub fn build_with_opts(
    kind: BackendKind,
    cfg: &MachineConfig,
    pool: Option<Arc<ThreadPool>>,
    popts: PipelineOptions,
) -> Box<dyn ProbConvBackend> {
    build_with_opts_monitored(kind, cfg, pool, popts, None)
}

/// [`build_with_opts`] with an optional entropy-health monitor: the stochastic
/// substrates attach duty-cycled [`crate::entropy::health::BlockTap`]s to
/// their entropy streams so every produced block can be audited off the hot
/// path.  Taps observe by copy and never advance stream state, so a monitored
/// backend replays bitwise-identically to an unmonitored one.  The mean-field
/// backend draws no entropy and ignores the monitor.
pub fn build_with_opts_monitored(
    kind: BackendKind,
    cfg: &MachineConfig,
    pool: Option<Arc<ThreadPool>>,
    popts: PipelineOptions,
    monitor: Option<Arc<Monitor>>,
) -> Box<dyn ProbConvBackend> {
    match kind {
        BackendKind::Photonic => Box::new(PhotonicSimBackend::with_opts_monitored(
            cfg.clone(),
            pool,
            popts,
            monitor,
        )),
        BackendKind::Digital => Box::new(DigitalBaselineBackend::with_opts_monitored(
            cfg.scale_dac,
            cfg.scale_adc,
            cfg.seed,
            pool,
            popts,
            monitor,
        )),
        // a deterministic single pass: nothing worth sharding, prefetching,
        // or health-monitoring (no entropy is drawn)
        BackendKind::MeanField => Box::new(MeanFieldBackend::new(cfg.scale_dac, cfg.scale_adc)),
    }
}

/// Pluggable provider of the unit-variance `eps` operand used by the AOT
/// surrogate path and the SVI trainer's serving-time evaluation — the same
/// photonic-vs-digital seam as [`ProbConvBackend`], for the reparameterized
/// noise instead of the convolution.
pub enum EpsSource {
    /// Normalized chaotic-light intensity at a given channel bandwidth.
    Chaotic { src: ChaoticLightSource, bw_ghz: f64 },
    /// xoshiro256++ + Box–Muller standard normals.
    Digital { rng: Xoshiro256pp, gauss: Gaussian },
}

impl EpsSource {
    pub fn chaotic(seed: u64, bw_ghz: f64) -> Self {
        EpsSource::Chaotic {
            src: ChaoticLightSource::with_defaults(seed),
            bw_ghz,
        }
    }

    pub fn digital(seed: u64) -> Self {
        EpsSource::Digital {
            rng: Xoshiro256pp::new(seed),
            gauss: Gaussian::new(),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            EpsSource::Chaotic { .. } => "chaotic",
            EpsSource::Digital { .. } => "digital",
        }
    }

    /// Fill `out` with zero-mean, unit-std noise.
    pub fn fill(&mut self, out: &mut [f32]) {
        match self {
            EpsSource::Chaotic { src, bw_ghz } => src.fill_eps(*bw_ghz, out),
            EpsSource::Digital { rng, gauss } => gauss.fill_f32(rng, out),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::photonics::machine::im2col_3x3;
    use crate::photonics::PhotonicMachine;
    use crate::util::mathstat::Welford;

    fn quiet_cfg(seed: u64) -> MachineConfig {
        MachineConfig {
            rx_noise: 0.0,
            actuator_sigma: 0.0,
            actuator_jitter: 0.0,
            ripple_rms_ps: 0.0,
            seed,
            ..MachineConfig::default()
        }
    }

    fn targets9(mu: f32, sigma: f32) -> Vec<TapTarget> {
        vec![TapTarget { mu, sigma }; 9]
    }

    #[test]
    fn kind_parse_roundtrip() {
        for kind in [BackendKind::Photonic, BackendKind::Digital, BackendKind::MeanField] {
            assert_eq!(BackendKind::parse(kind.name()).unwrap(), kind);
        }
        assert!(BackendKind::parse("quantum").is_err());
        assert_eq!(BackendKind::parse("mean-field").unwrap(), BackendKind::MeanField);
    }

    #[test]
    fn plan_sizes_and_validation() {
        let plan = SamplePlan::new(10, 8, 8, 7, 7);
        assert_eq!(plan.item_size(), 8 * 49);
        assert_eq!(plan.sample_size(), 8 * 8 * 49);
        assert_eq!(plan.total_size(), 10 * 8 * 8 * 49);
        assert_eq!(plan.checked_total_size(), Some(plan.total_size()));
        assert!(plan.check(plan.sample_size(), plan.total_size(), 8).is_ok());
        assert!(plan.check(plan.sample_size() - 1, plan.total_size(), 8).is_err());
        assert!(plan.check(plan.sample_size(), plan.total_size() - 1, 8).is_err());
        assert!(plan.check(plan.sample_size(), plan.total_size(), 7).is_err());
        let empty = SamplePlan::new(0, 8, 8, 7, 7);
        assert!(empty.check(0, 0, 8).is_err());
        // zero-sized items would divide-by-zero downstream shard math
        for degenerate in [
            SamplePlan::new(1, 1, 0, 5, 5),
            SamplePlan::new(1, 1, 2, 0, 5),
            SamplePlan::new(1, 1, 2, 5, 0),
        ] {
            assert!(degenerate.check(0, 0, 8).is_err(), "{degenerate:?}");
        }
    }

    #[test]
    fn oversized_plans_rejected_without_overflow() {
        // attacker-shaped dimensions whose products wrap usize must be
        // rejected with an error, not a panic or a tiny wrapped allocation
        let huge = SamplePlan::new(usize::MAX, 2, 3, 5, 7);
        assert_eq!(huge.checked_total_size(), None);
        let err = huge.check(2 * 3 * 5 * 7, 1024, 3).unwrap_err();
        assert!(err.to_string().contains("overflow"), "{err}");

        let wide = SamplePlan::new(2, usize::MAX / 2, 3, 5, 7);
        assert!(wide.checked_sample_size().is_none());
        assert!(wide.check(0, 0, 3).is_err());
    }

    #[test]
    fn shard_ranges_cover_grid_exactly() {
        for (n, shards) in [(0, 4), (1, 4), (7, 3), (64, 4), (10, 16), (100, 1)] {
            let ranges = shard_ranges(n, shards);
            assert_eq!(ranges.len(), shards.max(1));
            let mut next = 0usize;
            for r in &ranges {
                assert_eq!(r.start, next, "contiguous at n={n} shards={shards}");
                next = r.end;
            }
            assert_eq!(next, n, "covers 0..{n} with {shards} shards");
            let lens: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
            let (min, max) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
            assert!(max - min <= 1, "near-equal split: {lens:?}");
        }
    }

    /// Satellite acceptance: sampled weight moments of the photonic and the
    /// digital backend both match the programmed `TapTarget` within
    /// tolerance — the statistical contract that makes the photonic-vs-
    /// digital throughput comparison apples-to-apples.
    #[test]
    fn backends_statistically_equivalent_on_programmed_targets() {
        let tgt = TapTarget { mu: 0.6, sigma: 0.3 }; // rel sigma 0.5: realizable
        let kernels = vec![targets9(tgt.mu, tgt.sigma); 2];
        let cfg = quiet_cfg(21);
        for kind in [BackendKind::Photonic, BackendKind::Digital] {
            let mut be = build(kind, &cfg);
            be.program(&kernels, false).unwrap();
            assert_eq!(be.num_kernels(), 2);
            let mut w = Welford::new();
            for _ in 0..40_000 {
                w.push(be.sample_weight(1, 4));
            }
            assert!(
                (w.mean() - tgt.mu as f64).abs() < 0.02,
                "{kind}: mean {}",
                w.mean()
            );
            assert!(
                (w.std() - tgt.sigma as f64).abs() < 0.02,
                "{kind}: std {}",
                w.std()
            );
        }
    }

    /// Satellite acceptance: the batched `sample_conv` matches the old
    /// per-sample `depthwise_conv` loop bit-for-bit on a fixed seed.
    #[test]
    fn batched_sample_conv_matches_per_sample_loop_bitwise() {
        let (c, h, w) = (2usize, 5usize, 5usize);
        let kernels = vec![targets9(0.4, 0.3), targets9(-0.2, 0.25)];
        let cfg = quiet_cfg(33);
        let x: Vec<f32> = (0..2 * c * h * w).map(|i| ((i % 9) as f32) * 0.35).collect();
        let plan = SamplePlan::new(3, 2, c, h, w);

        // new API: one batched call
        let mut be = PhotonicSimBackend::new(cfg.clone());
        be.program(&kernels, false).unwrap();
        let mut batched = vec![0.0f32; plan.total_size()];
        be.sample_conv(&plan, &x, &mut batched).unwrap();

        // old API: identically-seeded machine, per-sample per-item loop
        let mut m = PhotonicMachine::new(cfg);
        for t in &kernels {
            m.load_kernel(t);
        }
        let item = plan.item_size();
        let mut looped = vec![0.0f32; plan.total_size()];
        for s in 0..plan.n_samples {
            for b in 0..plan.batch {
                let y = m.depthwise_conv(0, &x[b * item..(b + 1) * item], c, h, w);
                looped[(s * plan.batch + b) * item..(s * plan.batch + b + 1) * item]
                    .copy_from_slice(&y);
            }
        }
        assert_eq!(batched, looped);
    }

    #[test]
    fn digital_and_mean_conv_agree_in_expectation() {
        let (c, h, w) = (1usize, 4usize, 4usize);
        let kernels = vec![targets9(0.3, 0.2)];
        let cfg = quiet_cfg(5);
        let x: Vec<f32> = (0..c * h * w).map(|i| 0.2 * (i % 5) as f32).collect();
        let plan = SamplePlan::new(400, 1, c, h, w);

        let mut dig = build(BackendKind::Digital, &cfg);
        dig.program(&kernels, false).unwrap();
        let mut outs = vec![0.0f32; plan.total_size()];
        dig.sample_conv(&plan, &x, &mut outs).unwrap();
        let mut acc = vec![0.0f64; plan.item_size()];
        for s in 0..plan.n_samples {
            for (a, &v) in acc.iter_mut().zip(&outs[s * plan.item_size()..]) {
                *a += v as f64 / plan.n_samples as f64;
            }
        }

        let mut mf = build(BackendKind::MeanField, &cfg);
        mf.program(&kernels, false).unwrap();
        assert!(mf.is_deterministic());
        let one = SamplePlan::new(1, 1, c, h, w);
        let mut mean_out = vec![0.0f32; one.total_size()];
        mf.sample_conv(&one, &x, &mut mean_out).unwrap();

        for (p, (&m, a)) in mean_out.iter().zip(&acc).enumerate() {
            assert!(
                (m as f64 - a).abs() < 0.08,
                "pixel {p}: mean-field {m} vs digital mean {a}"
            );
        }
    }

    #[test]
    fn mean_field_matches_reference_dot_product() {
        let (c, h, w) = (1usize, 3usize, 3usize);
        let mu = 0.5f32;
        let kernels = vec![targets9(mu, 0.0)];
        let cfg = quiet_cfg(1);
        let x: Vec<f32> = (0..9).map(|i| 0.3 * i as f32).collect();
        let mut mf = build(BackendKind::MeanField, &cfg);
        mf.program(&kernels, false).unwrap();
        let plan = SamplePlan::new(1, 1, c, h, w);
        let mut out = vec![0.0f32; plan.total_size()];
        mf.sample_conv(&plan, &x, &mut out).unwrap();

        let dac = crate::photonics::converters::Quantizer::new(cfg.scale_dac);
        let mut patches = vec![0.0f32; h * w * 9];
        im2col_3x3(&x, h, w, &mut patches);
        for p in 0..h * w {
            let want: f32 = patches[p * 9..(p + 1) * 9]
                .iter()
                .map(|&v| mu * dac.quantize(v.max(0.0)))
                .sum();
            assert!(
                (out[p] - want).abs() < 0.1,
                "pixel {p}: got {} want {want}",
                out[p]
            );
        }
    }

    #[test]
    fn deterministic_backends_repeat_stochastic_differ() {
        let kernels = vec![targets9(0.4, 0.3)];
        let cfg = quiet_cfg(9);
        let plan = SamplePlan::new(2, 1, 1, 3, 3);
        let x = vec![0.5f32; plan.sample_size()];

        let mut mf = build(BackendKind::MeanField, &cfg);
        mf.program(&kernels, false).unwrap();
        let mut out = vec![0.0f32; plan.total_size()];
        mf.sample_conv(&plan, &x, &mut out).unwrap();
        assert_eq!(out[..plan.sample_size()], out[plan.sample_size()..]);

        let mut dig = build(BackendKind::Digital, &cfg);
        dig.program(&kernels, false).unwrap();
        let mut out = vec![0.0f32; plan.total_size()];
        dig.sample_conv(&plan, &x, &mut out).unwrap();
        assert_ne!(out[..plan.sample_size()], out[plan.sample_size()..]);
    }

    #[test]
    fn eps_sources_produce_unit_noise() {
        for mut src in [EpsSource::chaotic(4, 150.0), EpsSource::digital(4)] {
            let mut buf = vec![0.0f32; 20_000];
            src.fill(&mut buf);
            let m = crate::util::mathstat::mean_f32(&buf);
            let s = crate::util::mathstat::std_f32(&buf);
            assert!(m.abs() < 0.05, "{}: mean {m}", src.name());
            assert!((s - 1.0).abs() < 0.05, "{}: std {s}", src.name());
        }
    }
}
