//! Decision policies over the predictive distribution.
//!
//! The serving engine applies an [`UncertaintyPolicy`] to each aggregated
//! prediction: reject as out-of-domain when the epistemic score (MI) is
//! high, flag as ambiguous when the aleatoric score (SE) is high, otherwise
//! accept the argmax class — the "uncertainty reasoning" of Fig. 5.

use super::aggregate::Predictive;

/// The verdict for one request.
#[derive(Debug, Clone, PartialEq)]
pub enum Decision {
    /// Confident in-domain prediction.
    Accept { class: usize, confidence: f32 },
    /// Epistemic rejection: the input looks out-of-domain (MI above
    /// threshold) — "seek further assessment".
    RejectOod { mutual_information: f64 },
    /// Aleatoric flag: the input itself is ambiguous (SE above threshold);
    /// a class is still reported but marked unreliable.
    FlagAmbiguous { class: usize, softmax_entropy: f64 },
}

/// Thresholds for the two uncertainty axes.
#[derive(Debug, Clone, Copy)]
pub struct UncertaintyPolicy {
    /// MI threshold for OOD rejection (paper: 0.0185 blood / 0.00308 MNIST).
    pub mi_threshold: f64,
    /// SE threshold for the aleatoric flag (None disables it).
    pub se_threshold: Option<f64>,
}

impl UncertaintyPolicy {
    pub fn ood_only(mi_threshold: f64) -> Self {
        Self {
            mi_threshold,
            se_threshold: None,
        }
    }

    pub fn full(mi_threshold: f64, se_threshold: f64) -> Self {
        Self {
            mi_threshold,
            se_threshold: Some(se_threshold),
        }
    }

    /// Apply the policy: epistemic rejection dominates, then the aleatoric
    /// flag, then acceptance.
    pub fn decide(&self, pred: &Predictive) -> Decision {
        if pred.mutual_information > self.mi_threshold {
            return Decision::RejectOod {
                mutual_information: pred.mutual_information,
            };
        }
        if let Some(se_thr) = self.se_threshold {
            if pred.softmax_entropy > se_thr {
                return Decision::FlagAmbiguous {
                    class: pred.predicted,
                    softmax_entropy: pred.softmax_entropy,
                };
            }
        }
        Decision::Accept {
            class: pred.predicted,
            confidence: pred.confidence(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pred(rows: Vec<Vec<f32>>) -> Predictive {
        Predictive::from_logits(&rows)
    }

    #[test]
    fn accepts_confident_consistent() {
        let p = pred(vec![vec![5.0, 0.0, 0.0]; 10]);
        let d = UncertaintyPolicy::full(0.02, 0.5).decide(&p);
        match d {
            Decision::Accept { class, confidence } => {
                assert_eq!(class, 0);
                assert!(confidence > 0.9);
            }
            other => panic!("expected accept, got {other:?}"),
        }
    }

    #[test]
    fn rejects_disagreeing_passes() {
        let mut rows = Vec::new();
        for n in 0..10 {
            let mut r = vec![0.0f32; 3];
            r[n % 3] = 6.0;
            rows.push(r);
        }
        let d = UncertaintyPolicy::full(0.02, 0.5).decide(&pred(rows));
        assert!(matches!(d, Decision::RejectOod { .. }));
    }

    #[test]
    fn flags_flat_distributions() {
        let rows = vec![vec![0.0f32; 4]; 10]; // uniform every pass
        let d = UncertaintyPolicy::full(0.02, 0.5).decide(&pred(rows));
        assert!(matches!(d, Decision::FlagAmbiguous { .. }));
    }

    #[test]
    fn ood_only_policy_accepts_ambiguous() {
        let rows = vec![vec![0.0f32; 4]; 10];
        let d = UncertaintyPolicy::ood_only(0.02).decide(&pred(rows));
        assert!(matches!(d, Decision::Accept { .. }));
    }

    #[test]
    fn epistemic_rejection_dominates_aleatoric_flag() {
        // both MI and SE high: policy must reject OOD first
        let mut rows = Vec::new();
        for n in 0..10 {
            let mut r = vec![0.4f32; 3];
            r[n % 3] = 2.0;
            rows.push(r);
        }
        let p = pred(rows);
        assert!(p.mutual_information > 0.02 || p.softmax_entropy > 0.2);
        let d = UncertaintyPolicy::full(0.0005, 0.0005).decide(&p);
        assert!(matches!(d, Decision::RejectOod { .. }));
    }
}
