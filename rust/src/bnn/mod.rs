//! Bayesian-inference post-processing: uncertainty metrics (Eq. 1 / Eq. 2),
//! predictive aggregation over the N stochastic forward passes, ROC/AUROC,
//! confusion matrices with rejection, and decision policies.

pub mod aggregate;
pub mod confusion;
pub mod metrics;
pub mod policy;
pub mod rocauc;

pub use aggregate::Predictive;
pub use metrics::{mutual_information, shannon_entropy, softmax_entropy};
pub use policy::{Decision, UncertaintyPolicy};
