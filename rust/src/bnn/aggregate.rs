//! Predictive aggregation over the N stochastic forward passes.

use super::metrics;
use crate::util::mathstat::softmax;

/// The BNN's predictive distribution for one input: per-pass probabilities
/// plus the derived uncertainty metrics.
#[derive(Debug, Clone)]
pub struct Predictive {
    /// Row-major (n_samples, n_classes) per-pass probabilities.
    pub probs: Vec<Vec<f32>>,
    /// Mean predictive distribution.
    pub mean_probs: Vec<f32>,
    /// argmax of the mean predictive.
    pub predicted: usize,
    /// Eq. 1 — total uncertainty.
    pub shannon_entropy: f64,
    /// Eq. 2 — aleatoric uncertainty.
    pub softmax_entropy: f64,
    /// H − SE — epistemic uncertainty.
    pub mutual_information: f64,
    /// Fraction of passes agreeing with the majority class.
    pub agreement: f64,
}

impl Predictive {
    /// Aggregate per-pass logits (row-major `(n_samples, n_classes)`).
    pub fn from_logits(logits: &[Vec<f32>]) -> Self {
        let probs: Vec<Vec<f32>> = logits.iter().map(|row| softmax(row)).collect();
        Self::from_probs(probs)
    }

    /// Aggregate a flat logits buffer of `n_samples * n_classes`.
    pub fn from_flat_logits(flat: &[f32], n_classes: usize) -> Self {
        assert_eq!(flat.len() % n_classes, 0);
        let probs: Vec<Vec<f32>> = flat.chunks(n_classes).map(softmax).collect();
        Self::from_probs(probs)
    }

    /// Aggregate one image's logits out of per-pass batch buffers: pass
    /// `p`'s logits for the image live at
    /// `passes[p][image*n_classes..(image+1)*n_classes]`.  Strided view —
    /// the serving engine's per-request hot path, with no per-pass logit
    /// row copies (`Predictive` still owns its probability rows; those are
    /// the result, not staging).
    pub fn from_batched_logits(passes: &[Vec<f32>], image: usize, n_classes: usize) -> Self {
        let probs: Vec<Vec<f32>> = passes
            .iter()
            .map(|pass| softmax(&pass[image * n_classes..(image + 1) * n_classes]))
            .collect();
        Self::from_probs(probs)
    }

    pub fn from_probs(probs: Vec<Vec<f32>>) -> Self {
        assert!(!probs.is_empty());
        let c = probs[0].len();
        let n = probs.len();
        let mut mean = vec![0.0f32; c];
        for row in &probs {
            debug_assert_eq!(row.len(), c);
            for (m, &p) in mean.iter_mut().zip(row) {
                *m += p / n as f32;
            }
        }
        let predicted = argmax(&mean);
        let votes = probs
            .iter()
            .filter(|row| argmax(row) == predicted)
            .count();
        let h = metrics::shannon_entropy(&probs);
        let se = metrics::softmax_entropy(&probs);
        Self {
            mean_probs: mean,
            predicted,
            shannon_entropy: h,
            softmax_entropy: se,
            mutual_information: (h - se).max(0.0),
            agreement: votes as f64 / n as f64,
            probs,
        }
    }

    pub fn n_samples(&self) -> usize {
        self.probs.len()
    }

    pub fn n_classes(&self) -> usize {
        self.mean_probs.len()
    }

    /// Confidence of the mean predictive in its argmax.
    pub fn confidence(&self) -> f32 {
        self.mean_probs[self.predicted]
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_logits_consistent() {
        let logits = vec![vec![2.0, 0.0, -1.0]; 10];
        let p = Predictive::from_logits(&logits);
        assert_eq!(p.predicted, 0);
        assert_eq!(p.n_samples(), 10);
        assert_eq!(p.n_classes(), 3);
        assert!((p.agreement - 1.0).abs() < 1e-12);
        assert!(p.mutual_information < 1e-6);
    }

    #[test]
    fn from_flat_matches_nested() {
        let flat = vec![1.0, 0.0, 0.5, 0.2, 2.0, -1.0];
        let a = Predictive::from_flat_logits(&flat, 3);
        let b = Predictive::from_logits(&[vec![1.0, 0.0, 0.5], vec![0.2, 2.0, -1.0]]);
        assert_eq!(a.predicted, b.predicted);
        assert!((a.mutual_information - b.mutual_information).abs() < 1e-12);
    }

    #[test]
    fn from_batched_matches_per_image_rows() {
        // two passes x three images x two classes
        let passes = vec![
            vec![2.0, 0.0, 0.1, 0.9, -1.0, 1.0],
            vec![1.5, 0.5, 0.8, 0.2, -0.5, 0.5],
        ];
        for i in 0..3 {
            let rows: Vec<Vec<f32>> =
                passes.iter().map(|p| p[i * 2..(i + 1) * 2].to_vec()).collect();
            let a = Predictive::from_batched_logits(&passes, i, 2);
            let b = Predictive::from_logits(&rows);
            assert_eq!(a.predicted, b.predicted, "image {i}");
            assert_eq!(a.probs, b.probs, "image {i}");
            assert!((a.mutual_information - b.mutual_information).abs() < 1e-12);
        }
    }

    #[test]
    fn disagreement_lowers_agreement() {
        let logits = vec![
            vec![3.0, 0.0],
            vec![3.0, 0.0],
            vec![0.0, 3.0],
            vec![0.0, 3.0],
            vec![3.0, 0.0],
        ];
        let p = Predictive::from_logits(&logits);
        assert!((p.agreement - 0.6).abs() < 1e-12);
        assert!(p.mutual_information > 0.2);
    }

    #[test]
    fn mean_probs_sum_to_one() {
        let logits = vec![vec![0.3, -0.2, 1.5, 0.0], vec![-1.0, 0.4, 0.2, 2.0]];
        let p = Predictive::from_logits(&logits);
        let s: f32 = p.mean_probs.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
    }
}
