//! Confusion matrices with a rejection column (paper Fig. 4(d)).
//!
//! The paper's Fig. 4(d) confusion matrix includes the OOD erythroblast rows
//! (labelled "x") and a *reject* decision; accuracy-with-rejection improves
//! from 90.26 % to 94.62 % at the optimal MI threshold.

/// Confusion matrix over `n_classes` true labels (+ optional OOD label) and
/// `n_classes + 1` predictions (last column = rejected).
#[derive(Debug, Clone)]
pub struct ConfusionMatrix {
    pub n_classes: usize,
    /// rows: true label (0..n_classes, or n_classes for OOD inputs);
    /// cols: predicted label (0..n_classes) or n_classes for "rejected".
    counts: Vec<u64>,
}

impl ConfusionMatrix {
    pub fn new(n_classes: usize) -> Self {
        Self {
            n_classes,
            counts: vec![0; (n_classes + 1) * (n_classes + 1)],
        }
    }

    fn idx(&self, true_label: usize, pred: usize) -> usize {
        true_label * (self.n_classes + 1) + pred
    }

    /// Record a prediction. `true_label == n_classes` marks an OOD input;
    /// `pred == n_classes` marks a rejection.
    pub fn record(&mut self, true_label: usize, pred: usize) {
        assert!(true_label <= self.n_classes && pred <= self.n_classes);
        let i = self.idx(true_label, pred);
        self.counts[i] += 1;
    }

    pub fn count(&self, true_label: usize, pred: usize) -> u64 {
        self.counts[self.idx(true_label, pred)]
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Plain accuracy over *accepted in-domain* inputs (the paper's
    /// accuracy-with-rejection numerator/denominator).
    pub fn accepted_accuracy(&self) -> f64 {
        let mut correct = 0u64;
        let mut accepted = 0u64;
        for t in 0..self.n_classes {
            for p in 0..self.n_classes {
                accepted += self.count(t, p);
                if t == p {
                    correct += self.count(t, p);
                }
            }
        }
        if accepted == 0 {
            return 0.0;
        }
        correct as f64 / accepted as f64
    }

    /// Accuracy over all ID inputs counting rejections as wrong.
    pub fn strict_accuracy(&self) -> f64 {
        let mut correct = 0u64;
        let mut total = 0u64;
        for t in 0..self.n_classes {
            for p in 0..=self.n_classes {
                total += self.count(t, p);
                if t == p {
                    correct += self.count(t, p);
                }
            }
        }
        if total == 0 {
            return 0.0;
        }
        correct as f64 / total as f64
    }

    /// Fraction of ID inputs that were rejected.
    pub fn id_rejection_rate(&self) -> f64 {
        let mut rej = 0u64;
        let mut total = 0u64;
        for t in 0..self.n_classes {
            for p in 0..=self.n_classes {
                total += self.count(t, p);
            }
            rej += self.count(t, self.n_classes);
        }
        if total == 0 {
            return 0.0;
        }
        rej as f64 / total as f64
    }

    /// Fraction of OOD inputs that were (correctly) rejected.
    pub fn ood_rejection_rate(&self) -> f64 {
        let t = self.n_classes;
        let total: u64 = (0..=self.n_classes).map(|p| self.count(t, p)).sum();
        if total == 0 {
            return 0.0;
        }
        self.count(t, self.n_classes) as f64 / total as f64
    }

    /// Render as an aligned text table (the Fig. 4(d) artifact).
    pub fn render(&self, class_names: &[&str]) -> String {
        let mut s = String::new();
        let name = |i: usize| -> String {
            if i == self.n_classes {
                "x".into()
            } else {
                class_names
                    .get(i)
                    .map(|n| n.to_string())
                    .unwrap_or_else(|| format!("{i}"))
            }
        };
        s.push_str(&format!("{:>12} |", "true\\pred"));
        for p in 0..self.n_classes {
            s.push_str(&format!("{:>6}", name(p)));
        }
        s.push_str(&format!("{:>7}\n", "reject"));
        for t in 0..=self.n_classes {
            let row_total: u64 = (0..=self.n_classes).map(|p| self.count(t, p)).sum();
            if row_total == 0 {
                continue;
            }
            s.push_str(&format!("{:>12} |", name(t)));
            for p in 0..self.n_classes {
                s.push_str(&format!("{:>6}", self.count(t, p)));
            }
            s.push_str(&format!("{:>7}\n", self.count(t, self.n_classes)));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_counts() {
        let mut cm = ConfusionMatrix::new(3);
        cm.record(0, 0);
        cm.record(0, 0);
        cm.record(1, 2); // wrong
        cm.record(2, 2);
        cm.record(1, 3); // rejected ID
        cm.record(3, 3); // OOD rejected
        cm.record(3, 0); // OOD accepted (bad)
        assert!((cm.accepted_accuracy() - 0.75).abs() < 1e-12);
        assert!((cm.strict_accuracy() - 3.0 / 5.0).abs() < 1e-12);
        assert!((cm.id_rejection_rate() - 0.2).abs() < 1e-12);
        assert!((cm.ood_rejection_rate() - 0.5).abs() < 1e-12);
        assert_eq!(cm.total(), 7);
    }

    #[test]
    fn rejection_improves_accepted_accuracy() {
        // classic pattern: rejecting the error-prone cases raises accuracy
        let mut cm = ConfusionMatrix::new(2);
        for _ in 0..90 {
            cm.record(0, 0);
        }
        for _ in 0..10 {
            cm.record(0, 3.min(2)); // rejected
        }
        for _ in 0..80 {
            cm.record(1, 1);
        }
        for _ in 0..5 {
            cm.record(1, 0);
        }
        assert!(cm.accepted_accuracy() > cm.strict_accuracy());
    }

    #[test]
    fn render_contains_all_rows() {
        let mut cm = ConfusionMatrix::new(2);
        cm.record(0, 1);
        cm.record(2, 2); // OOD rejected
        let s = cm.render(&["a", "b"]);
        assert!(s.contains('a') && s.contains('x') && s.contains("reject"));
    }
}
