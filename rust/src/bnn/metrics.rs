//! Uncertainty metrics over the BNN's sampled output distribution.
//!
//! For N stochastic forward passes with per-pass class probabilities
//! `p(y_n = c | x, θ_n)` the paper uses (Eq. 1, Eq. 2):
//!
//! * **Shannon entropy** `H` of the *mean* predictive — total uncertainty,
//! * **Softmax entropy** `SE` — mean of the per-pass entropies — aleatoric,
//! * **Mutual information** `MI = H − SE` — epistemic.
//!
//! All entropies are in nats.

/// Shannon entropy of a probability vector (nats). Zero-probability entries
/// contribute zero (lim p→0 of p·log p).
pub fn entropy(p: &[f32]) -> f64 {
    p.iter()
        .filter(|&&x| x > 0.0)
        .map(|&x| -(x as f64) * (x as f64).ln())
        .sum()
}

/// Eq. 1: entropy of the mean predictive distribution over `n` samples.
/// `probs` is row-major `(n_samples, n_classes)`.
pub fn shannon_entropy(probs: &[Vec<f32>]) -> f64 {
    assert!(!probs.is_empty());
    let c = probs[0].len();
    let n = probs.len() as f64;
    let mut mean = vec![0.0f32; c];
    for row in probs {
        for (m, &p) in mean.iter_mut().zip(row) {
            *m += p / n as f32;
        }
    }
    entropy(&mean)
}

/// Eq. 2: mean of per-sample entropies (aleatoric uncertainty).
pub fn softmax_entropy(probs: &[Vec<f32>]) -> f64 {
    assert!(!probs.is_empty());
    probs.iter().map(|row| entropy(row)).sum::<f64>() / probs.len() as f64
}

/// Mutual information `MI = H − SE` (epistemic uncertainty).  Clamped at 0:
/// Jensen guarantees `H >= SE` analytically, and the clamp removes the tiny
/// negative values finite-precision aggregation can produce.
pub fn mutual_information(probs: &[Vec<f32>]) -> f64 {
    (shannon_entropy(probs) - softmax_entropy(probs)).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entropy_uniform_is_log_c() {
        let p = vec![0.25f32; 4];
        assert!((entropy(&p) - (4f64).ln()).abs() < 1e-6);
    }

    #[test]
    fn entropy_onehot_is_zero() {
        let p = vec![1.0, 0.0, 0.0];
        assert!(entropy(&p).abs() < 1e-9);
    }

    #[test]
    fn confident_consistent_low_everything() {
        // ID case: every pass confidently predicts class 0
        let probs = vec![vec![0.99, 0.005, 0.005]; 10];
        assert!(shannon_entropy(&probs) < 0.1);
        assert!(softmax_entropy(&probs) < 0.1);
        assert!(mutual_information(&probs) < 0.01);
    }

    #[test]
    fn confident_disagreement_high_mi() {
        // OOD case: each pass confident but in different classes
        let mut probs = Vec::new();
        for n in 0..10 {
            let mut p = vec![0.005f32; 3];
            p[n % 3] = 0.99;
            probs.push(p);
        }
        let mi = mutual_information(&probs);
        let se = softmax_entropy(&probs);
        assert!(mi > 0.8, "mi {mi}");
        assert!(se < 0.1, "se {se}");
    }

    #[test]
    fn flat_agreement_high_se_low_mi() {
        // aleatoric case: every pass returns the same flat distribution
        let probs = vec![vec![1.0 / 3.0; 3]; 10];
        let se = softmax_entropy(&probs);
        let mi = mutual_information(&probs);
        assert!((se - (3f64).ln()).abs() < 1e-6);
        assert!(mi < 1e-6, "mi {mi}");
    }

    #[test]
    fn mi_nonnegative_random() {
        use crate::entropy::{BitSource, Xoshiro256pp};
        use crate::util::mathstat::softmax;
        let mut rng = Xoshiro256pp::new(5);
        for _ in 0..200 {
            let probs: Vec<Vec<f32>> = (0..10)
                .map(|_| {
                    let logits: Vec<f32> =
                        (0..7).map(|_| (rng.next_f64() * 6.0 - 3.0) as f32).collect();
                    softmax(&logits)
                })
                .collect();
            assert!(mutual_information(&probs) >= 0.0);
            assert!(shannon_entropy(&probs) >= softmax_entropy(&probs) - 1e-6);
        }
    }

    #[test]
    fn h_equals_se_plus_mi() {
        let probs = vec![
            vec![0.7, 0.2, 0.1],
            vec![0.2, 0.7, 0.1],
            vec![0.4, 0.4, 0.2],
        ];
        let h = shannon_entropy(&probs);
        let se = softmax_entropy(&probs);
        let mi = mutual_information(&probs);
        assert!((h - (se + mi)).abs() < 1e-9);
    }
}
