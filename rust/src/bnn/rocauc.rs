//! ROC curves and AUROC for the OOD / uncertainty detectors.
//!
//! The paper's Fig. 4(c) sweeps the MI threshold to trade false-positive
//! against true-positive rejection of unknown cell types (AUROC 91.16 %);
//! Fig. 5(f) reports AUROC 84.42 % (epistemic / Fashion probe, MI score) and
//! 88.03 % (aleatoric / Ambiguous probe, SE score).

/// One point of a ROC curve.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RocPoint {
    pub threshold: f64,
    pub fpr: f64,
    pub tpr: f64,
}

/// ROC curve over scores: `positives` should score *higher* than
/// `negatives`.  Returns points sorted by increasing FPR (threshold from
/// +inf down to -inf inclusive).
pub fn roc_curve(positives: &[f64], negatives: &[f64]) -> Vec<RocPoint> {
    assert!(!positives.is_empty() && !negatives.is_empty());
    let mut events: Vec<(f64, bool)> = positives
        .iter()
        .map(|&s| (s, true))
        .chain(negatives.iter().map(|&s| (s, false)))
        .collect();
    // descending score
    events.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let np = positives.len() as f64;
    let nn = negatives.len() as f64;
    let mut pts = vec![RocPoint {
        threshold: f64::INFINITY,
        fpr: 0.0,
        tpr: 0.0,
    }];
    let (mut tp, mut fp) = (0usize, 0usize);
    let mut i = 0;
    while i < events.len() {
        let thr = events[i].0;
        // consume all events tied at this threshold
        while i < events.len() && events[i].0 == thr {
            if events[i].1 {
                tp += 1;
            } else {
                fp += 1;
            }
            i += 1;
        }
        pts.push(RocPoint {
            threshold: thr,
            fpr: fp as f64 / nn,
            tpr: tp as f64 / np,
        });
    }
    pts
}

/// AUROC by trapezoidal integration of the ROC curve.
pub fn auroc(positives: &[f64], negatives: &[f64]) -> f64 {
    let pts = roc_curve(positives, negatives);
    let mut area = 0.0;
    for w in pts.windows(2) {
        area += (w[1].fpr - w[0].fpr) * (w[1].tpr + w[0].tpr) / 2.0;
    }
    area
}

/// Rank-based AUROC (Mann–Whitney U) — an independent formula used to
/// cross-check the trapezoid in tests.
pub fn auroc_rank(positives: &[f64], negatives: &[f64]) -> f64 {
    let mut wins = 0.0;
    for &p in positives {
        for &n in negatives {
            if p > n {
                wins += 1.0;
            } else if p == n {
                wins += 0.5;
            }
        }
    }
    wins / (positives.len() as f64 * negatives.len() as f64)
}

/// The threshold maximizing Youden's J = TPR − FPR (the "optimal" point the
/// paper quotes for accuracy-with-rejection).
pub fn best_threshold(positives: &[f64], negatives: &[f64]) -> RocPoint {
    roc_curve(positives, negatives)
        .into_iter()
        .max_by(|a, b| (a.tpr - a.fpr).partial_cmp(&(b.tpr - b.fpr)).unwrap())
        .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::entropy::{BitSource, Xoshiro256pp};

    #[test]
    fn perfect_separation_gives_auc_one() {
        let pos = [2.0, 3.0, 4.0];
        let neg = [0.0, 0.5, 1.0];
        assert!((auroc(&pos, &neg) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn inverted_separation_gives_zero() {
        let pos = [0.0, 0.1];
        let neg = [1.0, 2.0];
        assert!(auroc(&pos, &neg).abs() < 1e-12);
    }

    #[test]
    fn identical_distributions_give_half() {
        let mut rng = Xoshiro256pp::new(3);
        let pos: Vec<f64> = (0..2000).map(|_| rng.next_f64()).collect();
        let neg: Vec<f64> = (0..2000).map(|_| rng.next_f64()).collect();
        let a = auroc(&pos, &neg);
        assert!((a - 0.5).abs() < 0.03, "auc {a}");
    }

    #[test]
    fn trapezoid_matches_rank_statistic() {
        let mut rng = Xoshiro256pp::new(4);
        let pos: Vec<f64> = (0..300).map(|_| rng.next_f64() + 0.3).collect();
        let neg: Vec<f64> = (0..500).map(|_| rng.next_f64()).collect();
        let a = auroc(&pos, &neg);
        let b = auroc_rank(&pos, &neg);
        assert!((a - b).abs() < 1e-9, "{a} vs {b}");
    }

    #[test]
    fn ties_handled_consistently() {
        let pos = [1.0, 1.0, 2.0];
        let neg = [1.0, 0.0];
        assert!((auroc(&pos, &neg) - auroc_rank(&pos, &neg)).abs() < 1e-12);
    }

    #[test]
    fn roc_curve_monotone() {
        let mut rng = Xoshiro256pp::new(5);
        let pos: Vec<f64> = (0..100).map(|_| rng.next_f64() + 0.5).collect();
        let neg: Vec<f64> = (0..100).map(|_| rng.next_f64()).collect();
        let pts = roc_curve(&pos, &neg);
        for w in pts.windows(2) {
            assert!(w[1].fpr >= w[0].fpr);
            assert!(w[1].tpr >= w[0].tpr);
        }
        let last = pts.last().unwrap();
        assert!((last.fpr - 1.0).abs() < 1e-12 && (last.tpr - 1.0).abs() < 1e-12);
    }

    #[test]
    fn best_threshold_separates() {
        let pos = [0.8, 0.9, 0.95];
        let neg = [0.1, 0.2, 0.3];
        let pt = best_threshold(&pos, &neg);
        assert!(pt.threshold > 0.3 && pt.threshold <= 0.8);
        assert!((pt.tpr - 1.0).abs() < 1e-12 && pt.fpr.abs() < 1e-12);
    }
}
