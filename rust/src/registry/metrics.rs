//! Residency accounting for the model registry: hit/miss/switch/eviction
//! counters plus a per-model scorecard, shared between the backend (which
//! drives the cache) and the serving layer (which reports on `/info`).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Where one model's banked state currently lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Residency {
    /// Loaded in the backend's working slots, serving requests.
    Active,
    /// Parked in the LRU cache; a switch back is a hit.
    Resident,
    /// Was cached, got evicted under the byte budget; next switch rebuilds.
    Evicted,
    /// Registered, never activated.
    #[default]
    Cold,
}

impl Residency {
    pub fn name(self) -> &'static str {
        match self {
            Residency::Active => "active",
            Residency::Resident => "resident",
            Residency::Evicted => "evicted",
            Residency::Cold => "cold",
        }
    }
}

#[derive(Debug, Clone, Default)]
struct ModelCard {
    state: Residency,
    bytes: u64,
    hits: u64,
    misses: u64,
    switches_in: u64,
}

/// Shared counters.  Atomics for the hot counters; the per-model cards sit
/// behind a mutex taken only on switches and `/info` snapshots, never on
/// the sampling path.
#[derive(Debug, Default)]
pub struct RegistryMetrics {
    budget_bytes: AtomicU64,
    resident_bytes: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    switches: AtomicU64,
    evictions: AtomicU64,
    cards: Mutex<BTreeMap<String, ModelCard>>,
}

impl RegistryMetrics {
    /// Pre-register a model so `/info` lists it (state `cold`) before its
    /// first request.
    pub fn register(&self, model: &str) {
        self.cards.lock().unwrap().entry(model.into()).or_default();
    }

    pub fn set_budget(&self, bytes: u64) {
        self.budget_bytes.store(bytes, Ordering::Relaxed);
    }

    pub fn set_resident_bytes(&self, bytes: u64) {
        self.resident_bytes.store(bytes, Ordering::Relaxed);
    }

    /// A replacement cache starts empty: every card that claimed residency
    /// goes back to cold (used when the entropy-health fallback swaps the
    /// backend out from under the registry).
    pub fn reset_residency(&self) {
        self.resident_bytes.store(0, Ordering::Relaxed);
        for card in self.cards.lock().unwrap().values_mut() {
            card.state = Residency::Cold;
            card.bytes = 0;
        }
    }

    pub fn record_switch(&self, model: &str) {
        self.switches.fetch_add(1, Ordering::Relaxed);
        let mut cards = self.cards.lock().unwrap();
        cards.entry(model.into()).or_default().switches_in += 1;
    }

    pub fn record_hit(&self, model: &str) {
        self.hits.fetch_add(1, Ordering::Relaxed);
        let mut cards = self.cards.lock().unwrap();
        cards.entry(model.into()).or_default().hits += 1;
    }

    pub fn record_miss(&self, model: &str) {
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut cards = self.cards.lock().unwrap();
        cards.entry(model.into()).or_default().misses += 1;
    }

    pub fn record_eviction(&self, model: &str) {
        self.evictions.fetch_add(1, Ordering::Relaxed);
        let mut cards = self.cards.lock().unwrap();
        let card = cards.entry(model.into()).or_default();
        card.state = Residency::Evicted;
        card.bytes = 0;
    }

    pub fn mark_active(&self, model: &str, bytes: u64) {
        let mut cards = self.cards.lock().unwrap();
        let card = cards.entry(model.into()).or_default();
        card.state = Residency::Active;
        card.bytes = bytes;
    }

    pub fn mark_resident(&self, model: &str, bytes: u64) {
        let mut cards = self.cards.lock().unwrap();
        let card = cards.entry(model.into()).or_default();
        card.state = Residency::Resident;
        card.bytes = bytes;
    }

    pub fn snapshot(&self) -> RegistrySnapshot {
        let cards = self.cards.lock().unwrap();
        RegistrySnapshot {
            budget_bytes: self.budget_bytes.load(Ordering::Relaxed),
            resident_bytes: self.resident_bytes.load(Ordering::Relaxed),
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            switches: self.switches.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            models: cards
                .iter()
                .map(|(name, c)| ModelCardSnapshot {
                    model: name.clone(),
                    state: c.state,
                    bytes: c.bytes,
                    hits: c.hits,
                    misses: c.misses,
                    switches_in: c.switches_in,
                })
                .collect(),
        }
    }
}

/// Point-in-time view for `/info` (models sorted by name — `cards` is a
/// `BTreeMap`).
#[derive(Debug, Clone, Default)]
pub struct RegistrySnapshot {
    pub budget_bytes: u64,
    pub resident_bytes: u64,
    pub hits: u64,
    pub misses: u64,
    pub switches: u64,
    pub evictions: u64,
    pub models: Vec<ModelCardSnapshot>,
}

#[derive(Debug, Clone)]
pub struct ModelCardSnapshot {
    pub model: String,
    pub state: Residency,
    pub bytes: u64,
    pub hits: u64,
    pub misses: u64,
    pub switches_in: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_cards_track_a_switch_sequence() {
        let m = RegistryMetrics::default();
        m.register("digits");
        m.register("blood");
        m.set_budget(1 << 20);

        m.record_switch("digits");
        m.record_miss("digits");
        m.mark_active("digits", 4096);

        m.record_switch("blood");
        m.record_miss("blood");
        m.mark_resident("digits", 4096);
        m.mark_active("blood", 4096);
        m.set_resident_bytes(8192);

        m.record_switch("digits");
        m.record_hit("digits");
        m.mark_resident("blood", 4096);
        m.mark_active("digits", 4096);

        let s = m.snapshot();
        assert_eq!(s.switches, 3);
        assert_eq!(s.misses, 2);
        assert_eq!(s.hits, 1);
        assert_eq!(s.budget_bytes, 1 << 20);
        assert_eq!(s.models.len(), 2);
        // BTreeMap: sorted by name
        assert_eq!(s.models[0].model, "blood");
        assert_eq!(s.models[0].state, Residency::Resident);
        assert_eq!(s.models[1].model, "digits");
        assert_eq!(s.models[1].state, Residency::Active);
        assert_eq!(s.models[1].hits, 1);
    }

    #[test]
    fn eviction_marks_card_and_reset_goes_cold() {
        let m = RegistryMetrics::default();
        m.mark_resident("a", 100);
        m.record_eviction("a");
        let s = m.snapshot();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.models[0].state, Residency::Evicted);
        assert_eq!(s.models[0].bytes, 0);

        m.mark_active("a", 100);
        m.set_resident_bytes(100);
        m.reset_residency();
        let s = m.snapshot();
        assert_eq!(s.resident_bytes, 0);
        assert_eq!(s.models[0].state, Residency::Cold);
    }

    #[test]
    fn residency_names_are_wire_stable() {
        assert_eq!(Residency::Active.name(), "active");
        assert_eq!(Residency::Resident.name(), "resident");
        assert_eq!(Residency::Evicted.name(), "evicted");
        assert_eq!(Residency::Cold.name(), "cold");
    }
}
