//! Byte-budgeted LRU over per-model backend state.
//!
//! [`BankCache`] is the generic policy core (pure, unit-testable);
//! [`ModelCache`] wires it to [`RegistryMetrics`] with the
//! checkout/commit discipline the backends drive their program switches
//! through.  `T` is whatever a backend considers "one model's resident
//! state" — for the photonic backend the machine + shards + prefetched
//! weight bank triple; dropping an entry joins that model's background
//! entropy producers.

use std::sync::Arc;

use super::metrics::RegistryMetrics;

struct Entry<T> {
    key: String,
    value: T,
    bytes: usize,
    last_used: u64,
}

/// LRU keyed by model name under a byte budget.  Entries whose combined
/// size exceeds the budget are evicted least-recently-used first; a budget
/// of 0 caches nothing (every switch rebuilds cold), a budget of
/// `usize::MAX` never evicts.
pub struct BankCache<T> {
    entries: Vec<Entry<T>>,
    budget_bytes: usize,
    tick: u64,
}

impl<T> BankCache<T> {
    pub fn new(budget_bytes: usize) -> Self {
        Self {
            entries: Vec::new(),
            budget_bytes,
            tick: 0,
        }
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    pub fn resident_bytes(&self) -> usize {
        self.entries.iter().map(|e| e.bytes).sum()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn contains(&self, key: &str) -> bool {
        self.entries.iter().any(|e| e.key == key)
    }

    /// Remove and return `key`'s state (cache hit); `None` is a miss.
    pub fn take(&mut self, key: &str) -> Option<(T, usize)> {
        let idx = self.entries.iter().position(|e| e.key == key)?;
        let e = self.entries.swap_remove(idx);
        Some((e.value, e.bytes))
    }

    /// Insert (or replace) `key`, then evict least-recently-used entries
    /// until the cache fits its budget.  The just-inserted entry is the
    /// most recent, but is itself evicted if it alone exceeds the budget
    /// (budget 0 == cache nothing).  Returns the evicted entries so the
    /// caller can account for them before dropping.
    pub fn insert(&mut self, key: String, value: T, bytes: usize) -> Vec<(String, T, usize)> {
        if let Some(idx) = self.entries.iter().position(|e| e.key == key) {
            self.entries.swap_remove(idx);
        }
        self.tick += 1;
        self.entries.push(Entry {
            key,
            value,
            bytes,
            last_used: self.tick,
        });
        let mut evicted = Vec::new();
        while self.resident_bytes() > self.budget_bytes && !self.entries.is_empty() {
            let idx = self
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(i, _)| i)
                .unwrap();
            let e = self.entries.swap_remove(idx);
            evicted.push((e.key, e.value, e.bytes));
        }
        evicted
    }
}

/// Per-backend model cache: the active model's state lives *in* the
/// backend's working fields; everything else sits in the LRU.  Backends
/// drive switches as `checkout(model)` (take cached state, recording
/// hit/miss/switch) followed by `commit(model, bytes, prev)` (stash the
/// previous active state, evict over budget, publish residency).
pub struct ModelCache<T> {
    active: Option<(String, usize)>,
    lru: BankCache<T>,
    pub metrics: Arc<RegistryMetrics>,
}

impl<T> ModelCache<T> {
    pub fn new(budget_bytes: usize, metrics: Arc<RegistryMetrics>) -> Self {
        metrics.set_budget(budget_bytes as u64);
        // a fresh cache starts empty: any prior residency claims (e.g. from
        // a backend replaced by the entropy-health fallback) are void
        metrics.reset_residency();
        Self {
            active: None,
            lru: BankCache::new(budget_bytes),
            metrics,
        }
    }

    pub fn active_model(&self) -> Option<&str> {
        self.active.as_ref().map(|(n, _)| n.as_str())
    }

    pub fn is_active(&self, model: &str) -> bool {
        self.active_model() == Some(model)
    }

    /// Begin a switch to `model`: record it, and return the cached state
    /// on a hit (`None` = miss, the caller rebuilds from seed).
    pub fn checkout(&mut self, model: &str) -> Option<(T, usize)> {
        self.metrics.record_switch(model);
        match self.lru.take(model) {
            Some(hit) => {
                self.metrics.record_hit(model);
                Some(hit)
            }
            None => {
                self.metrics.record_miss(model);
                None
            }
        }
    }

    /// Finish a switch: stash the previous active state (if any) into the
    /// LRU, evicting over budget, and mark `model` active at `bytes`.
    /// Evicted state is dropped here (joining any producers it owns).
    pub fn commit(&mut self, model: &str, bytes: usize, prev: Option<T>) {
        if let Some((old_name, old_bytes)) = self.active.take() {
            if let Some(state) = prev {
                for (name, state, _) in self.lru.insert(old_name.clone(), state, old_bytes) {
                    drop(state);
                    self.metrics.record_eviction(&name);
                }
                if self.lru.contains(&old_name) {
                    self.metrics.mark_resident(&old_name, old_bytes as u64);
                }
            }
        }
        self.active = Some((model.to_string(), bytes));
        self.metrics.mark_active(model, bytes as u64);
        self.metrics
            .set_resident_bytes((self.lru.resident_bytes() + bytes) as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lru_takes_hits_and_misses() {
        let mut c: BankCache<u32> = BankCache::new(1000);
        assert!(c.insert("a".into(), 1, 100).is_empty());
        assert!(c.insert("b".into(), 2, 100).is_empty());
        assert_eq!(c.take("a"), Some((1, 100)));
        assert_eq!(c.take("a"), None, "take removes");
        assert!(c.contains("b") && !c.contains("a"));
        assert_eq!(c.resident_bytes(), 100);
    }

    #[test]
    fn lru_evicts_least_recently_used_first() {
        let mut c: BankCache<&'static str> = BankCache::new(250);
        c.insert("a".into(), "A", 100);
        c.insert("b".into(), "B", 100);
        // touch a by re-inserting it (take + insert is the real pattern)
        let (va, ba) = c.take("a").unwrap();
        c.insert("a".into(), va, ba);
        // c pushes over budget: b is now the LRU entry
        let ev = c.insert("c".into(), "C", 100);
        assert_eq!(ev.len(), 1);
        assert_eq!(ev[0].0, "b");
        assert!(c.contains("a") && c.contains("c"));
    }

    #[test]
    fn zero_budget_caches_nothing() {
        let mut c: BankCache<u8> = BankCache::new(0);
        let ev = c.insert("a".into(), 7, 64);
        assert_eq!(ev.len(), 1, "entry immediately evicted");
        assert_eq!(ev[0].0, "a");
        assert!(c.is_empty() && c.resident_bytes() == 0);
    }

    #[test]
    fn unbounded_budget_never_evicts() {
        let mut c: BankCache<u8> = BankCache::new(usize::MAX);
        for i in 0..16u8 {
            assert!(c.insert(format!("m{i}"), i, 1 << 20).is_empty());
        }
        assert_eq!(c.len(), 16);
    }

    #[test]
    fn model_cache_checkout_commit_accounting() {
        let m = Arc::new(RegistryMetrics::default());
        let mut c: ModelCache<u32> = ModelCache::new(1000, m.clone());
        assert!(c.active_model().is_none());

        // first activation: miss, nothing to stash
        assert!(c.checkout("a").is_none());
        c.commit("a", 100, None);
        assert!(c.is_active("a"));

        // switch to b: miss; a goes resident
        assert!(c.checkout("b").is_none());
        c.commit("b", 100, Some(1));
        let s = m.snapshot();
        assert_eq!(s.switches, 2);
        assert_eq!(s.misses, 2);
        assert_eq!(s.hits, 0);
        assert_eq!(s.resident_bytes, 200, "a cached + b active");

        // back to a: hit
        let hit = c.checkout("a");
        assert_eq!(hit, Some((1, 100)));
        c.commit("a", 100, Some(2));
        let s = m.snapshot();
        assert_eq!(s.hits, 1);
        assert!(c.is_active("a"));
    }

    #[test]
    fn model_cache_zero_budget_reports_evictions() {
        let m = Arc::new(RegistryMetrics::default());
        let mut c: ModelCache<u32> = ModelCache::new(0, m.clone());
        assert!(c.checkout("a").is_none());
        c.commit("a", 50, None);
        assert!(c.checkout("b").is_none());
        c.commit("b", 50, Some(1)); // a evicted immediately
        assert!(c.checkout("a").is_none(), "a was not retained");
        c.commit("a", 50, Some(2));
        let s = m.snapshot();
        assert_eq!(s.hits, 0);
        assert_eq!(s.misses, 3);
        assert!(s.evictions >= 2);
        assert_eq!(s.resident_bytes, 50, "only the active model");
    }
}
