//! Program registry: virtualizing one photonic machine across models.
//!
//! The paper's machine is a single shared analog substrate; production use
//! means one machine serving many models.  This module provides the naming
//! and accounting layer for that:
//!
//! - [`ProgramRegistry`] — an ordered set of named checkpoints
//!   ([`ModelCheckpoint`]: artifacts + parameter store), loaded from the
//!   same on-disk layout `runtime/artifact.rs` defines for one model.
//! - [`ProgramKey`] — the identity a backend programs against: model name
//!   plus the model-mixed seed and the per-model DAC/ADC scales.  Streams
//!   reseed deterministically per `(model, generation)`, so the bitwise
//!   replay contract holds per `(model, seed, threads, prefetch, rule)`.
//! - [`BankCache`] / [`ModelCache`] — byte-budgeted LRU over per-model
//!   machine + prefetched weight-plane bank state.  Switching models swaps
//!   cache entries instead of destroying them (generalizing the
//!   generation-keyed invalidation: a generation retires a *model's own*
//!   stale banks; the LRU retires *other models'* banks only under memory
//!   pressure).
//! - [`RegistryMetrics`] — residency + hit/miss/switch/eviction counters
//!   surfaced on `/info` next to the entropy-health scorecards.
//!
//! Replay contract under the cache: a cache **hit** continues the model's
//! entropy streams exactly where they left off, so a multi-model engine
//! behaves bitwise like a single-model engine that was never switched away
//! from.  An **eviction + reload** rebuilds the model's machine from its
//! seed, replaying the stream from the start — bitwise identical to a cold
//! engine with the same `(model, seed, threads, prefetch)`.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::entropy::xoshiro::splitmix64;
use crate::runtime::{ModelArtifacts, ParamStore};

mod cache;
mod metrics;

pub use cache::{BankCache, ModelCache};
pub use metrics::{ModelCardSnapshot, RegistryMetrics, RegistrySnapshot, Residency};

/// Mix a model name into a base seed (FNV-1a over the name, finalized with
/// splitmix64).  Distinct models get decorrelated stream seed spaces even
/// when the engine-level seed is shared; the same `(base, name)` pair is
/// stable across runs, which is what the per-model replay contract needs.
pub fn model_seed(base: u64, model: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in model.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    let mut st = base ^ h.rotate_left(17);
    splitmix64(&mut st)
}

/// The identity a backend program-switches against.  `seed` is already
/// model-mixed (see [`model_seed`]); the DAC/ADC scales ride along because
/// each checkpoint's meta pins its own quantization ranges.
#[derive(Debug, Clone, PartialEq)]
pub struct ProgramKey {
    pub model: String,
    pub seed: u64,
    pub scale_dac: f32,
    pub scale_adc: f32,
}

impl ProgramKey {
    pub fn new(model: &str, base_seed: u64, scale_dac: f32, scale_adc: f32) -> Self {
        Self {
            model: model.to_string(),
            seed: model_seed(base_seed, model),
            scale_dac,
            scale_adc,
        }
    }
}

/// Typed "no such model" error, surfaced through the wire protocol as
/// `"code":"unknown_model"`.
#[derive(Debug, Clone)]
pub struct UnknownModel {
    pub model: String,
    pub known: Vec<String>,
}

impl std::fmt::Display for UnknownModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "unknown model '{}' (have: {:?})",
            self.model, self.known
        )
    }
}

impl std::error::Error for UnknownModel {}

/// How to find one model's checkpoint on disk.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    /// Serving name (the wire protocol's `model` field).
    pub name: String,
    /// Subdirectory under the artifacts root holding `meta.json` etc.
    pub dir: String,
    /// Explicit parameter file; `None` picks `theta_trained.bin` if present,
    /// else the meta's init distributions.
    pub params_path: Option<PathBuf>,
}

impl ModelSpec {
    /// Name-is-directory spec (the `--model a,b` CLI form).
    pub fn named(name: &str) -> Self {
        Self {
            name: name.to_string(),
            dir: name.to_string(),
            params_path: None,
        }
    }
}

/// One named, fully-loaded checkpoint: artifacts (meta + compiled stage
/// programs) and the variational parameter store.
pub struct ModelCheckpoint {
    pub name: String,
    pub arts: ModelArtifacts,
    pub params: ParamStore,
}

impl ModelCheckpoint {
    pub fn load(artifacts_root: &Path, spec: &ModelSpec) -> Result<Self> {
        let dir = artifacts_root.join(&spec.dir);
        let arts = ModelArtifacts::load(&dir)
            .with_context(|| format!("loading model '{}' from {}", spec.name, dir.display()))?;
        let params = match &spec.params_path {
            Some(p) => ParamStore::load_bin(&arts.meta, p)
                .with_context(|| format!("model '{}' params {}", spec.name, p.display()))?,
            None => {
                let trained = dir.join("theta_trained.bin");
                if trained.exists() {
                    ParamStore::load_bin(&arts.meta, &trained)?
                } else {
                    ParamStore::load_init(&arts.meta, &dir)?
                }
            }
        };
        Ok(Self {
            name: spec.name.clone(),
            arts,
            params,
        })
    }
}

impl std::fmt::Debug for ModelCheckpoint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ModelCheckpoint")
            .field("name", &self.name)
            .field("dataset", &self.arts.meta.dataset)
            .finish()
    }
}

/// Ordered set of named checkpoints.  The first model is the engine's
/// default (requests without a `model` field go there); order otherwise
/// only affects error listings.
#[derive(Debug, Default)]
pub struct ProgramRegistry {
    pub models: Vec<ModelCheckpoint>,
}

impl ProgramRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Load every spec from `artifacts_root`.  Duplicate names are an
    /// error — the registry is the namespace the wire protocol routes on.
    pub fn load(artifacts_root: &Path, specs: &[ModelSpec]) -> Result<Self> {
        let mut reg = Self::new();
        for spec in specs {
            reg.push(ModelCheckpoint::load(artifacts_root, spec)?)?;
        }
        Ok(reg)
    }

    pub fn push(&mut self, ckpt: ModelCheckpoint) -> Result<()> {
        if self.models.iter().any(|m| m.name == ckpt.name) {
            return Err(anyhow!("duplicate model name '{}' in registry", ckpt.name));
        }
        self.models.push(ckpt);
        Ok(())
    }

    pub fn names(&self) -> Vec<String> {
        self.models.iter().map(|m| m.name.clone()).collect()
    }

    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }

    pub fn len(&self) -> usize {
        self.models.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_seed_is_stable_and_separates_models() {
        let a = model_seed(42, "digits");
        assert_eq!(a, model_seed(42, "digits"), "same inputs, same seed");
        assert_ne!(a, model_seed(42, "blood"), "name separates");
        assert_ne!(a, model_seed(43, "digits"), "base seed separates");
        // not the identity on the base seed
        assert_ne!(a, 42);
    }

    #[test]
    fn program_key_mixes_model_into_seed() {
        let k1 = ProgramKey::new("digits", 7, 1.0, 2.0);
        let k2 = ProgramKey::new("blood", 7, 1.0, 2.0);
        assert_ne!(k1.seed, k2.seed);
        assert_eq!(k1.seed, model_seed(7, "digits"));
        assert_eq!(k1.scale_dac, 1.0);
        assert_eq!(k2.scale_adc, 2.0);
    }

    #[test]
    fn unknown_model_formats_and_downcasts() {
        let err = UnknownModel {
            model: "nope".into(),
            known: vec!["digits".into()],
        };
        let any: anyhow::Error = err.into();
        let back = any.downcast_ref::<UnknownModel>().expect("typed error");
        assert_eq!(back.model, "nope");
        assert!(format!("{any}").contains("unknown model 'nope'"));
    }

    #[test]
    fn empty_registry_and_named_spec() {
        let reg = ProgramRegistry::new();
        assert!(reg.is_empty() && reg.len() == 0 && reg.names().is_empty());
        let spec = ModelSpec::named("digits");
        assert_eq!(spec.name, "digits");
        assert_eq!(spec.dir, "digits");
        assert!(spec.params_path.is_none());
    }
}
