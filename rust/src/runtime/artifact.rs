//! Artifact metadata + lazy-compiled executable registry for one model.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Context, Result};

use super::executable::CompiledFn;
use crate::util::json::{self};

/// One named parameter region in the flat parameter vector.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

/// Parsed `artifacts/<dataset>/meta.json` — the L2 ↔ L3 contract.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub dataset: String,
    pub in_channels: usize,
    pub n_classes: usize,
    pub img_hw: usize,
    pub prob_ch: usize,
    pub prob_hw: usize,
    pub num_taps: usize,
    pub feat_ch: usize,
    pub num_params: usize,
    pub scale_dac: f32,
    pub scale_adc: f32,
    pub prior_sigma: f32,
    pub min_rel_sigma: f32,
    pub train_batch: usize,
    pub pre_batches: Vec<usize>,
    pub post_batches: Vec<usize>,
    pub full_batches: Vec<usize>,
    pub param_layout: Vec<ParamSpec>,
    pub artifact_files: HashMap<String, String>,
}

impl ModelMeta {
    pub fn load(dir: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(dir.join("meta.json"))
            .with_context(|| format!("reading {}/meta.json", dir.display()))?;
        Self::from_json(&text).with_context(|| format!("{}/meta.json", dir.display()))
    }

    /// Parse + validate a `meta.json` document.  The layout check runs here
    /// — at the trust boundary — so a hostile or corrupted meta cannot push
    /// out-of-range or overlapping parameter regions into the slicing code
    /// downstream (`ParamStore` indexes the flat vector with these).
    pub fn from_json(text: &str) -> Result<Self> {
        let j = json::parse(text).map_err(|e| anyhow!("meta.json: {e}"))?;
        let num = |k: &str| -> Result<usize> {
            j.req(k)
                .map_err(|e| anyhow!(e))?
                .as_usize()
                .ok_or_else(|| anyhow!("{k} not a number"))
        };
        let fnum = |k: &str| -> Result<f32> {
            Ok(j.req(k)
                .map_err(|e| anyhow!(e))?
                .as_f64()
                .ok_or_else(|| anyhow!("{k} not a number"))? as f32)
        };
        let batches = j.req("batch_sizes").map_err(|e| anyhow!(e))?;
        let bvec = |k: &str| -> Result<Vec<usize>> {
            batches
                .req(k)
                .map_err(|e| anyhow!(e))?
                .as_usize_vec()
                .ok_or_else(|| anyhow!("batch_sizes.{k} malformed"))
        };
        let layout = j
            .req("param_layout")
            .map_err(|e| anyhow!(e))?
            .as_arr()
            .ok_or_else(|| anyhow!("param_layout not an array"))?
            .iter()
            .map(|s| -> Result<ParamSpec> {
                Ok(ParamSpec {
                    name: s
                        .req("name")
                        .map_err(|e| anyhow!(e))?
                        .as_str()
                        .ok_or_else(|| anyhow!("name"))?
                        .to_string(),
                    shape: s
                        .req("shape")
                        .map_err(|e| anyhow!(e))?
                        .as_usize_vec()
                        .ok_or_else(|| anyhow!("shape"))?,
                    offset: s
                        .req("offset")
                        .map_err(|e| anyhow!(e))?
                        .as_usize()
                        .ok_or_else(|| anyhow!("offset"))?,
                    size: s
                        .req("size")
                        .map_err(|e| anyhow!(e))?
                        .as_usize()
                        .ok_or_else(|| anyhow!("size"))?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let artifact_files = j
            .req("artifacts")
            .map_err(|e| anyhow!(e))?
            .as_obj()
            .ok_or_else(|| anyhow!("artifacts not an object"))?
            .iter()
            .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
            .collect();
        let meta = Self {
            dataset: j
                .req("dataset")
                .map_err(|e| anyhow!(e))?
                .as_str()
                .unwrap_or_default()
                .to_string(),
            in_channels: num("in_channels")?,
            n_classes: num("n_classes")?,
            img_hw: num("img_hw")?,
            prob_ch: num("prob_ch")?,
            prob_hw: num("prob_hw")?,
            num_taps: num("num_taps")?,
            feat_ch: num("feat_ch")?,
            num_params: num("num_params")?,
            scale_dac: fnum("scale_dac")?,
            scale_adc: fnum("scale_adc")?,
            prior_sigma: fnum("prior_sigma")?,
            min_rel_sigma: fnum("min_rel_sigma")?,
            train_batch: batches
                .req("train")
                .map_err(|e| anyhow!(e))?
                .as_usize()
                .ok_or_else(|| anyhow!("train batch"))?,
            pre_batches: bvec("pre")?,
            post_batches: bvec("post")?,
            full_batches: bvec("full")?,
            param_layout: layout,
            artifact_files,
        };
        meta.validate_layout()?;
        Ok(meta)
    }

    /// Reject metas whose `param_layout` cannot be indexed safely against
    /// `num_params`: duplicate region names, regions whose `offset + size`
    /// overflows or exceeds the parameter count, shape/size mismatches, and
    /// overlapping regions.
    fn validate_layout(&self) -> Result<()> {
        for s in &self.param_layout {
            let end = s
                .offset
                .checked_add(s.size)
                .ok_or_else(|| anyhow!("param '{}': offset + size overflows", s.name))?;
            if end > self.num_params {
                return Err(anyhow!(
                    "param '{}': region [{}, {}) exceeds num_params {}",
                    s.name,
                    s.offset,
                    end,
                    self.num_params
                ));
            }
            let shape_elems: usize = s.shape.iter().try_fold(1usize, |a, &d| {
                a.checked_mul(d)
                    .ok_or_else(|| anyhow!("param '{}': shape product overflows", s.name))
            })?;
            if shape_elems != s.size {
                return Err(anyhow!(
                    "param '{}': shape {:?} has {} elements but size = {}",
                    s.name,
                    s.shape,
                    shape_elems,
                    s.size
                ));
            }
        }
        // overlap + duplicate-name checks on a sorted view: any two regions
        // colliding appear adjacent after sorting by offset
        let mut sorted: Vec<&ParamSpec> = self.param_layout.iter().collect();
        sorted.sort_by_key(|s| s.offset);
        for w in sorted.windows(2) {
            if w[0].offset + w[0].size > w[1].offset {
                return Err(anyhow!(
                    "params '{}' and '{}' overlap ([{}, {}) vs [{}, {}))",
                    w[0].name,
                    w[1].name,
                    w[0].offset,
                    w[0].offset + w[0].size,
                    w[1].offset,
                    w[1].offset + w[1].size
                ));
            }
        }
        let mut names: Vec<&str> = self.param_layout.iter().map(|s| s.name.as_str()).collect();
        names.sort_unstable();
        for w in names.windows(2) {
            if w[0] == w[1] {
                return Err(anyhow!("duplicate param name '{}' in layout", w[0]));
            }
        }
        Ok(())
    }

    pub fn param(&self, name: &str) -> Option<&ParamSpec> {
        self.param_layout.iter().find(|s| s.name == name)
    }

    /// Image pixel count per sample.
    pub fn image_size(&self) -> usize {
        self.in_channels * self.img_hw * self.img_hw
    }

    /// Size of the activation tensor entering the photonic stage.
    pub fn act_size(&self) -> usize {
        self.prob_ch * self.prob_hw * self.prob_hw
    }

    /// Size of one eps noise tensor per sample.
    pub fn eps_size(&self) -> usize {
        self.act_size() * self.num_taps
    }
}

/// Lazily-compiled executable registry for one model directory.
pub struct ModelArtifacts {
    pub meta: ModelMeta,
    pub dir: PathBuf,
    cache: Mutex<HashMap<String, Arc<CompiledFn>>>,
}

impl ModelArtifacts {
    pub fn load(dir: &Path) -> Result<Self> {
        Ok(Self {
            meta: ModelMeta::load(dir)?,
            dir: dir.to_path_buf(),
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Load the artifacts for dataset `name` under `artifacts_root`.
    pub fn load_dataset(artifacts_root: &Path, name: &str) -> Result<Self> {
        Self::load(&artifacts_root.join(name))
    }

    /// Fetch (compiling on first use) the entry point `name`, e.g.
    /// `fwd_full_b8` or `train_step`.
    pub fn get(&self, name: &str) -> Result<Arc<CompiledFn>> {
        if let Some(f) = self.cache.lock().unwrap().get(name) {
            return Ok(f.clone());
        }
        let fname = self
            .meta
            .artifact_files
            .get(name)
            .ok_or_else(|| anyhow!("no artifact named '{name}' in meta.json"))?;
        let compiled = Arc::new(CompiledFn::load(&self.dir.join(fname), name)?);
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), compiled.clone());
        Ok(compiled)
    }

    /// The smallest compiled batch size >= `n` for an entry-point family
    /// (`fwd_pre` / `fwd_post` / `fwd_full`); falls back to the largest.
    pub fn pick_batch(&self, family: &str, n: usize) -> usize {
        let sizes = match family {
            "fwd_pre" => &self.meta.pre_batches,
            "fwd_post" => &self.meta.post_batches,
            "fwd_full" => &self.meta.full_batches,
            _ => panic!("unknown family {family}"),
        };
        *sizes
            .iter()
            .find(|&&b| b >= n)
            .unwrap_or_else(|| sizes.last().expect("no batch sizes"))
    }

    /// Names of all entry points.
    pub fn entry_points(&self) -> Vec<String> {
        self.meta.artifact_files.keys().cloned().collect()
    }
}

/// Resolve the default artifacts root: `$PBM_ARTIFACTS` or `./artifacts`.
pub fn artifacts_root() -> PathBuf {
    std::env::var("PBM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        artifacts_root().join("digits/meta.json").exists()
    }

    /// Minimal meta document with a caller-supplied `param_layout` — the
    /// hostile-meta tests mutate only the layout.
    fn meta_json(num_params: usize, layout: &str) -> String {
        format!(
            r#"{{
              "dataset": "t", "in_channels": 1, "n_classes": 2, "img_hw": 4,
              "prob_ch": 1, "prob_hw": 2, "num_taps": 9, "feat_ch": 1,
              "num_params": {num_params},
              "scale_dac": 4.0, "scale_adc": 8.0,
              "prior_sigma": 0.1, "min_rel_sigma": 0.01,
              "batch_sizes": {{"train": 8, "pre": [1], "post": [1], "full": [1]}},
              "param_layout": [{layout}],
              "artifacts": {{}}
            }}"#
        )
    }

    fn spec(name: &str, offset: usize, size: usize) -> String {
        format!(r#"{{"name": "{name}", "shape": [{size}], "offset": {offset}, "size": {size}}}"#)
    }

    #[test]
    fn valid_layout_passes_validation() {
        let text = meta_json(10, &format!("{}, {}", spec("a", 0, 4), spec("b", 4, 6)));
        let meta = ModelMeta::from_json(&text).unwrap();
        assert_eq!(meta.param_layout.len(), 2);
        assert_eq!(meta.param("b").unwrap().offset, 4);
        // a benign gap between regions is allowed (only overlap is hostile)
        let gappy = meta_json(20, &format!("{}, {}", spec("a", 0, 4), spec("b", 10, 6)));
        assert!(ModelMeta::from_json(&gappy).is_ok());
    }

    #[test]
    fn rejects_out_of_range_region() {
        let text = meta_json(8, &spec("a", 4, 6));
        let err = ModelMeta::from_json(&text).unwrap_err();
        assert!(err.to_string().contains("exceeds num_params"), "{err}");
    }

    #[test]
    fn rejects_offset_size_overflow() {
        let text = meta_json(8, &spec("a", usize::MAX, 2));
        let err = ModelMeta::from_json(&text).unwrap_err();
        // the huge offset dies either in checked_add or the range check —
        // both are rejections, never a wrapped index
        assert!(
            err.to_string().contains("overflow") || err.to_string().contains("exceeds"),
            "{err}"
        );
    }

    #[test]
    fn rejects_overlapping_regions() {
        // out-of-order offsets with a 2-element collision
        let text = meta_json(20, &format!("{}, {}", spec("b", 6, 6), spec("a", 0, 8)));
        let err = ModelMeta::from_json(&text).unwrap_err();
        assert!(err.to_string().contains("overlap"), "{err}");
    }

    #[test]
    fn rejects_duplicate_region_names() {
        let text = meta_json(10, &format!("{}, {}", spec("a", 0, 4), spec("a", 4, 4)));
        let err = ModelMeta::from_json(&text).unwrap_err();
        assert!(err.to_string().contains("duplicate param name"), "{err}");
    }

    #[test]
    fn rejects_shape_size_mismatch() {
        let lying =
            r#"{"name": "a", "shape": [2, 3], "offset": 0, "size": 4}"#.to_string();
        let err = ModelMeta::from_json(&meta_json(10, &lying)).unwrap_err();
        assert!(err.to_string().contains("elements"), "{err}");
    }

    #[test]
    fn meta_parses_and_is_consistent() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let meta = ModelMeta::load(&artifacts_root().join("digits")).unwrap();
        assert_eq!(meta.dataset, "digits");
        assert_eq!(meta.n_classes, 10);
        assert_eq!(meta.num_taps, 9);
        let last = meta.param_layout.last().unwrap();
        assert_eq!(last.offset + last.size, meta.num_params);
        assert!(meta.param("prob_mu").is_some());
        assert!(meta.param("prob_rho").is_some());
        assert_eq!(meta.eps_size(), meta.act_size() * 9);
    }

    #[test]
    fn pick_batch_rounds_up() {
        if !have_artifacts() {
            return;
        }
        let arts = ModelArtifacts::load(&artifacts_root().join("digits")).unwrap();
        assert_eq!(arts.pick_batch("fwd_full", 1), 1);
        assert_eq!(arts.pick_batch("fwd_full", 2), 8);
        assert_eq!(arts.pick_batch("fwd_full", 9), 32);
        assert_eq!(arts.pick_batch("fwd_full", 5000), 100);
    }

    #[test]
    fn compiles_and_runs_fwd_full() {
        if !have_artifacts() {
            return;
        }
        let arts = ModelArtifacts::load(&artifacts_root().join("digits")).unwrap();
        let f = arts.get("fwd_full_b1").unwrap();
        let meta = &arts.meta;
        let theta = vec![0.01f32; meta.num_params];
        let x = vec![0.5f32; meta.image_size()];
        let eps = vec![0.0f32; meta.eps_size()];
        let out = f
            .call(&[
                super::super::Arg::F32(&theta, &[meta.num_params as i64]),
                super::super::Arg::F32(
                    &x,
                    &[1, meta.in_channels as i64, meta.img_hw as i64, meta.img_hw as i64],
                ),
                super::super::Arg::F32(
                    &eps,
                    &[
                        1,
                        meta.prob_ch as i64,
                        meta.prob_hw as i64,
                        meta.prob_hw as i64,
                        meta.num_taps as i64,
                    ],
                ),
            ])
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), meta.n_classes);
        assert!(out[0].iter().all(|v| v.is_finite()));
        // cached second fetch
        let f2 = arts.get("fwd_full_b1").unwrap();
        assert!(Arc::ptr_eq(&f, &f2));
    }
}
