//! Flat parameter store: the single f32 vector holding every trainable
//! parameter, addressed through the meta.json layout.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::artifact::ModelMeta;
use crate::photonics::TapTarget;

/// Parameter vector + layout.
#[derive(Debug, Clone)]
pub struct ParamStore {
    pub theta: Vec<f32>,
    meta: ModelMeta,
}

/// Numerically-stable softplus.
pub fn softplus(x: f32) -> f32 {
    if x > 20.0 {
        x
    } else if x < -20.0 {
        x.exp()
    } else {
        (1.0 + x.exp()).ln()
    }
}

impl ParamStore {
    pub fn new(meta: &ModelMeta, theta: Vec<f32>) -> Result<Self> {
        if theta.len() != meta.num_params {
            return Err(anyhow!(
                "theta length {} != meta.num_params {}",
                theta.len(),
                meta.num_params
            ));
        }
        Ok(Self {
            theta,
            meta: meta.clone(),
        })
    }

    /// Load a raw little-endian f32 file (`params_init.bin` or a checkpoint).
    pub fn load_bin(meta: &ModelMeta, path: &Path) -> Result<Self> {
        let bytes =
            std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        if bytes.len() != meta.num_params * 4 {
            return Err(anyhow!(
                "{}: {} bytes, want {}",
                path.display(),
                bytes.len(),
                meta.num_params * 4
            ));
        }
        let theta = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Self::new(meta, theta)
    }

    /// The freshly-initialized parameters exported by aot.py.
    pub fn load_init(meta: &ModelMeta, model_dir: &Path) -> Result<Self> {
        Self::load_bin(meta, &model_dir.join("params_init.bin"))
    }

    pub fn save_bin(&self, path: &Path) -> Result<()> {
        let mut bytes = Vec::with_capacity(self.theta.len() * 4);
        for x in &self.theta {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        std::fs::write(path, bytes).with_context(|| format!("writing {}", path.display()))
    }

    /// Slice of a named parameter region.
    pub fn slice(&self, name: &str) -> Result<&[f32]> {
        let spec = self
            .meta
            .param(name)
            .ok_or_else(|| anyhow!("no parameter '{name}'"))?;
        Ok(&self.theta[spec.offset..spec.offset + spec.size])
    }

    /// The probabilistic taps as machine targets: `mu` straight from
    /// `prob_mu`, `sigma = max(softplus(prob_rho), min_rel_sigma * |mu|)` —
    /// the same straight-through floor the L2 surrogate applies, so the
    /// machine is programmed with exactly the distribution trained against.
    ///
    /// Returns `prob_ch` kernels of `num_taps` targets each.
    pub fn prob_kernels(&self) -> Result<Vec<Vec<TapTarget>>> {
        let mu = self.slice("prob_mu")?;
        let rho = self.slice("prob_rho")?;
        let nt = self.meta.num_taps;
        let floor = self.meta.min_rel_sigma;
        Ok(mu
            .chunks(nt)
            .zip(rho.chunks(nt))
            .map(|(mus, rhos)| {
                mus.iter()
                    .zip(rhos)
                    .map(|(&m, &r)| TapTarget {
                        mu: m,
                        sigma: softplus(r).max(floor * m.abs()),
                    })
                    .collect()
            })
            .collect())
    }

    pub fn num_params(&self) -> usize {
        self.theta.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::artifacts_root;

    #[test]
    fn softplus_properties() {
        assert!((softplus(0.0) - (2f32).ln()).abs() < 1e-6);
        assert!((softplus(-3.0) - 0.048587).abs() < 1e-5);
        assert!((softplus(30.0) - 30.0).abs() < 1e-5);
        assert!(softplus(-30.0) > 0.0);
    }

    #[test]
    fn loads_init_params_and_prob_kernels() {
        let root = artifacts_root().join("digits");
        if !root.join("meta.json").exists() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let meta = ModelMeta::load(&root).unwrap();
        let ps = ParamStore::load_init(&meta, &root).unwrap();
        assert_eq!(ps.num_params(), meta.num_params);
        let kernels = ps.prob_kernels().unwrap();
        assert_eq!(kernels.len(), meta.prob_ch);
        assert_eq!(kernels[0].len(), meta.num_taps);
        // rho init -3 -> sigma ~= softplus(-3) = 0.04859, or the rel floor
        for kern in &kernels {
            for t in kern {
                let expect = softplus(-3.0).max(meta.min_rel_sigma * t.mu.abs());
                assert!((t.sigma - expect).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let root = artifacts_root().join("digits");
        if !root.join("meta.json").exists() {
            return;
        }
        let meta = ModelMeta::load(&root).unwrap();
        let mut ps = ParamStore::load_init(&meta, &root).unwrap();
        ps.theta[3] = 42.5;
        let tmp = std::env::temp_dir().join("pbm_params_rt.bin");
        ps.save_bin(&tmp).unwrap();
        let ps2 = ParamStore::load_bin(&meta, &tmp).unwrap();
        assert_eq!(ps.theta, ps2.theta);
    }

    #[test]
    fn wrong_size_rejected() {
        let root = artifacts_root().join("digits");
        if !root.join("meta.json").exists() {
            return;
        }
        let meta = ModelMeta::load(&root).unwrap();
        assert!(ParamStore::new(&meta, vec![0.0; 10]).is_err());
    }
}
