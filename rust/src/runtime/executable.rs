//! Compiled-executable wrapper: typed arguments in, flat f32 tensors out.

use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::client::thread_client;

/// A typed input argument for a compiled function.
pub enum Arg<'a> {
    /// f32 tensor with shape.
    F32(&'a [f32], &'a [i64]),
    /// i32 tensor with shape.
    I32(&'a [i32], &'a [i64]),
    /// f32 scalar.
    ScalarF32(f32),
}

impl<'a> Arg<'a> {
    fn to_literal(&self) -> Result<xla::Literal> {
        match self {
            Arg::F32(data, shape) => {
                let expect: i64 = shape.iter().product();
                if expect != data.len() as i64 {
                    return Err(anyhow!(
                        "arg shape {:?} wants {} elements, got {}",
                        shape,
                        expect,
                        data.len()
                    ));
                }
                Ok(xla::Literal::vec1(data)
                    .reshape(shape)
                    .map_err(|e| anyhow!("reshape: {e:?}"))?)
            }
            Arg::I32(data, shape) => {
                let expect: i64 = shape.iter().product();
                if expect != data.len() as i64 {
                    return Err(anyhow!("arg shape mismatch"));
                }
                Ok(xla::Literal::vec1(data)
                    .reshape(shape)
                    .map_err(|e| anyhow!("reshape: {e:?}"))?)
            }
            Arg::ScalarF32(x) => Ok(xla::Literal::scalar(*x)),
        }
    }
}

/// One compiled HLO entry point (compile once, execute many).
pub struct CompiledFn {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
    /// Cumulative execute() wall time (telemetry).
    pub exec_ns: std::sync::atomic::AtomicU64,
    pub exec_count: std::sync::atomic::AtomicU64,
}

impl CompiledFn {
    /// Load HLO text from `path` and compile it on this thread's CPU client.
    pub fn load(path: &Path, name: &str) -> Result<Self> {
        let client = thread_client()?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing HLO text {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))?;
        Ok(Self {
            name: name.to_string(),
            exe,
            exec_ns: Default::default(),
            exec_count: Default::default(),
        })
    }

    /// Execute with typed args; returns each tuple element flattened to f32.
    ///
    /// The AOT path lowers with `return_tuple=True`, so the single output is
    /// a tuple whose elements we decompose and convert.
    pub fn call(&self, args: &[Arg<'_>]) -> Result<Vec<Vec<f32>>> {
        let t0 = Instant::now();
        let literals: Vec<xla::Literal> = args
            .iter()
            .map(|a| a.to_literal())
            .collect::<Result<_>>()
            .context("building input literals")?;
        let bufs = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {}: {e:?}", self.name))?;
        let result = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result: {e:?}"))?;
        let elems = result
            .to_tuple()
            .map_err(|e| anyhow!("decomposing tuple: {e:?}"))?;
        let mut out = Vec::with_capacity(elems.len());
        for el in elems {
            let el_f32 = match el.ty().map_err(|e| anyhow!("{e:?}"))? {
                xla::ElementType::F32 => el,
                _ => el
                    .convert(xla::PrimitiveType::F32)
                    .map_err(|e| anyhow!("convert: {e:?}"))?,
            };
            out.push(el_f32.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?);
        }
        self.exec_ns.fetch_add(
            t0.elapsed().as_nanos() as u64,
            std::sync::atomic::Ordering::Relaxed,
        );
        self.exec_count
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Ok(out)
    }

    /// Mean execute latency so far (telemetry).
    pub fn mean_latency_us(&self) -> f64 {
        let n = self.exec_count.load(std::sync::atomic::Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.exec_ns.load(std::sync::atomic::Ordering::Relaxed) as f64 / n as f64 / 1000.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    /// A tiny hand-written HLO module: f(x, y) = (x + y, x * y) over f32[4].
    const HLO: &str = r#"
HloModule tiny.0

ENTRY main {
  x = f32[4] parameter(0)
  y = f32[4] parameter(1)
  add = f32[4] add(x, y)
  mul = f32[4] multiply(x, y)
  ROOT out = (f32[4], f32[4]) tuple(add, mul)
}
"#;

    fn write_tmp(name: &str, text: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("pbm_rt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(name);
        let mut f = std::fs::File::create(&p).unwrap();
        f.write_all(text.as_bytes()).unwrap();
        p
    }

    #[test]
    fn loads_and_executes_hlo_text() {
        let p = write_tmp("tiny.hlo.txt", HLO);
        let f = CompiledFn::load(&p, "tiny").unwrap();
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let y = [10.0f32, 20.0, 30.0, 40.0];
        let out = f
            .call(&[Arg::F32(&x, &[4]), Arg::F32(&y, &[4])])
            .unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0], vec![11.0, 22.0, 33.0, 44.0]);
        assert_eq!(out[1], vec![10.0, 40.0, 90.0, 160.0]);
        assert!(f.mean_latency_us() > 0.0);
    }

    #[test]
    fn shape_mismatch_is_an_error() {
        let p = write_tmp("tiny2.hlo.txt", HLO);
        let f = CompiledFn::load(&p, "tiny2").unwrap();
        let x = [1.0f32, 2.0];
        let err = f.call(&[Arg::F32(&x, &[4]), Arg::F32(&x, &[2])]);
        assert!(err.is_err());
    }

    #[test]
    fn missing_file_is_an_error() {
        let err = CompiledFn::load(Path::new("/nonexistent/x.hlo.txt"), "x");
        assert!(err.is_err());
    }
}
