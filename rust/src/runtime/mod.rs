//! PJRT runtime: load HLO-text artifacts, compile once, execute from the
//! serving/training hot path.
//!
//! Wraps the `xla` crate (PJRT C API bindings, xla_extension 0.5.1):
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`.  HLO **text** is the interchange format —
//! jax ≥ 0.5 serialized protos use 64-bit instruction ids the 0.5.1 parser
//! rejects, while the text parser reassigns ids (see aot.py).

pub mod artifact;
pub mod client;
pub mod executable;
pub mod params;

pub use artifact::{ModelArtifacts, ModelMeta};
pub use executable::{Arg, CompiledFn};
pub use params::ParamStore;
