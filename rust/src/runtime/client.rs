//! Per-thread PJRT CPU client.
//!
//! The `xla` crate's `PjRtClient` is an `Rc`-based handle — neither `Send`
//! nor `Sync` — so there is no process-global client.  Instead each thread
//! that touches XLA gets a thread-local client, and the architecture keeps
//! the number of such threads at one: the coordinator confines all PJRT
//! work to a dedicated engine thread (see `coordinator::service`), which is
//! also the right shape for the CPU backend (executables parallelize
//! internally via their own thread pool; concurrent dispatch buys nothing).
//!
//! Not to be confused with the *network* client
//! ([`crate::server::tcp::Client`]), which carries the serving RPC
//! idempotency rule: `ping`/`info` retry freely
//! (`call_idempotent`), a plain `classify` is never retried (the engine's
//! persistent entropy stream makes a repeat a *different* answer and a
//! double spend), and a plan-seeded classify retries via
//! `call_replayable` because its answer is a pure function of
//! `(model, plan_seed, budget)` — see that module for the
//! dirty-connection mechanics that close the duplicate-answer window.

use std::cell::RefCell;

use anyhow::Result;

thread_local! {
    static CLIENT: RefCell<Option<xla::PjRtClient>> = const { RefCell::new(None) };
}

/// The calling thread's CPU client (created on first use).
pub fn thread_client() -> Result<xla::PjRtClient> {
    CLIENT.with(|c| {
        let mut slot = c.borrow_mut();
        if slot.is_none() {
            let client =
                xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?;
            *slot = Some(client);
        }
        Ok(slot.as_ref().unwrap().clone())
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_is_cpu() {
        let c = thread_client().unwrap();
        assert!(c.device_count() >= 1);
        assert!(c.platform_name().to_lowercase().contains("cpu")
            || c.platform_name().to_lowercase().contains("host"));
    }

    #[test]
    fn reuse_within_thread() {
        // both calls must succeed and be cheap (same underlying client)
        let _a = thread_client().unwrap();
        let _b = thread_client().unwrap();
    }
}
