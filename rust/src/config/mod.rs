//! Configuration files: a TOML-subset (`key = value` with `[sections]`)
//! parser and the typed serving/training configs built on it.
//!
//! Example (`pbm serve --config serve.toml`):
//!
//! ```toml
//! [server]
//! addr = "127.0.0.1:7878"
//! workers = 8
//! # close connections with no complete request for this long (0 = never);
//! # announced with a coded "idle_timeout" error line
//! idle_timeout_ms = 60000
//!
//! [engine]
//! datasets = "digits,blood"
//! # multi-model registry: LRU budget for cached per-model kernel-bank
//! # state (MiB); models beyond the budget are evicted and replayed
//! # bitwise-identically on reload
//! bank_budget_mb = 256
//! n_samples = 10
//! # sampling substrate: photonic | digital | mean | surrogate
//! backend = "photonic"
//! mi_threshold = 0.0185
//! calibrate = true
//! # sampling worker threads per engine: 1 = sequential, 0 = one per core;
//! # results are deterministic for a fixed (seed, threads)
//! threads = 4
//! # decoupled entropy pipeline: off (inline draws), sync (banked streams,
//! # drawn at consumption), on (background producers + SPSC block rings);
//! # sync and on are bitwise identical for a fixed (seed, threads)
//! entropy_prefetch = "on"
//! # draws per prefetched entropy block
//! entropy_block = 4096
//! # act on sustained entropy-health degradation by swapping the sampling
//! # backend (requires [health] enabled): digital | none
//! entropy_fallback = "digital"
//!
//! [health]
//! # online entropy-health monitor: tap producer blocks, score sliding bit
//! # windows with the hardened NIST battery + min-entropy estimators, and
//! # publish per-(shard, stream) scorecards on /info
//! enabled = true
//! # sliding analysis window (bits); >= 4096 lets the full battery apply
//! window_bits = 4096
//! # fraction of produced blocks tapped (keeps the monitor off the hot path)
//! duty = 0.05
//! # EWMA smoothing for the per-stream pass-rate score
//! ewma_alpha = 0.3
//! # EWMA score below which a window counts as failing
//! fail_threshold = 0.5
//! # consecutive failing windows before a Degraded event fires
//! fail_consecutive = 2
//! # SP800-90B most-common-value min-entropy floor (bits/bit)
//! min_entropy_floor = 0.9
//! # maximum acceptable |lag-1 serial correlation|
//! serial_corr_cap = 0.2
//!
//! # one engine serving several models through a shared program registry:
//! # model name = artifact subdirectory under the artifacts root; requests
//! # pick a model via the protocol's `model` field (first entry = default)
//! [models]
//! digits = "digits"
//! blood = "blood"
//!
//! [batcher]
//! max_batch = 8
//! max_wait_ms = 2
//! queue_depth = 256
//!
//! [overload]
//! # server-default request deadline (ms, 0 = none); per-request
//! # deadline_ms wins.  Expired requests shed with code=deadline_exceeded
//! deadline_ms = 0
//! # admission work budget in estimated samples (0 = auto:
//! # queue_depth x engine n_samples); beyond it requests shed with
//! # code=overloaded + retry_after_ms
//! work_capacity = 0
//! # pressure (EWMA of work-queue utilization, 0..1) above which request
//! # sample budgets are clamped and responses flag degraded:true
//! clamp_pressure = 0.75
//! # clamped per-request budget (samples, 0 = auto: n_samples / 2)
//! clamp_samples = 0
//! # pressure above which the engine browns out to the mean-field
//! # backend (requires brownout = true)
//! brownout_pressure = 0.92
//! # opt into the brownout tier (off by default: a degraded answer is a
//! # policy decision, not a given)
//! brownout = false
//!
//! [observe]
//! # record per-request trace spans (admission/queue/batch_form/chunk/
//! # respond); off by default.  Responses are bitwise identical either
//! # way — tracing records timestamps, never bytes
//! trace = false
//! # span ring capacity (oldest spans overwritten)
//! trace_capacity = 4096
//! # requests slower than this retain a verbatim span exemplar
//! # (0 = every traced request); query with {"op":"trace"}
//! slow_ms = 250
//! # retained exemplars (FIFO)
//! exemplars = 32
//!
//! [cluster]
//! # pbm cluster: comma-separated worker gateway addresses
//! workers = "127.0.0.1:7979,127.0.0.1:7980"
//! # base seed of the extended replay contract: a request's entropy
//! # stream is lane_seed(seed, placement), independent of which worker
//! # serves it
//! seed = 12648818
//! model = "synth"
//! image_size = 4
//! # stochastic passes per request (match the workers' --samples so the
//! # local-fallback path stays bitwise-faithful)
//! n_samples = 8
//! # hedge a straggling primary after max(hedge_min_ms, ewma x factor)
//! hedge_min_ms = 50
//! hedge_factor = 3.0
//! # worker health-probe period (ms, 0 = no automatic probing); a worker
//! # with degraded entropy health is drained within one interval
//! probe_interval_ms = 1000
//! # with no routable worker, serve locally (degraded:true) instead of
//! # answering code=worker_unavailable
//! local_fallback = false
//!
//! [sampler]
//! # adaptive sequential sampling: fixed | confidence-gap | uncertainty
//! rule = "uncertainty"
//! # never stop before / after this many stochastic passes
//! min_samples = 2
//! max_samples = 20
//! # samples per round between stop checks (0 = auto: max(2, threads))
//! chunk = 0
//! # consecutive chunk checks a criterion must hold (hysteresis)
//! stable = 2
//! # uncertainty rule: the unresolved MI band
//! mi_low = 0.002
//! mi_high = 0.08
//! # confidence-gap rule: required argmax posterior margin
//! target_gap = 0.5
//! ```

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::coordinator::ExecMode;

/// Parsed config: section -> key -> raw string value.
#[derive(Debug, Clone, Default)]
pub struct Config {
    sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Self> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') && line.ends_with(']') {
                section = line[1..line.len() - 1].trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
            let v = v.trim().trim_matches('"').to_string();
            cfg.sections
                .entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), v);
        }
        Ok(cfg)
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(String::as_str)
    }

    pub fn get_or(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key).unwrap_or(default).to_string()
    }

    pub fn get_f64(&self, section: &str, key: &str, default: f64) -> Result<f64> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("[{section}] {key} = {v}: {e}")),
        }
    }

    pub fn get_usize(&self, section: &str, key: &str, default: usize) -> Result<usize> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("[{section}] {key} = {v}: {e}")),
        }
    }

    pub fn get_bool(&self, section: &str, key: &str, default: bool) -> Result<bool> {
        match self.get(section, key) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => Err(anyhow!("[{section}] {key} = {v}: not a bool")),
        }
    }

    /// Typed accessor for an execution-mode / backend key
    /// (`photonic|digital|mean|surrogate`).
    pub fn get_mode(&self, section: &str, key: &str, default: ExecMode) -> Result<ExecMode> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => ExecMode::parse(v).map_err(|e| anyhow!("[{section}] {key}: {e}")),
        }
    }

    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(String::as_str)
    }

    /// Every `key = value` pair of a section, in key order (BTreeMap) — used
    /// for open-ended tables like `[models]` where the keys themselves are
    /// data (model name = artifact subdirectory).
    pub fn items(&self, section: &str) -> Vec<(String, String)> {
        self.sections
            .get(section)
            .map(|kv| kv.iter().map(|(k, v)| (k.clone(), v.clone())).collect())
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# serving config
[server]
addr = "127.0.0.1:0"
workers = 4

[engine]
n_samples = 10
mode = photonic
backend = digital
mi_threshold = 0.0185
calibrate = true
threads = 8
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get("server", "addr"), Some("127.0.0.1:0"));
        assert_eq!(c.get_usize("server", "workers", 1).unwrap(), 4);
        assert_eq!(c.get_f64("engine", "mi_threshold", 0.0).unwrap(), 0.0185);
        assert!(c.get_bool("engine", "calibrate", false).unwrap());
        assert_eq!(c.get_or("engine", "mode", "surrogate"), "photonic");
        assert_eq!(c.get_usize("engine", "threads", 1).unwrap(), 8);
    }

    #[test]
    fn mode_key_is_typed() {
        use crate::backend::BackendKind;
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(
            c.get_mode("engine", "mode", ExecMode::Surrogate).unwrap(),
            ExecMode::photonic()
        );
        assert_eq!(
            c.get_mode("engine", "backend", ExecMode::Surrogate).unwrap(),
            ExecMode::Split(BackendKind::Digital)
        );
        // missing key -> default; bad value -> error
        assert_eq!(
            c.get_mode("engine", "nope", ExecMode::Surrogate).unwrap(),
            ExecMode::Surrogate
        );
        assert!(Config::parse("[e]\nmode = quantum")
            .unwrap()
            .get_mode("e", "mode", ExecMode::Surrogate)
            .is_err());
    }

    #[test]
    fn sampler_table_parses() {
        let c = Config::parse(
            "[sampler]\nrule = \"uncertainty\"\nmin_samples = 3\nmax_samples = 20\n\
             mi_low = 0.004\nstable = 2\n",
        )
        .unwrap();
        assert_eq!(c.get_or("sampler", "rule", "fixed"), "uncertainty");
        assert_eq!(c.get_usize("sampler", "min_samples", 2).unwrap(), 3);
        assert_eq!(c.get_usize("sampler", "max_samples", 0).unwrap(), 20);
        assert_eq!(c.get_f64("sampler", "mi_low", 0.002).unwrap(), 0.004);
        // unset knobs fall back to rule defaults
        assert_eq!(c.get_f64("sampler", "mi_high", 0.08).unwrap(), 0.08);
    }

    #[test]
    fn health_table_parses() {
        let c = Config::parse(
            "[engine]\nentropy_fallback = \"digital\"\n\n[health]\nenabled = true\n\
             window_bits = 8192\nduty = 0.1\nfail_threshold = 0.6\n",
        )
        .unwrap();
        assert_eq!(c.get("engine", "entropy_fallback"), Some("digital"));
        assert!(c.get_bool("health", "enabled", false).unwrap());
        assert_eq!(c.get_usize("health", "window_bits", 4096).unwrap(), 8192);
        assert_eq!(c.get_f64("health", "duty", 0.05).unwrap(), 0.1);
        // unset knobs fall back to monitor defaults
        assert_eq!(c.get_f64("health", "ewma_alpha", 0.3).unwrap(), 0.3);
    }

    #[test]
    fn cluster_table_parses() {
        let c = Config::parse(
            "[cluster]\nworkers = \"127.0.0.1:7979,127.0.0.1:7980\"\nseed = 99\n\
             n_samples = 4\nhedge_min_ms = 25\nhedge_factor = 2.5\n\
             probe_interval_ms = 500\nlocal_fallback = true\n",
        )
        .unwrap();
        assert_eq!(
            c.get("cluster", "workers"),
            Some("127.0.0.1:7979,127.0.0.1:7980")
        );
        assert_eq!(c.get_usize("cluster", "seed", 0).unwrap(), 99);
        assert_eq!(c.get_usize("cluster", "n_samples", 8).unwrap(), 4);
        assert_eq!(c.get_usize("cluster", "hedge_min_ms", 50).unwrap(), 25);
        assert_eq!(c.get_f64("cluster", "hedge_factor", 3.0).unwrap(), 2.5);
        assert_eq!(c.get_usize("cluster", "probe_interval_ms", 1000).unwrap(), 500);
        assert!(c.get_bool("cluster", "local_fallback", false).unwrap());
        // unset knobs fall back to coordinator defaults
        assert_eq!(c.get_usize("cluster", "image_size", 4).unwrap(), 4);
    }

    #[test]
    fn observe_table_parses() {
        let c = Config::parse(
            "[observe]\ntrace = true\ntrace_capacity = 1024\nslow_ms = 100\nexemplars = 8\n",
        )
        .unwrap();
        assert!(c.get_bool("observe", "trace", false).unwrap());
        assert_eq!(c.get_usize("observe", "trace_capacity", 4096).unwrap(), 1024);
        assert_eq!(c.get_usize("observe", "slow_ms", 250).unwrap(), 100);
        assert_eq!(c.get_usize("observe", "exemplars", 32).unwrap(), 8);
        // unset section falls back to ObserveConfig defaults
        let d = Config::parse("").unwrap();
        assert!(!d.get_bool("observe", "trace", false).unwrap());
    }

    #[test]
    fn items_returns_whole_table_in_key_order() {
        let c = Config::parse("[models]\ndigits = \"digits\"\nblood = \"tissue/blood\"\n").unwrap();
        assert_eq!(
            c.items("models"),
            vec![
                ("blood".to_string(), "tissue/blood".to_string()),
                ("digits".to_string(), "digits".to_string()),
            ]
        );
        assert!(c.items("nope").is_empty());
    }

    #[test]
    fn defaults_apply() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.get_usize("x", "y", 7).unwrap(), 7);
        assert!(!c.get_bool("x", "y", false).unwrap());
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let c = Config::parse("# only comments\n\n  # more\n").unwrap();
        assert_eq!(c.sections().count(), 0);
    }

    #[test]
    fn malformed_line_is_error() {
        assert!(Config::parse("[s]\nnot a kv line").is_err());
        assert!(Config::parse("[e]\nbad_bool = maybe")
            .unwrap()
            .get_bool("e", "bad_bool", true)
            .is_err());
    }
}
