//! Feedback calibration of the photonic machine (paper, Supplement).
//!
//! The spectral shaper realizes commanded powers/bandwidths only
//! approximately (actuator error), so the machine is programmed
//! *iteratively*: load a command, measure the realized weight distribution
//! with probe convolutions, compare against the target moments, and relax
//! the command toward the target — "computing test convolutions and
//! calculating the difference between the target weight distributions and
//! the programmed distributions".
//!
//! [`CalibrationReport`] also reproduces the Fig. 2(c,d) experiment: program
//! many random kernels, then compare measured vs target moments of the
//! *output* distribution of test convolutions and report the normalized
//! computation error (paper: 0.158 for the mean, 0.266 for the std).

use crate::photonics::{PhotonicMachine, TapTarget};
use crate::util::mathstat::{linfit, mean_f32, std_f32, Welford};

/// Options for the feedback loop.
#[derive(Debug, Clone)]
pub struct CalibrationOptions {
    /// Probe samples per tap per round.
    pub probe_samples: usize,
    /// Feedback rounds.
    pub rounds: usize,
    /// Relaxation factor (1.0 = full correction per round).
    pub relax: f64,
}

impl Default for CalibrationOptions {
    fn default() -> Self {
        Self {
            probe_samples: 256,
            rounds: 4,
            relax: 0.8,
        }
    }
}

/// Measured moments of every tap of one kernel.
#[derive(Debug, Clone)]
pub struct TapMeasurement {
    pub mean: f64,
    pub std: f64,
}

/// Measure the realized weight distribution of each tap via probe draws
/// (physically: convolutions with one-hot patches).
pub fn measure_taps(
    machine: &mut PhotonicMachine,
    idx: usize,
    samples: usize,
) -> Vec<TapMeasurement> {
    let nt = machine.num_taps();
    (0..nt)
        .map(|k| {
            let mut w = Welford::new();
            for _ in 0..samples {
                w.push(machine.sample_weight(idx, k));
            }
            TapMeasurement {
                mean: w.mean(),
                std: w.std(),
            }
        })
        .collect()
}

/// Iteratively calibrate kernel `idx` of the machine toward `targets`.
///
/// Each round measures the realized per-tap moments, derives a *corrected
/// target* (additive correction for the mean, multiplicative for the std —
/// the natural error models of the rail-difference and speckle-dof knobs),
/// and re-solves the full physics inversion for the corrected target.
/// Re-solving (rather than nudging individual actuator values) is what lets
/// the loop traverse the inversion's branch structure: taps that need
/// common-mode power to reach a large sigma, or that sit on the bandwidth
/// clamp, are re-planned instead of being stuck on a clamped knob.
///
/// Returns the final per-tap measurements.
pub fn calibrate_kernel(
    machine: &mut PhotonicMachine,
    idx: usize,
    targets: &[TapTarget],
    opts: &CalibrationOptions,
) -> Vec<TapMeasurement> {
    let nt = machine.num_taps();
    assert_eq!(targets.len(), nt);
    // corrected targets, refined each round
    let mut corr: Vec<(f64, f64)> = targets
        .iter()
        .map(|t| (t.mu as f64, (t.sigma as f64).max(1e-6)))
        .collect();
    let mut last = measure_taps(machine, idx, opts.probe_samples);
    for _ in 0..opts.rounds {
        let mut cmds = Vec::with_capacity(nt);
        for k in 0..nt {
            let tgt_mu = targets[k].mu as f64;
            let tgt_sigma = (targets[k].sigma as f64).max(1e-6);
            let meas = &last[k];
            // additive mean correction, multiplicative std correction
            corr[k].0 += opts.relax * (tgt_mu - meas.mean);
            let ratio = (tgt_sigma / meas.std.max(1e-9)).clamp(0.25, 4.0);
            corr[k].1 *= ratio.powf(opts.relax);
            let plan = machine.solve_program(
                k,
                TapTarget {
                    mu: corr[k].0 as f32,
                    sigma: corr[k].1.max(1e-6) as f32,
                },
            );
            cmds.push((plan.cmd_p_plus, plan.cmd_p_minus, plan.cmd_dof));
        }
        machine.reprogram_kernel(idx, cmds);
        last = measure_taps(machine, idx, opts.probe_samples);
    }
    last
}

/// Result of the Fig. 2(c,d) computation-error experiment.
#[derive(Debug, Clone)]
pub struct CalibrationReport {
    /// Normalized error of the output-distribution mean (paper: 0.158).
    pub mean_error: f64,
    /// Normalized error of the output-distribution std (paper: 0.266).
    pub std_error: f64,
    /// Correlation slope of measured vs target means (ideal 1.0).
    pub mean_slope: f64,
    /// Correlation slope of measured vs target stds (ideal 1.0).
    pub std_slope: f64,
    pub kernels: usize,
}

/// Run the Fig. 2(c,d) experiment: `n_kernels` random 9-tap kernels, each
/// calibrated, then evaluated with random test-convolution inputs; compare
/// the measured output moments with the analytically expected (target) ones.
///
/// Normalization follows Eq. S8 in spirit: errors are RMS deviations divided
/// by the ensemble spread of the target quantity, making both numbers
/// dimensionless and comparable to the paper's 0.158 / 0.266.
pub fn computation_error_experiment(
    machine: &mut PhotonicMachine,
    n_kernels: usize,
    outputs_per_kernel: usize,
    seed: u64,
) -> CalibrationReport {
    use crate::entropy::{BitSource, Xoshiro256pp};
    let mut rng = Xoshiro256pp::new(seed);
    let nt = machine.num_taps();
    let opts = CalibrationOptions::default();

    let mut tgt_means = Vec::new();
    let mut tgt_stds = Vec::new();
    let mut meas_means = Vec::new();
    let mut meas_stds = Vec::new();

    for _ in 0..n_kernels {
        // random kernel in the machine's native range
        let targets: Vec<TapTarget> = (0..nt)
            .map(|_| {
                let mu = (rng.next_f64() * 2.0 - 1.0) as f32; // [-1, 1]
                let rel = 0.4 + 0.5 * rng.next_f64(); // realizable rel sigma
                TapTarget {
                    mu,
                    sigma: (mu.abs() * rel as f32).max(0.05),
                }
            })
            .collect();
        let idx = machine.load_kernel(&targets);
        calibrate_kernel(machine, idx, &targets, &opts);

        // random non-negative test input patch (post-ReLU activations)
        let patch: Vec<f32> = (0..nt)
            .map(|_| (rng.next_f64() * machine.cfg.scale_dac as f64) as f32)
            .collect();
        // quantize through the machine's own DAC so target == ideal digital
        let dacq = crate::photonics::converters::Quantizer::new(machine.cfg.scale_dac);
        let patch_q: Vec<f32> = patch.iter().map(|&x| dacq.quantize(x)).collect();

        // target output distribution moments (analytic, from targets)
        let t_mean: f64 = targets
            .iter()
            .zip(&patch_q)
            .map(|(t, &x)| t.mu as f64 * x as f64)
            .sum();
        let t_var: f64 = targets
            .iter()
            .zip(&patch_q)
            .map(|(t, &x)| (t.sigma as f64 * x as f64).powi(2))
            .sum();
        tgt_means.push(t_mean);
        tgt_stds.push(t_var.sqrt());

        // measured output distribution
        let mut outs = vec![0.0f32; outputs_per_kernel];
        let stream: Vec<f32> = patch_q.repeat(outputs_per_kernel);
        machine.conv_patches(idx, &stream, &mut outs);
        meas_means.push(mean_f32(&outs));
        meas_stds.push(std_f32(&outs));
    }

    // Normalize by the *range* of the target quantity (Eq. S8 in spirit):
    // the paper attributes the larger std error to the std's smaller output
    // range, which is exactly what a range-normalized error expresses.
    let range = |v: &[f64]| -> f64 {
        let lo = v.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = v.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        (hi - lo).max(1e-12)
    };
    let spread_mean = range(&tgt_means);
    let spread_std = range(&tgt_stds);
    let rms = |a: &[f64], b: &[f64]| -> f64 {
        (a.iter()
            .zip(b)
            .map(|(x, y)| (x - y) * (x - y))
            .sum::<f64>()
            / a.len() as f64)
            .sqrt()
    };
    let (_, mean_slope, _) = linfit(&tgt_means, &meas_means);
    let (_, std_slope, _) = linfit(&tgt_stds, &meas_stds);
    CalibrationReport {
        mean_error: rms(&meas_means, &tgt_means) / spread_mean,
        std_error: rms(&meas_stds, &tgt_stds) / spread_std,
        mean_slope,
        std_slope,
        kernels: n_kernels,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::photonics::MachineConfig;

    fn noisy_machine(seed: u64) -> PhotonicMachine {
        PhotonicMachine::new(MachineConfig {
            seed,
            ..MachineConfig::default()
        })
    }

    #[test]
    fn calibration_reduces_programming_error() {
        let mut m = noisy_machine(11);
        let targets: Vec<TapTarget> = (0..9)
            .map(|k| TapTarget {
                mu: 0.1 * (k as f32 - 4.0),
                sigma: 0.25,
            })
            .collect();
        let idx = m.load_kernel(&targets);
        let before = measure_taps(&mut m, idx, 2048);
        let err = |meas: &[TapMeasurement]| -> f64 {
            meas.iter()
                .zip(&targets)
                .map(|(ms, t)| (ms.mean - t.mu as f64).abs() + (ms.std - t.sigma as f64).abs())
                .sum::<f64>()
        };
        let opts = CalibrationOptions {
            probe_samples: 2048,
            rounds: 5,
            relax: 0.8,
        };
        calibrate_kernel(&mut m, idx, &targets, &opts);
        let after = measure_taps(&mut m, idx, 2048);
        assert!(
            err(&after) < err(&before) * 0.8,
            "before {} after {}",
            err(&before),
            err(&after)
        );
    }

    #[test]
    fn computation_error_in_paper_ballpark() {
        let mut m = noisy_machine(13);
        let rep = computation_error_experiment(&mut m, 12, 512, 99);
        // the paper reports 0.158 (mean) and 0.266 (std); the simulator
        // should land in the same regime, and std error should exceed mean
        // error (smaller output range, as the paper notes)
        assert!(rep.mean_error < 0.5, "mean error {}", rep.mean_error);
        assert!(rep.std_error < 1.0, "std error {}", rep.std_error);
        assert!(rep.mean_slope > 0.8 && rep.mean_slope < 1.2);
    }

    #[test]
    fn measure_taps_returns_one_entry_per_channel() {
        let mut m = noisy_machine(17);
        let idx = m.load_kernel(&vec![TapTarget { mu: 0.2, sigma: 0.2 }; 9]);
        let meas = measure_taps(&mut m, idx, 64);
        assert_eq!(meas.len(), 9);
        for t in meas {
            assert!(t.std > 0.0);
        }
    }
}
