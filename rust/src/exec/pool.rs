//! Fixed-size worker thread pool with scoped fork-join.

use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use super::channel::{channel, Sender};

/// A boxed unit of work.
pub type Job = Box<dyn FnOnce() + Send + 'static>;

/// Error: the pool's queue is closed (the pool is draining / shut down).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolShutDown;

impl std::fmt::Display for PoolShutDown {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("thread pool is shut down")
    }
}

impl std::error::Error for PoolShutDown {}

/// Signals a completion channel when dropped — even if the job panics, the
/// scoped fork-join barrier still advances (workers catch the panic).
struct DoneGuard(Option<Sender<()>>);

impl Drop for DoneGuard {
    fn drop(&mut self) {
        if let Some(tx) = self.0.take() {
            let _ = tx.send(());
        }
    }
}

/// A fixed pool of worker threads executing boxed jobs.  Panicking jobs are
/// caught and counted; the pool survives them.
pub struct ThreadPool {
    tx: Sender<Job>,
    workers: Vec<JoinHandle<()>>,
    panics: Arc<AtomicU64>,
}

impl ThreadPool {
    pub fn new(n_workers: usize) -> Self {
        let (tx, rx) = channel::<Job>(1024);
        let panics = Arc::new(AtomicU64::new(0));
        let workers = (0..n_workers.max(1))
            .map(|i| {
                let rx = rx.clone();
                let panics = panics.clone();
                std::thread::Builder::new()
                    .name(format!("pbm-worker-{i}"))
                    .spawn(move || {
                        while let Some(job) = rx.recv() {
                            if std::panic::catch_unwind(AssertUnwindSafe(job)).is_err() {
                                panics.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self {
            tx,
            workers,
            panics,
        }
    }

    /// Submit a boxed job; a shut-down pool hands the job back to the
    /// caller, which can run it inline or drop it.
    pub fn try_execute(&self, job: Job) -> Result<(), Job> {
        self.tx.send(job).map_err(|e| e.0)
    }

    /// Submit a job.  A draining pool returns [`PoolShutDown`] instead of
    /// panicking, so a closing server cannot take down the coordinator; the
    /// rejected job is dropped.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) -> Result<(), PoolShutDown> {
        self.try_execute(Box::new(f)).map_err(|_| PoolShutDown)
    }

    /// Scoped fork-join: run every job on the pool and block until all have
    /// finished.  Jobs may borrow from the caller's stack — the barrier
    /// guarantees every borrow ends before this frame returns.  If the pool
    /// is shutting down, rejected jobs run inline on the caller so no work
    /// is lost.  A panicking job is caught — on a worker or inline — and
    /// counted (see [`Self::panic_count`]); its output buffers are left
    /// as-is.
    ///
    /// Do not call from a pool worker thread: jobs queued behind the caller
    /// would deadlock the barrier.
    pub fn scope_run<'env>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'env>>) {
        let n = jobs.len();
        if n == 0 {
            return;
        }
        let (done_tx, done_rx) = channel::<()>(n);
        for job in jobs {
            // SAFETY: the barrier below waits for every job's DoneGuard
            // before returning, so borrows with lifetime 'env cannot outlive
            // this frame; the guard fires even on unwind, and nothing
            // between a submission and the barrier can itself unwind —
            // inline fallbacks run under catch_unwind exactly like jobs on
            // a worker, so the barrier is always reached while earlier jobs
            // may still be running.  The transmute only erases the
            // lifetime — fat-pointer layout is unchanged.
            let job: Box<dyn FnOnce() + Send + 'static> = unsafe { std::mem::transmute(job) };
            let done = DoneGuard(Some(done_tx.clone()));
            let wrapped: Job = Box::new(move || {
                let _done = done;
                job();
            });
            if let Err(rejected) = self.try_execute(wrapped) {
                // draining pool: run on the caller, still signaling the
                // guard; contain panics so they cannot unwind past the
                // barrier while workers hold 'env borrows
                if std::panic::catch_unwind(AssertUnwindSafe(rejected)).is_err() {
                    self.panics.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        for _ in 0..n {
            done_rx.recv();
        }
    }

    /// Run a closure over each item of a slice in parallel, blocking until
    /// all complete (scoped fork-join over the pool).  Items rejected by a
    /// draining pool run inline on the caller, with panics contained the
    /// same way the workers contain them.
    pub fn scoped_for_each<T, F>(&self, items: Vec<T>, f: F)
    where
        T: Send + 'static,
        F: Fn(T) + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (done_tx, done_rx) = channel::<()>(items.len().max(1));
        let n = items.len();
        for item in items {
            let f = f.clone();
            let done = DoneGuard(Some(done_tx.clone()));
            let job: Job = Box::new(move || {
                let _done = done;
                f(item);
            });
            if let Err(rejected) = self.try_execute(job) {
                if std::panic::catch_unwind(AssertUnwindSafe(rejected)).is_err() {
                    self.panics.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        for _ in 0..n {
            done_rx.recv();
        }
    }

    pub fn panic_count(&self) -> u64 {
        self.panics.load(Ordering::Relaxed)
    }

    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = channel::<()>(128);
        for _ in 0..100 {
            let c = counter.clone();
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            })
            .unwrap();
        }
        for _ in 0..100 {
            rx.recv();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn survives_panicking_jobs() {
        let pool = ThreadPool::new(1); // single worker: panic job completes first
        pool.execute(|| panic!("boom")).unwrap();
        let (tx, rx) = channel::<u8>(1);
        pool.execute(move || {
            let _ = tx.send(42);
        })
        .unwrap();
        assert_eq!(rx.recv(), Some(42));
        assert!(pool.panic_count() >= 1);
    }

    #[test]
    fn execute_on_shut_down_pool_errors_instead_of_panicking() {
        let pool = ThreadPool::new(1);
        pool.tx.close(); // simulate a draining server
        assert_eq!(pool.execute(|| {}), Err(PoolShutDown));
        let job: Job = Box::new(|| {});
        assert!(pool.try_execute(job).is_err());
    }

    #[test]
    fn scoped_for_each_completes() {
        let pool = ThreadPool::new(3);
        let sum = Arc::new(AtomicUsize::new(0));
        let s2 = sum.clone();
        pool.scoped_for_each((1..=100usize).collect(), move |x| {
            s2.fetch_add(x, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 5050);
    }

    #[test]
    fn scoped_for_each_runs_inline_when_shut_down() {
        let pool = ThreadPool::new(2);
        pool.tx.close();
        let sum = Arc::new(AtomicUsize::new(0));
        let s2 = sum.clone();
        pool.scoped_for_each((1..=10usize).collect(), move |x| {
            s2.fetch_add(x, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 55, "no work lost on drain");
    }

    #[test]
    fn scope_run_borrows_caller_buffers() {
        let pool = ThreadPool::new(4);
        let mut buf = vec![0u64; 64];
        {
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            let mut rest: &mut [u64] = &mut buf;
            let mut base = 0u64;
            while !rest.is_empty() {
                let take = rest.len().min(16);
                let (head, tail) = rest.split_at_mut(take);
                rest = tail;
                let start = base;
                jobs.push(Box::new(move || {
                    for (i, slot) in head.iter_mut().enumerate() {
                        *slot = start + i as u64;
                    }
                }));
                base += take as u64;
            }
            pool.scope_run(jobs);
        }
        let want: Vec<u64> = (0..64).collect();
        assert_eq!(buf, want);
    }

    #[test]
    fn scope_run_survives_a_panicking_shard() {
        let pool = ThreadPool::new(2);
        let done = Arc::new(AtomicUsize::new(0));
        let d2 = done.clone();
        let d3 = done.clone();
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
            Box::new(move || {
                d2.fetch_add(1, Ordering::SeqCst);
            }),
            Box::new(|| panic!("shard boom")),
            Box::new(move || {
                d3.fetch_add(1, Ordering::SeqCst);
            }),
        ];
        pool.scope_run(jobs); // must not hang on the panicked job
        assert_eq!(done.load(Ordering::SeqCst), 2);
        assert!(pool.panic_count() >= 1);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        let c = Arc::new(AtomicUsize::new(0));
        for _ in 0..10 {
            let c = c.clone();
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                c.fetch_add(1, Ordering::SeqCst);
            })
            .unwrap();
        }
        drop(pool); // must block until queued jobs are done
        assert_eq!(c.load(Ordering::SeqCst), 10);
    }
}
