//! Fixed-size worker thread pool.

use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use super::channel::{channel, Sender};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool of worker threads executing boxed jobs.  Panicking jobs are
/// caught and counted; the pool survives them.
pub struct ThreadPool {
    tx: Sender<Job>,
    workers: Vec<JoinHandle<()>>,
    panics: Arc<AtomicU64>,
}

impl ThreadPool {
    pub fn new(n_workers: usize) -> Self {
        let (tx, rx) = channel::<Job>(1024);
        let panics = Arc::new(AtomicU64::new(0));
        let workers = (0..n_workers.max(1))
            .map(|i| {
                let rx = rx.clone();
                let panics = panics.clone();
                std::thread::Builder::new()
                    .name(format!("pbm-worker-{i}"))
                    .spawn(move || {
                        while let Some(job) = rx.recv() {
                            if std::panic::catch_unwind(AssertUnwindSafe(job)).is_err() {
                                panics.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self {
            tx,
            workers,
            panics,
        }
    }

    /// Submit a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .send(Box::new(f))
            .unwrap_or_else(|_| panic!("pool is shut down"));
    }

    /// Run a closure over each item of a slice in parallel, blocking until
    /// all complete (scoped fork-join over the pool).
    pub fn scoped_for_each<T, F>(&self, items: Vec<T>, f: F)
    where
        T: Send + 'static,
        F: Fn(T) + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (done_tx, done_rx) = channel::<()>(items.len().max(1));
        let n = items.len();
        for item in items {
            let f = f.clone();
            let done = done_tx.clone();
            self.execute(move || {
                f(item);
                let _ = done.send(());
            });
        }
        for _ in 0..n {
            done_rx.recv();
        }
    }

    pub fn panic_count(&self) -> u64 {
        self.panics.load(Ordering::Relaxed)
    }

    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = channel::<()>(128);
        for _ in 0..100 {
            let c = counter.clone();
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..100 {
            rx.recv();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn survives_panicking_jobs() {
        let pool = ThreadPool::new(1); // single worker: panic job completes first
        pool.execute(|| panic!("boom"));
        let (tx, rx) = channel::<u8>(1);
        pool.execute(move || {
            let _ = tx.send(42);
        });
        assert_eq!(rx.recv(), Some(42));
        assert!(pool.panic_count() >= 1);
    }

    #[test]
    fn scoped_for_each_completes() {
        let pool = ThreadPool::new(3);
        let sum = Arc::new(AtomicUsize::new(0));
        let s2 = sum.clone();
        pool.scoped_for_each((1..=100usize).collect(), move |x| {
            s2.fetch_add(x, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 5050);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        let c = Arc::new(AtomicUsize::new(0));
        for _ in 0..10 {
            let c = c.clone();
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // must block until queued jobs are done
        assert_eq!(c.load(Ordering::SeqCst), 10);
    }
}
