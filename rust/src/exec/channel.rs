//! Bounded MPMC channel (Mutex + Condvar), with close semantics.
//!
//! `std::sync::mpsc` is single-consumer; the dynamic batcher needs multiple
//! workers pulling from one queue, so this is a small MPMC built from std
//! primitives.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

struct Shared<T> {
    queue: Mutex<State<T>>,
    not_empty: Condvar,
    not_full: Condvar,
}

struct State<T> {
    items: VecDeque<T>,
    closed: bool,
    capacity: usize,
}

/// Sending half (cloneable).
pub struct Sender<T> {
    shared: Arc<Shared<T>>,
}

/// Receiving half (cloneable).
pub struct Receiver<T> {
    shared: Arc<Shared<T>>,
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        Self {
            shared: self.shared.clone(),
        }
    }
}

impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        Self {
            shared: self.shared.clone(),
        }
    }
}

/// Create a bounded MPMC channel.
pub fn channel<T>(capacity: usize) -> (Sender<T>, Receiver<T>) {
    let shared = Arc::new(Shared {
        queue: Mutex::new(State {
            items: VecDeque::new(),
            closed: false,
            capacity: capacity.max(1),
        }),
        not_empty: Condvar::new(),
        not_full: Condvar::new(),
    });
    (
        Sender {
            shared: shared.clone(),
        },
        Receiver { shared },
    )
}

/// Error returned when sending to a closed channel.
#[derive(Debug, PartialEq, Eq)]
pub struct SendError<T>(pub T);

/// Error returned by [`Sender::try_send`]: the item comes back so the
/// caller can shed it with a reply instead of dropping it silently.
#[derive(Debug, PartialEq, Eq)]
pub enum TrySendError<T> {
    /// Queue at capacity — the admission-control signal.
    Full(T),
    /// Channel closed.
    Closed(T),
}

impl<T> Sender<T> {
    /// Blocking send; errors if the channel is closed.
    pub fn send(&self, item: T) -> Result<(), SendError<T>> {
        let mut st = self.shared.queue.lock().unwrap();
        loop {
            if st.closed {
                return Err(SendError(item));
            }
            if st.items.len() < st.capacity {
                st.items.push_back(item);
                self.shared.not_empty.notify_one();
                return Ok(());
            }
            st = self.shared.not_full.wait(st).unwrap();
        }
    }

    /// Non-blocking send: a full queue is an immediate
    /// [`TrySendError::Full`] rather than backpressure into the caller's
    /// thread — the load-shedding primitive.
    pub fn try_send(&self, item: T) -> Result<(), TrySendError<T>> {
        let mut st = self.shared.queue.lock().unwrap();
        if st.closed {
            return Err(TrySendError::Closed(item));
        }
        if st.items.len() >= st.capacity {
            return Err(TrySendError::Full(item));
        }
        st.items.push_back(item);
        self.shared.not_empty.notify_one();
        Ok(())
    }

    /// Close the channel: receivers drain remaining items then get `None`.
    pub fn close(&self) {
        let mut st = self.shared.queue.lock().unwrap();
        st.closed = true;
        self.shared.not_empty.notify_all();
        self.shared.not_full.notify_all();
    }
}

impl<T> Receiver<T> {
    /// Blocking receive; `None` once closed and drained.
    pub fn recv(&self) -> Option<T> {
        let mut st = self.shared.queue.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                self.shared.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.shared.not_empty.wait(st).unwrap();
        }
    }

    /// Receive with timeout; `Ok(None)` = closed, `Err(())` = timed out.
    pub fn recv_timeout(&self, dur: Duration) -> Result<Option<T>, ()> {
        let mut st = self.shared.queue.lock().unwrap();
        let deadline = std::time::Instant::now() + dur;
        loop {
            if let Some(item) = st.items.pop_front() {
                self.shared.not_full.notify_one();
                return Ok(Some(item));
            }
            if st.closed {
                return Ok(None);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Err(());
            }
            let (guard, res) = self
                .shared
                .not_empty
                .wait_timeout(st, deadline - now)
                .unwrap();
            st = guard;
            if res.timed_out() && st.items.is_empty() {
                if st.closed {
                    return Ok(None);
                }
                return Err(());
            }
        }
    }

    /// Drain up to `max` items without blocking beyond the first.
    pub fn recv_many(&self, max: usize) -> Vec<T> {
        let mut out = Vec::new();
        if let Some(first) = self.recv() {
            out.push(first);
            let mut st = self.shared.queue.lock().unwrap();
            while out.len() < max {
                match st.items.pop_front() {
                    Some(x) => out.push(x),
                    None => break,
                }
            }
            self.shared.not_full.notify_all();
        }
        out
    }

    pub fn len(&self) -> usize {
        self.shared.queue.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn fifo_order() {
        let (tx, rx) = channel(10);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(rx.recv(), Some(i));
        }
    }

    #[test]
    fn close_drains_then_none() {
        let (tx, rx) = channel(10);
        tx.send(1).unwrap();
        tx.close();
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), None);
        assert_eq!(tx.send(2), Err(SendError(2)));
    }

    #[test]
    fn mpmc_all_items_delivered_once() {
        let (tx, rx) = channel::<usize>(64);
        let n = 1000;
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let tx = tx.clone();
                thread::spawn(move || {
                    for i in 0..n / 4 {
                        tx.send(p * (n / 4) + i).unwrap();
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..4)
            .map(|_| {
                let rx = rx.clone();
                thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(x) = rx.recv() {
                        got.push(x);
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        tx.close();
        let mut all: Vec<usize> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        assert_eq!(all, (0..n).collect::<Vec<_>>());
    }

    #[test]
    fn bounded_capacity_blocks_until_drained() {
        let (tx, rx) = channel(2);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        let tx2 = tx.clone();
        let h = thread::spawn(move || {
            tx2.send(3).unwrap(); // blocks until rx drains
            3
        });
        thread::sleep(Duration::from_millis(20));
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(h.join().unwrap(), 3);
    }

    #[test]
    fn try_send_full_and_closed() {
        let (tx, rx) = channel(1);
        assert_eq!(tx.try_send(1), Ok(()));
        assert_eq!(tx.try_send(2), Err(TrySendError::Full(2)));
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(tx.try_send(3), Ok(()));
        tx.close();
        assert_eq!(tx.try_send(4), Err(TrySendError::Closed(4)));
        assert_eq!(rx.recv(), Some(3));
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn recv_timeout_times_out() {
        let (_tx, rx) = channel::<u8>(1);
        assert!(rx.recv_timeout(Duration::from_millis(10)).is_err());
    }

    #[test]
    fn recv_many_batches() {
        let (tx, rx) = channel(16);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        let got = rx.recv_many(3);
        assert_eq!(got, vec![0, 1, 2]);
        let got = rx.recv_many(10);
        assert_eq!(got, vec![3, 4]);
    }
}
