//! Execution substrate: thread pool, MPMC channel, cancellation token.
//!
//! The offline crate cache carries no `tokio`; the coordinator's event loop
//! is thread-based, built on these primitives.

pub mod cancel;
pub mod channel;
pub mod pool;
pub mod ring;
pub mod scratch;

pub use cancel::CancelToken;
pub use channel::{channel, Receiver, Sender, TrySendError};
pub use pool::{PoolShutDown, ThreadPool};
pub use scratch::ScratchArena;
