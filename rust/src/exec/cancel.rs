//! Cooperative cancellation token.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Cloneable cancellation flag shared between workers.
#[derive(Debug, Clone, Default)]
pub struct CancelToken {
    flag: Arc<AtomicBool>,
}

impl CancelToken {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn cancel(&self) {
        self.flag.store(true, Ordering::SeqCst);
    }

    pub fn is_cancelled(&self) -> bool {
        self.flag.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cancel_visible_across_clones() {
        let t = CancelToken::new();
        let t2 = t.clone();
        assert!(!t2.is_cancelled());
        t.cancel();
        assert!(t2.is_cancelled());
    }

    #[test]
    fn cancel_visible_across_threads() {
        let t = CancelToken::new();
        let t2 = t.clone();
        let h = std::thread::spawn(move || {
            while !t2.is_cancelled() {
                std::thread::yield_now();
            }
            true
        });
        t.cancel();
        assert!(h.join().unwrap());
    }
}
