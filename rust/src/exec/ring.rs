//! Lock-free SPSC block ring buffer.
//!
//! The entropy pipeline decouples entropy *production* (background producer
//! threads drawing Gaussian weight planes / chaotic rail pairs) from
//! *consumption* (the `sample_conv` worker shards).  Each producer/consumer
//! pair communicates over one of these rings: a fixed number of slots, a
//! monotonic head (consumer) and tail (producer) counter, and no locks —
//! one atomic load + one atomic store per side per transfer.  FIFO order is
//! the load-bearing property: blocks arrive in exactly the order the
//! producer drew them, so a consumer that pops sequentially observes the
//! producer's entropy stream in its original draw order (the bitwise
//! prefetch-on/off equivalence of `entropy::pipeline` rests on this).
//!
//! The ring is strictly single-producer/single-consumer: [`ring`] hands out
//! one non-cloneable handle per side, and dropping either side closes the
//! channel (the survivor observes `Disconnected` instead of blocking
//! forever).

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::CancelToken;

/// Pad the hot atomics onto separate cache lines so producer and consumer
/// cores do not false-share.
#[repr(align(64))]
struct Padded<T>(T);

struct Shared<T> {
    slots: Box<[UnsafeCell<Option<T>>]>,
    /// Next slot index the consumer will pop (monotonic, wraps at usize).
    head: Padded<AtomicUsize>,
    /// Next slot index the producer will push (monotonic, wraps at usize).
    tail: Padded<AtomicUsize>,
    producer_alive: AtomicBool,
    consumer_alive: AtomicBool,
}

// SAFETY: only the unique Producer writes uninhabited slots and only the
// unique Consumer takes inhabited ones; the head/tail acquire/release pair
// orders every slot access.
unsafe impl<T: Send> Sync for Shared<T> {}
unsafe impl<T: Send> Send for Shared<T> {}

/// Error returned by [`Producer::try_push`].
#[derive(Debug, PartialEq, Eq)]
pub enum PushError<T> {
    /// All slots occupied; the value is handed back.
    Full(T),
    /// The consumer is gone; the value is handed back.
    Disconnected(T),
}

/// Error returned by [`Consumer::try_pop`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PopError {
    /// No block ready (the producer may still push more).
    Empty,
    /// The producer is gone and every pushed block has been drained.
    Disconnected,
}

/// The producing half (not cloneable — SPSC by construction).
pub struct Producer<T> {
    shared: Arc<Shared<T>>,
}

/// The consuming half (not cloneable — SPSC by construction).
pub struct Consumer<T> {
    shared: Arc<Shared<T>>,
}

/// Create a bounded SPSC ring with `capacity` slots (at least 1).
pub fn ring<T: Send>(capacity: usize) -> (Producer<T>, Consumer<T>) {
    let capacity = capacity.max(1);
    let slots = (0..capacity)
        .map(|_| UnsafeCell::new(None))
        .collect::<Vec<_>>()
        .into_boxed_slice();
    let shared = Arc::new(Shared {
        slots,
        head: Padded(AtomicUsize::new(0)),
        tail: Padded(AtomicUsize::new(0)),
        producer_alive: AtomicBool::new(true),
        consumer_alive: AtomicBool::new(true),
    });
    (
        Producer {
            shared: shared.clone(),
        },
        Consumer { shared },
    )
}

/// Back-off for the blocking helpers: yield a few times, then sleep briefly
/// so a stalled peer does not burn a core.
fn backoff(round: &mut u32) {
    if *round < 16 {
        std::thread::yield_now();
    } else {
        std::thread::sleep(Duration::from_micros(50));
    }
    *round = round.saturating_add(1);
}

impl<T> Producer<T> {
    /// Push one block without blocking.
    pub fn try_push(&mut self, value: T) -> Result<(), PushError<T>> {
        let sh = &*self.shared;
        if !sh.consumer_alive.load(Ordering::Acquire) {
            return Err(PushError::Disconnected(value));
        }
        let tail = sh.tail.0.load(Ordering::Relaxed);
        let head = sh.head.0.load(Ordering::Acquire);
        if tail.wrapping_sub(head) >= sh.slots.len() {
            return Err(PushError::Full(value));
        }
        // SAFETY: this slot is outside [head, tail), so the consumer will
        // not touch it until the tail store below publishes it.
        unsafe {
            *sh.slots[tail % sh.slots.len()].get() = Some(value);
        }
        sh.tail.0.store(tail.wrapping_add(1), Ordering::Release);
        Ok(())
    }

    /// Push, blocking while the ring is full.  Returns the value back if the
    /// consumer disconnects or `cancel` fires first.
    pub fn push_blocking(&mut self, mut value: T, cancel: &CancelToken) -> Result<(), T> {
        let mut round = 0u32;
        loop {
            match self.try_push(value) {
                Ok(()) => return Ok(()),
                Err(PushError::Disconnected(v)) => return Err(v),
                Err(PushError::Full(v)) => {
                    if cancel.is_cancelled() {
                        return Err(v);
                    }
                    value = v;
                    backoff(&mut round);
                }
            }
        }
    }

    /// Blocks currently queued (approximate under concurrency).
    pub fn len(&self) -> usize {
        let sh = &*self.shared;
        sh.tail
            .0
            .load(Ordering::Relaxed)
            .wrapping_sub(sh.head.0.load(Ordering::Relaxed))
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.shared.slots.len()
    }
}

impl<T> Drop for Producer<T> {
    fn drop(&mut self) {
        self.shared.producer_alive.store(false, Ordering::Release);
    }
}

impl<T> Consumer<T> {
    /// Pop the oldest block without blocking.
    pub fn try_pop(&mut self) -> Result<T, PopError> {
        let sh = &*self.shared;
        let head = sh.head.0.load(Ordering::Relaxed);
        let tail = sh.tail.0.load(Ordering::Acquire);
        if head == tail {
            // Re-check emptiness *after* observing the closed flag: a
            // producer pushes, then drops, so seeing `!alive` first and an
            // empty ring second cannot lose a block.
            if !sh.producer_alive.load(Ordering::Acquire) {
                let tail = sh.tail.0.load(Ordering::Acquire);
                if head == tail {
                    return Err(PopError::Disconnected);
                }
            } else {
                return Err(PopError::Empty);
            }
        }
        // SAFETY: head < tail, so this slot was published by the producer's
        // release store and will not be written again until head advances.
        let value = unsafe { (*sh.slots[head % sh.slots.len()].get()).take() };
        sh.head.0.store(head.wrapping_add(1), Ordering::Release);
        Ok(value.expect("published ring slot is inhabited"))
    }

    /// Pop, blocking while the ring is empty.  `None` once the producer is
    /// gone and every block has been drained.
    pub fn pop_blocking(&mut self) -> Option<T> {
        let mut round = 0u32;
        loop {
            match self.try_pop() {
                Ok(v) => return Some(v),
                Err(PopError::Disconnected) => return None,
                Err(PopError::Empty) => backoff(&mut round),
            }
        }
    }

    /// Blocks currently queued (approximate under concurrency).
    pub fn len(&self) -> usize {
        let sh = &*self.shared;
        sh.tail
            .0
            .load(Ordering::Relaxed)
            .wrapping_sub(sh.head.0.load(Ordering::Relaxed))
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Drop for Consumer<T> {
    fn drop(&mut self) {
        self.shared.consumer_alive.store(false, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_and_capacity() {
        let (mut tx, mut rx) = ring::<u32>(4);
        assert_eq!(tx.capacity(), 4);
        for i in 0..4 {
            tx.try_push(i).unwrap();
        }
        assert_eq!(tx.try_push(99), Err(PushError::Full(99)));
        for i in 0..4 {
            assert_eq!(rx.try_pop(), Ok(i));
        }
        assert_eq!(rx.try_pop(), Err(PopError::Empty));
    }

    #[test]
    fn wraparound_preserves_order() {
        let (mut tx, mut rx) = ring::<usize>(3);
        for i in 0..100 {
            tx.try_push(i).unwrap();
            assert_eq!(rx.try_pop(), Ok(i));
        }
    }

    #[test]
    fn producer_drop_disconnects_after_drain() {
        let (mut tx, mut rx) = ring::<u8>(2);
        tx.try_push(1).unwrap();
        drop(tx);
        assert_eq!(rx.try_pop(), Ok(1), "pushed blocks survive the drop");
        assert_eq!(rx.try_pop(), Err(PopError::Disconnected));
        assert_eq!(rx.pop_blocking(), None);
    }

    #[test]
    fn consumer_drop_rejects_pushes() {
        let (mut tx, rx) = ring::<u8>(2);
        drop(rx);
        assert_eq!(tx.try_push(5), Err(PushError::Disconnected(5)));
        let cancel = CancelToken::new();
        assert_eq!(tx.push_blocking(6, &cancel), Err(6));
    }

    #[test]
    fn push_blocking_respects_cancellation() {
        let (mut tx, _rx) = ring::<u8>(1);
        tx.try_push(1).unwrap();
        let cancel = CancelToken::new();
        cancel.cancel();
        // ring full + live consumer: only the token can unblock this
        assert_eq!(tx.push_blocking(2, &cancel), Err(2));
    }

    #[test]
    fn cross_thread_stream_is_lossless_and_ordered() {
        let (mut tx, mut rx) = ring::<u64>(8);
        let n = 50_000u64;
        let producer = std::thread::spawn(move || {
            let cancel = CancelToken::new();
            for i in 0..n {
                tx.push_blocking(i, &cancel).unwrap();
            }
        });
        for i in 0..n {
            assert_eq!(rx.pop_blocking(), Some(i));
        }
        assert_eq!(rx.pop_blocking(), None, "producer done and drained");
        producer.join().unwrap();
    }

    #[test]
    fn consumer_drop_unblocks_a_full_producer() {
        let (mut tx, mut rx) = ring::<u64>(2);
        let producer = std::thread::spawn(move || {
            let cancel = CancelToken::new();
            let mut sent = 0u64;
            loop {
                if tx.push_blocking(sent, &cancel).is_err() {
                    return sent; // consumer went away
                }
                sent += 1;
            }
        });
        // consume a little, then walk away mid-stream
        for i in 0..10 {
            assert_eq!(rx.pop_blocking(), Some(i));
        }
        drop(rx);
        let sent = producer.join().unwrap();
        assert!(sent >= 10, "producer made progress before the disconnect");
    }
}
