//! Grow-only scratch arenas for the sampling hot path.
//!
//! Every steady-state request used to allocate: the engine built fresh
//! `eps`/`d_all`/`d3` buffers per call, the backends re-allocated im2col
//! patch planes, and the photonic conv loop rebuilt a per-kernel program
//! vector.  At serving rates those allocations dominate the digital-backend
//! latency the paper's photonic-vs-digital comparison is supposed to
//! isolate.  [`ScratchArena`] replaces them: one arena per engine / backend
//! / worker shard, with named grow-only lanes that reach a high-water mark
//! after the first request and never touch the allocator again.
//!
//! Lanes are plain `pub` fields so callers can borrow several of them
//! simultaneously (the borrow checker splits disjoint field borrows); the
//! [`grow`] helper returns an exactly-sized slice, growing the lane only
//! when a larger request arrives.

/// Grow `buf` to at least `len` elements and return the `[..len]` slice.
///
/// Never shrinks: after the first request at a given size, subsequent calls
/// are allocation-free.  The slice is returned as-is (previous contents up
/// to the high-water mark survive), so callers that need zeroed memory must
/// `fill` it — see the stale-data test in `tests/parallel_determinism.rs`.
pub fn grow<T: Copy + Default>(buf: &mut Vec<T>, len: usize) -> &mut [T] {
    if buf.len() < len {
        buf.resize(len, T::default());
    }
    &mut buf[..len]
}

/// Named reusable buffers for the probabilistic-convolution hot path.
///
/// One arena lives in each [`crate::coordinator::Engine`], each
/// [`crate::backend::ProbConvBackend`], and each parallel worker shard, so
/// concurrent shards never contend for scratch memory.
#[derive(Debug, Clone, Default)]
pub struct ScratchArena {
    /// im2col patch planes (`pixels x 9` f32 per (item, channel) plane).
    pub patches: Vec<f32>,
    /// Bulk standard-normal draws (digital backend weight sampling).
    pub draws: Vec<f64>,
    /// Per-pixel accumulators (photonic conv core).
    pub acc: Vec<f64>,
    /// EOM transmissions for one channel (photonic conv core).
    pub trans: Vec<f32>,
    /// Plus-rail bulk intensity draws (photonic conv core).
    pub rail_plus: Vec<f64>,
    /// Minus-rail bulk intensity draws (photonic conv core).
    pub rail_minus: Vec<f64>,
    /// Padded engine input batch (`x` resized to the artifact batch size).
    pub input: Vec<f32>,
    /// Surrogate-path `eps` noise operand.
    pub noise: Vec<f32>,
    /// All-samples backend output (split path `d_all`).
    pub samples: Vec<f32>,
    /// Per-pass staging buffer (split path `d3`).
    pub pass: Vec<f32>,
}

impl ScratchArena {
    /// Total resident scratch bytes across all lanes (telemetry).
    pub fn resident_bytes(&self) -> usize {
        self.patches.capacity() * 4
            + self.draws.capacity() * 8
            + self.acc.capacity() * 8
            + self.trans.capacity() * 4
            + self.rail_plus.capacity() * 8
            + self.rail_minus.capacity() * 8
            + self.input.capacity() * 4
            + self.noise.capacity() * 4
            + self.samples.capacity() * 4
            + self.pass.capacity() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grow_reaches_and_keeps_high_water_mark() {
        let mut arena = ScratchArena::default();
        assert_eq!(grow(&mut arena.patches, 100).len(), 100);
        // a smaller request returns a shorter slice without shrinking
        assert_eq!(grow(&mut arena.patches, 10).len(), 10);
        assert!(arena.patches.len() >= 100);
        // steady state: same size means no reallocation (pointer is stable)
        let p0 = arena.patches.as_ptr();
        let _ = grow(&mut arena.patches, 100);
        assert_eq!(arena.patches.as_ptr(), p0);
    }

    #[test]
    fn grow_preserves_contents_up_to_len() {
        let mut buf: Vec<f64> = Vec::new();
        grow(&mut buf, 4).copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        let s = grow(&mut buf, 8);
        assert_eq!(&s[..4], &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(&s[4..], &[0.0; 4]);
    }

    #[test]
    fn resident_bytes_tracks_capacity() {
        let mut arena = ScratchArena::default();
        assert_eq!(arena.resident_bytes(), 0);
        let _ = grow(&mut arena.acc, 128);
        assert!(arena.resident_bytes() >= 128 * 8);
    }
}
