//! TCP serving gateway: newline-delimited JSON over TCP.
//!
//! Protocol (one JSON object per line):
//!
//! ```text
//! -> {"op":"classify","dataset":"blood","image":[...C*H*W floats in 0..1],
//!     "max_samples":20,"target_confidence":0.9}          // budget: optional
//! <- {"ok":true,"class":4,"decision":"accept","confidence":0.93,
//!     "mi":0.004,"se":0.12,"h":0.124,"mean_probs":[...],
//!     "samples_used":4,"latency_us":812}
//! -> {"op":"info"}
//! <- {"ok":true,"datasets":["digits","blood"],"version":"0.1.0"}
//! -> {"op":"ping"}   <- {"ok":true,"pong":true}
//! ```
//!
//! `max_samples` caps the request's stochastic passes below the engine's
//! budget (never raises it); `target_confidence` asks for adaptive early
//! stopping at that posterior mass.  Invalid budgets (`0`, non-finite or
//! out-of-range confidence) are rejected at parse time with a typed error
//! response.  `samples_used` reports the passes actually spent.
//!
//! Overload safety: `deadline_ms` bounds how long the server may hold a
//! request (expired ones answer `"code":"deadline_exceeded"`); a full or
//! over-budget queue answers `"code":"overloaded"` with `retry_after_ms`;
//! a batch that panics the engine answers `"code":"internal_error"` while
//! the engine rebuilds; idle connections are closed with
//! `"code":"idle_timeout"`.  Degraded (clamped/brownout) answers carry
//! `"degraded":true`.
//!
//! Cluster extensions: `{"op":"hello","role":"coordinator"}` is the role
//! handshake (the server answers with its own role — `worker` for
//! `pbm worker`, `coordinator` for `pbm cluster`); classify requests may
//! carry `"plan_seed":"<u64 as decimal string>"` to pin the entropy
//! stream of a shard-scoped plan (a string because JSON numbers are f64
//! and would corrupt seeds above 2^53); a coordinator whose worker pool
//! is empty answers `"code":"worker_unavailable"` with a `down` count.
//! The coordinator's `/info` carries a `cluster` section of per-worker
//! cards (state, latency EWMA, entropy health, p50/p95/p99).
//!
//! Observability: classify requests may carry `"request_id":"<nonzero
//! u64 as decimal string>"` — the server traces the request under that id
//! (forwarded coordinator → worker, so cluster hops stitch into one
//! trace) and echoes it in the response; without one, responses are
//! byte-identical whether tracing is on or off.  `{"op":"metrics"}`
//! answers the Prometheus text exposition in a `body` field;
//! `{"op":"trace","request_id":"N"}` returns the recorded spans (omit
//! `request_id` for the retained slow-request exemplars).

pub mod protocol;
pub mod tcp;

pub use tcp::{respond, serve, Client, ClientConfig, ServerOptions};
