//! TCP serving gateway: newline-delimited JSON over TCP.
//!
//! Protocol (one JSON object per line):
//!
//! ```text
//! -> {"op":"classify","dataset":"blood","image":[...C*H*W floats in 0..1]}
//! <- {"ok":true,"class":4,"decision":"accept","confidence":0.93,
//!     "mi":0.004,"se":0.12,"h":0.124,"mean_probs":[...],"latency_us":812}
//! -> {"op":"info"}
//! <- {"ok":true,"datasets":["digits","blood"],"version":"0.1.0"}
//! -> {"op":"ping"}   <- {"ok":true,"pong":true}
//! ```

pub mod protocol;
pub mod tcp;

pub use tcp::{serve, Client, ServerOptions};
