//! TCP gateway: accept loop + per-connection workers over the router.

use std::io::{BufRead, BufReader, IoSlice, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use super::protocol::{self, Request};
use crate::coordinator::service::ClassifyRequest;
use crate::coordinator::Router;
use crate::exec::{CancelToken, ThreadPool};
use crate::log_info;

/// Server options.
#[derive(Debug, Clone)]
pub struct ServerOptions {
    pub addr: String,
    pub workers: usize,
}

impl Default for ServerOptions {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7878".into(),
            workers: 8,
        }
    }
}

/// Serve the router over TCP until `cancel` fires.  Returns the bound local
/// address via the `on_bound` callback (useful with port 0 in tests).
pub fn serve(
    router: Router,
    opts: ServerOptions,
    cancel: CancelToken,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> Result<()> {
    let listener = TcpListener::bind(&opts.addr).with_context(|| format!("bind {}", opts.addr))?;
    listener.set_nonblocking(true)?;
    on_bound(listener.local_addr()?);
    log_info!("serving on {}", listener.local_addr()?);
    let router = Arc::new(router);
    let pool = ThreadPool::new(opts.workers);
    while !cancel.is_cancelled() {
        match listener.accept() {
            Ok((stream, peer)) => {
                let router = router.clone();
                let cancel = cancel.clone();
                let submitted = pool.execute(move || {
                    if let Err(e) = handle_conn(stream, &router, &cancel) {
                        crate::log_debug!("conn {peer}: {e}");
                    }
                });
                if submitted.is_err() {
                    // a draining pool refuses new connections instead of
                    // panicking the accept loop
                    crate::log_debug!("worker pool shut down; dropping connection from {peer}");
                }
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            Err(e) => return Err(anyhow!("accept: {e}")),
        }
    }
    drop(pool); // join workers
    if let Ok(r) = Arc::try_unwrap(router) {
        r.shutdown();
    }
    Ok(())
}

/// Largest accepted request line (bytes).  Bounds per-connection memory at
/// the transport boundary — a hostile client cannot make the gateway buffer
/// an unbounded "line".  Generous enough for a [`protocol::MAX_IMAGE_LEN`]
/// image in JSON text.
const MAX_LINE_BYTES: usize = 8 << 20;

/// Write `body` + the protocol's line terminator as **one vectored
/// syscall** (`write_vectored` of `[body, "\n"]`): the response `String`
/// stays reused and untouched — no per-response `push('\n')` churn — and
/// the newline never costs a second `write` syscall.  Handles partial
/// vectored writes (kernels may accept any prefix) and `Interrupted`.
pub(crate) fn write_line_vectored<W: Write>(w: &mut W, body: &[u8]) -> std::io::Result<()> {
    const NL: &[u8] = b"\n";
    let total = body.len() + 1;
    let mut written = 0usize;
    while written < total {
        let res = if written < body.len() {
            w.write_vectored(&[IoSlice::new(&body[written..]), IoSlice::new(NL)])
        } else {
            // only the terminator (or its tail after a partial write) left
            w.write(&NL[written - body.len()..])
        };
        match res {
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::WriteZero,
                    "failed to write whole response line",
                ))
            }
            Ok(n) => written += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

fn handle_conn(stream: TcpStream, router: &Router, cancel: &CancelToken) -> Result<()> {
    stream.set_read_timeout(Some(std::time::Duration::from_millis(200)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    // one response buffer per connection, reused across requests: encodes
    // append into it instead of allocating a fresh String per response
    let mut resp = String::new();
    loop {
        if cancel.is_cancelled() {
            return Ok(());
        }
        if line.len() >= MAX_LINE_BYTES {
            resp.clear();
            protocol::encode_error_into(
                &format!("request line exceeds {MAX_LINE_BYTES} bytes"),
                &mut resp,
            );
            write_line_vectored(&mut writer, resp.as_bytes())?;
            return Ok(()); // close: the rest of the oversized line is garbage
        }
        // cap the read; partial lines (timeout or cap) accumulate in `line`
        let budget = (MAX_LINE_BYTES - line.len()) as u64;
        match (&mut reader).take(budget).read_line(&mut line) {
            Ok(0) => {
                // peer closed; a buffered newline-less final request still
                // gets its response before we hang up
                if !line.is_empty() {
                    respond_into(router, &line, &mut resp);
                    write_line_vectored(&mut writer, resp.as_bytes())?;
                }
                return Ok(());
            }
            Ok(_) if line.ends_with('\n') => {
                respond_into(router, &line, &mut resp);
                write_line_vectored(&mut writer, resp.as_bytes())?;
                line.clear();
            }
            Ok(_) => {} // mid-line: keep accumulating (next loop re-budgets)
            Err(ref e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue
            }
            Err(e) => return Err(e.into()),
        }
    }
}

/// Compute the response line for one request line (transport-independent —
/// also used by unit tests without sockets).
pub fn respond(router: &Router, line: &str) -> String {
    let mut out = String::new();
    respond_into(router, line, &mut out);
    out
}

/// [`respond`] into a reusable buffer: clears `out`, then append-encodes
/// the response (no trailing newline).
pub fn respond_into(router: &Router, line: &str, out: &mut String) {
    out.clear();
    match protocol::parse_request(line) {
        Err(e) => protocol::encode_error_into(&format!("{e}"), out),
        Ok(Request::Ping) => out.push_str(&protocol::encode_pong()),
        Ok(Request::Info) => out.push_str(&protocol::encode_info(
            &router.datasets(),
            &router.health_snapshot(),
            &router.registry_snapshot(),
        )),
        Ok(Request::Classify {
            model,
            image,
            budget,
        }) => {
            // the engine thread re-resolves the name against its registry,
            // so the request carries it even though routing also uses it
            let (req, rx) = ClassifyRequest::with_model(Some(model.clone()), image, budget);
            match router.route(&model, req) {
                Err(e) => encode_routing_error(&e, out),
                Ok(()) => match rx.recv() {
                    Some(Ok(result)) => protocol::encode_result_into(&result, out),
                    Some(Err(e)) => encode_routing_error(&e, out),
                    None => protocol::encode_error_into("engine dropped request", out),
                },
            }
        }
    }
}

/// Encode a routing/engine error, surfacing [`UnknownModel`] as a
/// machine-readable `"code":"unknown_model"` response.
fn encode_routing_error(e: &anyhow::Error, out: &mut String) {
    if e.downcast_ref::<crate::registry::UnknownModel>().is_some() {
        protocol::encode_error_coded_into("unknown_model", &format!("{e}"), out);
    } else {
        protocol::encode_error_into(&format!("{e}"), out);
    }
}

/// Simple blocking client for the gateway (used by examples and tests).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        let writer = stream.try_clone()?;
        Ok(Self {
            reader: BufReader::new(stream),
            writer,
        })
    }

    /// Send one request line; wait for one response line.  Reads are capped
    /// at [`MAX_LINE_BYTES`] — the mirror image of the server's request cap
    /// — so a misbehaving (or spoofed) server cannot make the client buffer
    /// an unbounded response.
    pub fn call(&mut self, line: &str) -> Result<crate::util::json::Json> {
        // mirror of the gateway's response path: body + newline in one
        // vectored syscall
        write_line_vectored(&mut self.writer, line.as_bytes())?;
        let mut resp = String::new();
        (&mut self.reader)
            .take(MAX_LINE_BYTES as u64)
            .read_line(&mut resp)?;
        if !resp.ends_with('\n') && resp.len() >= MAX_LINE_BYTES {
            // the unread tail of the oversized line is still in flight; a
            // further call would read mid-line garbage as its response, so
            // poison the connection (mirrors the server closing on an
            // oversized request)
            let _ = self.writer.shutdown(std::net::Shutdown::Both);
            return Err(anyhow!("response line exceeds {MAX_LINE_BYTES} bytes"));
        }
        crate::util::json::parse(&resp).map_err(|e| anyhow!("bad response: {e} ({resp:?})"))
    }

    pub fn ping(&mut self) -> Result<bool> {
        let j = self.call("{\"op\":\"ping\"}")?;
        Ok(j.get("pong").and_then(|v| v.as_bool()).unwrap_or(false))
    }

    pub fn classify(&mut self, model: &str, image: &[f32]) -> Result<crate::util::json::Json> {
        self.call(&protocol::encode_classify(model, image))
    }

    /// Classify with per-request budget overrides (`max_samples` /
    /// `target_confidence` protocol fields).
    pub fn classify_with_budget(
        &mut self,
        model: &str,
        image: &[f32],
        budget: &crate::sampler::RequestBudget,
    ) -> Result<crate::util::json::Json> {
        self.call(&protocol::encode_classify_with_budget(model, image, budget))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn respond_into_reuses_and_clears_the_buffer() {
        let router = Router::new();
        let mut buf = String::from("stale residue from the previous request");
        respond_into(&router, "{\"op\":\"ping\"}", &mut buf);
        assert_eq!(buf, respond(&router, "{\"op\":\"ping\"}"));
        respond_into(&router, "garbage", &mut buf);
        assert!(buf.contains("\"ok\":false"));
        assert!(!buf.contains("pong"), "buffer cleared between responses");
    }

    /// A writer that accepts at most `cap` bytes per call and ignores all
    /// but the first buffer of a vectored write — the worst-legal-case
    /// kernel behavior the helper must survive.
    struct ChunkyWriter {
        cap: usize,
        data: Vec<u8>,
    }

    impl Write for ChunkyWriter {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            let n = buf.len().min(self.cap);
            self.data.extend_from_slice(&buf[..n]);
            Ok(n)
        }

        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn vectored_line_write_is_complete_under_partial_writes() {
        for cap in [1, 2, 3, 7, 64] {
            let mut w = ChunkyWriter {
                cap,
                data: Vec::new(),
            };
            write_line_vectored(&mut w, b"{\"ok\":true}").unwrap();
            assert_eq!(w.data, b"{\"ok\":true}\n", "cap {cap}");
        }
        // empty body still terminates the line
        let mut w = ChunkyWriter {
            cap: 8,
            data: Vec::new(),
        };
        write_line_vectored(&mut w, b"").unwrap();
        assert_eq!(w.data, b"\n");
    }

    #[test]
    fn vectored_line_write_single_call_fast_path() {
        // a Vec<u8> writer consumes both buffers in one vectored call
        let mut buf: Vec<u8> = Vec::new();
        write_line_vectored(&mut buf, b"body").unwrap();
        assert_eq!(buf, b"body\n");
    }

    #[test]
    fn respond_handles_ping_info_and_errors_without_engines() {
        let router = Router::new();
        let pong = respond(&router, "{\"op\":\"ping\"}");
        assert!(pong.contains("pong"));
        let info = respond(&router, "{\"op\":\"info\"}");
        assert!(info.contains("datasets"));
        assert!(info.contains("models"));
        // unknown model (via either field name) is the typed coded error
        let err = respond(&router, "{\"op\":\"classify\",\"dataset\":\"x\",\"image\":[1]}");
        assert!(err.contains("\"ok\":false"));
        assert!(err.contains("\"code\":\"unknown_model\""), "{err}");
        let err = respond(&router, "{\"op\":\"classify\",\"model\":\"x\",\"image\":[1]}");
        assert!(err.contains("\"code\":\"unknown_model\""), "{err}");
        let bad = respond(&router, "garbage");
        assert!(bad.contains("\"ok\":false"));
    }
}
