//! TCP gateway: accept loop + per-connection workers over the router.

use std::io::{BufRead, BufReader, IoSlice, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use super::protocol::{self, Request};
use crate::coordinator::service::ClassifyRequest;
use crate::coordinator::Router;
use crate::exec::{CancelToken, ThreadPool};
use crate::log_info;
use crate::observe::{prom, Stage};

/// Server options.
#[derive(Debug, Clone)]
pub struct ServerOptions {
    pub addr: String,
    pub workers: usize,
    /// Close a connection after this long without a complete request.
    /// Each connection pins a pool worker, so a silent peer (or a
    /// slowloris trickling bytes forever) would otherwise hold one of
    /// `workers` slots indefinitely.  The close is announced with a coded
    /// `"idle_timeout"` error line.  `Duration::ZERO` disables the limit.
    pub idle_timeout: std::time::Duration,
}

impl Default for ServerOptions {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7878".into(),
            workers: 8,
            idle_timeout: std::time::Duration::from_secs(60),
        }
    }
}

/// Serve the router over TCP until `cancel` fires.  Returns the bound local
/// address via the `on_bound` callback (useful with port 0 in tests).
pub fn serve(
    router: Router,
    opts: ServerOptions,
    cancel: CancelToken,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> Result<()> {
    let listener = TcpListener::bind(&opts.addr).with_context(|| format!("bind {}", opts.addr))?;
    listener.set_nonblocking(true)?;
    on_bound(listener.local_addr()?);
    log_info!("serving on {}", listener.local_addr()?);
    let router = Arc::new(router);
    let pool = ThreadPool::new(opts.workers);
    while !cancel.is_cancelled() {
        match listener.accept() {
            Ok((stream, peer)) => {
                let router = router.clone();
                let cancel = cancel.clone();
                let idle = opts.idle_timeout;
                let submitted = pool.execute(move || {
                    if let Err(e) = handle_conn(stream, &router, &cancel, idle) {
                        crate::log_debug!("conn {peer}: {e}");
                    }
                });
                if submitted.is_err() {
                    // a draining pool refuses new connections instead of
                    // panicking the accept loop
                    crate::log_debug!("worker pool shut down; dropping connection from {peer}");
                }
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            Err(e) => return Err(anyhow!("accept: {e}")),
        }
    }
    drop(pool); // join workers
    if let Ok(r) = Arc::try_unwrap(router) {
        r.shutdown();
    }
    Ok(())
}

/// Largest accepted request line (bytes).  Bounds per-connection memory at
/// the transport boundary — a hostile client cannot make the gateway buffer
/// an unbounded "line".  Generous enough for a [`protocol::MAX_IMAGE_LEN`]
/// image in JSON text.
const MAX_LINE_BYTES: usize = 8 << 20;

/// Write `body` + the protocol's line terminator as **one vectored
/// syscall** (`write_vectored` of `[body, "\n"]`): the response `String`
/// stays reused and untouched — no per-response `push('\n')` churn — and
/// the newline never costs a second `write` syscall.  Handles partial
/// vectored writes (kernels may accept any prefix) and `Interrupted`.
pub(crate) fn write_line_vectored<W: Write>(w: &mut W, body: &[u8]) -> std::io::Result<()> {
    const NL: &[u8] = b"\n";
    let total = body.len() + 1;
    let mut written = 0usize;
    while written < total {
        let res = if written < body.len() {
            w.write_vectored(&[IoSlice::new(&body[written..]), IoSlice::new(NL)])
        } else {
            // only the terminator (or its tail after a partial write) left
            w.write(&NL[written - body.len()..])
        };
        match res {
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::WriteZero,
                    "failed to write whole response line",
                ))
            }
            Ok(n) => written += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

fn handle_conn(
    stream: TcpStream,
    router: &Router,
    cancel: &CancelToken,
    idle_timeout: std::time::Duration,
) -> Result<()> {
    // short read timeout = the poll tick for cancellation and idle checks;
    // the actual idle budget is `idle_timeout`, measured from the last
    // completed request (the old code's 200 ms "timeout" only ever ticked —
    // it never closed anything, so silent connections pinned workers
    // forever)
    stream.set_read_timeout(Some(std::time::Duration::from_millis(200)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    // one response buffer per connection, reused across requests: encodes
    // append into it instead of allocating a fresh String per response
    let mut resp = String::new();
    let mut last_activity = std::time::Instant::now();
    loop {
        if cancel.is_cancelled() {
            return Ok(());
        }
        // a trickling peer resets nothing: only a *complete* request
        // counts as activity, so slowloris half-lines still time out
        if !idle_timeout.is_zero() && last_activity.elapsed() >= idle_timeout {
            resp.clear();
            protocol::encode_error_coded_into(
                "idle_timeout",
                &format!("closing idle connection after {} ms", idle_timeout.as_millis()),
                &mut resp,
            );
            let _ = write_line_vectored(&mut writer, resp.as_bytes());
            return Ok(());
        }
        if line.len() >= MAX_LINE_BYTES {
            resp.clear();
            protocol::encode_error_into(
                &format!("request line exceeds {MAX_LINE_BYTES} bytes"),
                &mut resp,
            );
            write_line_vectored(&mut writer, resp.as_bytes())?;
            return Ok(()); // close: the rest of the oversized line is garbage
        }
        // cap the read; partial lines (timeout or cap) accumulate in `line`
        let budget = (MAX_LINE_BYTES - line.len()) as u64;
        match (&mut reader).take(budget).read_line(&mut line) {
            Ok(0) => {
                // peer closed; a buffered newline-less final request still
                // gets its response before we hang up
                if !line.is_empty() {
                    respond_into(router, &line, &mut resp);
                    write_line_vectored(&mut writer, resp.as_bytes())?;
                }
                return Ok(());
            }
            Ok(_) if line.ends_with('\n') => {
                // Worker-side chaos hooks, exercised by the cluster chaos
                // suite.  Gated on classify lines so health probes keep
                // working while a worker misbehaves for real requests.
                #[cfg(feature = "fault-injection")]
                if line.contains("\"op\":\"classify\"") {
                    // crash: drop the connection with no response — the
                    // coordinator must fail over without losing the request
                    if crate::util::fault::faultpoint("worker.kill").is_err() {
                        return Ok(());
                    }
                    // straggle: DelayMs sleeps inside the faultpoint itself
                    let _ = crate::util::fault::faultpoint("worker.stall");
                    // corrupt: emit a non-protocol line instead of the answer
                    if crate::util::fault::faultpoint("worker.garbage").is_err() {
                        write_line_vectored(&mut writer, b"%%% not protocol json %%%")?;
                        line.clear();
                        last_activity = std::time::Instant::now();
                        continue;
                    }
                }
                respond_into(router, &line, &mut resp);
                write_line_vectored(&mut writer, resp.as_bytes())?;
                line.clear();
                last_activity = std::time::Instant::now();
            }
            Ok(_) => {} // mid-line: keep accumulating (next loop re-budgets)
            Err(ref e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue
            }
            Err(e) => return Err(e.into()),
        }
    }
}

/// Compute the response line for one request line (transport-independent —
/// also used by unit tests without sockets).
pub fn respond(router: &Router, line: &str) -> String {
    let mut out = String::new();
    respond_into(router, line, &mut out);
    out
}

/// [`respond`] into a reusable buffer: clears `out`, then append-encodes
/// the response (no trailing newline).
pub fn respond_into(router: &Router, line: &str, out: &mut String) {
    out.clear();
    match protocol::parse_request(line) {
        Err(e) => protocol::encode_error_into(&format!("{e}"), out),
        Ok(Request::Ping) => out.push_str(&protocol::encode_pong()),
        Ok(Request::Hello { role: _ }) => {
            // the peer announces its role; we answer with ours so a
            // coordinator probing a pool can verify it dialed an actual
            // worker (and not, say, another coordinator or a bare server)
            protocol::encode_hello_ack_into(router.role(), out)
        }
        Ok(Request::Info) => out.push_str(&protocol::encode_info(
            &router.datasets(),
            &router.health_snapshot(),
            &router.registry_snapshot(),
            &router.serving_snapshot(),
            &router.cluster_snapshot(),
            &router.trace_stats(),
        )),
        Ok(Request::Metrics) => {
            let body = prom::render(router);
            protocol::encode_metrics_into(&body, out);
        }
        Ok(Request::Trace { request_id }) => match request_id {
            Some(id) => protocol::encode_trace_spans_into(id, &router.trace_spans(id), out),
            None => protocol::encode_trace_exemplars_into(&router.trace_exemplars(), out),
        },
        Ok(Request::Classify {
            model,
            image,
            budget,
            deadline_ms,
            plan_seed,
            request_id,
        }) => {
            let t_req = std::time::Instant::now();
            // the engine thread re-resolves the name against its registry,
            // so the request carries it even though routing also uses it
            let (mut req, rx) = ClassifyRequest::with_model(Some(model.clone()), image, budget);
            req.plan_seed = plan_seed;
            // the deadline clock starts here, at admission: queueing time
            // counts against it (that is the point — shed what went stale
            // in the queue)
            req.deadline =
                deadline_ms.map(|ms| t_req + std::time::Duration::from_millis(ms));
            // A client-supplied id is both used and echoed back; otherwise,
            // with tracing on, mint an internal one that is *not* echoed —
            // so response bytes are identical with tracing on or off.
            let rid = match request_id {
                Some(id) => id,
                None => match router.get(&model) {
                    Ok(h) if h.recorder.enabled() => h.recorder.mint_id(),
                    _ => 0,
                },
            };
            req.request_id = rid;
            match router.route(&model, req) {
                Err(e) => encode_routing_error(&e, out),
                Ok(()) => match rx.recv() {
                    Some(Ok(result)) => {
                        let t_resp = std::time::Instant::now();
                        match request_id {
                            Some(id) => protocol::encode_result_traced_into(&result, id, out),
                            None => protocol::encode_result_into(&result, out),
                        }
                        if let Ok(h) = router.get(&model) {
                            h.uncertainty.record(
                                &model,
                                result.predictive.shannon_entropy,
                                result.predictive.mutual_information,
                                result.samples_used as u32,
                            );
                            if rid != 0 {
                                h.recorder.record(rid, Stage::Respond, 0, t_resp, t_resp.elapsed());
                                h.recorder.maybe_capture_exemplar(rid, t_req.elapsed());
                            }
                        }
                    }
                    Some(Err(e)) => encode_routing_error(&e, out),
                    None => protocol::encode_error_into("engine dropped request", out),
                },
            }
        }
    }
}

/// Encode a routing/engine error, surfacing typed serving-lifecycle errors
/// ([`crate::coordinator::overload::ServeError`]: `overloaded`,
/// `deadline_exceeded`, `internal_error`) and [`UnknownModel`] as
/// machine-readable coded responses.
fn encode_routing_error(e: &anyhow::Error, out: &mut String) {
    if let Some(se) = e.downcast_ref::<crate::coordinator::overload::ServeError>() {
        protocol::encode_serve_error_into(se, out);
    } else if e.downcast_ref::<crate::registry::UnknownModel>().is_some() {
        protocol::encode_error_coded_into("unknown_model", &format!("{e}"), out);
    } else {
        protocol::encode_error_into(&format!("{e}"), out);
    }
}

/// Client-side timeouts and retry policy.
#[derive(Debug, Clone)]
pub struct ClientConfig {
    pub connect_timeout: std::time::Duration,
    /// Per-response read timeout.  The old client blocked forever on a
    /// silent server; classification can legitimately take a while, so
    /// the default is generous rather than absent.
    pub read_timeout: std::time::Duration,
    pub write_timeout: std::time::Duration,
    /// Extra attempts for calls that are safe to repeat
    /// ([`Client::call_idempotent`] for `ping`/`info`,
    /// [`Client::call_replayable`] for plan-seeded classifies).  A plain
    /// classify is never retried — it could double-spend engine samples
    /// on a response that was merely slow.
    pub retries: u32,
    /// First retry backoff; doubles per attempt up to `backoff_cap`, with
    /// a deterministic jitter factor in `[0.5, 1.5)` so a fleet of clients
    /// retrying a recovering server does not stampede in lockstep.
    pub backoff_base: std::time::Duration,
    pub backoff_cap: std::time::Duration,
    /// Seed for the jitter stream (deterministic for tests).
    pub seed: u64,
}

impl Default for ClientConfig {
    fn default() -> Self {
        Self {
            connect_timeout: std::time::Duration::from_secs(5),
            read_timeout: std::time::Duration::from_secs(30),
            write_timeout: std::time::Duration::from_secs(5),
            retries: 3,
            backoff_base: std::time::Duration::from_millis(50),
            backoff_cap: std::time::Duration::from_secs(2),
            seed: 0x00C1_1E47,
        }
    }
}

/// Backoff before retry `attempt` (1-based): exponential from
/// `backoff_base`, capped at `backoff_cap`, jittered to 50–150% by the
/// caller-owned splitmix64 stream.
fn backoff_delay(cfg: &ClientConfig, attempt: u32, rng: &mut u64) -> std::time::Duration {
    let exp = cfg
        .backoff_base
        .saturating_mul(1u32 << attempt.saturating_sub(1).min(16))
        .min(cfg.backoff_cap);
    let frac = 0.5 + (crate::util::fault::splitmix64(rng) >> 11) as f64 / (1u64 << 53) as f64;
    exp.mul_f64(frac)
}

/// Open a connection with the configured timeouts.  `TcpStream::connect`
/// has no timeout parameter, so resolve first and use `connect_timeout`
/// per candidate address.
fn dial(addr: &str, cfg: &ClientConfig) -> Result<TcpStream> {
    use std::net::ToSocketAddrs;
    let addrs: Vec<_> = addr
        .to_socket_addrs()
        .with_context(|| format!("resolve {addr}"))?
        .collect();
    let mut last = None;
    for a in &addrs {
        match TcpStream::connect_timeout(a, cfg.connect_timeout) {
            Ok(s) => {
                // zero = no timeout (std rejects Some(ZERO))
                s.set_read_timeout((!cfg.read_timeout.is_zero()).then_some(cfg.read_timeout))?;
                s.set_write_timeout((!cfg.write_timeout.is_zero()).then_some(cfg.write_timeout))?;
                return Ok(s);
            }
            Err(e) => last = Some(e),
        }
    }
    Err(match last {
        Some(e) => anyhow!("connect {addr}: {e}"),
        None => anyhow!("connect {addr}: no addresses resolved"),
    })
}

/// Simple blocking client for the gateway (used by examples and tests).
/// Connects with a timeout, bounds every read/write, and retries
/// idempotent calls with jittered exponential backoff.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    addr: String,
    cfg: ClientConfig,
    rng: u64,
    /// Set while a request may have left a response (whole or partial) in
    /// flight on this connection.  Reading the next reply off a dirty
    /// connection could consume the *previous* request's answer — the
    /// duplicate-answer window that makes naive retry unsafe.  [`call`]
    /// re-dials a dirty connection before sending, so every request reads
    /// from a stream that provably holds no stale response.
    dirty: bool,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        Self::connect_with(addr, ClientConfig::default())
    }

    pub fn connect_with(addr: &str, cfg: ClientConfig) -> Result<Self> {
        let stream = dial(addr, &cfg)?;
        let writer = stream.try_clone()?;
        let rng = cfg.seed;
        Ok(Self {
            reader: BufReader::new(stream),
            writer,
            addr: addr.to_string(),
            cfg,
            rng,
            dirty: false,
        })
    }

    /// Replace the half-dead stream with a freshly dialed one.
    fn reconnect(&mut self) -> Result<()> {
        let stream = dial(&self.addr, &self.cfg)?;
        self.writer = stream.try_clone()?;
        self.reader = BufReader::new(stream);
        self.dirty = false;
        Ok(())
    }

    /// Send one request line; wait for one response line.  Reads are capped
    /// at [`MAX_LINE_BYTES`] — the mirror image of the server's request cap
    /// — so a misbehaving (or spoofed) server cannot make the client buffer
    /// an unbounded response.
    pub fn call(&mut self, line: &str) -> Result<crate::util::json::Json> {
        if self.dirty {
            self.reconnect()?;
        }
        // dirty until a complete response line has been read and parsed:
        // any early exit leaves the connection marked for re-dial
        self.dirty = true;
        // mirror of the gateway's response path: body + newline in one
        // vectored syscall
        write_line_vectored(&mut self.writer, line.as_bytes())?;
        let mut resp = String::new();
        (&mut self.reader)
            .take(MAX_LINE_BYTES as u64)
            .read_line(&mut resp)?;
        if !resp.ends_with('\n') && resp.len() >= MAX_LINE_BYTES {
            // the unread tail of the oversized line is still in flight; a
            // further call would read mid-line garbage as its response, so
            // poison the connection (mirrors the server closing on an
            // oversized request)
            let _ = self.writer.shutdown(std::net::Shutdown::Both);
            return Err(anyhow!("response line exceeds {MAX_LINE_BYTES} bytes"));
        }
        let j = crate::util::json::parse(&resp)
            .map_err(|e| anyhow!("bad response: {e} ({resp:?})"))?;
        self.dirty = false;
        Ok(j)
    }

    /// [`call`](Self::call) with bounded retries for idempotent requests:
    /// on failure, re-dial the server and back off exponentially with
    /// jitter (`ClientConfig::retries` extra attempts).  Only for requests
    /// that are safe to repeat — `ping`/`info` use it, and
    /// [`call_replayable`](Self::call_replayable) reuses it for
    /// plan-seeded classifies.
    pub fn call_idempotent(&mut self, line: &str) -> Result<crate::util::json::Json> {
        let mut last_err = None;
        for attempt in 0..=self.cfg.retries {
            if attempt > 0 {
                std::thread::sleep(backoff_delay(&self.cfg, attempt, &mut self.rng));
                // the old stream may be half-dead (timed-out read leaves
                // an unread response in flight): force `call` to re-dial
                self.dirty = true;
            }
            match self.call(line) {
                Ok(j) => return Ok(j),
                Err(e) => last_err = Some(e),
            }
        }
        Err(last_err.unwrap_or_else(|| anyhow!("no attempts made")))
    }

    /// Retry-on-reconnect for **replayable** requests.
    ///
    /// # The idempotency rule
    ///
    /// A plain classify must not be retried: the engine draws from a
    /// stateful entropy stream, so a second attempt would both spend
    /// fresh samples and return a *different* answer than the (possibly
    /// merely slow) first attempt.  A classify that pins its entropy
    /// with a `plan_seed` is replayable — any server, asked any number
    /// of times, computes the bitwise-identical response — so a retry
    /// can never observe a divergent answer.  Single-in-flight is
    /// enforced by the dirty-connection tracking in [`call`](Self::call):
    /// a retry always starts on a freshly dialed connection, so it can
    /// never read a stale response left over from the failed attempt
    /// (no duplicate-answer window).
    pub fn call_replayable(&mut self, line: &str) -> Result<crate::util::json::Json> {
        self.call_idempotent(line)
    }

    pub fn ping(&mut self) -> Result<bool> {
        let j = self.call_idempotent("{\"op\":\"ping\"}")?;
        Ok(j.get("pong").and_then(|v| v.as_bool()).unwrap_or(false))
    }

    /// Role handshake: announce our role, return the server's.  A cluster
    /// coordinator uses this to verify it dialed an actual worker.
    pub fn hello(&mut self, role: &str) -> Result<String> {
        let j = self.call_idempotent(&protocol::encode_hello(role))?;
        j.get("role")
            .and_then(|v| v.as_str())
            .map(str::to_string)
            .ok_or_else(|| anyhow!("hello ack missing role"))
    }

    /// Fetch the server's `info` document (models, health, registry,
    /// serving counters), with idempotent retry.
    pub fn info(&mut self) -> Result<crate::util::json::Json> {
        self.call_idempotent("{\"op\":\"info\"}")
    }

    pub fn classify(&mut self, model: &str, image: &[f32]) -> Result<crate::util::json::Json> {
        self.call(&protocol::encode_classify(model, image))
    }

    /// Classify with per-request budget overrides (`max_samples` /
    /// `target_confidence` protocol fields).
    pub fn classify_with_budget(
        &mut self,
        model: &str,
        image: &[f32],
        budget: &crate::sampler::RequestBudget,
    ) -> Result<crate::util::json::Json> {
        self.call(&protocol::encode_classify_with_budget(model, image, budget))
    }

    /// Classify with budget overrides and an optional relative deadline
    /// (`deadline_ms` protocol field).  Not retried: the server may have
    /// spent samples on an attempt whose response was merely slow.
    pub fn classify_opts(
        &mut self,
        model: &str,
        image: &[f32],
        budget: &crate::sampler::RequestBudget,
        deadline_ms: Option<u64>,
    ) -> Result<crate::util::json::Json> {
        self.call(&protocol::encode_classify_opts(model, image, budget, deadline_ms))
    }

    /// Shard-scoped classify pinned to `plan_seed`, retried on reconnect —
    /// see [`call_replayable`](Self::call_replayable) for why pinning the
    /// seed makes the retry safe.
    pub fn classify_replayable(
        &mut self,
        model: &str,
        image: &[f32],
        budget: &crate::sampler::RequestBudget,
        deadline_ms: Option<u64>,
        plan_seed: u64,
    ) -> Result<crate::util::json::Json> {
        self.call_replayable(&protocol::encode_classify_sharded(
            model,
            image,
            budget,
            deadline_ms,
            plan_seed,
        ))
    }

    /// [`classify_replayable`](Self::classify_replayable) carrying a
    /// client-chosen nonzero `request_id`: the server traces the request
    /// under that id (stitched across cluster hops) and echoes it in the
    /// response.
    #[allow(clippy::too_many_arguments)]
    pub fn classify_traced(
        &mut self,
        model: &str,
        image: &[f32],
        budget: &crate::sampler::RequestBudget,
        deadline_ms: Option<u64>,
        plan_seed: u64,
        request_id: u64,
    ) -> Result<crate::util::json::Json> {
        self.call_replayable(&protocol::encode_classify_sharded_traced(
            model,
            image,
            budget,
            deadline_ms,
            plan_seed,
            request_id,
        ))
    }

    /// Fetch the Prometheus text-format metrics body (the `metrics` op),
    /// with idempotent retry.
    pub fn metrics(&mut self) -> Result<String> {
        let j = self.call_idempotent(&protocol::encode_metrics_req())?;
        j.get("body")
            .and_then(|v| v.as_str())
            .map(str::to_string)
            .ok_or_else(|| anyhow!("metrics response missing body"))
    }

    /// Fetch trace spans for one `request_id` (`Some(id)`) or the retained
    /// slow-request exemplars (`None`), with idempotent retry — reading a
    /// trace never spends engine samples.
    pub fn trace(&mut self, request_id: Option<u64>) -> Result<crate::util::json::Json> {
        self.call_idempotent(&protocol::encode_trace_req(request_id))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn respond_into_reuses_and_clears_the_buffer() {
        let router = Router::new();
        let mut buf = String::from("stale residue from the previous request");
        respond_into(&router, "{\"op\":\"ping\"}", &mut buf);
        assert_eq!(buf, respond(&router, "{\"op\":\"ping\"}"));
        respond_into(&router, "garbage", &mut buf);
        assert!(buf.contains("\"ok\":false"));
        assert!(!buf.contains("pong"), "buffer cleared between responses");
    }

    /// A writer that accepts at most `cap` bytes per call and ignores all
    /// but the first buffer of a vectored write — the worst-legal-case
    /// kernel behavior the helper must survive.
    struct ChunkyWriter {
        cap: usize,
        data: Vec<u8>,
    }

    impl Write for ChunkyWriter {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            let n = buf.len().min(self.cap);
            self.data.extend_from_slice(&buf[..n]);
            Ok(n)
        }

        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn vectored_line_write_is_complete_under_partial_writes() {
        for cap in [1, 2, 3, 7, 64] {
            let mut w = ChunkyWriter {
                cap,
                data: Vec::new(),
            };
            write_line_vectored(&mut w, b"{\"ok\":true}").unwrap();
            assert_eq!(w.data, b"{\"ok\":true}\n", "cap {cap}");
        }
        // empty body still terminates the line
        let mut w = ChunkyWriter {
            cap: 8,
            data: Vec::new(),
        };
        write_line_vectored(&mut w, b"").unwrap();
        assert_eq!(w.data, b"\n");
    }

    #[test]
    fn vectored_line_write_single_call_fast_path() {
        // a Vec<u8> writer consumes both buffers in one vectored call
        let mut buf: Vec<u8> = Vec::new();
        write_line_vectored(&mut buf, b"body").unwrap();
        assert_eq!(buf, b"body\n");
    }

    #[test]
    fn backoff_is_jittered_bounded_and_deterministic() {
        let cfg = ClientConfig::default();
        let mut rng = cfg.seed;
        let mut rng2 = cfg.seed;
        for attempt in 1..=8 {
            let d = backoff_delay(&cfg, attempt, &mut rng);
            // 50–150% of the capped exponential
            let exp = cfg
                .backoff_base
                .saturating_mul(1u32 << (attempt - 1).min(16))
                .min(cfg.backoff_cap);
            assert!(d >= exp.mul_f64(0.5) && d < exp.mul_f64(1.5), "attempt {attempt}: {d:?}");
            assert!(d <= cfg.backoff_cap.mul_f64(1.5));
            // same seed, same schedule
            assert_eq!(d, backoff_delay(&cfg, attempt, &mut rng2));
        }
    }

    #[test]
    fn idle_connection_is_closed_with_coded_error() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let router = Router::new();
            let cancel = CancelToken::new();
            handle_conn(
                stream,
                &router,
                &cancel,
                std::time::Duration::from_millis(250),
            )
            .unwrap();
        });
        let mut c = TcpStream::connect(addr).unwrap();
        // a live request resets the idle clock...
        write_line_vectored(&mut c, b"{\"op\":\"ping\"}").unwrap();
        let mut r = BufReader::new(c.try_clone().unwrap());
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert!(line.contains("pong"), "{line}");
        // ...then silence: the server must announce and close, not hang
        line.clear();
        let t0 = std::time::Instant::now();
        r.read_line(&mut line).unwrap();
        assert!(line.contains("\"code\":\"idle_timeout\""), "{line}");
        line.clear();
        assert_eq!(r.read_line(&mut line).unwrap(), 0, "connection closed");
        assert!(t0.elapsed() < std::time::Duration::from_secs(5));
        h.join().unwrap();
    }

    #[test]
    fn respond_handles_ping_info_and_errors_without_engines() {
        let router = Router::new();
        let pong = respond(&router, "{\"op\":\"ping\"}");
        assert!(pong.contains("pong"));
        let info = respond(&router, "{\"op\":\"info\"}");
        assert!(info.contains("datasets"));
        assert!(info.contains("models"));
        // unknown model (via either field name) is the typed coded error
        let err = respond(&router, "{\"op\":\"classify\",\"dataset\":\"x\",\"image\":[1]}");
        assert!(err.contains("\"ok\":false"));
        assert!(err.contains("\"code\":\"unknown_model\""), "{err}");
        let err = respond(&router, "{\"op\":\"classify\",\"model\":\"x\",\"image\":[1]}");
        assert!(err.contains("\"code\":\"unknown_model\""), "{err}");
        let bad = respond(&router, "garbage");
        assert!(bad.contains("\"ok\":false"));
    }

    #[test]
    fn metrics_and_trace_verbs_answer_without_engines() {
        let router = Router::new();
        let m = respond(&router, "{\"op\":\"metrics\"}");
        assert!(m.contains("\"ok\":true"), "{m}");
        assert!(m.contains("text/plain"), "{m}");
        assert!(m.contains("pbm_build_info"), "{m}");
        // a trace query for an unknown id is an empty span list, not an error
        let t = respond(&router, "{\"op\":\"trace\",\"request_id\":\"42\"}");
        assert!(t.contains("\"ok\":true"), "{t}");
        assert!(t.contains("\"spans\":[]"), "{t}");
        let ex = respond(&router, "{\"op\":\"trace\"}");
        assert!(ex.contains("\"ok\":true"), "{ex}");
    }

    #[test]
    fn hello_reports_router_role() {
        let router = Router::new();
        let ack = respond(&router, "{\"op\":\"hello\",\"role\":\"coordinator\"}");
        assert!(ack.contains("\"ok\":true"), "{ack}");
        assert!(ack.contains("\"role\":\"server\""), "{ack}");
        let mut worker = Router::new();
        worker.set_role("worker");
        let ack = respond(&worker, "{\"op\":\"hello\"}");
        assert!(ack.contains("\"role\":\"worker\""), "{ack}");
    }
}
