//! Wire protocol: request parsing and response encoding.

use anyhow::{anyhow, Result};

use crate::bnn::{Decision, Predictive};
use crate::cluster::WorkerCard;
use crate::coordinator::engine::ClassifyResult;
use crate::coordinator::metrics::ServeSnapshot;
use crate::coordinator::overload::ServeError;
use crate::entropy::health::Scorecard;
use crate::observe::{critical_path_us, Exemplar, Span, TraceStats};
use crate::registry::RegistrySnapshot;
use crate::sampler::RequestBudget;
use crate::util::json::{self, Json};

/// Largest accepted `image` array (elements).  Image sizes are set by model
/// metadata; 2^18 = 262,144 elements admits anything up to a 512x512
/// single-channel (or 360x360 multi-channel-ish) input while staying well
/// inside the gateway's 8 MiB request-line cap.  The cap exists so an
/// attacker-controlled request cannot drive the downstream `SamplePlan`
/// size math or engine buffers into overflow/OOM territory before the
/// shape check even runs.
pub const MAX_IMAGE_LEN: usize = 1 << 18;

/// A parsed client request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Classify {
        /// Target model name.  The wire field is `model`; `dataset` is
        /// accepted as a legacy alias (`model` wins when both appear).
        model: String,
        image: Vec<f32>,
        /// Optional per-request sample budget (`max_samples` /
        /// `target_confidence` fields) — validated here at the protocol
        /// boundary so hostile budgets (`0`, NaN, out-of-range) are a
        /// typed error response, not a downstream panic or NaN decision.
        budget: RequestBudget,
        /// Optional relative deadline in milliseconds: the server sheds
        /// the request (typed `deadline_exceeded`) once this much time
        /// has passed since admission, instead of burning samples on an
        /// answer the client has stopped waiting for.  `None` falls back
        /// to the server's configured default.
        deadline_ms: Option<u64>,
        /// Shard-scoped plan seed (cluster mode): the exact seed this
        /// request's stochastic stream must derive from, making the
        /// answer a pure function of `(model, plan_seed, budget)` and
        /// therefore safe to re-route, hedge, or replay.  Travels as a
        /// decimal *string* on the wire — JSON numbers are f64 and would
        /// corrupt 64-bit seeds.
        plan_seed: Option<u64>,
        /// Optional trace key, a nonzero u64 carried as a decimal string
        /// (same rationale as `plan_seed`).  Clients set it to correlate
        /// the reply (it is echoed back) and query the trace afterwards;
        /// a cluster coordinator forwards the gateway-minted id so the
        /// worker's spans stitch into the same trace.  Purely
        /// observational — never feeds any computation.
        request_id: Option<u64>,
    },
    Info,
    Ping,
    /// Render the Prometheus text exposition ([`crate::observe::prom`]).
    Metrics,
    /// Query recorded spans for one traced request (`request_id` as a
    /// decimal string), or — without a `request_id` — list the retained
    /// slow-request exemplars.
    Trace { request_id: Option<u64> },
    /// Role handshake (cluster mode): a coordinator announces itself and
    /// learns whether the peer is a `worker` before routing shard-scoped
    /// plans at it.
    Hello {
        /// The *peer's* announced role (`"coordinator"`, `"worker"`,
        /// `"client"`; free-form).
        role: String,
    },
}

/// Parse one request line.
pub fn parse_request(line: &str) -> Result<Request> {
    let j = json::parse(line.trim()).map_err(|e| anyhow!("bad json: {e}"))?;
    match j.req("op").map_err(|e| anyhow!(e))?.as_str() {
        Some("classify") => {
            let model = j
                .get("model")
                .or_else(|| j.get("dataset"))
                .ok_or_else(|| anyhow!("missing required field 'model'"))?
                .as_str()
                .ok_or_else(|| anyhow!("model must be a string"))?
                .to_string();
            let image: Vec<f32> = j
                .req("image")
                .map_err(|e| anyhow!(e))?
                .as_f64_vec()
                .ok_or_else(|| anyhow!("image must be a numeric array"))?
                .into_iter()
                .map(|x| x as f32)
                .collect();
            if image.len() > MAX_IMAGE_LEN {
                return Err(anyhow!(
                    "image has {} elements, exceeding the protocol cap of {}",
                    image.len(),
                    MAX_IMAGE_LEN
                ));
            }
            let budget = parse_budget(&j)?;
            let deadline_ms = parse_deadline_ms(&j)?;
            let plan_seed = parse_plan_seed(&j)?;
            let request_id = parse_request_id(&j)?;
            Ok(Request::Classify {
                model,
                image,
                budget,
                deadline_ms,
                plan_seed,
                request_id,
            })
        }
        Some("info") => Ok(Request::Info),
        Some("ping") => Ok(Request::Ping),
        Some("metrics") => Ok(Request::Metrics),
        Some("trace") => Ok(Request::Trace {
            request_id: parse_request_id(&j)?,
        }),
        Some("hello") => {
            let role = match j.get("role") {
                None => "client".to_string(),
                Some(v) => v
                    .as_str()
                    .ok_or_else(|| anyhow!("role must be a string"))?
                    .to_string(),
            };
            Ok(Request::Hello { role })
        }
        other => Err(anyhow!("unknown op {other:?}")),
    }
}

/// Parse the optional `plan_seed` field: a u64 carried as a decimal
/// string (JSON numbers are f64 — above 2^53 they silently lose bits,
/// which for a seed means a silently different stochastic stream).
fn parse_plan_seed(j: &Json) -> Result<Option<u64>> {
    match j.get("plan_seed") {
        None => Ok(None),
        Some(v) => {
            let s = v
                .as_str()
                .ok_or_else(|| anyhow!("plan_seed must be a decimal string (u64)"))?;
            let seed: u64 = s
                .parse()
                .map_err(|e| anyhow!("plan_seed '{s}' is not a u64: {e}"))?;
            Ok(Some(seed))
        }
    }
}

/// Parse the optional `request_id` field: a *nonzero* u64 carried as a
/// decimal string (0 is the internal untraced sentinel; accepting it
/// would let a client silently opt out of its own echo).
fn parse_request_id(j: &Json) -> Result<Option<u64>> {
    match j.get("request_id") {
        None => Ok(None),
        Some(v) => {
            let s = v
                .as_str()
                .ok_or_else(|| anyhow!("request_id must be a decimal string (u64)"))?;
            let id: u64 = s
                .parse()
                .map_err(|e| anyhow!("request_id '{s}' is not a u64: {e}"))?;
            if id == 0 {
                return Err(anyhow!("request_id must be nonzero"));
            }
            Ok(Some(id))
        }
    }
}

/// Parse + validate the optional budget fields of a classify request.
fn parse_budget(j: &Json) -> Result<RequestBudget> {
    let max_samples = match j.get("max_samples") {
        None => None,
        Some(v) => {
            // exact integer required: a silently floored 3.9 would alter
            // the client's stated budget
            let f = v
                .as_f64()
                .filter(|f| *f >= 0.0 && f.fract() == 0.0 && *f <= usize::MAX as f64)
                .ok_or_else(|| anyhow!("max_samples must be a non-negative integer"))?;
            Some(f as usize)
        }
    };
    let target_confidence = match j.get("target_confidence") {
        None => None,
        Some(v) => Some(
            v.as_f64()
                .ok_or_else(|| anyhow!("target_confidence must be a number"))?,
        ),
    };
    let budget = RequestBudget {
        max_samples,
        target_confidence,
    };
    budget
        .validate()
        .map_err(|e| anyhow!("invalid sample budget: {e}"))?;
    Ok(budget)
}

/// Parse + validate the optional `deadline_ms` field: a positive exact
/// integer (0 would expire every request before its first sample, which
/// can only be a client bug — reject it loudly at the boundary).
fn parse_deadline_ms(j: &Json) -> Result<Option<u64>> {
    match j.get("deadline_ms") {
        None => Ok(None),
        Some(v) => {
            let f = v
                .as_f64()
                .filter(|f| *f >= 1.0 && f.fract() == 0.0 && *f <= u64::MAX as f64)
                .ok_or_else(|| anyhow!("deadline_ms must be a positive integer"))?;
            Ok(Some(f as u64))
        }
    }
}

/// Encode a classification result.
pub fn encode_result(r: &ClassifyResult) -> String {
    let mut s = String::new();
    encode_result_into(r, &mut s);
    s
}

/// Append-encode a classification result into a reusable buffer (the
/// gateway's per-connection fast path).
pub fn encode_result_into(r: &ClassifyResult, out: &mut String) {
    let (decision, class, extra): (&str, Option<usize>, Vec<(&str, Json)>) = match &r.decision {
        Decision::Accept { class, confidence } => (
            "accept",
            Some(*class),
            vec![("confidence", Json::Num(*confidence as f64))],
        ),
        Decision::RejectOod { mutual_information } => (
            "reject_ood",
            None,
            vec![("mi_trigger", Json::Num(*mutual_information))],
        ),
        Decision::FlagAmbiguous {
            class,
            softmax_entropy,
        } => (
            "flag_ambiguous",
            Some(*class),
            vec![("se_trigger", Json::Num(*softmax_entropy))],
        ),
    };
    let mut o = Json::obj();
    o.set("ok", Json::Bool(true));
    o.set("decision", Json::Str(decision.into()));
    if let Some(c) = class {
        o.set("class", Json::Num(c as f64));
    }
    o.set("predicted", Json::Num(r.predictive.predicted as f64));
    o.set("mi", Json::Num(r.predictive.mutual_information));
    o.set("se", Json::Num(r.predictive.softmax_entropy));
    o.set("h", Json::Num(r.predictive.shannon_entropy));
    o.set("agreement", Json::Num(r.predictive.agreement));
    o.set("mean_probs", Json::arr_f32(&r.predictive.mean_probs));
    o.set("samples_used", Json::Num(r.samples_used as f64));
    o.set("latency_us", Json::Num(r.latency_us));
    // only flagged when true: the overwhelmingly common healthy path pays
    // no bytes for it
    if r.degraded {
        o.set("degraded", Json::Bool(true));
    }
    for (k, v) in extra {
        o.set(k, v);
    }
    o.write_compact(out);
}

/// Append-encode a classification result echoing the client-supplied
/// `request_id` (decimal string, like `plan_seed`).  Only called when
/// the client sent one: untraced and internally-traced responses use
/// [`encode_result_into`] unchanged, so enabling tracing on a server
/// never alters a response byte.
pub fn encode_result_traced_into(r: &ClassifyResult, request_id: u64, out: &mut String) {
    encode_result_into(r, out);
    // splice the id in as a string field (see `parse_request_id`)
    out.truncate(out.len() - 1);
    out.push_str(&format!(",\"request_id\":\"{request_id}\"}}"));
}

/// Encode an error response.
pub fn encode_error(msg: &str) -> String {
    let mut s = String::new();
    encode_error_into(msg, &mut s);
    s
}

/// Append-encode an error response into a reusable buffer.
pub fn encode_error_into(msg: &str, out: &mut String) {
    let mut o = Json::obj();
    o.set("ok", Json::Bool(false));
    o.set("error", Json::Str(msg.into()));
    o.write_compact(out);
}

/// Append-encode an error response carrying a machine-readable `code`
/// (e.g. `"unknown_model"`) so clients can dispatch without parsing the
/// human-readable message.
pub fn encode_error_coded_into(code: &str, msg: &str, out: &mut String) {
    let mut o = Json::obj();
    o.set("ok", Json::Bool(false));
    o.set("code", Json::Str(code.into()));
    o.set("error", Json::Str(msg.into()));
    o.write_compact(out);
}

/// Append-encode a typed serving-lifecycle error ([`ServeError`]): the
/// coded form plus the code-specific retry hint — `retry_after_ms` on
/// `overloaded` (queue drain estimate), `samples_used` on
/// `deadline_exceeded` (stochastic passes spent before expiry).
pub fn encode_serve_error_into(e: &ServeError, out: &mut String) {
    let mut o = Json::obj();
    o.set("ok", Json::Bool(false));
    o.set("code", Json::Str(e.code().into()));
    o.set("error", Json::Str(e.to_string()));
    match e {
        ServeError::Overloaded { retry_after_ms } => {
            o.set("retry_after_ms", Json::Num(*retry_after_ms as f64));
        }
        ServeError::DeadlineExceeded { samples_used } => {
            o.set("samples_used", Json::Num(*samples_used as f64));
        }
        ServeError::Internal { .. } => {}
        ServeError::WorkerUnavailable { down } => {
            o.set("down", Json::Num(*down as f64));
        }
    }
    o.write_compact(out);
}

/// Encode the `info` response.  `models` lists every servable model name
/// (emitted under both `models` and the legacy `datasets` key); `health`
/// carries per-dataset entropy-health scorecards (see
/// [`crate::coordinator::Router::health_snapshot`]) and `registry` the
/// per-engine model-registry residency snapshots (see
/// [`crate::coordinator::Router::registry_snapshot`]); `serving` the
/// per-engine overload/robustness counters (see
/// [`crate::coordinator::Router::serving_snapshot`]); `cluster` the
/// per-worker pool cards of a cluster coordinator (see
/// [`crate::coordinator::Router::cluster_snapshot`]) — pass empty slices
/// and the respective object is omitted entirely.
pub fn encode_info(
    models: &[&str],
    health: &[(String, Vec<Scorecard>)],
    registry: &[(String, RegistrySnapshot)],
    serving: &[(String, ServeSnapshot)],
    cluster: &[(String, Vec<WorkerCard>)],
    observe: &[(String, TraceStats)],
) -> String {
    let mut o = Json::obj();
    o.set("ok", Json::Bool(true));
    let names = Json::Arr(models.iter().map(|d| Json::Str(d.to_string())).collect());
    o.set("models", names.clone());
    // legacy alias kept for pre-multi-model clients
    o.set("datasets", names);
    o.set("version", Json::Str(crate::version().into()));
    if !health.is_empty() {
        let mut h = Json::obj();
        for (dataset, cards) in health {
            h.set(
                dataset,
                Json::Arr(cards.iter().map(encode_scorecard).collect()),
            );
        }
        o.set("entropy_health", h);
    }
    if !registry.is_empty() {
        let mut r = Json::obj();
        for (engine, snap) in registry {
            r.set(engine, encode_registry_snapshot(snap));
        }
        o.set("registry", r);
    }
    if !serving.is_empty() {
        let mut s = Json::obj();
        for (engine, snap) in serving {
            s.set(engine, snap.to_json());
        }
        o.set("serving", s);
    }
    if !cluster.is_empty() {
        let mut c = Json::obj();
        for (engine, cards) in cluster {
            c.set(
                engine,
                Json::Arr(cards.iter().map(encode_worker_card).collect()),
            );
        }
        o.set("cluster", c);
    }
    // tracing-disabled engines are omitted: a default /info stays
    // byte-identical to the pre-observe protocol
    let traced: Vec<_> = observe.iter().filter(|(_, t)| t.enabled).collect();
    if !traced.is_empty() {
        let mut t = Json::obj();
        for (engine, stats) in traced {
            let mut s = Json::obj();
            s.set("trace_capacity", Json::Num(stats.capacity as f64));
            s.set("spans_recorded", Json::Num(stats.recorded as f64));
            s.set("spans_dropped", Json::Num(stats.dropped as f64));
            s.set("exemplars", Json::Num(stats.exemplars as f64));
            t.set(engine, s);
        }
        o.set("observe", t);
    }
    o.to_string_compact()
}

/// One cluster worker's pool card as a JSON object.
fn encode_worker_card(c: &WorkerCard) -> Json {
    let mut o = Json::obj();
    o.set("addr", Json::Str(c.addr.clone()));
    o.set("state", Json::Str(c.state.name().into()));
    o.set("consecutive_fails", Json::Num(f64::from(c.consecutive_fails)));
    o.set("latency_ewma_us", Json::Num(c.latency_ewma_us));
    o.set("entropy_degraded", Json::Bool(c.entropy_degraded));
    o.set("p50_us", Json::Num(c.p50_us));
    o.set("p95_us", Json::Num(c.p95_us));
    o.set("p99_us", Json::Num(c.p99_us));
    o
}

/// One engine's model-registry snapshot as a JSON object: cache-wide
/// residency/budget bytes and hit/miss/switch/eviction counters, plus a
/// per-model card array (state, resident bytes, per-model counters).
fn encode_registry_snapshot(s: &RegistrySnapshot) -> Json {
    let mut o = Json::obj();
    o.set("budget_bytes", Json::Num(s.budget_bytes as f64));
    o.set("resident_bytes", Json::Num(s.resident_bytes as f64));
    o.set("hits", Json::Num(s.hits as f64));
    o.set("misses", Json::Num(s.misses as f64));
    o.set("switches", Json::Num(s.switches as f64));
    o.set("evictions", Json::Num(s.evictions as f64));
    o.set(
        "models",
        Json::Arr(
            s.models
                .iter()
                .map(|c| {
                    let mut m = Json::obj();
                    m.set("model", Json::Str(c.model.clone()));
                    m.set("state", Json::Str(c.state.name().into()));
                    m.set("bytes", Json::Num(c.bytes as f64));
                    m.set("hits", Json::Num(c.hits as f64));
                    m.set("misses", Json::Num(c.misses as f64));
                    m.set("switches_in", Json::Num(c.switches_in as f64));
                    m
                })
                .collect(),
        ),
    );
    o
}

/// One `(shard, stream)` scorecard as a JSON object.
fn encode_scorecard(c: &Scorecard) -> Json {
    let mut o = Json::obj();
    o.set("shard", Json::Num(c.shard as f64));
    o.set("stream", Json::Str(c.stream.clone()));
    o.set("windows", Json::Num(c.windows as f64));
    o.set("score_ewma", Json::Num(c.score_ewma));
    o.set("last_score", Json::Num(c.last_score));
    o.set("consecutive_fails", Json::Num(c.consecutive_fails as f64));
    o.set("min_entropy", Json::Num(c.min_entropy));
    o.set("serial_corr", Json::Num(c.serial_corr));
    o.set("degraded", Json::Bool(c.degraded));
    o
}

/// Encode the `ping` response.
pub fn encode_pong() -> String {
    "{\"ok\":true,\"pong\":true}".to_string()
}

/// Append-encode the `hello` response: the server announces its own
/// role (`"worker"` for `pbm worker`, `"coordinator"` for `pbm cluster`,
/// `"server"` otherwise) so a coordinator can verify it is routing
/// shard-scoped plans at an actual worker.
pub fn encode_hello_ack_into(server_role: &str, out: &mut String) {
    let mut o = Json::obj();
    o.set("ok", Json::Bool(true));
    o.set("role", Json::Str(server_role.into()));
    o.set("version", Json::Str(crate::version().into()));
    o.write_compact(out);
}

/// Client-side: encode a `hello` handshake announcing `role`.
pub fn encode_hello(role: &str) -> String {
    let mut o = Json::obj();
    o.set("op", Json::Str("hello".into()));
    o.set("role", Json::Str(role.into()));
    o.to_string_compact()
}

/// Client-side: encode a classify request.
pub fn encode_classify(model: &str, image: &[f32]) -> String {
    encode_classify_with_budget(model, image, &RequestBudget::default())
}

/// Client-side: encode a classify request carrying budget overrides.
pub fn encode_classify_with_budget(model: &str, image: &[f32], budget: &RequestBudget) -> String {
    encode_classify_opts(model, image, budget, None)
}

/// Client-side: encode a classify request with budget overrides and an
/// optional relative deadline.
pub fn encode_classify_opts(
    model: &str,
    image: &[f32],
    budget: &RequestBudget,
    deadline_ms: Option<u64>,
) -> String {
    let mut o = Json::obj();
    o.set("op", Json::Str("classify".into()));
    o.set("model", Json::Str(model.into()));
    o.set("image", Json::arr_f32(image));
    if let Some(m) = budget.max_samples {
        o.set("max_samples", Json::Num(m as f64));
    }
    if let Some(c) = budget.target_confidence {
        o.set("target_confidence", Json::Num(c));
    }
    if let Some(d) = deadline_ms {
        o.set("deadline_ms", Json::Num(d as f64));
    }
    o.to_string_compact()
}

/// Client-side (the cluster coordinator): encode a shard-scoped classify
/// request pinning the worker's stochastic stream to `plan_seed`.
pub fn encode_classify_sharded(
    model: &str,
    image: &[f32],
    budget: &RequestBudget,
    deadline_ms: Option<u64>,
    plan_seed: u64,
) -> String {
    let mut line = encode_classify_opts(model, image, budget, deadline_ms);
    // splice the seed in as a string field (see `parse_plan_seed`)
    line.truncate(line.len() - 1);
    line.push_str(&format!(",\"plan_seed\":\"{plan_seed}\"}}"));
    line
}

/// Client-side (the cluster coordinator): [`encode_classify_sharded`]
/// additionally forwarding the coordinator-side `request_id`, so the
/// worker's spans land under the same trace key and a failed-over or
/// hedged request still reads as ONE request end to end.
pub fn encode_classify_sharded_traced(
    model: &str,
    image: &[f32],
    budget: &RequestBudget,
    deadline_ms: Option<u64>,
    plan_seed: u64,
    request_id: u64,
) -> String {
    let mut line = encode_classify_sharded(model, image, budget, deadline_ms, plan_seed);
    line.truncate(line.len() - 1);
    line.push_str(&format!(",\"request_id\":\"{request_id}\"}}"));
    line
}

/// Client-side: encode a `metrics` request (Prometheus exposition).
pub fn encode_metrics_req() -> String {
    "{\"op\":\"metrics\"}".to_string()
}

/// Client-side: encode a `trace` request — for one request's spans
/// (`Some(id)`) or the exemplar list (`None`).
pub fn encode_trace_req(request_id: Option<u64>) -> String {
    match request_id {
        Some(id) => format!("{{\"op\":\"trace\",\"request_id\":\"{id}\"}}"),
        None => "{\"op\":\"trace\"}".to_string(),
    }
}

/// Append-encode the `metrics` response: the rendered Prometheus text
/// travels as one JSON string field so it fits the line-framed protocol
/// (`pbm scrape` unwraps it back to plain text).
pub fn encode_metrics_into(body: &str, out: &mut String) {
    let mut o = Json::obj();
    o.set("ok", Json::Bool(true));
    o.set(
        "content_type",
        Json::Str("text/plain; version=0.0.4".into()),
    );
    o.set("body", Json::Str(body.into()));
    o.write_compact(out);
}

/// One recorded span as a JSON object.
fn encode_span(s: &Span) -> Json {
    let mut o = Json::obj();
    o.set("stage", Json::Str(s.stage.name().into()));
    o.set("index", Json::Num(f64::from(s.index)));
    o.set("start_us", Json::Num(s.start_us as f64));
    o.set("dur_us", Json::Num(s.dur_us as f64));
    if s.stage.is_child() {
        o.set("child", Json::Bool(true));
    }
    if s.stage.is_annotation() {
        o.set("annotation", Json::Bool(true));
    }
    o
}

/// Append-encode the spans of one traced request: the span list plus
/// `critical_path_us`, the sum over top-level spans (children and
/// annotations excluded) that tracks the request's wall-clock latency.
pub fn encode_trace_spans_into(request_id: u64, spans: &[Span], out: &mut String) {
    let mut o = Json::obj();
    o.set("ok", Json::Bool(true));
    o.set("request_id", Json::Str(request_id.to_string()));
    o.set("spans", Json::Arr(spans.iter().map(encode_span).collect()));
    o.set("critical_path_us", Json::Num(critical_path_us(spans) as f64));
    o.write_compact(out);
}

/// Append-encode the retained slow-request exemplars, keyed by engine.
pub fn encode_trace_exemplars_into(exemplars: &[(String, Vec<Exemplar>)], out: &mut String) {
    let mut o = Json::obj();
    o.set("ok", Json::Bool(true));
    let mut by_engine = Json::obj();
    for (engine, list) in exemplars {
        by_engine.set(
            engine,
            Json::Arr(
                list.iter()
                    .map(|e| {
                        let mut x = Json::obj();
                        x.set("request_id", Json::Str(e.request_id.to_string()));
                        x.set("total_us", Json::Num(e.total_us as f64));
                        x.set("spans", Json::Arr(e.spans.iter().map(encode_span).collect()));
                        x
                    })
                    .collect(),
            ),
        );
    }
    o.set("exemplars", by_engine);
    o.write_compact(out);
}

/// Client-side: decode a successful classify response back into a
/// [`ClassifyResult`] — the inverse of [`encode_result_into`], used by
/// the cluster coordinator to forward worker answers through its own
/// serving loop.  f32 probabilities survive the trip bitwise: they widen
/// exactly to f64, and the JSON writer prints the shortest round-tripping
/// decimal.
pub fn decode_result(j: &Json) -> Result<ClassifyResult> {
    if j.get("ok").and_then(Json::as_bool) != Some(true) {
        return Err(anyhow!("not a successful classify response"));
    }
    let num = |k: &str| -> Result<f64> {
        j.get(k)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("classify response missing numeric '{k}'"))
    };
    let mean_probs: Vec<f32> = j
        .get("mean_probs")
        .and_then(Json::as_f64_vec)
        .ok_or_else(|| anyhow!("classify response missing mean_probs"))?
        .into_iter()
        .map(|x| x as f32)
        .collect();
    let predictive = Predictive {
        mean_probs,
        predicted: num("predicted")? as usize,
        shannon_entropy: num("h")?,
        softmax_entropy: num("se")?,
        mutual_information: num("mi")?,
        agreement: num("agreement")?,
    };
    let decision = match j.get("decision").and_then(Json::as_str) {
        Some("accept") => Decision::Accept {
            class: num("class")? as usize,
            confidence: num("confidence")? as f32,
        },
        Some("reject_ood") => Decision::RejectOod {
            mutual_information: num("mi_trigger")?,
        },
        Some("flag_ambiguous") => Decision::FlagAmbiguous {
            class: num("class")? as usize,
            softmax_entropy: num("se_trigger")?,
        },
        other => return Err(anyhow!("unknown decision {other:?}")),
    };
    Ok(ClassifyResult {
        predictive,
        decision,
        latency_us: num("latency_us")?,
        samples_used: num("samples_used")? as usize,
        degraded: j.get("degraded").and_then(Json::as_bool) == Some(true),
    })
}

/// Client-side: map a coded error response onto the typed [`ServeError`]
/// it came from (`None` for non-lifecycle errors like `unknown_model`).
pub fn decode_serve_error(j: &Json) -> Option<ServeError> {
    let usize_of = |k: &str| j.get(k).and_then(Json::as_usize);
    match j.get("code").and_then(Json::as_str) {
        Some("overloaded") => Some(ServeError::Overloaded {
            retry_after_ms: usize_of("retry_after_ms").unwrap_or(50) as u64,
        }),
        Some("deadline_exceeded") => Some(ServeError::DeadlineExceeded {
            samples_used: usize_of("samples_used").unwrap_or(0),
        }),
        Some("internal_error") => Some(ServeError::Internal {
            detail: j
                .get("error")
                .and_then(Json::as_str)
                .unwrap_or("internal error")
                .to_string(),
        }),
        Some("worker_unavailable") => Some(ServeError::WorkerUnavailable {
            down: usize_of("down").unwrap_or(0),
        }),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::Predictive;

    #[test]
    fn parse_classify_roundtrip() {
        let line = encode_classify("digits", &[0.0, 0.5, 1.0]);
        assert!(line.contains("\"model\""), "{line}");
        match parse_request(&line).unwrap() {
            Request::Classify {
                model,
                image,
                budget,
                deadline_ms,
                plan_seed,
                request_id,
            } => {
                assert_eq!(model, "digits");
                assert_eq!(image, vec![0.0, 0.5, 1.0]);
                assert!(budget.is_default());
                assert_eq!(deadline_ms, None);
                assert_eq!(plan_seed, None);
                assert_eq!(request_id, None);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn dataset_is_a_legacy_alias_and_model_wins() {
        // pre-multi-model clients send `dataset`
        let legacy = "{\"op\":\"classify\",\"dataset\":\"blood\",\"image\":[1]}";
        match parse_request(legacy).unwrap() {
            Request::Classify { model, .. } => assert_eq!(model, "blood"),
            other => panic!("{other:?}"),
        }
        // when both appear, the modern field wins
        let both = "{\"op\":\"classify\",\"model\":\"digits\",\"dataset\":\"blood\",\"image\":[1]}";
        match parse_request(both).unwrap() {
            Request::Classify { model, .. } => assert_eq!(model, "digits"),
            other => panic!("{other:?}"),
        }
        // neither is an error naming the missing field
        let err =
            parse_request("{\"op\":\"classify\",\"image\":[1]}").unwrap_err();
        assert!(err.to_string().contains("model"), "{err}");
    }

    #[test]
    fn parse_budget_fields_roundtrip() {
        let want = RequestBudget {
            max_samples: Some(5),
            target_confidence: Some(0.9),
        };
        let line = encode_classify_with_budget("digits", &[0.1], &want);
        match parse_request(&line).unwrap() {
            Request::Classify { budget, .. } => assert_eq!(budget, want),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn rejects_hostile_budgets_with_typed_errors() {
        let base = "{\"op\":\"classify\",\"dataset\":\"d\",\"image\":[1]";
        let err = parse_request(&format!("{base},\"max_samples\":0}}")).unwrap_err();
        assert!(err.to_string().contains("sample budget"), "{err}");
        // float→usize saturation turns negatives into 0 → same typed error
        assert!(parse_request(&format!("{base},\"max_samples\":-3}}")).is_err());
        let err =
            parse_request(&format!("{base},\"target_confidence\":1.5}}")).unwrap_err();
        assert!(err.to_string().contains("target_confidence"), "{err}");
        assert!(parse_request(&format!("{base},\"target_confidence\":0.2}}")).is_err());
        assert!(
            parse_request(&format!("{base},\"target_confidence\":\"high\"}}")).is_err(),
            "non-numeric confidence rejected"
        );
        // fractional budgets are rejected, not silently floored
        let err = parse_request(&format!("{base},\"max_samples\":3.9}}")).unwrap_err();
        assert!(err.to_string().contains("integer"), "{err}");
        // valid boundary values are accepted
        assert!(parse_request(&format!("{base},\"target_confidence\":0.5}}")).is_ok());
        assert!(parse_request(&format!("{base},\"max_samples\":1}}")).is_ok());
    }

    #[test]
    fn parse_deadline_ms_roundtrip_and_validation() {
        let line = encode_classify_opts("digits", &[0.1], &RequestBudget::default(), Some(250));
        match parse_request(&line).unwrap() {
            Request::Classify { deadline_ms, .. } => assert_eq!(deadline_ms, Some(250)),
            other => panic!("{other:?}"),
        }
        let base = "{\"op\":\"classify\",\"dataset\":\"d\",\"image\":[1]";
        // 0, negatives, fractions, and non-numbers are boundary errors
        for bad in ["0", "-5", "1.5", "\"soon\""] {
            let err =
                parse_request(&format!("{base},\"deadline_ms\":{bad}}}")).unwrap_err();
            assert!(err.to_string().contains("deadline_ms"), "{bad}: {err}");
        }
        assert!(parse_request(&format!("{base},\"deadline_ms\":1}}")).is_ok());
    }

    #[test]
    fn serve_errors_encode_typed_codes_and_hints() {
        let mut s = String::new();
        encode_serve_error_into(&ServeError::Overloaded { retry_after_ms: 40 }, &mut s);
        let j = crate::util::json::parse(&s).unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(j.get("code").unwrap().as_str(), Some("overloaded"));
        assert_eq!(j.get("retry_after_ms").unwrap().as_usize(), Some(40));

        s.clear();
        encode_serve_error_into(&ServeError::DeadlineExceeded { samples_used: 7 }, &mut s);
        let j = crate::util::json::parse(&s).unwrap();
        assert_eq!(j.get("code").unwrap().as_str(), Some("deadline_exceeded"));
        assert_eq!(j.get("samples_used").unwrap().as_usize(), Some(7));

        s.clear();
        encode_serve_error_into(
            &ServeError::Internal {
                detail: "boom".into(),
            },
            &mut s,
        );
        let j = crate::util::json::parse(&s).unwrap();
        assert_eq!(j.get("code").unwrap().as_str(), Some("internal_error"));
        assert!(j.get("retry_after_ms").is_none());
    }

    #[test]
    fn encode_info_reports_serving_counters() {
        let snap = ServeSnapshot {
            requests_shed: 4,
            deadline_expired: 2,
            overload_rejects: 2,
            panics_recovered: 1,
            queue_depth: 3,
            p95_us: 800.0,
            ..ServeSnapshot::default()
        };
        let line = encode_info(&["digits"], &[], &[], &[("digits".to_string(), snap)], &[], &[]);
        let j = crate::util::json::parse(&line).unwrap();
        let s = j.get("serving").unwrap().get("digits").unwrap();
        assert_eq!(s.get("requests_shed").unwrap().as_usize(), Some(4));
        assert_eq!(s.get("p95_us").unwrap().as_f64(), Some(800.0));
        assert_eq!(s.get("deadline_expired").unwrap().as_usize(), Some(2));
        assert_eq!(s.get("overload_rejects").unwrap().as_usize(), Some(2));
        assert_eq!(s.get("panics_recovered").unwrap().as_usize(), Some(1));
        assert_eq!(s.get("queue_depth").unwrap().as_usize(), Some(3));
    }

    #[test]
    fn parse_info_and_ping() {
        assert_eq!(parse_request("{\"op\":\"info\"}").unwrap(), Request::Info);
        assert_eq!(parse_request("{\"op\":\"ping\"}").unwrap(), Request::Ping);
    }

    #[test]
    fn hello_handshake_roundtrip() {
        let line = encode_hello("coordinator");
        assert_eq!(
            parse_request(&line).unwrap(),
            Request::Hello {
                role: "coordinator".into()
            }
        );
        // role defaults to "client" when omitted
        assert_eq!(
            parse_request("{\"op\":\"hello\"}").unwrap(),
            Request::Hello {
                role: "client".into()
            }
        );
        let mut ack = String::new();
        encode_hello_ack_into("worker", &mut ack);
        let j = crate::util::json::parse(&ack).unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("role").unwrap().as_str(), Some("worker"));
    }

    #[test]
    fn plan_seed_rides_as_string_and_survives_u64_range() {
        // a seed above 2^53 — exactly what a JSON number would corrupt
        let seed = u64::MAX - 12345;
        let line = encode_classify_sharded(
            "synth",
            &[0.1, 0.2],
            &RequestBudget::default(),
            Some(100),
            seed,
        );
        match parse_request(&line).unwrap() {
            Request::Classify {
                plan_seed,
                deadline_ms,
                ..
            } => {
                assert_eq!(plan_seed, Some(seed));
                assert_eq!(deadline_ms, Some(100));
            }
            other => panic!("{other:?}"),
        }
        // numeric plan_seed is a boundary error, not silent precision loss
        let bad = "{\"op\":\"classify\",\"model\":\"m\",\"image\":[1],\"plan_seed\":42}";
        assert!(parse_request(bad).is_err());
        let bad = "{\"op\":\"classify\",\"model\":\"m\",\"image\":[1],\"plan_seed\":\"x\"}";
        assert!(parse_request(bad).is_err());
    }

    #[test]
    fn request_id_rides_as_string_and_rejects_zero() {
        let seed = 9;
        let id = u64::MAX - 7; // above 2^53: a JSON number would corrupt it
        let line = encode_classify_sharded_traced(
            "synth",
            &[0.1],
            &RequestBudget::default(),
            None,
            seed,
            id,
        );
        match parse_request(&line).unwrap() {
            Request::Classify {
                plan_seed,
                request_id,
                ..
            } => {
                assert_eq!(plan_seed, Some(seed));
                assert_eq!(request_id, Some(id));
            }
            other => panic!("{other:?}"),
        }
        let base = "{\"op\":\"classify\",\"model\":\"m\",\"image\":[1]";
        // numeric, zero, and garbage ids are boundary errors
        for bad in ["42", "\"0\"", "\"x\""] {
            assert!(
                parse_request(&format!("{base},\"request_id\":{bad}}}")).is_err(),
                "{bad}"
            );
        }
    }

    #[test]
    fn metrics_and_trace_verbs_parse() {
        assert_eq!(parse_request(&encode_metrics_req()).unwrap(), Request::Metrics);
        assert_eq!(
            parse_request(&encode_trace_req(Some(77))).unwrap(),
            Request::Trace {
                request_id: Some(77)
            }
        );
        assert_eq!(
            parse_request(&encode_trace_req(None)).unwrap(),
            Request::Trace { request_id: None }
        );
    }

    #[test]
    fn traced_result_is_plain_result_plus_echo() {
        let pred = Predictive::from_logits(&vec![vec![3.0, 0.0]; 5]);
        let decision = crate::bnn::UncertaintyPolicy::ood_only(0.5).decide(&pred);
        let r = ClassifyResult {
            predictive: pred,
            decision,
            latency_us: 1.0,
            samples_used: 5,
            degraded: false,
        };
        let plain = encode_result(&r);
        let mut traced = String::new();
        encode_result_traced_into(&r, 321, &mut traced);
        // the traced form is the plain bytes plus exactly the echo field
        assert!(traced.starts_with(&plain[..plain.len() - 1]), "{traced}");
        assert!(traced.ends_with(",\"request_id\":\"321\"}"), "{traced}");
        let j = crate::util::json::parse(&traced).unwrap();
        assert_eq!(j.get("request_id").unwrap().as_str(), Some("321"));
    }

    #[test]
    fn encode_trace_spans_reports_critical_path() {
        use crate::observe::Stage;
        let spans = vec![
            Span {
                request_id: 5,
                stage: Stage::Queue,
                index: 0,
                start_us: 0,
                dur_us: 100,
            },
            Span {
                request_id: 5,
                stage: Stage::SampleConv,
                index: 0,
                start_us: 100,
                dur_us: 40,
            },
            Span {
                request_id: 5,
                stage: Stage::Chunk,
                index: 0,
                start_us: 100,
                dur_us: 50,
            },
        ];
        let mut s = String::new();
        encode_trace_spans_into(5, &spans, &mut s);
        let j = crate::util::json::parse(&s).unwrap();
        assert_eq!(j.get("request_id").unwrap().as_str(), Some("5"));
        let arr = j.get("spans").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[0].get("stage").unwrap().as_str(), Some("queue"));
        assert_eq!(arr[1].get("child").unwrap().as_bool(), Some(true));
        assert!(arr[2].get("child").is_none());
        // children are excluded from the critical path: 100 + 50
        assert_eq!(j.get("critical_path_us").unwrap().as_usize(), Some(150));
    }

    #[test]
    fn encode_info_reports_observe_only_when_tracing() {
        let off = TraceStats {
            enabled: false,
            capacity: 0,
            recorded: 0,
            dropped: 0,
            exemplars: 0,
        };
        let line = encode_info(&["m"], &[], &[], &[], &[], &[("m".to_string(), off)]);
        let j = crate::util::json::parse(&line).unwrap();
        assert!(j.get("observe").is_none(), "disabled tracing stays invisible");
        let on = TraceStats {
            enabled: true,
            capacity: 64,
            recorded: 10,
            dropped: 2,
            exemplars: 1,
        };
        let line = encode_info(&["m"], &[], &[], &[], &[], &[("m".to_string(), on)]);
        let j = crate::util::json::parse(&line).unwrap();
        let t = j.get("observe").unwrap().get("m").unwrap();
        assert_eq!(t.get("trace_capacity").unwrap().as_usize(), Some(64));
        assert_eq!(t.get("spans_recorded").unwrap().as_usize(), Some(10));
        assert_eq!(t.get("spans_dropped").unwrap().as_usize(), Some(2));
        assert_eq!(t.get("exemplars").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn decode_result_inverts_encode_bitwise() {
        let pred = Predictive::from_logits(&vec![vec![3.0, 0.7, 0.1]; 5]);
        let decision = crate::bnn::UncertaintyPolicy::ood_only(0.5).decide(&pred);
        let r = ClassifyResult {
            predictive: pred,
            decision,
            latency_us: 123.0,
            samples_used: 5,
            degraded: true,
        };
        let j = crate::util::json::parse(&encode_result(&r)).unwrap();
        let back = decode_result(&j).unwrap();
        let bits = |r: &ClassifyResult| -> Vec<u32> {
            r.predictive.mean_probs.iter().map(|p| p.to_bits()).collect()
        };
        assert_eq!(bits(&r), bits(&back), "f32 probs survive the wire bitwise");
        assert_eq!(back.samples_used, 5);
        assert!(back.degraded);
        assert_eq!(back.predictive.predicted, r.predictive.predicted);
        assert_eq!(back.decision, r.decision);
        // error responses refuse to decode as results
        let err = crate::util::json::parse("{\"ok\":false,\"code\":\"overloaded\"}").unwrap();
        assert!(decode_result(&err).is_err());
    }

    #[test]
    fn decode_serve_error_inverts_encode() {
        let cases = [
            ServeError::Overloaded { retry_after_ms: 40 },
            ServeError::DeadlineExceeded { samples_used: 7 },
            ServeError::WorkerUnavailable { down: 2 },
        ];
        for e in cases {
            let mut s = String::new();
            encode_serve_error_into(&e, &mut s);
            let j = crate::util::json::parse(&s).unwrap();
            assert_eq!(decode_serve_error(&j).as_ref(), Some(&e), "{s}");
        }
        let um = crate::util::json::parse("{\"ok\":false,\"code\":\"unknown_model\"}").unwrap();
        assert!(decode_serve_error(&um).is_none());
    }

    #[test]
    fn worker_unavailable_encodes_down_count() {
        let mut s = String::new();
        encode_serve_error_into(&ServeError::WorkerUnavailable { down: 2 }, &mut s);
        let j = crate::util::json::parse(&s).unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(j.get("code").unwrap().as_str(), Some("worker_unavailable"));
        assert_eq!(j.get("down").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn encode_info_reports_cluster_cards() {
        use crate::cluster::WorkerState;
        let card = WorkerCard {
            addr: "127.0.0.1:7979".into(),
            state: WorkerState::Suspect,
            consecutive_fails: 1,
            latency_ewma_us: 850.0,
            entropy_degraded: true,
            p50_us: 400.0,
            p95_us: 900.0,
            p99_us: 1200.0,
        };
        let line = encode_info(
            &["synth"],
            &[],
            &[],
            &[],
            &[("cluster".to_string(), vec![card])],
            &[],
        );
        let j = crate::util::json::parse(&line).unwrap();
        let cards = j
            .get("cluster")
            .unwrap()
            .get("cluster")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(cards.len(), 1);
        assert_eq!(cards[0].get("state").unwrap().as_str(), Some("suspect"));
        assert_eq!(cards[0].get("entropy_degraded").unwrap().as_bool(), Some(true));
        assert_eq!(cards[0].get("p95_us").unwrap().as_f64(), Some(900.0));
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_request("{}").is_err());
        assert!(parse_request("{\"op\":\"classify\"}").is_err());
        assert!(parse_request("not json").is_err());
        assert!(parse_request("{\"op\":\"classify\",\"dataset\":\"d\",\"image\":\"x\"}").is_err());
    }

    #[test]
    fn rejects_oversized_image_with_clear_error() {
        let image = vec![0.0f32; MAX_IMAGE_LEN + 1];
        let line = encode_classify("digits", &image);
        let err = parse_request(&line).unwrap_err();
        assert!(err.to_string().contains("protocol cap"), "{err}");
        // the boundary itself is accepted
        let ok = encode_classify("digits", &vec![0.0f32; 784]);
        assert!(parse_request(&ok).is_ok());
    }

    #[test]
    fn encode_result_has_metrics() {
        let pred = Predictive::from_logits(&vec![vec![3.0, 0.0]; 5]);
        let decision = crate::bnn::UncertaintyPolicy::ood_only(0.5).decide(&pred);
        let mut r = ClassifyResult {
            predictive: pred,
            decision,
            latency_us: 123.0,
            samples_used: 5,
            degraded: false,
        };
        let line = encode_result(&r);
        let j = crate::util::json::parse(&line).unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("decision").unwrap().as_str(), Some("accept"));
        assert_eq!(j.get("class").unwrap().as_usize(), Some(0));
        assert!(j.get("mi").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(j.get("samples_used").unwrap().as_usize(), Some(5));
        // healthy responses carry no degraded flag at all
        assert!(j.get("degraded").is_none());
        r.degraded = true;
        let j = crate::util::json::parse(&encode_result(&r)).unwrap();
        assert_eq!(j.get("degraded").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn encode_info_reports_health_scorecards() {
        // no monitors -> no entropy_health object at all
        let plain = encode_info(&["digits"], &[], &[], &[], &[], &[]);
        let j = crate::util::json::parse(&plain).unwrap();
        assert!(j.get("entropy_health").is_none());
        assert!(j.get("registry").is_none());
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
        // model list appears under both the modern and the legacy key
        assert_eq!(j.get("models").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(j.get("datasets").unwrap().as_arr().unwrap().len(), 1);

        let card = Scorecard {
            shard: 1,
            stream: "pho-s1".into(),
            windows: 4,
            score_ewma: 0.25,
            last_score: 0.2,
            consecutive_fails: 3,
            min_entropy: 0.41,
            serial_corr: 0.6,
            degraded: true,
        };
        let line = encode_info(
            &["digits"],
            &[("digits".to_string(), vec![card])],
            &[],
            &[],
            &[],
            &[],
        );
        let j = crate::util::json::parse(&line).unwrap();
        let cards = j
            .get("entropy_health")
            .unwrap()
            .get("digits")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(cards.len(), 1);
        let c = &cards[0];
        assert_eq!(c.get("shard").unwrap().as_usize(), Some(1));
        assert_eq!(c.get("stream").unwrap().as_str(), Some("pho-s1"));
        assert_eq!(c.get("windows").unwrap().as_usize(), Some(4));
        assert_eq!(c.get("score_ewma").unwrap().as_f64(), Some(0.25));
        assert_eq!(c.get("consecutive_fails").unwrap().as_usize(), Some(3));
        assert_eq!(c.get("degraded").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn encode_error_flagged_not_ok() {
        let j = crate::util::json::parse(&encode_error("boom")).unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(j.get("error").unwrap().as_str(), Some("boom"));
    }

    #[test]
    fn coded_error_carries_machine_readable_code() {
        let mut s = String::new();
        encode_error_coded_into("unknown_model", "unknown model 'x'", &mut s);
        let j = crate::util::json::parse(&s).unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(j.get("code").unwrap().as_str(), Some("unknown_model"));
        assert_eq!(j.get("error").unwrap().as_str(), Some("unknown model 'x'"));
    }

    #[test]
    fn encode_info_reports_model_registry() {
        use crate::registry::{ModelCardSnapshot, Residency};
        let snap = RegistrySnapshot {
            budget_bytes: 1024,
            resident_bytes: 512,
            hits: 3,
            misses: 2,
            switches: 5,
            evictions: 1,
            models: vec![
                ModelCardSnapshot {
                    model: "blood".into(),
                    state: Residency::Evicted,
                    bytes: 0,
                    hits: 1,
                    misses: 1,
                    switches_in: 2,
                },
                ModelCardSnapshot {
                    model: "digits".into(),
                    state: Residency::Active,
                    bytes: 512,
                    hits: 2,
                    misses: 1,
                    switches_in: 3,
                },
            ],
        };
        let line = encode_info(
            &["blood", "digits"],
            &[],
            &[("digits".to_string(), snap)],
            &[],
            &[],
            &[],
        );
        let j = crate::util::json::parse(&line).unwrap();
        let r = j.get("registry").unwrap().get("digits").unwrap();
        assert_eq!(r.get("budget_bytes").unwrap().as_usize(), Some(1024));
        assert_eq!(r.get("resident_bytes").unwrap().as_usize(), Some(512));
        assert_eq!(r.get("hits").unwrap().as_usize(), Some(3));
        assert_eq!(r.get("switches").unwrap().as_usize(), Some(5));
        let cards = r.get("models").unwrap().as_arr().unwrap();
        assert_eq!(cards.len(), 2);
        assert_eq!(cards[0].get("model").unwrap().as_str(), Some("blood"));
        assert_eq!(cards[0].get("state").unwrap().as_str(), Some("evicted"));
        assert_eq!(cards[1].get("state").unwrap().as_str(), Some("active"));
        assert_eq!(cards[1].get("bytes").unwrap().as_usize(), Some(512));
    }
}
