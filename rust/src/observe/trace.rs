//! Lock-free per-request span recorder.
//!
//! A [`TraceRecorder`] is a fixed ring of atomic slots following the
//! `AtomicLatencyHistogram` discipline: the steady-state record path is
//! a handful of relaxed stores plus one `fetch_add`, with zero
//! allocation.  Writers claim a slot with `head.fetch_add` (wrap
//! overwrites the oldest span) and publish by storing the request id
//! last with `Release`; readers load the id with `Acquire` and accept
//! that a concurrently rewritten slot can yield a torn span — this is a
//! telemetry surface, not an invariant, and the race window is one slot
//! out of thousands.
//!
//! Span taxonomy: `admission`, `queue`, `batch_form`, `chunk[k]`, and
//! `respond` are disjoint top-level stages whose durations sum to
//! wall-clock request latency; `sample_conv[k]` and `fwd_post[k]` are
//! children nested inside `chunk[k]`; `failover`/`hedge`/`fallback` are
//! cluster-event annotations.  Children and annotations are excluded
//! from the top-level sum.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::ObserveConfig;

/// Request lifecycle stage of a recorded span.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Admission control at submit (cost estimate + budget check).
    Admission,
    /// Enqueued, waiting for the batcher to pick the request up.
    Queue,
    /// Inside the batcher's collection window.
    BatchForm,
    /// One adaptive sampling chunk (covers its children).
    Chunk,
    /// Probabilistic convolution passes of one chunk (child of `Chunk`).
    SampleConv,
    /// Forward post-processing of one chunk (child of `Chunk`).
    FwdPost,
    /// Gateway response encode after the reply arrived.
    Respond,
    /// Cluster annotation: a worker attempt failed and was retried.
    Failover,
    /// Cluster annotation: a hedge request was launched.
    Hedge,
    /// Cluster annotation: served by the coordinator's local fallback.
    Fallback,
}

impl Stage {
    pub fn name(self) -> &'static str {
        match self {
            Stage::Admission => "admission",
            Stage::Queue => "queue",
            Stage::BatchForm => "batch_form",
            Stage::Chunk => "chunk",
            Stage::SampleConv => "sample_conv",
            Stage::FwdPost => "fwd_post",
            Stage::Respond => "respond",
            Stage::Failover => "failover",
            Stage::Hedge => "hedge",
            Stage::Fallback => "fallback",
        }
    }

    fn code(self) -> u8 {
        match self {
            Stage::Admission => 0,
            Stage::Queue => 1,
            Stage::BatchForm => 2,
            Stage::Chunk => 3,
            Stage::SampleConv => 4,
            Stage::FwdPost => 5,
            Stage::Respond => 6,
            Stage::Failover => 7,
            Stage::Hedge => 8,
            Stage::Fallback => 9,
        }
    }

    fn from_code(c: u8) -> Option<Stage> {
        Some(match c {
            0 => Stage::Admission,
            1 => Stage::Queue,
            2 => Stage::BatchForm,
            3 => Stage::Chunk,
            4 => Stage::SampleConv,
            5 => Stage::FwdPost,
            6 => Stage::Respond,
            7 => Stage::Failover,
            8 => Stage::Hedge,
            9 => Stage::Fallback,
            _ => return None,
        })
    }

    /// Child spans nest inside a `chunk` span (excluded from the
    /// disjoint top-level sum).
    pub fn is_child(self) -> bool {
        matches!(self, Stage::SampleConv | Stage::FwdPost)
    }

    /// Cluster-event annotations (excluded from the top-level sum).
    pub fn is_annotation(self) -> bool {
        matches!(self, Stage::Failover | Stage::Hedge | Stage::Fallback)
    }
}

/// One recorded span, decoded from the ring or a retained exemplar.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Span {
    pub request_id: u64,
    pub stage: Stage,
    /// Chunk index `k` for chunked stages; worker index for cluster
    /// annotations; 0 otherwise.
    pub index: u16,
    /// Start offset from the recorder's epoch, microseconds.
    pub start_us: u64,
    pub dur_us: u64,
}

/// Sum of top-level span durations (children/annotations excluded) —
/// the disjoint account of wall-clock latency.
pub fn critical_path_us(spans: &[Span]) -> u64 {
    spans
        .iter()
        .filter(|s| !s.stage.is_child() && !s.stage.is_annotation())
        .map(|s| s.dur_us)
        .sum()
}

/// Slow-request exemplar: the full span set retained verbatim at
/// respond time.
#[derive(Debug, Clone)]
pub struct Exemplar {
    pub request_id: u64,
    pub total_us: u64,
    pub spans: Vec<Span>,
}

/// Point-in-time recorder statistics (for `/info` and `/metrics`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TraceStats {
    pub enabled: bool,
    pub capacity: usize,
    /// Spans ever recorded (including those since overwritten).
    pub recorded: u64,
    /// Spans overwritten by ring wrap.
    pub dropped: u64,
    pub exemplars: usize,
}

struct Slot {
    /// 0 = empty or mid-write; stored last by the writer (`Release`).
    id: AtomicU64,
    /// stage code (low 8 bits) | index << 8.
    meta: AtomicU64,
    start_us: AtomicU64,
    dur_us: AtomicU64,
}

impl Slot {
    fn new() -> Self {
        Slot {
            id: AtomicU64::new(0),
            meta: AtomicU64::new(0),
            start_us: AtomicU64::new(0),
            dur_us: AtomicU64::new(0),
        }
    }
}

/// Lock-free per-request span ring (see module docs).
pub struct TraceRecorder {
    enabled: bool,
    epoch: Instant,
    slots: Box<[Slot]>,
    head: AtomicU64,
    dropped: AtomicU64,
    next_id: AtomicU64,
    slow_us: u64,
    max_exemplars: usize,
    exemplars: Mutex<VecDeque<Exemplar>>,
}

impl std::fmt::Debug for TraceRecorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraceRecorder")
            .field("enabled", &self.enabled)
            .field("capacity", &self.slots.len())
            .finish()
    }
}

impl TraceRecorder {
    pub fn new(cfg: &ObserveConfig) -> Self {
        let cap = if cfg.trace {
            cfg.trace_capacity.max(8)
        } else {
            0
        };
        TraceRecorder {
            enabled: cfg.trace,
            epoch: Instant::now(),
            slots: (0..cap).map(|_| Slot::new()).collect(),
            head: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            next_id: AtomicU64::new(1),
            slow_us: cfg.slow_ms.saturating_mul(1000),
            max_exemplars: cfg.exemplars,
            exemplars: Mutex::new(VecDeque::new()),
        }
    }

    /// A recorder that records nothing (tracing off): every call is a
    /// cheap no-op, so the untraced hot path stays untouched.
    pub fn disabled() -> Self {
        Self::new(&ObserveConfig::default())
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Mint a nonzero request id (gateway-side, for clients that did
    /// not supply one).
    pub fn mint_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Record one span.  `request_id == 0` means untraced; disabled
    /// recorders drop everything.
    pub fn record(&self, request_id: u64, stage: Stage, index: u16, start: Instant, dur: Duration) {
        if !self.enabled || request_id == 0 {
            return;
        }
        let claim = self.head.fetch_add(1, Ordering::Relaxed);
        let cap = self.slots.len() as u64;
        if claim >= cap {
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        let slot = &self.slots[(claim % cap) as usize];
        let start_us = start.saturating_duration_since(self.epoch).as_micros() as u64;
        // invalidate, mutate, then publish the id last
        slot.id.store(0, Ordering::Release);
        slot.meta
            .store(stage.code() as u64 | (index as u64) << 8, Ordering::Relaxed);
        slot.start_us.store(start_us, Ordering::Relaxed);
        slot.dur_us.store(dur.as_micros() as u64, Ordering::Relaxed);
        slot.id.store(request_id, Ordering::Release);
    }

    fn scan_ring(&self, request_id: u64) -> Vec<Span> {
        let mut out = Vec::new();
        for slot in self.slots.iter() {
            if slot.id.load(Ordering::Acquire) != request_id {
                continue;
            }
            let meta = slot.meta.load(Ordering::Relaxed);
            let Some(stage) = Stage::from_code((meta & 0xff) as u8) else {
                continue;
            };
            out.push(Span {
                request_id,
                stage,
                index: ((meta >> 8) & 0xffff) as u16,
                start_us: slot.start_us.load(Ordering::Relaxed),
                dur_us: slot.dur_us.load(Ordering::Relaxed),
            });
        }
        out
    }

    /// All spans recorded for `request_id` — live ring first, falling
    /// back to a retained exemplar once the ring has wrapped past the
    /// request.  Sorted by start time.
    pub fn spans_for(&self, request_id: u64) -> Vec<Span> {
        if request_id == 0 {
            return Vec::new();
        }
        let mut out = self.scan_ring(request_id);
        if out.is_empty() {
            if let Ok(ex) = self.exemplars.lock() {
                if let Some(e) = ex.iter().find(|e| e.request_id == request_id) {
                    out = e.spans.clone();
                }
            }
        }
        out.sort_by_key(|s| (s.start_us, s.start_us + s.dur_us));
        out
    }

    /// Retain a verbatim exemplar if the request's wall-clock exceeded
    /// the slow threshold (`slow_ms = 0` captures every traced
    /// request).  Called at respond time, off the steady-state path.
    pub fn maybe_capture_exemplar(&self, request_id: u64, total: Duration) {
        if !self.enabled || request_id == 0 {
            return;
        }
        let total_us = total.as_micros() as u64;
        if total_us < self.slow_us {
            return;
        }
        let mut spans = self.scan_ring(request_id);
        if spans.is_empty() {
            return;
        }
        spans.sort_by_key(|s| (s.start_us, s.start_us + s.dur_us));
        if let Ok(mut ex) = self.exemplars.lock() {
            ex.retain(|e| e.request_id != request_id);
            ex.push_back(Exemplar {
                request_id,
                total_us,
                spans,
            });
            while ex.len() > self.max_exemplars {
                ex.pop_front();
            }
        }
    }

    pub fn exemplars(&self) -> Vec<Exemplar> {
        self.exemplars
            .lock()
            .map(|ex| ex.iter().cloned().collect())
            .unwrap_or_default()
    }

    pub fn stats(&self) -> TraceStats {
        TraceStats {
            enabled: self.enabled,
            capacity: self.slots.len(),
            recorded: self.head.load(Ordering::Relaxed),
            dropped: self.dropped.load(Ordering::Relaxed),
            exemplars: self.exemplars.lock().map(|e| e.len()).unwrap_or(0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(capacity: usize, slow_ms: u64, exemplars: usize) -> ObserveConfig {
        ObserveConfig {
            trace: true,
            trace_capacity: capacity,
            slow_ms,
            exemplars,
        }
    }

    #[test]
    fn records_and_reads_back_spans() {
        let r = TraceRecorder::new(&cfg(64, 1000, 4));
        let t0 = Instant::now();
        r.record(7, Stage::Queue, 0, t0, Duration::from_micros(100));
        r.record(7, Stage::Chunk, 2, t0, Duration::from_micros(300));
        r.record(9, Stage::Queue, 0, t0, Duration::from_micros(50));
        let spans = r.spans_for(7);
        assert_eq!(spans.len(), 2);
        assert!(spans.iter().any(|s| s.stage == Stage::Chunk && s.index == 2));
        assert_eq!(r.spans_for(9).len(), 1);
        assert!(r.spans_for(12345).is_empty());
        assert!(r.spans_for(0).is_empty());
    }

    #[test]
    fn ring_wrap_overwrites_and_counts_drops() {
        let r = TraceRecorder::new(&cfg(8, 1000, 0));
        let t0 = Instant::now();
        for i in 1..=20u64 {
            r.record(i, Stage::Chunk, 0, t0, Duration::from_micros(1));
        }
        let s = r.stats();
        assert_eq!(s.recorded, 20);
        assert_eq!(s.dropped, 12);
        // the oldest ids have been overwritten, the newest survive
        assert!(r.spans_for(1).is_empty());
        assert_eq!(r.spans_for(20).len(), 1);
    }

    #[test]
    fn disabled_recorder_is_a_no_op() {
        let r = TraceRecorder::disabled();
        assert!(!r.enabled());
        r.record(1, Stage::Queue, 0, Instant::now(), Duration::from_micros(5));
        assert!(r.spans_for(1).is_empty());
        assert_eq!(r.stats().recorded, 0);
        r.maybe_capture_exemplar(1, Duration::from_secs(10));
        assert!(r.exemplars().is_empty());
    }

    #[test]
    fn mint_ids_are_nonzero_and_distinct() {
        let r = TraceRecorder::new(&cfg(8, 1000, 0));
        let a = r.mint_id();
        let b = r.mint_id();
        assert_ne!(a, 0);
        assert_ne!(b, 0);
        assert_ne!(a, b);
    }

    #[test]
    fn exemplar_captured_over_threshold_only() {
        let r = TraceRecorder::new(&cfg(64, 100, 4));
        let t0 = Instant::now();
        r.record(5, Stage::Chunk, 0, t0, Duration::from_micros(200));
        r.maybe_capture_exemplar(5, Duration::from_millis(50));
        assert!(r.exemplars().is_empty(), "under threshold");
        r.maybe_capture_exemplar(5, Duration::from_millis(200));
        let ex = r.exemplars();
        assert_eq!(ex.len(), 1);
        assert_eq!(ex[0].request_id, 5);
        assert_eq!(ex[0].spans.len(), 1);
    }

    #[test]
    fn exemplar_survives_ring_wrap_and_fifo_evicts() {
        let r = TraceRecorder::new(&cfg(8, 0, 2));
        let t0 = Instant::now();
        for id in 1..=4u64 {
            r.record(id, Stage::Chunk, 0, t0, Duration::from_micros(10));
            r.maybe_capture_exemplar(id, Duration::from_micros(10));
        }
        // FIFO cap of 2: only the last two exemplars survive
        let ids: Vec<u64> = r.exemplars().iter().map(|e| e.request_id).collect();
        assert_eq!(ids, vec![3, 4]);
        // wrap the ring past id 3, then spans_for falls back to the exemplar
        for i in 100..120u64 {
            r.record(i, Stage::Queue, 0, t0, Duration::from_micros(1));
        }
        assert!(!r.spans_for(3).is_empty(), "exemplar fallback");
    }

    #[test]
    fn critical_path_excludes_children_and_annotations() {
        let sp = |stage, dur_us| Span {
            request_id: 1,
            stage,
            index: 0,
            start_us: 0,
            dur_us,
        };
        let spans = vec![
            sp(Stage::Admission, 10),
            sp(Stage::Queue, 20),
            sp(Stage::BatchForm, 30),
            sp(Stage::Chunk, 400),
            sp(Stage::SampleConv, 350),
            sp(Stage::FwdPost, 40),
            sp(Stage::Failover, 999),
            sp(Stage::Respond, 5),
        ];
        assert_eq!(critical_path_us(&spans), 10 + 20 + 30 + 400 + 5);
    }
}
