//! Observability: per-request tracing, uncertainty telemetry, and
//! Prometheus-style metrics exposition.
//!
//! Three surfaces, one subsystem:
//!
//! - [`trace::TraceRecorder`] — a lock-free ring of per-request spans
//!   (`admission → queue → batch_form → chunk[k] → respond`, with
//!   `sample_conv`/`fwd_post` nested as children of each chunk and
//!   cluster events — failover, hedge, local fallback — annotated),
//!   keyed by a `request_id` minted at the gateway or supplied by the
//!   client, and forwarded coordinator → worker so a failed-over or
//!   hedged request stitches into one trace across hops.
//! - [`stats::UncertaintyTelemetry`] — running fixed-bucket histograms
//!   of predictive entropy, mutual information, and `samples_used` per
//!   model, so OOD drift is visible operationally, not just per-reply.
//! - [`prom::render`] — one Prometheus text-format scrape surface
//!   (`{"op":"metrics"}`) over serving counters, latency histograms,
//!   registry/health/cluster state, trace stats, and the uncertainty
//!   histograms; [`expo::lint`] is a minimal in-repo checker for the
//!   exposition format, wired into CI against a live server.
//!
//! Tracing never alters outputs: responses are bitwise identical with
//! tracing on or off (a `request_id` is echoed only when the client
//! supplied one), and the `(model, seed, threads, prefetch, rule,
//! placement)` replay contract is untouched — instrumentation records
//! stage timestamps and nothing else.

pub mod buckets;
pub mod expo;
pub mod prom;
pub mod stats;
pub mod trace;

pub use stats::{HistSnapshot, UncertaintySnapshot, UncertaintyStats, UncertaintyTelemetry};
pub use trace::{critical_path_us, Exemplar, Span, Stage, TraceRecorder, TraceStats};

/// Tracing configuration (the `[observe]` config table / `--trace` flags).
#[derive(Debug, Clone)]
pub struct ObserveConfig {
    /// Record spans (off by default; recording is cheap but not free).
    pub trace: bool,
    /// Ring capacity in spans (the oldest spans are overwritten).
    pub trace_capacity: usize,
    /// Requests slower than this retain a verbatim span exemplar;
    /// `0` captures every traced request.
    pub slow_ms: u64,
    /// Maximum retained exemplars (FIFO eviction).
    pub exemplars: usize,
}

impl Default for ObserveConfig {
    fn default() -> Self {
        Self {
            trace: false,
            trace_capacity: 4096,
            slow_ms: 250,
            exemplars: 32,
        }
    }
}

impl ObserveConfig {
    /// Tracing on with defaults (tests, benches).
    pub fn enabled() -> Self {
        Self {
            trace: true,
            ..Self::default()
        }
    }
}
