//! Minimal Prometheus text-exposition (0.0.4) format checker.
//!
//! Used by CI to lint the live `/metrics` scrape (`pbm scrape --lint`)
//! and by tests against [`super::prom::render`].  Checks the subset of
//! the format this crate emits: metric/label name grammar, HELP/TYPE
//! placement, family contiguity, value parseability, duplicate series,
//! and histogram shape (ascending `le`, terminal `+Inf`, cumulative
//! bucket counts, `_count` consistency).

use std::collections::{BTreeMap, BTreeSet};

/// Lint `text`; returns a list of violations (empty = clean).
pub fn lint(text: &str) -> Vec<String> {
    let mut errs = Vec::new();
    // family name -> declared type ("counter" | "gauge" | "histogram" | ...)
    let mut types: BTreeMap<String, String> = BTreeMap::new();
    let mut helped: BTreeSet<String> = BTreeSet::new();
    let mut closed: BTreeSet<String> = BTreeSet::new();
    let mut current: Option<String> = None;
    let mut seen_series: BTreeSet<String> = BTreeSet::new();
    // (family, labels-minus-le) -> [(le, value)]
    let mut buckets: BTreeMap<(String, String), Vec<(f64, f64)>> = BTreeMap::new();
    let mut counts: BTreeMap<(String, String), f64> = BTreeMap::new();

    for (ln, raw) in text.lines().enumerate() {
        let n = ln + 1;
        let line = raw.trim_end();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let name = rest.split_whitespace().next().unwrap_or("");
            check_metric_name(name, n, &mut errs);
            if !helped.insert(name.to_string()) {
                errs.push(format!("line {n}: duplicate HELP for '{name}'"));
            }
            if types.contains_key(name) {
                errs.push(format!("line {n}: HELP for '{name}' after its TYPE"));
            }
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it.next().unwrap_or("");
            let kind = it.next().unwrap_or("");
            check_metric_name(name, n, &mut errs);
            if !matches!(kind, "counter" | "gauge" | "histogram" | "summary" | "untyped") {
                errs.push(format!("line {n}: unknown TYPE '{kind}' for '{name}'"));
            }
            if types.insert(name.to_string(), kind.to_string()).is_some() {
                errs.push(format!("line {n}: duplicate TYPE for '{name}'"));
            }
            if closed.contains(name) {
                errs.push(format!("line {n}: family '{name}' reopened"));
            }
            if let Some(prev) = current.replace(name.to_string()) {
                closed.insert(prev);
            }
            continue;
        }
        if line.starts_with('#') {
            continue; // plain comment
        }

        // sample line: name[{labels}] value
        let (series, value) = match split_sample(line) {
            Ok(v) => v,
            Err(e) => {
                errs.push(format!("line {n}: {e}"));
                continue;
            }
        };
        let (name, labels) = match split_labels(&series) {
            Ok(v) => v,
            Err(e) => {
                errs.push(format!("line {n}: {e}"));
                continue;
            }
        };
        check_metric_name(&name, n, &mut errs);
        for (k, _) in &labels {
            if !is_label_name(k) {
                errs.push(format!("line {n}: invalid label name '{k}'"));
            }
        }
        if value.parse::<f64>().is_err()
            && !matches!(value.as_str(), "+Inf" | "-Inf" | "NaN")
        {
            errs.push(format!("line {n}: unparseable value '{value}'"));
        }
        if !seen_series.insert(series.clone()) {
            errs.push(format!("line {n}: duplicate series '{series}'"));
        }

        // resolve the owning family (histograms own _bucket/_sum/_count)
        let family = ["_bucket", "_sum", "_count"]
            .iter()
            .find_map(|suf| {
                name.strip_suffix(suf)
                    .filter(|base| types.get(*base).map(String::as_str) == Some("histogram"))
                    .map(str::to_string)
            })
            .unwrap_or_else(|| name.clone());
        match types.get(&family) {
            None => errs.push(format!("line {n}: sample '{name}' has no TYPE")),
            Some(kind) => {
                if current.as_deref() != Some(family.as_str()) {
                    errs.push(format!(
                        "line {n}: sample '{name}' outside its family group '{family}'"
                    ));
                }
                if kind == "histogram" {
                    let rest: Vec<(String, String)> = labels
                        .iter()
                        .filter(|(k, _)| k != "le")
                        .cloned()
                        .collect();
                    let key = (family.clone(), format!("{rest:?}"));
                    if name.ends_with("_bucket") {
                        match labels.iter().find(|(k, _)| k == "le") {
                            None => errs.push(format!("line {n}: bucket without 'le' label")),
                            Some((_, le)) => {
                                let edge = if le == "+Inf" {
                                    f64::INFINITY
                                } else {
                                    le.parse::<f64>().unwrap_or(f64::NAN)
                                };
                                let v = value.parse::<f64>().unwrap_or(f64::NAN);
                                buckets.entry(key).or_default().push((edge, v));
                            }
                        }
                    } else if name.ends_with("_count") {
                        counts.insert(key, value.parse::<f64>().unwrap_or(f64::NAN));
                    }
                } else if name != family {
                    errs.push(format!(
                        "line {n}: sample '{name}' does not match {kind} family '{family}'"
                    ));
                }
            }
        }
    }

    for ((family, labels), series) in &buckets {
        let ctx = format!("histogram '{family}' {labels}");
        if series.windows(2).any(|w| w[0].0 >= w[1].0) {
            errs.push(format!("{ctx}: 'le' edges not strictly ascending"));
        }
        if series.last().map(|(e, _)| *e) != Some(f64::INFINITY) {
            errs.push(format!("{ctx}: missing terminal le=\"+Inf\" bucket"));
        }
        if series.windows(2).any(|w| w[0].1 > w[1].1) {
            errs.push(format!("{ctx}: bucket counts not cumulative"));
        }
        if let (Some((_, inf)), Some(total)) =
            (series.last(), counts.get(&(family.clone(), labels.clone())))
        {
            if (inf - total).abs() > 0.0 {
                errs.push(format!("{ctx}: +Inf bucket {inf} != _count {total}"));
            }
        }
    }
    errs
}

fn check_metric_name(name: &str, line: usize, errs: &mut Vec<String>) {
    let ok = !name.is_empty()
        && name
            .chars()
            .next()
            .is_some_and(|c| c.is_ascii_alphabetic() || c == '_' || c == ':')
        && name
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':');
    if !ok {
        errs.push(format!("line {line}: invalid metric name '{name}'"));
    }
}

fn is_label_name(name: &str) -> bool {
    !name.is_empty()
        && name
            .chars()
            .next()
            .is_some_and(|c| c.is_ascii_alphabetic() || c == '_')
        && name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_')
}

/// Split a sample line into (series, value); series keeps its labels.
fn split_sample(line: &str) -> Result<(String, String), String> {
    // the value is the last whitespace-separated token *outside* braces
    let split_at = match line.find('{') {
        Some(ob) => {
            let cb = line[ob..]
                .find('}')
                .map(|i| ob + i)
                .ok_or_else(|| "unterminated label block".to_string())?;
            cb + 1
        }
        None => line
            .find(char::is_whitespace)
            .ok_or_else(|| "sample without value".to_string())?,
    };
    let series = line[..split_at].trim().to_string();
    let value = line[split_at..].trim();
    if value.is_empty() {
        return Err("sample without value".to_string());
    }
    // optional timestamp would be a second token; this crate never emits one
    let value = value.split_whitespace().next().unwrap_or("").to_string();
    Ok((series, value))
}

/// Split a series into (metric name, label pairs).
fn split_labels(series: &str) -> Result<(String, Vec<(String, String)>), String> {
    let Some(ob) = series.find('{') else {
        return Ok((series.to_string(), Vec::new()));
    };
    if !series.ends_with('}') {
        return Err(format!("malformed label block in '{series}'"));
    }
    let name = series[..ob].to_string();
    let body = &series[ob + 1..series.len() - 1];
    let mut labels = Vec::new();
    let mut rest = body;
    while !rest.is_empty() {
        let eq = rest
            .find('=')
            .ok_or_else(|| format!("label without '=' in '{body}'"))?;
        let key = rest[..eq].trim().to_string();
        let after = &rest[eq + 1..];
        if !after.starts_with('"') {
            return Err(format!("unquoted label value in '{body}'"));
        }
        // scan for the closing quote, honoring backslash escapes
        let mut end = None;
        let mut esc = false;
        for (i, c) in after.char_indices().skip(1) {
            if esc {
                esc = false;
            } else if c == '\\' {
                esc = true;
            } else if c == '"' {
                end = Some(i);
                break;
            }
        }
        let end = end.ok_or_else(|| format!("unterminated label value in '{body}'"))?;
        labels.push((key, after[1..end].to_string()));
        rest = after[end + 1..].trim_start_matches(',');
    }
    Ok((name, labels))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_exposition_passes() {
        let text = "\
# HELP pbm_requests_total Requests served.
# TYPE pbm_requests_total counter
pbm_requests_total{engine=\"digits\"} 42
pbm_requests_total{engine=\"synth\"} 7
# HELP pbm_queue_depth Queue depth.
# TYPE pbm_queue_depth gauge
pbm_queue_depth 3
# TYPE pbm_latency_us histogram
pbm_latency_us_bucket{le=\"2\"} 1
pbm_latency_us_bucket{le=\"4\"} 3
pbm_latency_us_bucket{le=\"+Inf\"} 5
pbm_latency_us_sum 123.5
pbm_latency_us_count 5
";
        assert_eq!(lint(text), Vec::<String>::new());
    }

    #[test]
    fn flags_sample_without_type() {
        let errs = lint("pbm_orphan 1\n");
        assert!(errs.iter().any(|e| e.contains("no TYPE")), "{errs:?}");
    }

    #[test]
    fn flags_bad_names_and_values() {
        let text = "\
# TYPE 9bad counter
9bad 1
# TYPE pbm_ok gauge
pbm_ok{0l=\"x\"} nope
";
        let errs = lint(text);
        assert!(errs.iter().any(|e| e.contains("invalid metric name")), "{errs:?}");
        assert!(errs.iter().any(|e| e.contains("invalid label name")), "{errs:?}");
        assert!(errs.iter().any(|e| e.contains("unparseable value")), "{errs:?}");
    }

    #[test]
    fn flags_histogram_shape_violations() {
        let text = "\
# TYPE h histogram
h_bucket{le=\"4\"} 5
h_bucket{le=\"2\"} 1
h_count 5
";
        let errs = lint(text);
        assert!(errs.iter().any(|e| e.contains("not strictly ascending")), "{errs:?}");
        assert!(errs.iter().any(|e| e.contains("missing terminal")), "{errs:?}");
    }

    #[test]
    fn flags_non_cumulative_buckets_and_count_mismatch() {
        let text = "\
# TYPE h histogram
h_bucket{le=\"2\"} 5
h_bucket{le=\"4\"} 3
h_bucket{le=\"+Inf\"} 6
h_count 9
";
        let errs = lint(text);
        assert!(errs.iter().any(|e| e.contains("not cumulative")), "{errs:?}");
        assert!(errs.iter().any(|e| e.contains("!= _count")), "{errs:?}");
    }

    #[test]
    fn flags_duplicate_series_and_split_family() {
        let text = "\
# TYPE a counter
a 1
a 2
# TYPE b counter
b 1
a 3
";
        let errs = lint(text);
        assert!(errs.iter().any(|e| e.contains("duplicate series")), "{errs:?}");
        assert!(errs.iter().any(|e| e.contains("outside its family group")), "{errs:?}");
    }

    #[test]
    fn escaped_label_values_parse() {
        let (name, labels) =
            split_labels("m{path=\"a\\\"b\",x=\"y\"}").unwrap();
        assert_eq!(name, "m");
        assert_eq!(labels.len(), 2);
        assert_eq!(labels[0].1, "a\\\"b");
    }
}
