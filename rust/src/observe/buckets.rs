//! Shared log2 bucket math for the latency histograms.
//!
//! `LatencyHistogram` (single-threaded, engine metrics) and
//! `AtomicLatencyHistogram` (lock-free, serving layer) use the same
//! geometry — bucket `i` covers `[2^i, 2^(i+1))` microseconds — and the
//! same max-clamped percentile read.  Both delegate here so the
//! semantics can't drift apart again.

/// Bucket count used by both latency histograms (1 us .. ~1 s, 2x).
pub const NUM_BUCKETS: usize = 21;

/// Bucket index for a sample: `floor(log2(us))`, clamped to the table.
#[inline]
pub fn bucket_index(us: f64, num_buckets: usize) -> usize {
    (us.max(1.0).log2() as usize).min(num_buckets - 1)
}

/// Upper edge of bucket `i` in microseconds (`2^(i+1)`).
#[inline]
pub fn bucket_upper_us(i: usize) -> f64 {
    (1u64 << (i + 1)) as f64
}

/// Approximate percentile from bucket counts: walks the cumulative
/// counts to the target rank and reports the bucket's upper edge,
/// clamped to the recorded maximum (the raw edge of the last occupied
/// bucket can be nearly 2x the true max, so an unclamped p95/p100
/// would over-report tail latency).
pub fn percentile_us<I>(counts: I, count: u64, max_us: f64, p: f64) -> f64
where
    I: IntoIterator<Item = u64>,
{
    if count == 0 {
        return 0.0;
    }
    let target = (p / 100.0 * count as f64).ceil() as u64;
    let mut acc = 0u64;
    for (i, c) in counts.into_iter().enumerate() {
        acc += c;
        if acc >= target {
            return bucket_upper_us(i).min(max_us);
        }
    }
    max_us
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest_mini::{self, Config};

    #[test]
    fn index_and_edge_agree() {
        // a sample always lands in a bucket whose upper edge exceeds it
        for us in [1.0, 1.5, 2.0, 700.0, 1e6, 5e8] {
            let i = bucket_index(us, NUM_BUCKETS);
            assert!(bucket_upper_us(i) > us || i == NUM_BUCKETS - 1, "{us}");
        }
        // sub-microsecond samples clamp into the first bucket
        assert_eq!(bucket_index(0.0, NUM_BUCKETS), 0);
        assert_eq!(bucket_index(0.3, NUM_BUCKETS), 0);
    }

    /// Property: `percentile_us` is monotone in `p` and never exceeds
    /// the recorded maximum, for arbitrary recorded samples.
    #[test]
    fn percentile_monotone_and_clamped() {
        let cfg = Config::default();
        proptest_mini::check(
            "percentile_monotone_and_clamped",
            &cfg,
            proptest_mini::vec_f32(1, 200, 0.0, 2.0e6),
            |samples| {
                let mut counts = vec![0u64; NUM_BUCKETS];
                let mut max_us = 0.0f64;
                for &us in samples {
                    let us = us as f64;
                    counts[bucket_index(us, NUM_BUCKETS)] += 1;
                    max_us = max_us.max(us);
                }
                let count = samples.len() as u64;
                let mut prev = 0.0f64;
                for p in [0.0, 10.0, 25.0, 50.0, 75.0, 90.0, 95.0, 99.0, 100.0] {
                    let v = percentile_us(counts.iter().copied(), count, max_us, p);
                    if v < prev {
                        return Err(format!("p{p} = {v} < previous {prev}"));
                    }
                    if v > max_us {
                        return Err(format!("p{p} = {v} exceeds recorded max {max_us}"));
                    }
                    prev = v;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn empty_counts_report_zero() {
        assert_eq!(percentile_us(std::iter::empty(), 0, 0.0, 99.0), 0.0);
    }
}
