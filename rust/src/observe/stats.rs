//! Running fixed-bucket histograms for uncertainty telemetry.
//!
//! The serving stack's uncertainty outputs (predictive entropy, mutual
//! information, `samples_used`) are the product, but until now they
//! were only visible per-reply.  [`UncertaintyTelemetry`] aggregates
//! them per model with lock-free fixed-bucket histograms so OOD drift
//! shows up on the `/metrics` scrape surface: a population shifting
//! into the high-entropy buckets is drift, visible without logging a
//! single request.

use std::sync::atomic::{AtomicU64, Ordering};

/// Upper bounds (nats) for predictive-entropy and mutual-information
/// histograms; ln(10) ≈ 2.3 nats is the 10-class uniform ceiling.
pub const ENTROPY_BOUNDS: &[f64] = &[
    0.001, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 1.5, 2.5,
];

/// Upper bounds for `samples_used` (powers of two, like the budgets).
pub const SAMPLES_BOUNDS: &[f64] = &[1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0];

/// Lock-free histogram over static explicit bounds (the `+Inf` bucket
/// is implicit as the last counter).  Same relaxed-atomics discipline
/// as `AtomicLatencyHistogram`: reads are racy gauges, not invariants.
#[derive(Debug)]
pub struct FixedHistogram {
    bounds: &'static [f64],
    /// `bounds.len() + 1` counters; the last one is the overflow bucket.
    counts: Box<[AtomicU64]>,
    count: AtomicU64,
    /// Sum in millionths (fixed point keeps the add lock-free).
    sum_micro: AtomicU64,
}

impl FixedHistogram {
    pub fn new(bounds: &'static [f64]) -> Self {
        FixedHistogram {
            bounds,
            counts: (0..=bounds.len()).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_micro: AtomicU64::new(0),
        }
    }

    pub fn record(&self, v: f64) {
        let v = if v.is_finite() { v.max(0.0) } else { 0.0 };
        let i = self
            .bounds
            .iter()
            .position(|b| v <= *b)
            .unwrap_or(self.bounds.len());
        self.counts[i].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_micro
            .fetch_add((v * 1e6).round() as u64, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            bounds: self.bounds.to_vec(),
            counts: self
                .counts
                .iter()
                .map(|c| c.load(Ordering::Relaxed))
                .collect(),
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum_micro.load(Ordering::Relaxed) as f64 / 1e6,
        }
    }
}

/// Plain-data copy of a [`FixedHistogram`] (per-bucket counts, the last
/// entry being the overflow bucket).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct HistSnapshot {
    pub bounds: Vec<f64>,
    pub counts: Vec<u64>,
    pub count: u64,
    pub sum: f64,
}

/// Uncertainty histograms for one model.
#[derive(Debug)]
pub struct UncertaintyStats {
    pub entropy: FixedHistogram,
    pub mutual_information: FixedHistogram,
    pub samples_used: FixedHistogram,
}

impl Default for UncertaintyStats {
    fn default() -> Self {
        UncertaintyStats {
            entropy: FixedHistogram::new(ENTROPY_BOUNDS),
            mutual_information: FixedHistogram::new(ENTROPY_BOUNDS),
            samples_used: FixedHistogram::new(SAMPLES_BOUNDS),
        }
    }
}

impl UncertaintyStats {
    pub fn record(&self, entropy: f64, mutual_information: f64, samples_used: u32) {
        self.entropy.record(entropy);
        self.mutual_information.record(mutual_information);
        self.samples_used.record(samples_used as f64);
    }
}

/// Plain-data copy of one model's uncertainty histograms.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct UncertaintySnapshot {
    pub entropy: HistSnapshot,
    pub mutual_information: HistSnapshot,
    pub samples_used: HistSnapshot,
}

/// Per-model uncertainty telemetry.  Models are pre-registered at
/// engine spawn so the record path is lock-free (a linear scan over a
/// handful of names, no map, no lock).
#[derive(Debug, Default)]
pub struct UncertaintyTelemetry {
    models: Vec<(String, UncertaintyStats)>,
}

impl UncertaintyTelemetry {
    pub fn new(models: &[String]) -> Self {
        UncertaintyTelemetry {
            models: models
                .iter()
                .map(|m| (m.clone(), UncertaintyStats::default()))
                .collect(),
        }
    }

    /// Record one served result under `model`; unknown models (never
    /// routed here in practice) are dropped rather than locked in.
    pub fn record(&self, model: &str, entropy: f64, mutual_information: f64, samples_used: u32) {
        if let Some((_, s)) = self.models.iter().find(|(m, _)| m == model) {
            s.record(entropy, mutual_information, samples_used);
        }
    }

    pub fn snapshot(&self) -> Vec<(String, UncertaintySnapshot)> {
        self.models
            .iter()
            .map(|(m, s)| {
                (
                    m.clone(),
                    UncertaintySnapshot {
                        entropy: s.entropy.snapshot(),
                        mutual_information: s.mutual_information.snapshot(),
                        samples_used: s.samples_used.snapshot(),
                    },
                )
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_into_correct_buckets() {
        let h = FixedHistogram::new(&[1.0, 4.0, 16.0]);
        for v in [0.5, 1.0, 3.0, 20.0] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.counts, vec![2, 1, 0, 1]);
        assert_eq!(s.count, 4);
        assert!((s.sum - 24.5).abs() < 1e-6);
    }

    #[test]
    fn non_finite_and_negative_clamp_to_zero() {
        let h = FixedHistogram::new(&[1.0]);
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        h.record(-3.0);
        let s = h.snapshot();
        assert_eq!(s.counts, vec![3, 0]);
        assert_eq!(s.sum, 0.0);
    }

    #[test]
    fn telemetry_is_per_model_and_drops_unknown() {
        let t = UncertaintyTelemetry::new(&["a".into(), "b".into()]);
        t.record("a", 0.02, 0.003, 8);
        t.record("a", 1.2, 0.4, 32);
        t.record("nope", 9.0, 9.0, 999);
        let snap = t.snapshot();
        assert_eq!(snap.len(), 2);
        let (name, a) = &snap[0];
        assert_eq!(name, "a");
        assert_eq!(a.entropy.count, 2);
        assert_eq!(a.samples_used.count, 2);
        assert_eq!(snap[1].1.entropy.count, 0);
    }
}
