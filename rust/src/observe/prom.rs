//! Prometheus text-format (0.0.4) rendering: one scrape surface over
//! every telemetry source the router can reach.
//!
//! Conventions: every metric is prefixed `pbm_`, monotonic counters end
//! in `_total`, histograms carry explicit buckets with a terminal
//! `le="+Inf"`, and series are labeled `engine` (the engine's primary
//! dataset name) plus `model`/`worker`/`stream`/`shard` where finer
//! attribution exists.  `_count` is always emitted equal to the `+Inf`
//! bucket (both derived from the same per-bucket reads) so a racy
//! scrape still lints clean.

use crate::coordinator::Router;

use super::stats::HistSnapshot;

/// Render the full exposition for `router`'s engines.
pub fn render(router: &Router) -> String {
    let mut w = Writer::default();

    w.family("pbm_build_info", "gauge", "Crate version (value is always 1).");
    w.sample("pbm_build_info", &[("version", crate::version())], "1");

    w.family("pbm_models", "gauge", "Servable model names registered on this router.");
    w.sample("pbm_models", &[], &router.datasets().len().to_string());

    let serving = router.serving_snapshot();
    let counter =
        |w: &mut Writer, name: &str, help: &str, pick: &dyn Fn(&crate::coordinator::ServeSnapshot) -> u64| {
            w.family(name, "counter", help);
            for (engine, s) in &serving {
                w.sample(name, &[("engine", engine)], &pick(s).to_string());
            }
        };
    counter(
        &mut w,
        "pbm_requests_shed_total",
        "Requests answered with a typed error instead of being served.",
        &|s| s.requests_shed,
    );
    counter(
        &mut w,
        "pbm_deadline_expired_total",
        "Requests whose deadline passed at dequeue or mid-run.",
        &|s| s.deadline_expired,
    );
    counter(
        &mut w,
        "pbm_overload_rejects_total",
        "Requests rejected at admission (queue/work budget full).",
        &|s| s.overload_rejects,
    );
    counter(
        &mut w,
        "pbm_panics_recovered_total",
        "Batch panics isolated and recovered from.",
        &|s| s.panics_recovered,
    );
    w.family("pbm_queue_depth", "gauge", "Queue depth last observed at admission/dequeue.");
    for (engine, s) in &serving {
        w.sample("pbm_queue_depth", &[("engine", engine)], &s.queue_depth.to_string());
    }

    w.family(
        "pbm_request_latency_us",
        "histogram",
        "Per-request service latency in microseconds (log2 buckets).",
    );
    for (engine, raw) in router.serving_latency() {
        // bucket i covers [2^i, 2^(i+1)); the final clamp bucket folds
        // into +Inf rather than lying about a 2^21 us edge
        let labels = [("engine", engine.as_str())];
        let mut acc = 0u64;
        for (i, c) in raw.counts.iter().enumerate() {
            acc += c;
            if i + 1 < raw.counts.len() {
                w.bucket("pbm_request_latency_us", &labels, &fmt_f64((1u64 << (i + 1)) as f64), acc);
            }
        }
        w.bucket("pbm_request_latency_us", &labels, "+Inf", acc);
        w.sample_suffixed("pbm_request_latency_us", "_sum", &labels, &raw.sum_us.to_string());
        w.sample_suffixed("pbm_request_latency_us", "_count", &labels, &acc.to_string());
    }

    let registry = router.registry_snapshot();
    if !registry.is_empty() {
        let reg_metric = |w: &mut Writer, name: &str, kind: &str, help: &str, pick: &dyn Fn(&crate::registry::RegistrySnapshot) -> u64| {
            w.family(name, kind, help);
            for (engine, r) in &registry {
                w.sample(name, &[("engine", engine)], &pick(r).to_string());
            }
        };
        reg_metric(&mut w, "pbm_registry_budget_bytes", "gauge", "Model-cache byte budget.", &|r| r.budget_bytes);
        reg_metric(&mut w, "pbm_registry_resident_bytes", "gauge", "Bytes of realized banks currently cached.", &|r| r.resident_bytes);
        reg_metric(&mut w, "pbm_registry_hits_total", "counter", "Model switches served from cache.", &|r| r.hits);
        reg_metric(&mut w, "pbm_registry_misses_total", "counter", "Model switches requiring a rebuild.", &|r| r.misses);
        reg_metric(&mut w, "pbm_registry_switches_total", "counter", "Program switches between models.", &|r| r.switches);
        reg_metric(&mut w, "pbm_registry_evictions_total", "counter", "Models evicted under the byte budget.", &|r| r.evictions);
        w.family("pbm_model_bytes", "gauge", "Realized bank bytes per model.");
        for (engine, r) in &registry {
            for m in &r.models {
                w.sample("pbm_model_bytes", &[("engine", engine), ("model", &m.model)], &m.bytes.to_string());
            }
        }
    }

    let health = router.health_snapshot();
    if !health.is_empty() {
        let health_metric = |w: &mut Writer, name: &str, kind: &str, help: &str, pick: &dyn Fn(&crate::entropy::health::Scorecard) -> String| {
            w.family(name, kind, help);
            for (engine, cards) in &health {
                for c in cards {
                    let shard = c.shard.to_string();
                    w.sample(
                        name,
                        &[("engine", engine), ("stream", &c.stream), ("shard", &shard)],
                        &pick(c),
                    );
                }
            }
        };
        health_metric(&mut w, "pbm_entropy_degraded", "gauge", "1 while the entropy stream is degraded.", &|c| u64::from(c.degraded).to_string());
        health_metric(&mut w, "pbm_entropy_score_ewma", "gauge", "Entropy-battery pass-rate EWMA in [0,1].", &|c| fmt_f64(c.score_ewma));
        health_metric(&mut w, "pbm_entropy_min_entropy", "gauge", "MCV min-entropy (bits/bit) of the last window.", &|c| fmt_f64(c.min_entropy));
        health_metric(&mut w, "pbm_entropy_windows_total", "counter", "Entropy windows analyzed.", &|c| c.windows.to_string());
    }

    let cluster = router.cluster_snapshot();
    if !cluster.is_empty() {
        let worker_metric = |w: &mut Writer, name: &str, kind: &str, help: &str, pick: &dyn Fn(&crate::cluster::WorkerCard) -> String| {
            w.family(name, kind, help);
            for (engine, cards) in &cluster {
                for c in cards {
                    w.sample(name, &[("engine", engine), ("worker", &c.addr)], &pick(c));
                }
            }
        };
        worker_metric(&mut w, "pbm_worker_up", "gauge", "1 while the worker takes traffic (healthy/recovering).", &|c| {
            let up = matches!(
                c.state,
                crate::cluster::WorkerState::Healthy | crate::cluster::WorkerState::Recovering
            );
            u64::from(up).to_string()
        });
        worker_metric(&mut w, "pbm_worker_consecutive_fails", "gauge", "Consecutive failures against this worker.", &|c| c.consecutive_fails.to_string());
        worker_metric(&mut w, "pbm_worker_latency_ewma_us", "gauge", "EWMA of observed worker request latency (us).", &|c| fmt_f64(c.latency_ewma_us));
        worker_metric(&mut w, "pbm_worker_entropy_degraded", "gauge", "1 while the worker reports degraded entropy.", &|c| u64::from(c.entropy_degraded).to_string());
    }

    let traces = router.trace_stats();
    w.family("pbm_trace_enabled", "gauge", "1 while span recording is on for this engine.");
    for (engine, t) in &traces {
        w.sample("pbm_trace_enabled", &[("engine", engine)], &u64::from(t.enabled).to_string());
    }
    w.family("pbm_trace_spans_recorded_total", "counter", "Spans recorded (including those since overwritten).");
    for (engine, t) in &traces {
        w.sample("pbm_trace_spans_recorded_total", &[("engine", engine)], &t.recorded.to_string());
    }
    w.family("pbm_trace_spans_dropped_total", "counter", "Spans overwritten by ring wrap.");
    for (engine, t) in &traces {
        w.sample("pbm_trace_spans_dropped_total", &[("engine", engine)], &t.dropped.to_string());
    }
    w.family("pbm_trace_exemplars", "gauge", "Slow-request exemplars currently retained.");
    for (engine, t) in &traces {
        w.sample("pbm_trace_exemplars", &[("engine", engine)], &t.exemplars.to_string());
    }

    let uncertainty = router.uncertainty_snapshot();
    let unc_hist = |w: &mut Writer, name: &str, help: &str, pick: &dyn Fn(&super::UncertaintySnapshot) -> HistSnapshot| {
        w.family(name, "histogram", help);
        for (engine, models) in &uncertainty {
            for (model, u) in models {
                w.hist(name, &[("engine", engine), ("model", model)], &pick(u));
            }
        }
    };
    unc_hist(
        &mut w,
        "pbm_predictive_entropy_nats",
        "Predictive entropy of served results (nats).",
        &|u| u.entropy.clone(),
    );
    unc_hist(
        &mut w,
        "pbm_mutual_information_nats",
        "Mutual information (epistemic uncertainty) of served results (nats).",
        &|u| u.mutual_information.clone(),
    );
    unc_hist(
        &mut w,
        "pbm_samples_used",
        "Stochastic passes spent per served request.",
        &|u| u.samples_used.clone(),
    );

    w.out
}

/// Shortest lossless-enough rendering: integers print bare, everything
/// else uses Rust's shortest-roundtrip `Display`.
fn fmt_f64(v: f64) -> String {
    if v.is_finite() && v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

#[derive(Default)]
struct Writer {
    out: String,
}

impl Writer {
    fn family(&mut self, name: &str, kind: &str, help: &str) {
        self.out.push_str("# HELP ");
        self.out.push_str(name);
        self.out.push(' ');
        self.out.push_str(help);
        self.out.push('\n');
        self.out.push_str("# TYPE ");
        self.out.push_str(name);
        self.out.push(' ');
        self.out.push_str(kind);
        self.out.push('\n');
    }

    fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: &str) {
        self.sample_suffixed(name, "", labels, value);
    }

    fn sample_suffixed(&mut self, name: &str, suffix: &str, labels: &[(&str, &str)], value: &str) {
        self.out.push_str(name);
        self.out.push_str(suffix);
        if !labels.is_empty() {
            self.out.push('{');
            for (i, (k, v)) in labels.iter().enumerate() {
                if i > 0 {
                    self.out.push(',');
                }
                self.out.push_str(k);
                self.out.push_str("=\"");
                escape_into(v, &mut self.out);
                self.out.push('"');
            }
            self.out.push('}');
        }
        self.out.push(' ');
        self.out.push_str(value);
        self.out.push('\n');
    }

    fn bucket(&mut self, name: &str, labels: &[(&str, &str)], le: &str, cumulative: u64) {
        let mut with_le: Vec<(&str, &str)> = labels.to_vec();
        with_le.push(("le", le));
        self.sample_suffixed(name, "_bucket", &with_le, &cumulative.to_string());
    }

    /// Emit `_bucket`/`_sum`/`_count` for a fixed-bound histogram whose
    /// last count is the overflow bucket.
    fn hist(&mut self, name: &str, labels: &[(&str, &str)], snap: &HistSnapshot) {
        let mut acc = 0u64;
        for (i, c) in snap.counts.iter().enumerate() {
            acc += c;
            if i < snap.bounds.len() {
                self.bucket(name, labels, &fmt_f64(snap.bounds[i]), acc);
            }
        }
        self.bucket(name, labels, "+Inf", acc);
        self.sample_suffixed(name, "_sum", labels, &fmt_f64(snap.sum));
        self.sample_suffixed(name, "_count", labels, &acc.to_string());
    }
}

fn escape_into(s: &str, out: &mut String) {
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_router_renders_and_lints_clean() {
        let router = Router::new();
        let text = render(&router);
        assert!(text.contains("pbm_build_info"));
        assert!(text.contains("# TYPE pbm_request_latency_us histogram"));
        let errs = super::super::expo::lint(&text);
        assert!(errs.is_empty(), "{errs:?}");
    }

    #[test]
    fn fmt_f64_prints_integers_bare() {
        assert_eq!(fmt_f64(1.0), "1");
        assert_eq!(fmt_f64(0.001), "0.001");
        assert_eq!(fmt_f64(256.0), "256");
        assert_eq!(fmt_f64(f64::NAN), "NaN");
    }

    #[test]
    fn label_values_escape() {
        let mut w = Writer::default();
        w.family("m", "gauge", "x");
        w.sample("m", &[("k", "a\"b\\c")], "1");
        assert!(w.out.contains("m{k=\"a\\\"b\\\\c\"} 1"), "{}", w.out);
        assert!(super::super::expo::lint(&w.out).is_empty());
    }
}
