//! Decoupled entropy pipeline: background producers, block rings, and the
//! synchronous fallback.
//!
//! The paper's performance story is architectural: chaotic light produces
//! randomness *continuously at line rate*, so the compute path never waits
//! on entropy — the source is a free-running producer, the detector merely
//! consumes.  The simulator historically re-coupled the two: every
//! `sample_conv` shard synthesized its Gamma/Gaussian draws inline, on the
//! same thread as the convolution arithmetic.  This module restores the
//! split.
//!
//! An [`EntropyStream`] is a sequential stream of `f64` entropy draws with
//! two interchangeable engines:
//!
//! * **Sync** — draws happen inline at `fill` time on the caller's thread
//!   (the `prefetch = off`/`sync` fallback; also what the digital backend's
//!   historical inline path is);
//! * **Piped** — a dedicated producer thread owns the generator and
//!   continuously fills fixed-size blocks into a lock-free SPSC
//!   [`crate::exec::ring`]; `fill` copies out of pre-drawn blocks.
//!
//! Because the generator state (PRNG + Gaussian spare) lives with exactly
//! one owner and blocks traverse the ring in FIFO order, the sequence of
//! draws a consumer observes is **bitwise identical** in both engines — the
//! testable equivalence that makes prefetching safe to enable in
//! production.  Spent blocks are recycled to the producer over a second
//! ring, so the steady state allocates nothing.
//!
//! Generators are small: [`NormalGen`] emits standard normals (the digital
//! backend's weight planes, Box–Muller moved off the hot thread) and
//! [`WeightGen`] emits realized photonic tap weights
//! `gain·(I⁺ − I⁻)` at a programmed `(P⁺, P⁻, M)` operating point (the
//! prefetched weight-plane banks; invalidated by reprogramming — see
//! `backend::photonic`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use super::chaotic::fill_realized_weights;
use super::gaussian::Gaussian;
use super::health::{BlockTap, Monitor};
use super::xoshiro::{splitmix64, Xoshiro256pp};
use crate::exec::ring::{self, Consumer, Producer, PushError};
use crate::exec::CancelToken;

/// How `sample_conv` obtains its entropy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PrefetchMode {
    /// Inline draws in the historical stream organization — bit-identical
    /// to the pre-pipeline engine.  The default.
    #[default]
    Off,
    /// Pipeline stream organization, drawn synchronously at consumption
    /// time (the fallback the prefetch-on path is verified against).
    Sync,
    /// Pipeline stream organization with background producer threads and
    /// SPSC block rings — entropy production off the compute threads.
    On,
}

impl PrefetchMode {
    pub fn name(&self) -> &'static str {
        match self {
            PrefetchMode::Off => "off",
            PrefetchMode::Sync => "sync",
            PrefetchMode::On => "on",
        }
    }

    /// Parse a CLI/config token (`off|sync|on`).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "off" | "inline" => Ok(PrefetchMode::Off),
            "sync" => Ok(PrefetchMode::Sync),
            "on" | "async" | "pipelined" => Ok(PrefetchMode::On),
            other => Err(anyhow!("entropy prefetch must be off|sync|on, got {other}")),
        }
    }

    /// True when the pipeline's banked stream organization is in effect
    /// (either engine); false for the historical inline path.
    pub fn banked(&self) -> bool {
        !matches!(self, PrefetchMode::Off)
    }
}

impl std::fmt::Display for PrefetchMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Pipeline tuning knobs, carried from config/CLI into the backends.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PipelineOptions {
    pub mode: PrefetchMode,
    /// Draws per entropy block (the ring transfer granularity).
    pub block: usize,
    /// Blocks per SPSC ring (how far a producer may run ahead).
    pub depth: usize,
}

impl Default for PipelineOptions {
    fn default() -> Self {
        Self {
            mode: PrefetchMode::Off,
            block: 4096,
            depth: 4,
        }
    }
}

impl PipelineOptions {
    /// Clamp degenerate knob values (a zero-length block would spin forever).
    pub fn sanitized(mut self) -> Self {
        self.block = self.block.clamp(64, 1 << 22);
        self.depth = self.depth.clamp(2, 1024);
        self
    }
}

/// A deterministic sequential generator of `f64` entropy draws.  Exactly one
/// owner (the sync stream or a producer thread) ever advances it.
pub trait BlockGen: Send + 'static {
    fn fill(&mut self, out: &mut [f64]);
}

/// Standard normals from a forked xoshiro256++ stream — the digital
/// backend's per-shard weight-plane generator.
pub struct NormalGen {
    pub rng: Xoshiro256pp,
    pub gauss: Gaussian,
}

impl NormalGen {
    pub fn new(rng: Xoshiro256pp) -> Self {
        Self {
            rng,
            gauss: Gaussian::new(),
        }
    }
}

impl BlockGen for NormalGen {
    fn fill(&mut self, out: &mut [f64]) {
        self.gauss.fill_f64(&mut self.rng, out);
    }
}

/// Realized photonic tap weights at one programmed operating point — the
/// weight-plane bank generator.  One stream per (shard, kernel, tap),
/// reseeded per program generation, so prefetched planes can never survive
/// a reprogram.
pub struct WeightGen {
    pub rng: Xoshiro256pp,
    pub gauss: Gaussian,
    pub p_plus: f64,
    pub p_minus: f64,
    pub dof: f64,
    pub gain_eff: f64,
}

impl BlockGen for WeightGen {
    fn fill(&mut self, out: &mut [f64]) {
        fill_realized_weights(
            &mut self.rng,
            &mut self.gauss,
            self.p_plus,
            self.p_minus,
            self.dof,
            self.gain_eff,
            out,
        );
    }
}

/// Derive the deterministic seed of one pipeline stream.  Both engines use
/// the same derivation, which is half of the prefetch-on/off equivalence;
/// mixing in the program generation is the bank-invalidation half.
pub fn stream_seed(base: u64, generation: u64, shard: usize, kernel: usize, tap: usize) -> u64 {
    let mut st = base ^ 0x9E6B_1A57_E17B_A2C3;
    let _ = splitmix64(&mut st);
    st ^= generation.wrapping_mul(0xA076_1D64_78BD_642F);
    let _ = splitmix64(&mut st);
    st ^= (shard as u64).wrapping_mul(0xE703_7ED1_A0B4_28DB);
    let _ = splitmix64(&mut st);
    st ^= ((kernel as u64) << 32) ^ tap as u64;
    splitmix64(&mut st)
}

/// One entropy block in flight.
type Block = Vec<f64>;

/// Handle owning a producer thread: cancels and joins on drop, so dropping
/// a backend (or invalidating a bank) can never leak a spinning thread.
/// Shared (`Arc`) by every stream the thread produces for; the last stream
/// dropped performs the join.
struct ProducerHandle {
    cancel: CancelToken,
    thread: Option<JoinHandle<()>>,
}

impl Drop for ProducerHandle {
    fn drop(&mut self) {
        self.cancel.cancel();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Consumer half of a piped stream: pops pre-drawn blocks, recycles spent
/// ones, and tracks a read cursor inside the current block.  (Public only
/// because it names an [`EntropyStream`] variant; not constructible
/// directly.)
pub struct Piped {
    rx: Consumer<Block>,
    recycle: Producer<Block>,
    cur: Block,
    pos: usize,
    // declared last: the ring handles above drop (and disconnect) first,
    // unblocking the producer before any join in ProducerHandle::drop
    _producer: Arc<ProducerHandle>,
}

impl Piped {
    fn fill(&mut self, out: &mut [f64]) {
        let mut done = 0usize;
        while done < out.len() {
            if self.pos == self.cur.len() {
                let spent = std::mem::take(&mut self.cur);
                if spent.capacity() > 0 {
                    // hand the allocation back; a full/closed recycle ring
                    // just drops it (allocation-free steady state, not a
                    // correctness dependency)
                    let _ = self.recycle.try_push(spent);
                }
                self.cur = self
                    .rx
                    .pop_blocking()
                    .expect("entropy producer terminated mid-stream");
                self.pos = 0;
            }
            let n = (out.len() - done).min(self.cur.len() - self.pos);
            out[done..done + n].copy_from_slice(&self.cur[self.pos..self.pos + n]);
            done += n;
            self.pos += n;
        }
    }
}

/// Producer-side state of one stream inside a producer group.
struct StreamSlot<G> {
    gen: G,
    tx: Producer<Block>,
    recycle: Consumer<Block>,
    /// A drawn-but-unpushed block (its draws are already committed to the
    /// stream sequence; it is pushed as soon as the ring has room).
    pending: Option<Block>,
    /// Consumer disconnected — stop producing for this stream.
    done: bool,
    /// Optional health-monitor tap: observes (copies) produced blocks at a
    /// duty cycle, on the producer thread — off the consuming hot path.
    tap: Option<BlockTap>,
}

/// The free-running group producer: round-robin over the group's streams,
/// filling whichever ring has room, until cancelled or every consumer has
/// disconnected.  One thread serves many rings, so a photonic shard's full
/// (kernel × tap) bank costs one producer thread, not dozens.
fn group_producer_loop<G: BlockGen>(
    mut slots: Vec<StreamSlot<G>>,
    block_len: usize,
    cancel: CancelToken,
    produced: Arc<AtomicU64>,
) {
    // escalate the idle sleep (50us -> 5ms) while every ring stays full, so
    // a saturated pipeline on an idle server costs ~no CPU; any progress
    // resets to the short sleep for low refill latency under load
    let mut idle_us = 50u64;
    loop {
        if cancel.is_cancelled() {
            return;
        }
        let mut progressed = false;
        let mut all_done = true;
        for slot in &mut slots {
            if slot.done {
                continue;
            }
            all_done = false;
            if slot.pending.is_none() && slot.tx.len() < slot.tx.capacity() {
                let mut block = slot.recycle.try_pop().unwrap_or_default();
                block.resize(block_len, 0.0);
                slot.gen.fill(&mut block);
                produced.fetch_add(block_len as u64, Ordering::Relaxed);
                if let Some(tap) = slot.tap.as_mut() {
                    // copy-only observation: the block's draws are already
                    // committed to the stream sequence above
                    tap.observe(&block);
                }
                slot.pending = Some(block);
            }
            if let Some(b) = slot.pending.take() {
                match slot.tx.try_push(b) {
                    Ok(()) => progressed = true,
                    Err(PushError::Full(back)) => slot.pending = Some(back),
                    Err(PushError::Disconnected(_)) => slot.done = true,
                }
            }
        }
        if all_done {
            return;
        }
        if progressed {
            idle_us = 50;
        } else {
            std::thread::sleep(std::time::Duration::from_micros(idle_us));
            idle_us = (idle_us * 2).min(5_000);
        }
    }
}

/// A deterministic entropy stream with interchangeable engines (see the
/// module docs).  `fill` hands out the next `out.len()` draws of the
/// stream; the draw sequence is identical whichever engine runs it.
pub enum EntropyStream<G: BlockGen> {
    Sync(G, Option<BlockTap>),
    Piped(Piped),
}

impl<G: BlockGen> EntropyStream<G> {
    /// Build one stream for `opts.mode`: `On` spawns a dedicated producer
    /// thread, anything else keeps the generator inline.  `produced`
    /// accumulates producer-side draw counts (pipeline telemetry; shared
    /// across the streams of one backend).
    pub fn new(gen: G, opts: &PipelineOptions, label: &str, produced: Arc<AtomicU64>) -> Self {
        Self::new_monitored(gen, opts, label, produced, None)
    }

    /// [`EntropyStream::new`] with an optional health-monitor tap
    /// `(monitor, shard)`: produced blocks are observed (by copy) under the
    /// stream's label — on the producer thread for `On`, at `fill` time for
    /// `Off`/`Sync`.  The tap never advances generator state, so monitored
    /// and unmonitored streams deliver bitwise-identical draws.
    pub fn new_monitored(
        gen: G,
        opts: &PipelineOptions,
        label: &str,
        produced: Arc<AtomicU64>,
        monitor: Option<(Arc<Monitor>, usize)>,
    ) -> Self {
        spawn_group_monitored(vec![gen], opts, label, produced, monitor)
            .pop()
            .expect("one generator in, one stream out")
    }

    /// The next `out.len()` draws of the stream, in draw order.
    pub fn fill(&mut self, out: &mut [f64]) {
        match self {
            EntropyStream::Sync(gen, tap) => {
                gen.fill(out);
                if let Some(t) = tap.as_mut() {
                    t.observe(out);
                }
            }
            EntropyStream::Piped(p) => p.fill(out),
        }
    }

    pub fn is_piped(&self) -> bool {
        matches!(self, EntropyStream::Piped(_))
    }
}

/// Build a group of streams sharing one producer thread (`PrefetchMode::On`)
/// or all-inline (`Off`/`Sync`).  Stream `i` of the result is backed by
/// `gens[i]`; each has its own SPSC ring pair, so consumption on one stream
/// never reorders another.
pub fn spawn_group<G: BlockGen>(
    gens: Vec<G>,
    opts: &PipelineOptions,
    label: &str,
    produced: Arc<AtomicU64>,
) -> Vec<EntropyStream<G>> {
    spawn_group_monitored(gens, opts, label, produced, None)
}

/// [`spawn_group`] with an optional health-monitor tap `(monitor, shard)`.
/// Every stream of the group reports under the group's label, so a photonic
/// shard's whole (kernel × tap) bank rolls up into one `(shard, label)`
/// scorecard — the granularity `/info` exposes.
pub fn spawn_group_monitored<G: BlockGen>(
    gens: Vec<G>,
    opts: &PipelineOptions,
    label: &str,
    produced: Arc<AtomicU64>,
    monitor: Option<(Arc<Monitor>, usize)>,
) -> Vec<EntropyStream<G>> {
    let mk_tap = || {
        monitor
            .as_ref()
            .map(|(m, shard)| BlockTap::new(m.clone(), *shard, label))
    };
    if opts.mode != PrefetchMode::On {
        return gens
            .into_iter()
            .map(|g| EntropyStream::Sync(g, mk_tap()))
            .collect();
    }
    let opts = opts.sanitized();
    let cancel = CancelToken::new();
    let mut slots = Vec::with_capacity(gens.len());
    let mut consumers = Vec::with_capacity(gens.len());
    for gen in gens {
        let (tx, rx) = ring::ring::<Block>(opts.depth);
        let (recycle_tx, recycle_rx) = ring::ring::<Block>(opts.depth);
        slots.push(StreamSlot {
            gen,
            tx,
            recycle: recycle_rx,
            pending: None,
            done: false,
            tap: mk_tap(),
        });
        consumers.push((rx, recycle_tx));
    }
    let cancel2 = cancel.clone();
    let block = opts.block;
    let thread = std::thread::Builder::new()
        .name(format!("pbm-entropy-{label}"))
        .spawn(move || group_producer_loop(slots, block, cancel2, produced))
        .expect("spawn entropy producer");
    let handle = Arc::new(ProducerHandle {
        cancel,
        thread: Some(thread),
    });
    consumers
        .into_iter()
        .map(|(rx, recycle)| {
            EntropyStream::Piped(Piped {
                rx,
                recycle,
                cur: Vec::new(),
                pos: 0,
                _producer: handle.clone(),
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts(mode: PrefetchMode, block: usize, depth: usize) -> PipelineOptions {
        PipelineOptions { mode, block, depth }
    }

    #[test]
    fn prefetch_mode_parse_roundtrip() {
        for m in [PrefetchMode::Off, PrefetchMode::Sync, PrefetchMode::On] {
            assert_eq!(PrefetchMode::parse(m.name()).unwrap(), m);
        }
        assert!(PrefetchMode::parse("maybe").is_err());
        assert!(PrefetchMode::Off == PrefetchMode::default());
        assert!(!PrefetchMode::Off.banked() && PrefetchMode::Sync.banked());
    }

    #[test]
    fn sanitize_clamps_degenerate_knobs() {
        let o = opts(PrefetchMode::On, 0, 0).sanitized();
        assert!(o.block >= 64 && o.depth >= 2);
    }

    #[test]
    fn piped_normals_match_sync_bitwise_across_odd_fills() {
        let produced = Arc::new(AtomicU64::new(0));
        let mut piped = EntropyStream::new(
            NormalGen::new(Xoshiro256pp::new(42)),
            &opts(PrefetchMode::On, 128, 3),
            "test-normals",
            produced.clone(),
        );
        assert!(piped.is_piped());
        let mut sync = EntropyStream::new(
            NormalGen::new(Xoshiro256pp::new(42)),
            &opts(PrefetchMode::Sync, 128, 3),
            "unused",
            Arc::new(AtomicU64::new(0)),
        );
        // fill sizes straddling block boundaries in every way
        for len in [1usize, 7, 127, 128, 129, 300, 1000] {
            let mut a = vec![0.0f64; len];
            let mut b = vec![0.0f64; len];
            piped.fill(&mut a);
            sync.fill(&mut b);
            assert_eq!(a, b, "fill of {len}");
        }
        assert!(produced.load(Ordering::Relaxed) >= 1692, "producer ran ahead");
    }

    #[test]
    fn piped_weight_stream_matches_sync_bitwise() {
        let mk = |mode| {
            EntropyStream::new(
                WeightGen {
                    rng: Xoshiro256pp::new(stream_seed(7, 1, 0, 2, 4)),
                    gauss: Gaussian::new(),
                    p_plus: 1.1,
                    p_minus: 0.3,
                    dof: 4.5,
                    gain_eff: 0.9,
                },
                &opts(mode, 64, 2),
                "test-weights",
                Arc::new(AtomicU64::new(0)),
            )
        };
        let mut a_stream = mk(PrefetchMode::On);
        let mut b_stream = mk(PrefetchMode::Sync);
        let mut a = vec![0.0f64; 777];
        let mut b = vec![0.0f64; 777];
        a_stream.fill(&mut a);
        b_stream.fill(&mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn group_streams_are_independent_and_match_sync() {
        // one producer thread, three rings: consuming stream 2 heavily must
        // not perturb streams 0/1, and each must match its sync twin
        let gens = |mode: PrefetchMode| {
            spawn_group(
                (0..3u64)
                    .map(|i| NormalGen::new(Xoshiro256pp::new(100 + i)))
                    .collect(),
                &opts(mode, 64, 2),
                "group-test",
                Arc::new(AtomicU64::new(0)),
            )
        };
        let mut piped = gens(PrefetchMode::On);
        let mut sync = gens(PrefetchMode::Sync);
        let mut big = vec![0.0f64; 1000];
        let mut big2 = vec![0.0f64; 1000];
        piped[2].fill(&mut big);
        sync[2].fill(&mut big2);
        assert_eq!(big, big2, "hot stream");
        for i in [0usize, 1] {
            let mut a = vec![0.0f64; 97];
            let mut b = vec![0.0f64; 97];
            piped[i].fill(&mut a);
            sync[i].fill(&mut b);
            assert_eq!(a, b, "cold stream {i}");
        }
    }

    #[test]
    fn dropping_a_piped_stream_joins_its_producer() {
        // tiny ring: the producer is certainly parked on a full ring when
        // the drop lands; this must not deadlock
        for _ in 0..8 {
            let s: EntropyStream<NormalGen> = EntropyStream::new(
                NormalGen::new(Xoshiro256pp::new(1)),
                &opts(PrefetchMode::On, 64, 2),
                "drop-test",
                Arc::new(AtomicU64::new(0)),
            );
            drop(s);
        }
    }

    #[test]
    fn monitored_streams_match_unmonitored_bitwise_in_both_engines() {
        use super::super::health::{HealthConfig, Monitor};
        let hcfg = HealthConfig {
            enabled: true,
            window_bits: 256,
            duty: 1.0,
            ..HealthConfig::default()
        };
        for mode in [PrefetchMode::Sync, PrefetchMode::On] {
            let monitor = Arc::new(Monitor::new(hcfg));
            let mut tapped = EntropyStream::new_monitored(
                NormalGen::new(Xoshiro256pp::new(77)),
                &opts(mode, 128, 3),
                "mon-test",
                Arc::new(AtomicU64::new(0)),
                Some((monitor.clone(), 0)),
            );
            let mut plain = EntropyStream::new(
                NormalGen::new(Xoshiro256pp::new(77)),
                &opts(mode, 128, 3),
                "plain",
                Arc::new(AtomicU64::new(0)),
            );
            let mut a = vec![0.0f64; 1024];
            let mut b = vec![0.0f64; 1024];
            tapped.fill(&mut a);
            plain.fill(&mut b);
            assert_eq!(a, b, "tap changed draws in {mode}");
            // the tap did see blocks (On observes on the producer thread,
            // which may still be running — drop first to join it)
            drop(tapped);
            assert!(monitor.observed_blocks() >= 1, "{mode}");
            assert!(!monitor.any_degraded(), "healthy normals flagged ({mode})");
        }
    }

    #[test]
    fn stream_seed_separates_axes() {
        let base = stream_seed(9, 0, 0, 0, 0);
        assert_ne!(base, stream_seed(9, 1, 0, 0, 0), "generation");
        assert_ne!(base, stream_seed(9, 0, 1, 0, 0), "shard");
        assert_ne!(base, stream_seed(9, 0, 0, 1, 0), "kernel");
        assert_ne!(base, stream_seed(9, 0, 0, 0, 1), "tap");
        assert_eq!(base, stream_seed(9, 0, 0, 0, 0), "deterministic");
    }
}
